"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call is wall time of
the measured JAX call where applicable, else 0; ``derived`` carries the
figure's headline quantity).

  fig4_exec_time        t_fix staircase vs FFT length (measured, CPU)
  fig6_time_vs_freq     t_f/t_d regimes a/b/c (DVFS model, V100+Nano)
  fig7_energy_u_shape   E(f) per batch, N=16k (model)
  fig8_power_vs_freq    average power vs clock (model)
  fig9_optimal_freq     optimal f as % of boost (model)
  table3_mean_optimal   mean optimal clock per device x precision
  fig10_gflops_per_watt efficiency at the optimal clock
  fig11_exec_increase   slowdown at the optimal clock
  fig13_16_ief          efficiency increase vs boost & base clocks
  table4_pipeline       pulsar pipeline w/ per-stage clock locking
  kernels               Pallas kernels (interpret) vs jnp oracle wall time
  fft                   mixed-radix engine: stages, R2C vs C2C wall time,
                        J/transform model -> persists BENCH_fft.json
  fft2                  N-D plan graph: HBM passes vs the per-axis chain,
                        fused four-step parity -> persists BENCH_fft2.json
  fdas                  acceleration search on the overlap-save conv
                        engine: fused-epilogue pass counts, traffic
                        ratio, parity, pulsar recovery
                        -> persists BENCH_fdas.json
  tune                  autotuner smoke: cost-model-pruned search on two
                        lengths, speedup vs heuristic, zero-measurement
                        cache replay -> persists BENCH_autotune.json
  pipeline              end-to-end pulsar search (dedispersion -> FDAS ->
                        fused harmonic sum -> sift): injected-pulsar
                        recovery, no-signal control, per-stage DVFS
                        clocks + J/stage, real-time margin
                        -> persists BENCH_pipeline.json
  roofline              the dry-run roofline table (artifacts)
  dvfs_cells            the paper's technique applied to every dry-run cell
  serving               the energy-aware FFT service on a synthetic stream
  chaos                 deterministic chaos/load harness: a mixed
                        fft/fft2/fdas/pulsar stream under an injected
                        fault schedule (device kills, clock-lock
                        failures, stalls) with SLO admission control —
                        gates the every-request-gets-a-receipt invariant,
                        availability and bit-reproducibility
                        -> persists BENCH_chaos.json
  power                 closed-loop power governance: governed 8-device
                        site convergence under a power cap, watchdog +
                        static-sweep fallback under injected sensor
                        faults, the emergency shed rung, telemetered
                        serving receipts -> persists BENCH_power.json
  obs                   unified observability plane: tracing overhead
                        gate (< 5% on a warm drain), ledger-audited
                        fft2/fdas pass counts, bit-reproducible span +
                        ledger digests across two runs, drift detection
                        under a miscalibrated sensor model
                        -> persists BENCH_obs.json

Usage: ``python benchmarks/run.py [target ...]`` — no arguments runs all.
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _time_fn(fn, *args, **kwargs):
    """The shared warm-up/repeat timing helper (``repro.tune.timing``).

    One implementation serves the fft/fft2/fdas targets AND the autotuner,
    so benchmark and tuner wall-clock figures are methodologically
    identical (same warm-up discipline, same reduction).
    """
    from repro.tune.timing import time_fn
    return time_fn(fn, *args, **kwargs)


def _timeit(fn, *args, n=5, warmup=2, reduce=None):
    """Wall time per call [us]: mean of n by default, or e.g. ``min`` —
    best-of-n is robust to scheduler noise on shared CPUs."""
    mean = (lambda s: sum(s) / len(s))
    return _time_fn(fn, *args, repeats=n, warmup=warmup,
                    reduce=mean if reduce is None else reduce) * 1e6


#: Common envelope version for every persisted BENCH_*.json.  Bump when
#: any emitter's layout changes shape (v2 added the shared
#: schema_version/device stamp and the power target; v3 the journal
#: incarnation id).
BENCH_SCHEMA_VERSION = 3


def _persist(name, out, *, device, incarnation=None):
    """Write ``BENCH_<name>.json`` with the common metadata stamp.

    Every persisted benchmark carries the same envelope — a
    ``schema_version``, the ``device`` whose DeviceSpec the modelled
    numbers are for, and the ``incarnation`` that produced the artifact
    (the journal incarnation for journal-attached runs, the process
    incarnation otherwise) — so downstream tooling parses all of them
    the same way and can tell two generations of the same artifact
    apart.  Keys already present in ``out`` win over the stamp.
    """
    from repro.runtime.journal import process_incarnation
    out = {"schema_version": BENCH_SCHEMA_VERSION, "device": device,
           "backend": jax.default_backend(),
           "incarnation": (incarnation if incarnation is not None
                           else process_incarnation()), **out}
    path = os.path.join(os.path.dirname(__file__), "..",
                        f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return os.path.abspath(path)


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------

def fig4_exec_time():
    """t_fix staircase: fixed data volume, varying FFT length (measured)."""
    from repro.fft.plan import plan_for_length
    m_bytes = 2**22                                     # 4 MiB on CPU
    for logn in (5, 8, 11, 13, 14, 16):
        n = 2**logn
        batch = max(m_bytes // (n * 8), 1)
        x = (jax.random.normal(jax.random.PRNGKey(0), (batch, n))
             + 1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n))
             ).astype(jnp.complex64)
        plan = plan_for_length(n)
        us = _timeit(jax.jit(plan.fn), x)
        _row(f"fig4_tfix_n{n}", us,
             f"passes={plan.passes};alg={plan.algorithm}")


def fig6_time_vs_freq():
    from repro.core import JETSON_NANO, TESLA_V100, FFTCase, fft_workload
    for dev in (TESLA_V100, JETSON_NANO):
        for n in (2**10, 2**13, 2**14):
            prof = fft_workload(FFTCase(n=n), dev)
            f = dev.frequencies()
            t = prof.time(f, dev)
            _row(f"fig6_{dev.name}_n{n}", 0.0,
                 f"regime={prof.regime(dev)};max_slowdown="
                 f"{t.max()/t[0]:.2f}")


def fig7_energy_u_shape():
    from repro.core import JETSON_NANO, TESLA_V100, FFTCase, fft_workload, \
        sweep
    for dev in (TESLA_V100, JETSON_NANO):
        res = sweep(fft_workload(FFTCase(n=2**14), dev), dev)
        _row(f"fig7_{dev.name}_n16384", 0.0,
             f"opt_mhz={res.optimal.f:.0f};E_opt/E_boost="
             f"{res.optimal.energy/res.boost.energy:.3f}")


def fig8_power_vs_freq():
    from repro.core import (JETSON_NANO, TESLA_V100, FFTCase, PowerModel,
                            evaluate, fft_workload)
    for dev in (TESLA_V100, JETSON_NANO):
        prof = fft_workload(FFTCase(n=2**14), dev)
        pm = PowerModel(dev)
        pts = evaluate(prof, dev, pm, dev.frequencies())
        _row(f"fig8_{dev.name}", 0.0,
             f"P_boost={pts[0].power:.1f}W;"
             f"P_min={min(p.power for p in pts):.1f}W")


def fig9_optimal_freq():
    from repro.core.calibration import calibrate
    from repro.core.hardware import JETSON_NANO, TESLA_V100
    for dev in (TESLA_V100, JETSON_NANO):
        s = calibrate(dev, "fp32")
        fracs = [x.optimal_frequency_frac for x in s.sweeps]
        _row(f"fig9_{dev.name}_fp32", 0.0,
             f"opt_frac_min={min(fracs):.2f};max={max(fracs):.2f}")


def table3_mean_optimal():
    from repro.core.calibration import calibrate, supported_precisions
    from repro.core.hardware import JETSON_NANO, TESLA_V100
    for dev in (TESLA_V100, JETSON_NANO):
        for prec in supported_precisions(dev):
            s = calibrate(dev, prec)
            _row(f"table3_{dev.name}_{prec}", 0.0,
                 f"mean_opt_mhz={s.mean_opt.f_mean:.1f}")


def fig10_gflops_per_watt():
    from repro.core.calibration import calibrate
    from repro.core.hardware import JETSON_NANO, TESLA_V100
    for dev in (TESLA_V100, JETSON_NANO):
        s = calibrate(dev, "fp32")
        effs = [x.optimal.gflops_per_watt for x in s.sweeps]
        _row(f"fig10_{dev.name}_fp32", 0.0,
             f"gflops_per_w_median={np.median(effs):.1f}")


def fig11_exec_increase():
    from repro.core.calibration import calibrate
    from repro.core.hardware import JETSON_NANO, TESLA_V100
    for dev in (TESLA_V100, JETSON_NANO):
        s = calibrate(dev, "fp32")
        _row(f"fig11_{dev.name}_fp32", 0.0,
             f"median_slowdown_pct={100*s.median_slowdown:.2f}")


def fig13_16_ief():
    from repro.core.calibration import calibrate
    from repro.core.hardware import JETSON_NANO, TESLA_V100
    for dev in (TESLA_V100, JETSON_NANO):
        s = calibrate(dev, "fp32")
        base = s.mean_i_ef_base
        _row(f"fig13_{dev.name}_ief_boost", 0.0,
             f"I_ef={s.mean_i_ef_boost:.3f}")
        if base is not None:
            _row(f"fig14_{dev.name}_ief_base", 0.0, f"I_ef={base:.3f}")
        _row(f"fig15_{dev.name}_ief_meanopt_boost", 0.0,
             f"I_ef={s.mean_opt.i_ef_mean:.3f};loss_pp="
             f"{s.mean_opt.loss_pp:.1f}")


def table4_pipeline():
    """Pulsar pipeline with the FFT stage clock-locked (Sec. 5.3)."""
    from repro.core.hardware import TESLA_V100
    from repro.core.scheduler import DVFSScheduler, predicted_pipeline_i_ef
    from repro.core.dvfs import sweep
    from repro.fft.pipeline import (PipelineShape, fft_time_share,
                                    stage_profiles)
    dev = TESLA_V100
    sched = DVFSScheduler(dev)
    for harmonics in (2, 4, 8, 16, 32):
        shape = PipelineShape(batch=32, n=2**20, n_harmonics=harmonics)
        profs = stage_profiles(shape, dev)
        share = fft_time_share(shape, dev)
        fft_res = sweep(profs[0], dev)
        stages = sched.plan(profs,
                            locked={profs[0].name: fft_res.optimal.f})
        rep = sched.evaluate_pipeline(stages)
        pred = predicted_pipeline_i_ef(share, fft_res.i_ef_boost)
        _row(f"table4_h{harmonics}", 0.0,
             f"fft_share={100*share:.1f}%;I_ef={rep.i_ef:.3f};"
             f"share_arith_pred={pred:.3f};slowdown={100*rep.slowdown:.2f}%")


def kernels():
    from repro.kernels.fft.ops import fft_kernel_c2c
    from repro.kernels.harmonic_sum.ops import harmonic_sum_kernel
    from repro.kernels.spectrum.ops import power_spectrum_stats_kernel
    x = (jax.random.normal(jax.random.PRNGKey(0), (16, 2048))
         + 1j * jax.random.normal(jax.random.PRNGKey(1), (16, 2048))
         ).astype(jnp.complex64)
    us = _timeit(lambda v: fft_kernel_c2c(v, interpret=True), x, n=3)
    ref = _timeit(jax.jit(jnp.fft.fft), x, n=3)
    _row("kernel_fft_2048x16_interp", us, f"jnp_ref_us={ref:.1f}")
    p = jnp.abs(x) ** 2
    us = _timeit(lambda v: harmonic_sum_kernel(v, 32, interpret=True), p,
                 n=3)
    _row("kernel_harmonic_sum_32", us, "levels=6")
    us = _timeit(lambda v: power_spectrum_stats_kernel(v, interpret=True),
                 x, n=3)
    _row("kernel_spectrum_stats", us, "fused=power+mean+var")


def roofline():
    """The dry-run roofline table (reads artifacts/dryrun/*.json)."""
    from repro.analysis.roofline import roofline_from_artifact
    paths = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not paths:
        _row("roofline", 0.0, "no-artifacts-run-dryrun-first")
        return
    from repro.configs import ARCHS
    for p in paths:
        if os.path.basename(p).split("__")[0] not in ARCHS:
            continue                      # fft-pencil handled separately
        t = roofline_from_artifact(p)
        r = t.row()
        _row(f"roofline_{t.arch}_{t.shape}_{t.mesh}", 0.0,
             f"bound={r['bound']};compute_ms={r['compute_ms']};"
             f"memory_ms={r['memory_ms']};coll_ms={r['collective_ms']};"
             f"useful={r['useful_ratio']};mfu={r['mfu_roofline']}")


def dvfs_cells():
    """The paper's technique applied to every lowered cell: optimal clock,
    predicted energy saving and slowdown — the headline integration."""
    from repro.analysis.roofline import roofline_from_artifact
    from repro.core.dvfs import sweep
    from repro.core.hardware import TPU_V5E
    from repro.core.workloads import roofline_workload
    paths = sorted(glob.glob(os.path.join(ART, "*__16x16.json")))
    from repro.configs import ARCHS
    for p in paths:
        if os.path.basename(p).split("__")[0] not in ARCHS:
            continue
        t = roofline_from_artifact(p)
        prof = roofline_workload(
            f"{t.arch}-{t.shape}", TPU_V5E, hlo_flops=t.hlo_flops,
            hbm_bytes=t.hbm_bytes, collective_bytes=t.collective_bytes,
            useful_flops=t.model_flops / t.chips, issue_efficiency=0.75)
        res = sweep(prof, TPU_V5E, time_budget=0.10)    # real-time margin
        _row(f"dvfs_{t.arch}_{t.shape}", 0.0,
             f"opt_mhz={res.optimal.f:.0f};power_cut="
             f"{100*res.power_reduction:.0f}%;slowdown="
             f"{100*res.slowdown:.1f}%;I_ef={res.i_ef_boost:.2f}")


def conclusions_cost_co2():
    """Paper Conclusions: recurrent cost + CO2 saving over years of
    operation.  Scenario: one 256-chip v5e pod serving decode traffic
    24/7 at the DVFS plan vs boost clocks (0.25 $/kWh, 0.4 kgCO2/kWh)."""
    from repro.analysis.roofline import roofline_from_artifact
    from repro.core.dvfs import sweep
    from repro.core.hardware import TPU_V5E
    from repro.core.realtime import CostModel
    from repro.core.workloads import roofline_workload
    path = os.path.join(ART, "codeqwen1.5-7b__decode_32k__16x16.json")
    if not os.path.exists(path):
        _row("cost_co2", 0.0, "no-artifacts")
        return
    t = roofline_from_artifact(path)
    prof = roofline_workload("decode", TPU_V5E, hlo_flops=t.hlo_flops,
                             hbm_bytes=t.hbm_bytes,
                             collective_bytes=t.collective_bytes,
                             issue_efficiency=0.75)
    res = sweep(prof, TPU_V5E, time_budget=0.10)
    cm = CostModel(device_cost=0.0, energy_cost=0.25, years=5.0)
    chips = 256
    kwh_saved = ((res.boost.power - res.optimal.power) / 1000.0
                 * 24 * 365 * 5 * chips)
    _row("conclusions_cost_co2", 0.0,
         f"pod_power_boost={res.boost.power*chips/1000:.1f}kW;"
         f"pod_power_opt={res.optimal.power*chips/1000:.1f}kW;"
         f"5yr_saving_usd={kwh_saved*0.25:,.0f};"
         f"5yr_co2_tonnes={kwh_saved*0.4/1000:,.0f}")


def fft_pencil_roofline():
    """The paper's own workload on the production mesh (fft_dryrun)."""
    for mesh in ("16x16", "2x16x16"):
        p = os.path.join(ART, f"fft-pencil__c2c_4096x8192_b64__{mesh}.json")
        if not os.path.exists(p):
            continue
        a = json.load(open(p))
        _row(f"fft_pencil_{mesh}", 0.0,
             f"coll_dev={a['collective_bytes_per_device']:.3e};"
             f"flops_dev={a['flops_per_device']:.3e};"
             f"fits={a['memory']['fits_16gb']}")


def fft():
    """Mixed-radix FFT engine microbench — persists BENCH_fft.json.

    Per length 2^10..2^22: plan route, HBM passes, butterfly stage count
    (radix-2 vs mixed-radix), modelled J/transform at the optimal clock
    (C2C vs R2C), and measured wall time (C2C vs R2C) through the routed
    plans (Pallas kernel in interpret mode off-TPU).  Long lengths are
    wall-timed only up to REPRO_FFT_BENCH_MAX_LOG2_WALL (default 13) —
    interpret mode is an emulator, not a clock; the analytic rows still
    cover the full range.
    """
    from repro.core.dvfs import energy_per_transform, sweep
    from repro.core.hardware import TESLA_V100
    from repro.core.workloads import FFTCase, fft_workload
    from repro.fft.plan import _four_step_split, plan_for_length
    from repro.fft.radix import stage_count

    wall_max = int(os.environ.get("REPRO_FFT_BENCH_MAX_LOG2_WALL", "13"))
    dev = TESLA_V100
    rows = []
    for logn in range(10, 23):
        n = 2**logn
        plan_c = plan_for_length(n)
        plan_r = plan_for_length(n, "r2c")
        # Like-for-like: sum stages over the plan's pow2 passes for both
        # engines (a radix-2 four-step would run log2(n1)+log2(n2) stages).
        if plan_c.algorithm == "four-step":
            n1, n2 = _four_step_split(n)
            stages_r2 = stage_count(n1, (2,)) + stage_count(n2, (2,))
        else:
            stages_r2 = stage_count(n, (2,))
        row = {
            "n": n,
            "algorithm": plan_c.algorithm,
            "passes_c2c": plan_c.passes,
            "passes_r2c": plan_r.passes,
            "stages_radix2": stages_r2,
            "stages_mixed": plan_c.stages,
            "stage_ratio": stages_r2 / max(plan_c.stages, 1),
        }
        for transform, plan in (("c2c", plan_c), ("r2c", plan_r)):
            case = FFTCase(n=n, transform=transform, radices=(4, 2))
            res = sweep(fft_workload(case, dev), dev)
            per = energy_per_transform(res, case.n_fft)
            row[f"model_j_per_fft_{transform}"] = per["optimal_j"]
            row[f"model_j_per_fft_{transform}_boost"] = per["boost_j"]
        if logn <= wall_max:
            batch = max(2**19 // n, 16)
            key = jax.random.PRNGKey(0)
            xr = jax.random.normal(key, (batch, n), jnp.float32)
            xc = (xr + 1j * jax.random.normal(key, (batch, n))
                  ).astype(jnp.complex64)
            row["batch"] = batch
            row["wall_us_c2c"] = _timeit(jax.jit(plan_c.fn), xc,
                                         n=7, warmup=3, reduce=min)
            row["wall_us_r2c"] = _timeit(jax.jit(plan_r.fn), xr,
                                         n=7, warmup=3, reduce=min)
            row["r2c_over_c2c"] = row["wall_us_r2c"] / row["wall_us_c2c"]
        rows.append(row)
        _row(f"fft_n{n}", row.get("wall_us_c2c", 0.0),
             f"alg={row['algorithm']};stages={row['stages_mixed']}v"
             f"{row['stages_radix2']};"
             f"r2c_ratio={row.get('r2c_over_c2c', float('nan')):.2f}")

    by_n = {r["n"]: r for r in rows}
    head = by_n[4096]
    out = {
        "device_model": dev.name,
        "radices": [4, 2],
        "backend": jax.default_backend(),
        # Headline acceptance figures at N = 2^12 (single fused pass).
        "criteria": {
            "stage_ratio_n4096": head["stage_ratio"],
            "r2c_over_c2c_wall_n4096": head.get("r2c_over_c2c"),
        },
        "lengths": rows,
    }
    path = _persist("fft", out, device=dev.name)
    _row("fft_bench_json", 0.0,
         f"written={path};"
         f"stage_ratio_n4096={head['stage_ratio']:.2f};"
         f"r2c_over_c2c_n4096={head.get('r2c_over_c2c', float('nan')):.2f}")


def fft2():
    """N-D plan-graph microbench — persists BENCH_fft2.json.

    Per 2-D shape: HBM passes of the plan graph vs the per-axis moveaxis
    chain (the acceptance >= 2x reduction for pow2 shapes), modelled
    J/transform at the boost vs the optimal clock (C2C and R2C), and
    measured wall time through the fused kernels (interpret mode
    off-TPU).  Also records the four-step headline: the long-1-D plan is
    two fused kernel passes with parity vs jnp.fft.fft at 1e-4 rtol.
    """
    from repro.core.dvfs import energy_per_transform, sweep
    from repro.core.hardware import TESLA_V100
    from repro.core.workloads import FFTCase, fft_workload
    from repro.fft.multidim import fft2 as fft2d, rfft2
    from repro.fft.plan import plan_for_length
    from repro.fft.plan_nd import plan_nd

    wall_max = int(os.environ.get("REPRO_FFT_BENCH_MAX_LOG2_WALL", "13"))
    dev = TESLA_V100
    shapes = [(64, 64), (128, 128), (256, 256), (512, 512),
              (1024, 1024), (2048, 2048), (100, 128), (12, 1024)]
    rows = []
    for shape in shapes:
        plan_c = plan_nd(shape)
        plan_r = plan_nd(shape, "r2c")
        row = {
            "shape": list(shape),
            "n": plan_c.n,
            "nodes": [n.op for n in plan_c.nodes],
            "passes_plan": plan_c.passes,
            "passes_chain": plan_c.chain_passes,
            "pass_reduction": plan_c.chain_passes / plan_c.passes,
            "passes_plan_r2c": plan_r.passes,
        }
        for transform, plan in (("c2c", plan_c), ("r2c", plan_r)):
            case = FFTCase(shape=shape, transform=transform, radices=(4, 2))
            res = sweep(fft_workload(case, dev), dev)
            per = energy_per_transform(res, case.n_fft)
            row[f"model_j_per_fft_{transform}"] = per["optimal_j"]
            row[f"model_j_per_fft_{transform}_boost"] = per["boost_j"]
        if math.log2(plan_c.n) <= wall_max:
            batch = max(2**18 // plan_c.n, 2)
            key = jax.random.PRNGKey(0)
            xr = jax.random.normal(key, (batch, *shape), jnp.float32)
            xc = (xr + 1j * jax.random.normal(key, (batch, *shape))
                  ).astype(jnp.complex64)
            row["batch"] = batch
            row["wall_us_c2c"] = _timeit(jax.jit(plan_c.fn), xc,
                                         n=5, warmup=2, reduce=min)
            row["wall_us_r2c"] = _timeit(jax.jit(plan_r.fn), xr,
                                         n=5, warmup=2, reduce=min)
            row["r2c_over_c2c"] = row["wall_us_r2c"] / row["wall_us_c2c"]
        rows.append(row)
        _row(f"fft2_{shape[0]}x{shape[1]}", row.get("wall_us_c2c", 0.0),
             f"passes={row['passes_plan']}v{row['passes_chain']};"
             f"nodes={'+'.join(row['nodes'])}")

    # Four-step headline: two fused passes + tight parity.  The pass
    # count is no longer taken from the plan's own claim: an eager run
    # inside a launch-ledger capture records the actual Pallas launches,
    # and the criteria report what the ledger saw.
    from repro.obs.ledger import LaunchLedger
    n4 = 2**14
    plan4 = plan_for_length(n4)
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, n4)) +
         1j * jax.random.normal(jax.random.PRNGKey(2), (2, n4))
         ).astype(jnp.complex64)
    led4 = LaunchLedger()
    with led4.capture():
        got = np.asarray(plan4(x))
    four_step_counts = led4.counts()
    four_step_launches = sum(n for k, n in four_step_counts.items()
                             if k.startswith("fft-"))
    want = np.fft.fft(np.asarray(x), axis=-1)
    four_step_rel = float(np.abs(got - want).max() / np.abs(want).max())
    _row("fft2_four_step", 0.0,
         f"passes={four_step_launches};rel_err={four_step_rel:.2e};"
         f"ledger={'+'.join(f'{k}:{v}' for k, v in four_step_counts.items())}")

    # Ledger audit of the pow2 2-D claim on the smallest measured shape.
    x64 = (jax.random.normal(jax.random.PRNGKey(3), (2, 64, 64))
           ).astype(jnp.complex64)
    led2 = LaunchLedger()
    with led2.capture():
        jax.block_until_ready(plan_nd((64, 64)).fn(x64))
    pow2_2d_ledger = led2.counts().get("fft-c2c-t", 0)

    pow2_rows = [r for r in rows if all(
        d & (d - 1) == 0 for d in r["shape"])]
    out = {
        "device_model": dev.name,
        "backend": jax.default_backend(),
        "criteria": {
            # Acceptance: >= 2x HBM-pass reduction for pow2 2-D shapes.
            "min_pass_reduction_pow2_2d": min(
                r["pass_reduction"] for r in pow2_rows),
            "pow2_2d_passes": max(r["passes_plan"] for r in pow2_rows),
            # Ledger audit: launches actually recorded by an eager run of
            # the 64x64 plan must equal the plan's claimed pass count.
            "pow2_2d_passes_ledger": pow2_2d_ledger,
            "pow2_2d_ledger_ok": pow2_2d_ledger == plan_nd((64, 64)).passes,
            # Acceptance: four-step = 2 fused passes, 1e-4 parity.  The
            # pass count is read from the launch ledger, not asserted.
            "four_step_passes": four_step_launches,
            "four_step_ledger_kernels": four_step_counts,
            "four_step_ledger_ok": four_step_launches == plan4.passes,
            "four_step_rel_err": four_step_rel,
            "four_step_parity_1e4": four_step_rel < 1e-4,
        },
        "shapes": rows,
    }
    path = _persist("fft2", out, device=dev.name)
    _row("fft2_bench_json", 0.0,
         f"written={path};"
         f"min_pass_reduction={out['criteria']['min_pass_reduction_pow2_2d']:.2f};"
         f"four_step_rel={four_step_rel:.2e}")


def fdas():
    """FDAS + overlap-save convolution engine — persists BENCH_fdas.json.

    Records the engine's pass accounting (one fused forward pass feeding
    the whole bank, T inverse passes, zero standalone multiply passes),
    the overlap-save vs direct pad-to-full-length traffic ratio, parity
    of the matched-filter plane against a direct ``jnp.fft``-based
    convolution oracle, recovery of an injected accelerated pulsar at
    its (template, bin) cell, and the per-stage DVFS play on the search
    pipeline (where the FFT-class share is far higher than the
    harmonic-sum demo's).
    """
    from repro.core.dvfs import sweep
    from repro.core.hardware import TESLA_V100
    from repro.core.scheduler import DVFSScheduler
    from repro.core.workloads import ConvCase, fdas_workload
    from repro.search import (TemplateBank, fdas_conv_plan, fdas_search,
                              matched_filter_plane)

    n = 2**13                                   # series length (CI-sized)
    bank = TemplateBank.linear(zmax=8, n_templates=9)
    t = bank.n_templates
    nbins = n // 2 + 1
    plan = fdas_conv_plan(n, bank)

    # --- parity: overlap-save plane vs direct pad-to-full-length oracle --
    rng = np.random.default_rng(0)
    spec = (rng.standard_normal((2, nbins))
            + 1j * rng.standard_normal((2, nbins))).astype(np.complex64)
    # The eager plane run is captured by a launch ledger, so the pass
    # claims below are audited against recorded Pallas launches rather
    # than restated from the plan's own accounting.
    from repro.obs.ledger import LaunchLedger
    ledger = LaunchLedger()
    with ledger.capture():
        got = np.asarray(matched_filter_plane(jnp.asarray(spec), bank))
    lcounts = ledger.counts()
    inv_records = [r for r in ledger.records if r.kernel == "fft-c2c"]
    # One batched inverse launch covers every (row, segment, template)
    # plane; T falls out of its recorded shape.
    inv_planes = (inv_records[0].shape[0]
                  // (spec.shape[0] * plan.n_segments)
                  if inv_records else 0)
    taps = bank.time_domain()
    m = 1 << (nbins + bank.taps - 2).bit_length()
    xs = np.fft.fft(spec, m, axis=-1)
    hs = np.fft.fft(taps, m, axis=-1)
    full = np.fft.ifft(xs[:, None, :] * hs[None], axis=-1)
    want = full[..., bank.offset:bank.offset + nbins]
    rel = float(np.abs(got - want).max() / np.abs(want).max())

    # --- injected accelerated pulsar ------------------------------------
    k0, z = 1200, 6.0
    s = np.arange(n) / n
    x = (0.25 * np.cos(2 * np.pi * (k0 * s + 0.5 * z * s * s))
         + 0.5 * rng.standard_normal(n)).astype(np.float32)[None]
    us = _timeit(lambda v: fdas_search(v, bank).power, jnp.asarray(x),
                 n=3, warmup=1)
    res = fdas_search(jnp.asarray(x), bank)
    power = np.asarray(res.power)[0]
    t_hit, b_hit = np.unravel_index(int(power.argmax()), power.shape)
    t_want = int(np.argmin(np.abs(np.array(bank.drifts) - z)))
    recovered = bool(t_hit == t_want and abs(b_hit - k0) <= 1)

    # --- DVFS: clock-lock the FFT-class stages --------------------------
    dev = TESLA_V100
    case = ConvCase(n=nbins, templates=t, taps=bank.taps)
    profs = fdas_workload(case, dev, series_n=n)
    sched = DVFSScheduler(dev)
    locked = {}
    for p in profs[:2]:                         # R2C + convolution stages
        locked[p.name] = sweep(p, dev).optimal.f
    rep = sched.evaluate_pipeline(sched.plan(profs, locked))
    times = [sweep(p, dev).boost.time for p in profs]
    fft_share = sum(times[:2]) / sum(times)

    _row("fdas_plane", us,
         f"nfft={plan.nfft};segments={plan.n_segments};"
         f"fwd_passes={plan.forward_passes};inv_passes={plan.inverse_passes};"
         f"traffic_ratio={plan.traffic_ratio:.2f};rel_err={rel:.2e}")
    _row("fdas_recovery", 0.0,
         f"template={t_hit}(want {t_want});bin={b_hit}(want {k0});"
         f"ok={recovered}")
    _row("fdas_dvfs", 0.0,
         f"fft_class_share={100*fft_share:.1f}%;I_ef={rep.i_ef:.3f};"
         f"slowdown={100*rep.slowdown:.2f}%")

    out = {
        "device_model": dev.name,
        "backend": jax.default_backend(),
        "series_n": n,
        "templates": t,
        "taps": bank.taps,
        "criteria": {
            # Acceptance: fused epilogues — forward + T inverse passes,
            # no standalone multiply pass.  Audited from the launch
            # ledger: one fft-c2c-mul launch (fused forward + bank
            # multiply), one batched inverse launch whose recorded shape
            # covers the T template planes.
            "forward_passes": plan.forward_passes,
            "inverse_passes": plan.inverse_passes,
            "forward_launches_ledger": lcounts.get("fft-c2c-mul", 0),
            "inverse_launches_ledger": lcounts.get("fft-c2c", 0),
            "inverse_planes_ledger": inv_planes,
            "ledger_audit_ok": (
                lcounts.get("fft-c2c-mul", 0) == plan.forward_passes
                and lcounts.get("fft-c2c", 0) == 1
                and inv_planes == plan.inverse_passes == t),
            "passes_per_template": plan.passes_per_template,
            "traffic_ratio_os_vs_direct": plan.traffic_ratio,
            # Acceptance: plane parity vs the direct oracle at 1e-4.
            "plane_rel_err": rel,
            "plane_parity_1e4": rel < 1e-4,
            # Acceptance: injected pulsar at the right (template, bin).
            "recovered_template": int(t_hit),
            "expected_template": t_want,
            "recovered_bin": int(b_hit),
            "expected_bin": k0,
            "recovered_ok": recovered,
        },
        "plan": {
            "nfft": plan.nfft,
            "step": plan.step,
            "n_segments": plan.n_segments,
            "os_bytes_per_row": plan.os_bytes,
            "direct_bytes_per_row": plan.direct_bytes,
        },
        "dvfs": {
            "fft_class_share": fft_share,
            "i_ef": rep.i_ef,
            "slowdown": rep.slowdown,
            "locked_mhz": locked,
        },
    }
    path = _persist("fdas", out, device=dev.name)
    _row("fdas_bench_json", 0.0,
         f"written={path};"
         f"traffic_ratio={plan.traffic_ratio:.2f};"
         f"parity={rel:.2e};recovered={recovered}")


def tune():
    """Autotuner smoke — persists BENCH_autotune.json.

    Tunes two small lengths end to end in interpret mode (candidate
    generation -> cost-model pruning -> measured survivors -> persisted
    choice), then reloads the persisted cache and replays both keys to
    prove the second run re-measures NOTHING, and reports the paper's
    Sec. 4 "common configuration" result on the software axis.

    Acceptance: ``speedup_vs_heuristic >= 1.0`` for every tuned length
    (the tuner may return the heuristic but never regress it — the
    heuristic's latency is the real-time bound) and a recorded cache-hit
    replay with zero measurements.
    """
    import tempfile
    from repro.tune import TuningCache, common_config, tune_length

    lengths = (256, 512)
    cache_file = os.path.join(tempfile.mkdtemp(prefix="repro-tune-bench-"),
                              "tune_cache.json")
    cache = TuningCache.load(path=cache_file)
    rows = []
    for n in lengths:
        res = tune_length(n, cache=cache, objective="energy",
                          repeats=3, warmup=1, save=False)
        rows.append({
            "n": n,
            "objective": res.record.objective,
            "chosen_config": res.config.to_dict(),
            "heuristic_config": res.record.heuristic.to_dict(),
            "wall_us_chosen": res.record.measured_s * 1e6,
            "wall_us_heuristic": res.record.heuristic_s * 1e6,
            "speedup_vs_heuristic": res.speedup_vs_heuristic,
            "candidates_generated": res.record.candidates,
            "candidates_measured": res.record.measured,
            "measurements": res.measurements,
        })
        _row(f"tune_n{n}", res.record.measured_s * 1e6,
             f"source={res.config.source};"
             f"speedup={res.speedup_vs_heuristic:.3f};"
             f"pruned={res.record.candidates}->{res.record.measured}")
    cache.save(cache_file)

    # --- cache-hit replay: a fresh process-equivalent load re-measures
    # nothing and returns the identical choice ------------------------------
    cache2 = TuningCache.load(path=cache_file)
    replays = []
    for row in rows:
        rep = tune_length(row["n"], cache=cache2)
        replays.append({
            "n": row["n"],
            "replayed": rep.replayed,
            "measurements": rep.measurements,
            "config_matches": rep.config.to_dict() == row["chosen_config"],
        })
    common, regret = common_config(cache2)
    _row("tune_replay", 0.0,
         f"cache_hits={sum(r['replayed'] for r in replays)};"
         f"re_measurements={sum(r['measurements'] for r in replays)};"
         f"common_src={common.source};common_regret={regret:.4f}")

    out = {
        "backend": jax.default_backend(),
        "device": cache.device,
        "cache_file": cache_file,
        "criteria": {
            # Acceptance: never regress the heuristic, per tuned length.
            "min_speedup_vs_heuristic": min(
                r["speedup_vs_heuristic"] for r in rows),
            "speedup_ok": all(
                r["speedup_vs_heuristic"] >= 1.0 for r in rows),
            # Acceptance: second run replays from the persisted cache
            # with zero re-measurement.
            "cache_hit_replays": sum(r["replayed"] for r in replays),
            "replay_measurements": sum(r["measurements"] for r in replays),
            "replay_configs_match": all(
                r["config_matches"] for r in replays),
        },
        "lengths": rows,
        "replays": replays,
        "common_config": {
            "config": common.to_dict(),
            "mean_regret": regret,
        },
    }
    path = _persist("autotune", out, device=cache.device)
    _row("tune_bench_json", 0.0,
         f"written={path};"
         f"min_speedup={out['criteria']['min_speedup_vs_heuristic']:.3f};"
         f"replay_measurements="
         f"{out['criteria']['replay_measurements']}")


def pipeline():
    """End-to-end pulsar search with per-stage DVFS — BENCH_pipeline.json.

    Runs the jitted ``repro.search.pipeline.pulsar_search`` graph
    (dedispersion -> FDAS -> fused harmonic sum -> sift) on a synthetic
    filterbank with two injected binary pulsars plus a noise-only
    control, and prices the four-stage DVFS plan on the V100 model.

    Self-checked acceptance (CI gates on a non-zero exit):
      * every injected pulsar is recovered at its exact
        (DM trial, template, bin) cell — no extras, no misses;
      * the no-signal control yields zero candidates;
      * the per-stage-locked pipeline stays real time
        (S = t_acquire / t_process >= 1).
    """
    from repro.core.hardware import TESLA_V100
    from repro.data.synthetic import (FilterbankSpec, InjectedPulsar,
                                      synthetic_filterbank)
    from repro.search import (DispersionPlan, TemplateBank,
                              plan_pulsar_stages, pulsar_search)

    spec = FilterbankSpec(nchan=16, ntime=2048)
    plan = DispersionPlan.from_spec(spec, n_trials=8)
    bank = TemplateBank.linear(zmax=4.0, n_templates=5)
    n_harmonics = 8
    # (DM trial, template, bin, drift): drifts (-4,-2,0,2,4) -> z=2 is
    # template 3, z=-4 template 0
    injected = [(3, 3, 300, 2.0), (6, 0, 611, -4.0)]
    pulsars = tuple(InjectedPulsar(dm=plan.dms[d], k0=b, z=z, amp=0.12)
                    for d, _, b, z in injected)
    fb = jnp.asarray(synthetic_filterbank(spec, pulsars, noise=1.0, seed=2))

    def run(v):
        return pulsar_search(v, plan, bank, n_harmonics=n_harmonics)

    us = _timeit(lambda v: run(v).candidates.snr, fb, n=3, warmup=1)
    c = run(fb).candidates
    got = sorted((int(d), int(t), int(b))
                 for d, t, b in zip(c.dm[0], c.template[0], c.bin[0])
                 if int(d) >= 0)
    want = sorted((d, t, b) for d, t, b, _ in injected)
    recovered_ok = got == want

    quiet = jnp.asarray(synthetic_filterbank(spec, (), noise=1.0, seed=3))
    false_pos = int((np.asarray(run(quiet).candidates.dm) >= 0).sum())

    dev = TESLA_V100
    sp = plan_pulsar_stages(spec, plan, bank, n_harmonics, dev)
    margin = sp.realtime_margin
    realtime_ok = margin >= 1.0

    _row("pipeline_search", us,
         f"recovered={got};want={want};ok={recovered_ok};"
         f"false_positives={false_pos}")
    for s in sp.report.stages:
        _row(f"pipeline_stage_{s.name}", 0.0,
             f"clock={s.f:.0f}MHz;time={s.time:.3e}s;energy={s.energy:.3e}J")
    _row("pipeline_dvfs", 0.0,
         f"I_ef={sp.report.i_ef:.3f};slowdown={100*sp.report.slowdown:.2f}%;"
         f"realtime_margin={margin:.1f}")

    out = {
        "device_model": dev.name,
        "backend": jax.default_backend(),
        "filterbank": {"nchan": spec.nchan, "ntime": spec.ntime,
                       "tsamp": spec.tsamp, "t_acquire": spec.t_acquire},
        "search": {"dm_trials": plan.n_trials,
                   "templates": bank.n_templates,
                   "n_harmonics": n_harmonics},
        "criteria": {
            # Acceptance: exact-cell recovery, zero false positives,
            # real-time at the per-stage locks.
            "injected": want,
            "recovered": got,
            "recovered_ok": recovered_ok,
            "false_positives": false_pos,
            "realtime_margin": margin,
            "realtime_ok": realtime_ok,
        },
        "dvfs": {
            "locked_mhz": sp.locked,
            "stages": [{"name": s.name, "clock_mhz": s.f,
                        "time_s": s.time, "energy_j": s.energy}
                       for s in sp.report.stages],
            "i_ef": sp.report.i_ef,
            "slowdown": sp.report.slowdown,
            "rows_per_batch": sp.case.n_rows,
        },
    }
    path = _persist("pipeline", out, device=dev.name)
    _row("pipeline_bench_json", 0.0,
         f"written={path};recovered={recovered_ok};"
         f"false_positives={false_pos};realtime_margin={margin:.1f}")
    if not (recovered_ok and false_pos == 0 and realtime_ok):
        raise SystemExit(
            f"pipeline self-check failed: recovered={got} (want {want}), "
            f"false_positives={false_pos}, realtime_margin={margin:.2f}")


def _synthetic_stream(rng, lengths, n_requests):
    """A repeated-shape request stream: (payload, length) tuples."""
    stream = []
    for i in range(n_requests):
        n = lengths[i % len(lengths)]
        b = 1 + int(rng.integers(0, 4))
        x = (rng.standard_normal((b, n))
             + 1j * rng.standard_normal((b, n))).astype(np.complex64)
        stream.append(x)
    return stream


def serving():
    """Energy-aware FFT service vs naive per-request execution.

    Reports service-level joules-per-transform, p50/p99 latency, cache
    behaviour (a repeated-shape stream must sweep each shape exactly once),
    and batched vs per-request throughput.
    """
    from repro.core.hardware import TPU_V5E
    from repro.serving import FFTService

    rng = np.random.default_rng(0)
    lengths = [1024, 4096, 1024, 2048]            # repeated shapes on purpose
    stream = _synthetic_stream(rng, lengths, n_requests=64)

    def play(service, stream, wave):
        """Stream requests in waves; returns (wall time, pass receipts).

        Each drain is one serving cycle — every wave after the first hits
        the plan/sweep cache (no re-sweep).
        """
        receipts = []
        t0 = time.perf_counter()
        for start in range(0, len(stream), wave):
            for x in stream[start:start + wave]:
                service.submit(x)
            receipts.extend(service.drain())
        return time.perf_counter() - t0, receipts

    svc = FFTService(TPU_V5E, keep_results=False)
    naive = FFTService(TPU_V5E, keep_results=False, coalesce_requests=False)
    # Warm both services (JIT compilation is one-time in a long-running
    # server), then measure a steady-state pass.
    play(svc, stream, wave=8)
    play(naive, stream, wave=8)
    wall_batched, steady = play(svc, stream, wave=8)
    rep = svc.report()
    wall_naive, steady_naive = play(naive, stream, wave=8)
    nrep = naive.report()
    # Steady-state figures come from the timed pass only (the cumulative
    # report also covers the JIT-compiling warm-up pass).
    lat = np.array([r.latency for r in steady])
    p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
    tps = sum(r.request.batch for r in steady) / wall_batched
    tps_naive = sum(r.request.batch for r in steady_naive) / wall_naive

    n_shapes = len(set(lengths))
    _row("serving_stream", wall_batched / max(len(steady), 1) * 1e6,
         f"J_per_fft={rep.joules_per_transform:.3e};"
         f"p50_ms={p50*1e3:.2f};p99_ms={p99*1e3:.2f};"
         f"I_ef={rep.i_ef:.2f};batches={rep.n_batches};"
         f"sweeps={rep.cache.sweeps};cache_hits={rep.cache.hits};"
         f"resweep_free={rep.cache.sweeps == n_shapes}")
    _row("serving_vs_naive", wall_naive / max(len(steady_naive), 1) * 1e6,
         f"batched_tput={tps:.0f}tps;naive_tput={tps_naive:.0f}tps;"
         f"speedup={wall_naive/wall_batched:.2f}x;"
         f"naive_batches={nrep.n_batches}")


def _chaos_pool(seed):
    """Deterministic payload pool, one array per distinct request shape.

    Payloads are built once and resubmitted (the service never mutates
    them), so 10^5 requests cost 10^5 receipt objects, not 10^5 arrays.
    """
    rng = np.random.default_rng(seed)

    def cplx(shape):
        return jnp.asarray((rng.standard_normal(shape)
                            + 1j * rng.standard_normal(shape)
                            ).astype(np.complex64))

    def real(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))

    return {
        "fft": {(n, b): cplx((b, n))
                for n in (256, 512, 1024) for b in (1, 2, 3, 4)},
        "r2c": {b: real((b, 512)) for b in (1, 2)},
        "fft2": cplx((2, 64, 64)),
        "fdas": real((1, 1024)),
        "pulsar": real((4, 256)),
    }


def _chaos_payload(i, pool):
    """Payload + submit kwargs for request ``i`` of the mixed stream.

    Pure function of (i, pool): the recovery harness re-resolves crashed
    requests' payloads from their journaled ``payload_ref`` (= i) with
    exactly this mapping.
    """
    if i % 997 == 111:
        return pool["pulsar"], {"kind": "pulsar", "dm_trials": 4,
                                "templates": 3, "n_harmonics": 4}
    if i % 211 == 23:
        return pool["fdas"], {"kind": "fdas", "templates": 3}
    if i % 53 == 17:
        return pool["fft2"], {"ndim": 2}
    if i % 7 == 3:
        return pool["r2c"][1 + i % 2], {"transform": "r2c"}
    return pool["fft"][((256, 512, 1024)[i % 3], 1 + i % 4)], {}


def _chaos_submit(svc, i, pool, **extra):
    """Submit request ``i`` of the deterministic mixed stream."""
    x, kwargs = _chaos_payload(i, pool)
    return svc.submit(x, **kwargs, **extra)


def _run_chaos(n_requests, seed, *, wave=512, deadline_s=7e-6):
    """One open-loop chaos run; returns (service, submitted, stats)."""
    import hashlib
    from repro.core.hardware import TPU_V5E
    from repro.power import FleetTelemetry
    from repro.runtime.faults import (FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD,
                                      KILL_DEVICE, SENSOR_KINDS,
                                      STALL_WORKER, FaultPlan)
    from repro.serving import SLO, FFTService, SLOPolicy, rung_name

    pool = _chaos_pool(seed)
    # ~7 distinct shapes coalesce to ~7 batches per wave; double it so the
    # generated schedule covers every batch id the run can reach.
    n_batches = max(2 * 8 * (n_requests // wave + 1), 16)
    plan = FaultPlan.generate(seed, n_batches=n_batches,
                              stall_duration_s=0.02)
    policy = SLOPolicy(default=SLO(deadline_s=deadline_s))
    # The telemetry plane shares the fault plan: scheduled SENSOR_* events
    # corrupt the per-batch power samples so the watchdog (not just the
    # execution path) is exercised by the same deterministic schedule.
    telemetry = FleetTelemetry.for_serving(TPU_V5E, seed=seed,
                                           fault_plan=plan)
    svc = FFTService(TPU_V5E, keep_results=False, slo=policy,
                     fault_plan=plan, drain_deadline_s=300.0,
                     telemetry=telemetry)
    submitted = []
    t0 = time.perf_counter()
    for start in range(0, n_requests, wave):
        for i in range(start, min(start + wave, n_requests)):
            submitted.append(_chaos_submit(svc, i, pool))
        svc.drain()
    wall = time.perf_counter() - t0

    receipts = [svc.receipt(r) for r in submitted]
    missing = sum(1 for r in receipts if r is None)
    # The reproducibility digest covers request-visible *outcomes* only:
    # worker placement and measured latencies are wall-clock-dependent,
    # the (outcome, rung, reason) trajectory must not be.
    h = hashlib.blake2b(digest_size=16)
    for req, r in zip(submitted, receipts):
        h.update(f"{req.kind}:{r.outcome}:{r.rung}:{r.reason}".encode()
                 if r is not None else b"MISSING")
    rep = svc.report()

    served = [r for r in receipts if r is not None and r.status == "served"]
    shed = [r for r in receipts if r is not None and r.status == "shed"]
    lat = np.array([r.latency for r in served]) if served else np.zeros(1)
    by_rung = {}
    for r in served:
        g = by_rung.setdefault(rung_name(r.rung),
                               {"n": 0, "transforms": 0, "energy_j": 0.0})
        g["n"] += 1
        g["transforms"] += r.request.batch
        g["energy_j"] += r.energy_j
    for g in by_rung.values():
        g["j_per_transform"] = g["energy_j"] / max(g["transforms"], 1)

    stats = {
        "n_requests": n_requests,
        "n_workers": svc.dispatcher.queue.n_workers,
        "wave": wave,
        "seed": seed,
        "wall_s": wall,
        "requests_per_s": n_requests / wall,
        "missing_receipts": missing,
        "outcomes": {
            "served": sum(1 for r in served if r.retries == 0),
            "retried": sum(1 for r in served if r.retries > 0),
            "shed": len(shed),
        },
        "shed_by_reason": {
            reason: sum(1 for r in shed if r.reason == reason)
            for reason in sorted({r.reason for r in shed})
        },
        "shed_rate": len(shed) / max(n_requests, 1),
        "availability": rep.availability,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "j_per_transform_by_rung": by_rung,
        "faults_fired": {k: plan.fired_count(k)
                         for k in (KILL_DEVICE, FAIL_CLOCK_LOCK,
                                   FAIL_PLAN_BUILD, STALL_WORKER)},
        "sensor_faults_fired": {k: plan.fired_count(k)
                                for k in SENSOR_KINDS},
        "faults_pending": plan.pending(),
        "measured_energy_j": rep.measured_energy_j,
        "modelled_energy_j": rep.energy_j,
        "telemetry": rep.telemetry,
        "breaker_opens": rep.breaker_opens,
        "redistributions": rep.redistributions,
        "steals": rep.steals,
        "degraded": rep.degraded,
        "admission": {"admitted": svc.admission.admitted,
                      "degraded": svc.admission.degraded,
                      "shed": svc.admission.shed},
        "digest": h.hexdigest(),
    }
    return svc, stats


def chaos():
    """Deterministic chaos/load harness — persists BENCH_chaos.json.

    Drives REPRO_CHAOS_REQUESTS (default 100000) mixed
    fft/fft2/fdas/pulsar requests through the SLO-governed service under
    a seed-generated fault schedule (>= 1 device kill, >= 1 clock-lock
    failure, >= 1 stalled worker), then re-runs a smaller stream twice to
    prove outcome bit-reproducibility.  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a
    simulated 8-device fleet.

    Self-checked acceptance (CI gates on a non-zero exit):
      * every submitted request terminates in exactly one receipt;
      * the fault plan was non-trivial AND every pinned kind fired;
      * availability >= 0.99 excluding admission sheds;
      * the same seed reproduces the same outcome digest.
    """
    from repro.runtime.faults import (FAIL_CLOCK_LOCK, KILL_DEVICE,
                                      STALL_WORKER)

    n_requests = int(os.environ.get("REPRO_CHAOS_REQUESTS", "100000"))
    seed = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
    # The SLO deadline is in *modelled* boost-clock seconds (the admission
    # controller never reads the wall clock); ~7us of modelled TPU work per
    # wave puts the mixed stream right at the degrade/shed knee.
    deadline_s = float(os.environ.get("REPRO_CHAOS_DEADLINE_S", "7e-6"))
    svc, stats = _run_chaos(n_requests, seed, deadline_s=deadline_s)
    _row("chaos_stream", stats["wall_s"] / max(n_requests, 1) * 1e6,
         f"workers={stats['n_workers']};rps={stats['requests_per_s']:.0f};"
         f"served={stats['outcomes']['served']};"
         f"retried={stats['outcomes']['retried']};"
         f"shed={stats['outcomes']['shed']};"
         f"availability={stats['availability']:.4f}")
    _row("chaos_faults", 0.0,
         f"fired={stats['faults_fired']};breaker_opens="
         f"{stats['breaker_opens']};redistributions="
         f"{stats['redistributions']}")

    # Bit-reproducibility: two fresh services, same seed, same (smaller)
    # stream — identical outcome digests.
    n_sub = min(n_requests, int(os.environ.get(
        "REPRO_CHAOS_REPRO_REQUESTS", "2000")))
    _, sub_a = _run_chaos(n_sub, seed, deadline_s=deadline_s)
    _, sub_b = _run_chaos(n_sub, seed, deadline_s=deadline_s)
    reproducible = sub_a["digest"] == sub_b["digest"]
    _row("chaos_repro", 0.0,
         f"n={n_sub};digest_a={sub_a['digest'][:16]};"
         f"digest_b={sub_b['digest'][:16]};match={reproducible}")

    fired = stats["faults_fired"]
    criteria = {
        # Acceptance: every request terminates in exactly one receipt.
        "missing_receipts": stats["missing_receipts"],
        "every_request_receipted": stats["missing_receipts"] == 0,
        # Acceptance: the schedule was non-trivial and actually fired.
        "nontrivial_fault_plan": (fired[KILL_DEVICE] >= 1
                                  and fired[FAIL_CLOCK_LOCK] >= 1
                                  and fired[STALL_WORKER] >= 1),
        # Acceptance: availability (excluding admission sheds) >= 99%.
        "availability": stats["availability"],
        "availability_ok": stats["availability"] >= 0.99,
        # Acceptance: same seed => same outcome trajectory.
        "reproducible": reproducible,
    }
    out = {
        "backend": jax.default_backend(),
        "criteria": criteria,
        "run": stats,
        "repro_runs": [sub_a, sub_b],
    }
    from repro.core.hardware import TPU_V5E
    path = _persist("chaos", out, device=TPU_V5E.name)
    _row("chaos_bench_json", 0.0,
         f"written={path};"
         f"availability={stats['availability']:.4f};"
         f"reproducible={reproducible}")
    if not (criteria["every_request_receipted"]
            and criteria["nontrivial_fault_plan"]
            and criteria["availability_ok"] and reproducible):
        raise SystemExit(f"chaos self-check failed: {criteria}")


def _run_recovery(n_requests, seed, *, crashes=2, process="poisson",
                  rate_hz=1e5, period_s=4e-2, deadline_s=6e-5,
                  journal_dir=None, snapshot_every=1,
                  segment_records=100_000):
    """One crash-and-recover run over a seeded arrival process.

    Drives the mixed chaos stream through a journal-attached service in
    Poisson/Gamma arrival waves (the service drains once per
    ``period_s`` of simulated arrival time, so wave sizes genuinely
    vary), simulating ``crashes`` process kills at evenly spaced
    admission ordinals via the fault plan's arrival seam.  Each crash
    abandons the service mid-wave (journal tail un-fsynced, in-memory
    state gone) and recovers from the journal: replayed receipts are
    verified bit-identical against the outcomes already collected,
    in-flight admits are re-enqueued, and the wave resumes.  Returns
    (stats, journal_audit) with a submission-order outcome digest that
    must not depend on the crash schedule.
    """
    import collections
    import hashlib
    import shutil
    import tempfile

    from repro.core.energy import guarded_ratio
    from repro.core.hardware import TPU_V5E
    from repro.data.arrivals import arrival_times, wave_slices
    from repro.power import FleetTelemetry
    from repro.runtime.faults import (CRASH_PROCESS, FAIL_CLOCK_LOCK,
                                      FAIL_PLAN_BUILD, KILL_DEVICE,
                                      KILL_HOST, SENSOR_KINDS, STALL_WORKER,
                                      FaultPlan, HostTopology)
    from repro.runtime.journal import RequestJournal, read_journal
    from repro.serving import SLO, FFTService, SLOPolicy
    from repro.serving.recovery import ReplayResult

    pool = _chaos_pool(seed)
    times = arrival_times(n_requests, seed=seed + 1, process=process,
                          rate_hz=rate_hz)
    waves = list(wave_slices(times, period_s))
    n_workers = len(jax.devices())
    # Host fault domains: group the fleet into ~4 simulated hosts.
    topology = HostTopology(n_workers,
                            devices_per_host=max(1, n_workers // 4))
    n_batches = max(16 * (len(waves) + 1), 64)
    crash_arrivals = tuple(sorted(
        {n_requests * (k + 1) // (crashes + 1)
         for k in range(crashes)})) if crashes else ()
    # Two host kills pinned to batch ids the run will certainly reach
    # (the 7-shape stream coalesces to >= ~6 batches per wave).
    est_batches = max(6 * len(waves), 12)
    host_kill_batches = (max(est_batches // 3, 2),
                         max(2 * est_batches // 3, 5))

    def make_plan():
        # Identical seeded draws regardless of crash_arrivals (harness-
        # only events append after the rng), so the crashed and uncrashed
        # runs see the same serving faults.
        return FaultPlan.generate(seed, n_batches=n_batches,
                                  stall_duration_s=0.02,
                                  crash_arrivals=crash_arrivals,
                                  host_kill_batches=host_kill_batches)

    def build(plan, *, recover_from=None):
        kwargs = dict(
            device_spec=TPU_V5E, keep_results=False,
            slo=SLOPolicy(default=SLO(deadline_s=deadline_s)),
            fault_plan=plan, drain_deadline_s=300.0,
            telemetry=FleetTelemetry.for_serving(TPU_V5E, seed=seed,
                                                 fault_plan=plan),
            max_retained_receipts=16384, topology=topology)
        if recover_from is not None:
            return FFTService.recover(
                recover_from,
                payload_fn=lambda ref, meta: _chaos_payload(ref, pool)[0],
                journal_kwargs={"segment_records": segment_records},
                **kwargs)
        journal = RequestJournal(journal_dir,
                                 segment_records=segment_records)
        return FFTService(journal=journal, **kwargs)

    owns_dir = journal_dir is None
    if owns_dir:
        journal_dir = tempfile.mkdtemp(prefix="repro-journal-")

    outcomes = {}
    counters = collections.Counter()
    fired = collections.Counter()
    fault_kinds = (KILL_DEVICE, KILL_HOST, FAIL_CLOCK_LOCK,
                   FAIL_PLAN_BUILD, STALL_WORKER, *SENSOR_KINDS)

    def collect(receipts):
        for r in receipts:
            ref = r.request.payload_ref
            if ref is None:
                continue
            t = (r.request.kind, r.outcome, r.rung, r.reason)
            prev = outcomes.get(ref)
            if prev is None:
                outcomes[ref] = t
                if r.recovered:
                    counters["recovered_only"] += 1
            elif r.recovered:
                # A replayed receipt for an outcome the harness already
                # saw live: the exactly-once contract says it must be
                # bit-identical (status/reason/rung).
                if prev == t:
                    counters["replays_verified"] += 1
                else:
                    counters["replay_mismatches"] += 1
            else:
                counters["reexecuted_duplicates"] += 1

    def absorb(svc, plan):
        for k in fault_kinds:
            fired[k] += plan.fired_count(k)
        counters["host_kills"] += svc.host_kills
        if svc.admission is not None:
            counters["admitted"] += svc.admission.admitted
            counters["degraded"] += svc.admission.degraded
            counters["adm_shed"] += svc.admission.shed

    plan = make_plan()
    svc = build(plan)
    crashes_done = 0
    t0 = time.perf_counter()
    for w, (start, stop) in enumerate(waves):
        for i in range(start, stop):
            if plan.take(CRASH_PROCESS, arrival=i) is not None:
                # Simulated kill -9 mid-wave: the journal tail is
                # abandoned without a durability barrier and every byte
                # of in-memory service state dies with the process.
                absorb(svc, plan)
                svc.journal.crash()
                crashes_done += 1
                plan = make_plan()
                svc = build(plan, recover_from=journal_dir)
                plan.drop_consumed(batch_before=svc._next_batch_id,
                                   arrival_before=i + 1)
                collect(svc.recovered_receipts)
                svc.recovered_receipts.clear()   # verified; free them
            _chaos_submit(svc, i, pool, payload_ref=i)
        collect(svc.drain())
        if snapshot_every and (w + 1) % snapshot_every == 0:
            svc.snapshot()
    collect(svc.drain())
    wall = time.perf_counter() - t0
    absorb(svc, plan)
    incarnation = svc.journal.incarnation
    svc.journal.close()

    # End-of-run audit straight off the durable log: every admit must
    # have exactly one terminal record, no more, no less.  Streamed
    # (retain=0 keeps counts, not payloads) so auditing a 10^6-request
    # journal costs seq-set memory, not record memory.
    audit = ReplayResult(retain=0)
    _, jstats = read_journal(journal_dir, sink=audit.feed)

    h = hashlib.blake2b(digest_size=16)
    for i in range(n_requests):
        t = outcomes.get(i)
        h.update(f"{t[0]}:{t[1]}:{t[2]}:{t[3]}".encode()
                 if t is not None else b"MISSING")
    served = sum(1 for t in outcomes.values()
                 if t[1] in ("served", "retried"))
    fault_shed = sum(1 for t in outcomes.values() if t[1] == "shed"
                     and str(t[3] or "").startswith("fault:"))
    stats = {
        "n_requests": n_requests,
        "n_workers": n_workers,
        "hosts": topology.n_hosts,
        "seed": seed,
        "process": process,
        "rate_hz": rate_hz,
        "period_s": period_s,
        "waves": len(waves),
        "mean_wave": n_requests / max(len(waves), 1),
        "wall_s": wall,
        "requests_per_s": n_requests / wall,
        "crashes": crashes_done,
        "crash_arrivals": list(crash_arrivals),
        "incarnation": incarnation,
        "lost_receipts": n_requests - len(outcomes),
        "duplicate_receipts": (counters["reexecuted_duplicates"]
                               + audit.duplicate_terminals),
        "replays_verified": counters["replays_verified"],
        "replay_mismatches": counters["replay_mismatches"],
        "recovered_only": counters["recovered_only"],
        "outcomes": {
            "served": sum(1 for t in outcomes.values()
                          if t[1] == "served"),
            "retried": sum(1 for t in outcomes.values()
                           if t[1] == "retried"),
            "shed": sum(1 for t in outcomes.values() if t[1] == "shed"),
        },
        "availability": guarded_ratio(served, served + fault_shed,
                                      on_zero=1.0),
        "admission": {"admitted": counters["admitted"],
                      "degraded": counters["degraded"],
                      "shed": counters["adm_shed"]},
        "faults_fired": {k: fired[k] for k in fault_kinds},
        "host_kills": counters["host_kills"],
        "journal": {
            "segments": jstats.segments,
            "records": jstats.records,
            "invalid": jstats.invalid,
            "admits": audit.admits_total,
            "terminals": audit.terminals_total,
            "open_admits": len(audit.open_admits),
            "duplicate_terminals": audit.duplicate_terminals,
            "incarnations": audit.incarnations,
            "availability": audit.availability,
            "duplicate_rate": audit.duplicate_rate,
        },
        "digest": h.hexdigest(),
    }
    if owns_dir:
        shutil.rmtree(journal_dir, ignore_errors=True)
    return stats


def recovery():
    """Crash-and-recover gate — persists BENCH_recovery.json.

    Drives REPRO_RECOVERY_REQUESTS (default 10^6) mixed requests through
    the journal-attached service in seeded Poisson arrival waves with
    REPRO_CHAOS_CRASHES (default 2, >= 2 enforced) simulated process
    kills mid-run, recovering from the write-ahead journal each time;
    then repeats a smaller Gamma-arrival pair for the bursty process.
    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
    a simulated 8-device / 4-host fleet.

    Self-checked acceptance (CI gates on a non-zero exit):
      * zero lost receipts and zero duplicated receipts — the journal
        audit proves exactly one terminal record per admit;
      * every replayed receipt bit-identical (status/reason/rung) to the
        live receipt the previous incarnation issued;
      * availability >= 0.99 excluding admission sheds;
      * the outcome digest is identical crashed-and-recovered vs
        uncrashed at the same seed (for Poisson AND Gamma arrivals);
      * >= 2 crashes and >= 1 host kill actually happened.
    """
    from repro.core.hardware import TPU_V5E
    from repro.runtime.faults import KILL_HOST

    n_requests = int(os.environ.get("REPRO_RECOVERY_REQUESTS", "1000000"))
    crashes = max(int(os.environ.get("REPRO_CHAOS_CRASHES", "2")), 2)
    seed = int(os.environ.get("REPRO_RECOVERY_SEED", "0"))
    deadline_s = float(os.environ.get("REPRO_RECOVERY_DEADLINE_S", "6e-5"))

    crashed = _run_recovery(n_requests, seed, crashes=crashes,
                            deadline_s=deadline_s)
    _row("recovery_stream",
         crashed["wall_s"] / max(n_requests, 1) * 1e6,
         f"rps={crashed['requests_per_s']:.0f};"
         f"crashes={crashed['crashes']};"
         f"lost={crashed['lost_receipts']};"
         f"dup={crashed['duplicate_receipts']};"
         f"replays_verified={crashed['replays_verified']};"
         f"availability={crashed['availability']:.4f}")
    uncrashed = _run_recovery(n_requests, seed, crashes=0,
                              deadline_s=deadline_s)
    digests_match = crashed["digest"] == uncrashed["digest"]
    _row("recovery_digest", 0.0,
         f"crashed={crashed['digest'][:16]};"
         f"uncrashed={uncrashed['digest'][:16]};match={digests_match}")

    # The bursty arrival process, smaller but with the same contract.
    n_gamma = min(n_requests,
                  int(os.environ.get("REPRO_RECOVERY_GAMMA_REQUESTS",
                                     "20000")))
    g_crashed = _run_recovery(n_gamma, seed, crashes=crashes,
                              process="gamma", deadline_s=deadline_s)
    g_uncrashed = _run_recovery(n_gamma, seed, crashes=0,
                                process="gamma", deadline_s=deadline_s)
    gamma_match = g_crashed["digest"] == g_uncrashed["digest"]
    _row("recovery_gamma", 0.0,
         f"n={n_gamma};crashes={g_crashed['crashes']};"
         f"lost={g_crashed['lost_receipts']};"
         f"dup={g_crashed['duplicate_receipts']};match={gamma_match}")

    criteria = {
        "crashes_injected": crashed["crashes"],
        "crashes_ok": crashed["crashes"] >= 2,
        "zero_lost": (crashed["lost_receipts"] == 0
                      and g_crashed["lost_receipts"] == 0),
        "zero_duplicated": (crashed["duplicate_receipts"] == 0
                            and g_crashed["duplicate_receipts"] == 0),
        "journal_exactly_once": (
            crashed["journal"]["admits"] == n_requests
            and crashed["journal"]["terminals"] == n_requests
            and crashed["journal"]["open_admits"] == 0),
        "replays_bit_identical": (crashed["replay_mismatches"] == 0
                                  and g_crashed["replay_mismatches"] == 0),
        "availability": crashed["availability"],
        "availability_ok": crashed["availability"] >= 0.99,
        "digest_crash_invariant": digests_match and gamma_match,
        "host_kill_fired": crashed["faults_fired"][KILL_HOST] >= 1,
    }
    out = {
        "criteria": criteria,
        "crashed": crashed,
        "uncrashed": uncrashed,
        "gamma": {"crashed": g_crashed, "uncrashed": g_uncrashed},
    }
    path = _persist("recovery", out, device=TPU_V5E.name,
                    incarnation=crashed["incarnation"])
    _row("recovery_bench_json", 0.0,
         f"written={path};zero_lost={criteria['zero_lost']};"
         f"zero_dup={criteria['zero_duplicated']};"
         f"digest_invariant={criteria['digest_crash_invariant']}")
    if not (criteria["crashes_ok"] and criteria["zero_lost"]
            and criteria["zero_duplicated"]
            and criteria["journal_exactly_once"]
            and criteria["replays_bit_identical"]
            and criteria["availability_ok"]
            and criteria["digest_crash_invariant"]
            and criteria["host_kill_fired"]):
        raise SystemExit(f"recovery self-check failed: {criteria}")


def _power_site(seed, *, fault_plan=None, site_cap_w=1400.0,
                hard_cap_w=1500.0, n_devices=8):
    """A governed 8-device TPU_V5E site with PR 5 sweep-optimum fallbacks."""
    from repro.core import FFTCase, fft_workload
    from repro.core.dvfs import sweep
    from repro.core.hardware import TPU_V5E
    from repro.power import SiteBudgetScheduler, SitePipeline

    fallback = sweep(fft_workload(FFTCase(n=4096), TPU_V5E),
                     TPU_V5E).optimal.f
    pipes = [SitePipeline(name=f"pipe{i}", device_index=i,
                          priority=(i % 4) + 1, fallback_mhz=fallback,
                          u_core=0.9, u_mem=0.8)
             for i in range(n_devices)]
    return SiteBudgetScheduler(TPU_V5E, pipes, site_cap_w=site_cap_w,
                               hard_cap_w=hard_cap_w, seed=seed,
                               fault_plan=fault_plan)


def power():
    """Closed-loop power governance harness — persists BENCH_power.json.

    Exercises the repro.power subsystem end to end on the simulated
    8-device fleet:

      converge     the governed site from a cold start: per-pipeline PI
                   governors steer measured power onto the
                   priority-weighted budget split
      faults       one run per sensor-fault kind (dropout / spike /
                   stale) injected as a 4-tick storm on device 0: the
                   watchdog must go unhealthy and the governor must pin
                   the static sweep-optimum fallback clock exactly
      emergency    the site cap drops mid-run below current draw: the
                   emergency rung floors clocks, sheds the
                   lowest-priority pipeline and restores headroom
      serving      a telemetered FFTService stream: receipts carry
                   measured_energy_j next to the modelled energy_j

    Self-checked acceptance (CI gates on a non-zero exit):
      * the governed fleet's true site power NEVER exceeds the cap;
      * the controller converges within REPRO_POWER_MAX_TICKS ticks;
      * under EACH injected sensor-fault kind the governor engages the
        bit-exact static-sweep fallback;
      * two fresh runs produce the identical site digest.
    """
    from repro.core.hardware import TPU_V5E
    from repro.power import FleetTelemetry
    from repro.runtime.faults import SENSOR_KINDS, FaultEvent, FaultPlan

    seed = int(os.environ.get("REPRO_POWER_SEED", "0"))
    n_ticks = int(os.environ.get("REPRO_POWER_TICKS", "80"))
    max_ticks = int(os.environ.get("REPRO_POWER_MAX_TICKS", "40"))
    dt = 0.1

    # --- phase A: cold-start convergence under the site cap ---------------
    site = _power_site(seed)
    ticks = site.run(n_ticks, dt=dt)
    peak_w = max(t.truth_w for t in ticks)
    converged_tick = site.first_converged_tick
    digest_a = site.digest()
    site_b = _power_site(seed)
    site_b.run(n_ticks, dt=dt)
    reproducible = digest_a == site_b.digest()
    _row("power_converge", 0.0,
         f"ticks={n_ticks};converged_tick={converged_tick};"
         f"peak_w={peak_w:.1f};cap_w={site.site_cap_w:.0f};"
         f"digest={digest_a[:16]};reproducible={reproducible}")

    # --- phase B: static-sweep fallback under each sensor-fault kind ------
    fallback_runs = {}
    for kind in SENSOR_KINDS:
        storm = FaultPlan(events=[FaultEvent(kind, batch_id=k, worker=0)
                                  for k in range(10, 14)])
        fsite = _power_site(seed, fault_plan=storm)
        fticks = fsite.run(30, dt=dt)
        gov = fsite.governors["pipe0"]
        fb_ticks = [k for k, t in enumerate(fticks)
                    if t.modes[0] == "fallback"]
        exact = all(fticks[k].clocks_mhz[0] == gov.fallback_mhz
                    for k in fb_ticks)
        fallback_runs[kind] = {
            "fired": storm.fired_count(kind),
            "fallback_engagements": gov.fallback_engagements,
            "fallback_ticks": fb_ticks,
            "fallback_clock_exact": exact,
            "fallback_mhz": gov.fallback_mhz,
            "engaged": gov.fallback_engagements >= 1 and bool(fb_ticks),
            "recovered": fticks[-1].health[0] == "healthy",
        }
        _row(f"power_fault_{kind.replace('sensor-', '')}", 0.0,
             f"fired={storm.fired_count(kind)};"
             f"fallback_ticks={len(fb_ticks)};exact={exact};"
             f"recovered={fallback_runs[kind]['recovered']}")

    # --- phase C: emergency rung on a mid-run hard-cap breach -------------
    esite = _power_site(seed)
    esite.run(20, dt=dt)
    pre_active = len(esite.active)
    esite.site_cap_w, esite.hard_cap_w = 850.0, 900.0
    eticks = esite.run(20, dt=dt)[20:]
    emergency_fired = esite.emergencies >= 1
    shed_count = pre_active - len(esite.active)
    cap_restored = eticks[-1].truth_w <= esite.hard_cap_w
    _row("power_emergency", 0.0,
         f"emergencies={esite.emergencies};shed={shed_count};"
         f"final_w={eticks[-1].truth_w:.1f};hard_cap_w="
         f"{esite.hard_cap_w:.0f};restored={cap_restored}")

    # --- serving integration: measured J on receipts (informational) -----
    from repro.serving import FFTService
    rng = np.random.default_rng(seed)
    tel = FleetTelemetry.for_serving(TPU_V5E, seed=seed)
    svc = FFTService(TPU_V5E, keep_results=False, telemetry=tel)
    for i in range(32):
        n = (256, 512, 1024)[i % 3]
        svc.submit((rng.standard_normal((2, n))
                    + 1j * rng.standard_normal((2, n))
                    ).astype(np.complex64))
    svc.drain()
    rep = svc.report()
    _row("power_serving", 0.0,
         f"measured_j={rep.measured_energy_j:.3e};"
         f"modelled_j={rep.energy_j:.3e};"
         f"reads={rep.telemetry['reads']}")

    criteria = {
        # Acceptance: the governed fleet never exceeds the site cap.
        "peak_site_w": peak_w,
        "site_cap_w": site.site_cap_w,
        "cap_never_exceeded": peak_w <= site.site_cap_w,
        # Acceptance: bounded-time convergence from a cold start.
        "converged_tick": converged_tick,
        "converged_in_bound": (converged_tick is not None
                               and converged_tick <= max_ticks),
        # Acceptance: the bit-exact static fallback engages under every
        # injected sensor-fault kind.
        "fallback_under_each_kind": all(
            r["engaged"] and r["fallback_clock_exact"]
            for r in fallback_runs.values()),
        # Acceptance: the emergency rung both fires and works.
        "emergency_engaged": emergency_fired,
        "emergency_shed": shed_count,
        "emergency_cap_restored": cap_restored,
        # Acceptance: same seed => identical site digest, fresh runs.
        "reproducible": reproducible,
    }
    out = {
        "criteria": criteria,
        "converge": {
            "n_ticks": n_ticks,
            "dt_s": dt,
            "n_devices": 8,
            "converged_tick": converged_tick,
            "peak_site_w": peak_w,
            "final_site_w": ticks[-1].truth_w,
            "targets_w": dict(site.targets),
            "final_clocks_mhz": list(ticks[-1].clocks_mhz),
            "digest": digest_a,
            "telemetry": site.telemetry.summary(),
        },
        "sensor_faults": fallback_runs,
        "emergency": {
            "emergencies": esite.emergencies,
            "shed": shed_count,
            "active_after": list(t for t in eticks[-1].active),
            "final_site_w": eticks[-1].truth_w,
            "hard_cap_w": esite.hard_cap_w,
        },
        "serving": {
            "measured_energy_j": rep.measured_energy_j,
            "modelled_energy_j": rep.energy_j,
            "n_requests": rep.n_requests,
        },
    }
    path = _persist("power", out, device=TPU_V5E.name)
    _row("power_bench_json", 0.0,
         f"written={path};cap_ok={criteria['cap_never_exceeded']};"
         f"converged_tick={converged_tick};"
         f"fallback_ok={criteria['fallback_under_each_kind']};"
         f"reproducible={reproducible}")
    if not (criteria["cap_never_exceeded"]
            and criteria["converged_in_bound"]
            and criteria["fallback_under_each_kind"]
            and criteria["emergency_engaged"] and cap_restored
            and reproducible):
        raise SystemExit(f"power self-check failed: {criteria}")


def obs():
    """Observability plane — persists BENCH_obs.json.

    Gates: (1) tracing overhead — a tracer-instrumented warm service
    drain within 5% wall time of an uninstrumented one (min-of-repeats;
    the ledger/metrics/drift plane is always on in both, so the delta
    prices exactly the opt-in span machinery); (2) ledger-audited pass
    claims — an eager pow2 2-D plan records exactly 2 fused launches and
    the fused FDAS convolution records 1 forward + one batched inverse
    launch covering all T template planes; (3) reproducibility — two
    fresh fake-timer serving runs produce identical blake2b span digests
    and identical ledger digests; (4) model-drift detection — the drift
    detector alerts under a deliberately miscalibrated sensor truth
    model and stays silent under the calibrated one.
    """
    import dataclasses as _dc

    from repro.core.hardware import TPU_V5E
    from repro.core.power_model import PowerModel
    from repro.fft.convolve import conv_plan, overlap_save_conv
    from repro.fft.plan_nd import plan_nd
    from repro.obs import LaunchLedger, Tracer, launches_digest
    from repro.obs import trace as trace_mod
    from repro.power.telemetry import FleetTelemetry
    from repro.serving import FFTService

    class _FakeTimer:
        """Deterministic clock: advances dt per call."""

        def __init__(self, dt=1e-4):
            self.t, self.dt = 0.0, dt

        def __call__(self):
            self.t += self.dt
            return self.t

    key = jax.random.PRNGKey(0)
    payloads = []
    for i in range(6):
        kr, ki, key = jax.random.split(key, 3)
        payloads.append((jax.random.normal(kr, (16, 2048))
                         + 1j * jax.random.normal(ki, (16, 2048))
                         ).astype(jnp.complex64))

    # --- 1. tracing overhead on a warm drain -------------------------
    def build(instrumented):
        return FFTService(TPU_V5E, devices=[None, None],
                          keep_results=False,
                          tracer=Tracer() if instrumented else None)

    def drive(svc):
        for p in payloads:
            svc.submit(p)
        return svc.drain()

    # Interleaved best-of-n: alternating the two services inside one
    # repeat loop exposes both to the same machine-state drift, so the
    # min-of-n delta prices the tracer, not the scheduler.
    plain, traced = build(False), build(True)
    for svc in (plain, traced):
        for _ in range(2):
            drive(svc)                                   # warm jit caches
    plain_s, traced_s = [], []
    for _ in range(9):
        t0 = time.perf_counter()
        drive(plain)
        plain_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        drive(traced)
        traced_s.append(time.perf_counter() - t0)
    plain_us, traced_us = 1e6 * min(plain_s), 1e6 * min(traced_s)
    overhead = traced_us / plain_us - 1.0
    overhead_ok = overhead < 0.05
    _row("obs_overhead", plain_us,
         f"traced_us={traced_us:.1f};overhead={100*overhead:+.2f}%;"
         f"ok={overhead_ok}")

    # --- 2. ledger-audited pass claims --------------------------------
    plan2 = plan_nd((64, 64))
    led = LaunchLedger()
    with led.capture():
        jax.block_until_ready(plan2.fn(payloads[0].reshape(-1, 64, 64)))
    fft2_counts = led.counts()
    fft2_ok = (fft2_counts.get("fft-c2c-t", 0) == plan2.passes == 2
               and len(fft2_counts) == 1)

    n, taps, t, nfft = 1000, 17, 3, 256
    cplan = conv_plan(n, taps, t, nfft)
    led = LaunchLedger()
    with led.capture():
        jax.block_until_ready(overlap_save_conv(
            payloads[1].reshape(-1)[:n], np.ones((t, taps), np.float32),
            nfft=nfft))
    fdas_counts = led.counts()
    inv = [r for r in led.records if r.kernel == "fft-c2c"]
    inv_planes = (inv[0].shape[0] // cplan.n_segments) if inv else 0
    fdas_ok = (fdas_counts.get("fft-c2c-mul", 0) == cplan.forward_passes
               and fdas_counts.get("fft-c2c", 0) == 1
               and inv_planes == cplan.inverse_passes == t)
    _row("obs_ledger_audit", 0.0,
         f"fft2={'+'.join(f'{k}:{v}' for k, v in fft2_counts.items())};"
         f"fdas_fwd={fdas_counts.get('fft-c2c-mul', 0)};"
         f"fdas_inv_planes={inv_planes};ok={fft2_ok and fdas_ok}")

    # --- 3/4. reproducible traces + drift detection -------------------
    def traced_run(power_model=None):
        timer = _FakeTimer()
        tracer = Tracer(timer=timer)
        svc = FFTService(
            TPU_V5E, devices=[None, None], timer=timer, tracer=tracer,
            keep_results=False,
            telemetry=FleetTelemetry.for_serving(
                TPU_V5E, seed=11, noise_frac=0.0,
                power_model=power_model))
        for p in payloads[:4]:
            # one drain per submit: every batch is metered, so the drift
            # detector clears its min_samples gate on one key
            svc.submit(p)
            svc.drain()
        return svc, tracer

    svc1, tr1 = traced_run()
    svc2, tr2 = traced_run()
    d1, d2 = trace_mod.digest(tr1.spans), trace_mod.digest(tr2.spans)
    # Receipt-level launch digests: the second run serves warm jit
    # executables (its own ledger records nothing live), so compare what
    # the receipts carry, replayed from the process-wide signature store.
    ld1 = launches_digest(r.launches for r in svc1.receipts)
    ld2 = launches_digest(r.launches for r in svc2.receipts)
    reproducible = d1 == d2 and ld1 == ld2
    launches_backed = all(
        r.launches and all(l.bytes_moved > 0 for l in r.launches)
        for svc in (svc1, svc2) for r in svc.receipts)
    _row("obs_trace_digest", 0.0,
         f"span_digest={d1};ledger_digest={ld1};match={reproducible}")

    hot = PowerModel(_dc.replace(TPU_V5E, name="hot-v5e",
                                 tdp=2.0 * TPU_V5E.tdp))
    svc_hot, _ = traced_run(power_model=hot)
    drift_ok = (svc1.drift.drift_alerts == 0
                and svc_hot.drift.drift_alerts >= 1)
    _row("obs_drift", 0.0,
         f"calibrated_alerts={svc1.drift.drift_alerts};"
         f"miscalibrated_alerts={svc_hot.drift.drift_alerts};"
         f"worst_err={svc_hot.drift.summary()['worst_ewma_error']:+.3f};"
         f"ok={drift_ok}")

    criteria = {
        # Acceptance: < 5% wall-time overhead for full tracing.
        "tracing_overhead_frac": overhead,
        "tracing_overhead_lt_5pct": overhead_ok,
        # Acceptance: ledger-audited pass counts match PR 3/4 claims.
        "fft2_ledger_counts": fft2_counts,
        "fft2_ledger_ok": fft2_ok,
        "fdas_ledger_counts": fdas_counts,
        "fdas_inverse_planes_ledger": inv_planes,
        "fdas_ledger_ok": fdas_ok,
        # Acceptance: identical digests across two fresh runs.
        "span_digest_run1": d1,
        "span_digest_run2": d2,
        "ledger_digest_run1": ld1,
        "ledger_digest_run2": ld2,
        "digests_reproducible": reproducible,
        "receipts_ledger_backed": launches_backed,
        # Acceptance: drift alerts iff the model is miscalibrated.
        "calibrated_drift_alerts": svc1.drift.drift_alerts,
        "miscalibrated_drift_alerts": svc_hot.drift.drift_alerts,
        "drift_detection_ok": drift_ok,
    }
    out = {
        "criteria": criteria,
        "overhead": {"plain_us": plain_us, "traced_us": traced_us,
                     "requests_per_drain": len(payloads)},
        "drift_miscalibrated": svc_hot.drift.summary(),
        "metrics_series": sorted(
            line.split("{")[0].split(" ")[0]
            for line in svc1.metrics_text().splitlines()
            if line and not line.startswith("#")),
    }
    path = _persist("obs", out, device=TPU_V5E.name)
    _row("obs_bench_json", 0.0,
         f"written={path};overhead_ok={overhead_ok};"
         f"ledger_ok={fft2_ok and fdas_ok};reproducible={reproducible};"
         f"drift_ok={drift_ok}")
    if not (overhead_ok and fft2_ok and fdas_ok and reproducible
            and launches_backed and drift_ok):
        raise SystemExit(f"obs self-check failed: {criteria}")


BENCHES = [fig4_exec_time, fig6_time_vs_freq, fig7_energy_u_shape,
           fig8_power_vs_freq, fig9_optimal_freq, table3_mean_optimal,
           fig10_gflops_per_watt, fig11_exec_increase, fig13_16_ief,
           table4_pipeline, kernels, fft, fft2, fdas, tune, pipeline,
           roofline, dvfs_cells, fft_pencil_roofline, conclusions_cost_co2,
           serving, chaos, recovery, power, obs]


def main(argv: list[str] | None = None) -> None:
    args = sys.argv[1:] if argv is None else argv
    by_name = {b.__name__: b for b in BENCHES}
    if args:
        unknown = [a for a in args if a not in by_name]
        if unknown:
            raise SystemExit(
                f"unknown target(s) {unknown}; have {sorted(by_name)}")
        selected = [by_name[a] for a in args]
    else:
        selected = BENCHES
    print("name,us_per_call,derived")
    for b in selected:
        b()


if __name__ == "__main__":
    main()
