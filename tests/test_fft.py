"""FFT substrate correctness: Stockham/Bluestein/four-step vs jnp.fft."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional test dep: skip property tests
    from _hyp import given, settings, st

from repro.fft import bluestein_fft, fft, fft2, ifft, plan_for_length
from repro.fft.plan import four_step_fft
from repro.fft.pipeline import (PipelineShape, candidate_snr, harmonic_sum,
                                power_spectrum, pulsar_pipeline,
                                spectrum_stats, stage_profiles)

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY, dtype=jnp.complex64):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(dtype)


@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 1024, 4096])
@pytest.mark.parametrize("batch", [(), (3,), (2, 5)])
def test_stockham_matches_reference(n, batch):
    x = rand_complex((*batch, n))
    np.testing.assert_allclose(fft(x), jnp.fft.fft(x), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [8, 256, 2048])
def test_ifft_inverts(n):
    x = rand_complex((4, n))
    np.testing.assert_allclose(ifft(fft(x)), x, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [3, 12, 100, 139, 139 * 139 // 139, 2187, 2401])
def test_bluestein_matches_reference(n):
    x = rand_complex((2, n))
    np.testing.assert_allclose(bluestein_fft(x), jnp.fft.fft(x),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("n1,n2", [(4, 8), (32, 32), (64, 128)])
def test_four_step_matches_reference(n1, n2):
    x = rand_complex((2, n1 * n2))
    np.testing.assert_allclose(four_step_fft(x, n1, n2), jnp.fft.fft(x),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n", [64, 8192, 2**15, 139, 100])
def test_planner_dispatch_and_correctness(n):
    plan = plan_for_length(n)
    expected = {True: "stockham" if n <= 2**13 else "four-step",
                False: "bluestein"}[(n & (n - 1)) == 0]
    assert plan.algorithm == expected
    assert plan.passes >= 1
    x = rand_complex((2, n))
    np.testing.assert_allclose(plan(x), jnp.fft.fft(x), rtol=3e-3, atol=3e-3)


def test_fft2_matches_reference():
    x = rand_complex((3, 16, 32))
    np.testing.assert_allclose(fft2(x), jnp.fft.fft2(x), rtol=3e-4, atol=3e-4)


def test_fft_axis_argument():
    x = rand_complex((8, 5))
    np.testing.assert_allclose(fft(x, axis=0), jnp.fft.fft(x, axis=0),
                               rtol=2e-4, atol=2e-4)


def test_float64_precision_path():
    with jax.experimental.enable_x64():
        x = rand_complex((2, 512), dtype=jnp.complex128)
        np.testing.assert_allclose(fft(x), jnp.fft.fft(x), rtol=1e-10)


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(logn=st.integers(3, 10), seed=st.integers(0, 2**31 - 1))
def test_property_parseval(logn, seed):
    """sum |x|^2 == sum |X|^2 / N (energy conservation)."""
    n = 2**logn
    x = rand_complex((n,), key=jax.random.PRNGKey(seed))
    X = fft(x)
    np.testing.assert_allclose(jnp.sum(jnp.abs(x) ** 2),
                               jnp.sum(jnp.abs(X) ** 2) / n, rtol=1e-3)


@settings(deadline=None, max_examples=20)
@given(logn=st.integers(2, 9), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_property_linearity(logn, seed, a, b):
    n = 2**logn
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, y = rand_complex((n,), k1), rand_complex((n,), k2)
    np.testing.assert_allclose(fft(a * x + b * y), a * fft(x) + b * fft(y),
                               rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=10)
@given(logn=st.integers(3, 8), shift=st.integers(1, 7))
def test_property_time_shift(logn, shift):
    """Circular time shift <-> linear phase in frequency."""
    n = 2**logn
    x = rand_complex((n,))
    X = fft(x)
    Xs = fft(jnp.roll(x, -shift))
    phase = jnp.exp(2j * jnp.pi * shift * jnp.arange(n) / n)
    np.testing.assert_allclose(Xs, X * phase, rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=10)
@given(logn=st.integers(4, 10), seed=st.integers(0, 2**31 - 1))
def test_property_impulse_is_flat(logn, seed):
    """FFT of a delta is a flat spectrum (magnitude 1 everywhere)."""
    n = 2**logn
    pos = seed % n
    x = jnp.zeros(n, jnp.complex64).at[pos].set(1.0)
    np.testing.assert_allclose(jnp.abs(fft(x)), jnp.ones(n), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Pulsar pipeline
# ---------------------------------------------------------------------------

def test_power_spectrum_and_stats():
    x = rand_complex((3, 256))
    X = fft(x)
    p = power_spectrum(X)
    assert p.shape == (3, 256)
    assert bool(jnp.all(p >= 0))
    mean, std = spectrum_stats(p)
    assert mean.shape == (3, 1) and std.shape == (3, 1)


def test_harmonic_sum_levels():
    p = jnp.ones((2, 128))
    hs = harmonic_sum(p, 8)
    assert hs.shape == (2, 4, 128)        # h = 1, 2, 4, 8
    # On a flat spectrum (away from the clipped tail) S_h = h.
    np.testing.assert_allclose(hs[:, 0, 1:16], 1.0)
    np.testing.assert_allclose(hs[:, 3, 1:16], 8.0)


def test_pipeline_finds_injected_pulsar():
    """A periodic signal must produce a high-S/N candidate at its bin."""
    n = 4096
    t = jnp.arange(n, dtype=jnp.float32)
    f0 = 128 / n                               # bin 128 fundamental
    key = jax.random.PRNGKey(1)
    noise = jax.random.normal(key, (1, n))
    # A pulse train has power in the fundamental AND its harmonics.
    signal = (jnp.sin(2 * jnp.pi * f0 * t) > 0.95).astype(jnp.float32)
    x = noise + 4.0 * signal[None, :]
    snr = pulsar_pipeline(x, n_harmonics=8)
    assert snr.shape == (1, 4, n)
    assert float(snr[0, :, 128].max()) > 8.0   # strong detection
    # and harmonic summing must help for a pulse train:
    assert float(snr[0, 1:, 128].max()) >= float(snr[0, 0, 128]) - 1.0


def test_stage_profiles_fft_dominant_share():
    """Sec. 5.3: with 2 harmonics the FFT is ~60% of pipeline time."""
    from repro.core.hardware import TESLA_V100
    from repro.fft.pipeline import fft_time_share
    share = fft_time_share(PipelineShape(batch=32, n=2**20, n_harmonics=2),
                           TESLA_V100)
    assert 0.35 <= share <= 0.85
