"""FFT substrate correctness: Stockham/Bluestein/four-step vs jnp.fft."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional test dep: skip property tests
    from _hyp import given, settings, st

from repro.fft import (bluestein_fft, fft, fft2, ifft, irfft,
                       plan_for_length, rfft, rfft2)
from repro.fft import plan as plan_mod
from repro.fft.plan import four_step_fft
from repro.fft.pipeline import (PipelineShape, candidate_snr, harmonic_sum,
                                power_spectrum, pulsar_pipeline,
                                spectrum_stats, stage_profiles)
from repro.fft.radix import radix_schedule, stage_count
from repro.fft.stockham import _stockham_pow2

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY, dtype=jnp.complex64):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(dtype)


@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 1024, 4096])
@pytest.mark.parametrize("batch", [(), (3,), (2, 5)])
def test_stockham_matches_reference(n, batch):
    x = rand_complex((*batch, n))
    np.testing.assert_allclose(fft(x), jnp.fft.fft(x), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [8, 256, 2048])
def test_ifft_inverts(n):
    x = rand_complex((4, n))
    np.testing.assert_allclose(ifft(fft(x)), x, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [3, 12, 100, 139, 139 * 139 // 139, 2187, 2401])
def test_bluestein_matches_reference(n):
    x = rand_complex((2, n))
    np.testing.assert_allclose(bluestein_fft(x), jnp.fft.fft(x),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("n1,n2", [(4, 8), (32, 32), (64, 128)])
def test_four_step_matches_reference(n1, n2):
    x = rand_complex((2, n1 * n2))
    np.testing.assert_allclose(four_step_fft(x, n1, n2), jnp.fft.fft(x),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n", [64, 8192, 2**15, 139, 100])
def test_planner_dispatch_and_correctness(n):
    plan = plan_for_length(n)
    expected = {True: "stockham" if n <= 2**13 else "four-step",
                False: "bluestein"}[(n & (n - 1)) == 0]
    assert plan.algorithm == expected
    assert plan.passes >= 1
    x = rand_complex((2, n))
    np.testing.assert_allclose(plan(x), jnp.fft.fft(x), rtol=3e-3, atol=3e-3)


def test_fft2_matches_reference():
    x = rand_complex((3, 16, 32))
    np.testing.assert_allclose(fft2(x), jnp.fft.fft2(x), rtol=3e-4, atol=3e-4)


def test_fft_axis_argument():
    x = rand_complex((8, 5))
    np.testing.assert_allclose(fft(x, axis=0), jnp.fft.fft(x, axis=0),
                               rtol=2e-4, atol=2e-4)


def test_float64_precision_path():
    with jax.experimental.enable_x64():
        x = rand_complex((2, 512), dtype=jnp.complex128)
        np.testing.assert_allclose(fft(x), jnp.fft.fft(x), rtol=1e-10)


# ---------------------------------------------------------------------------
# Mixed-radix engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("radices", [(2,), (4, 2), (8, 4, 2)])
@pytest.mark.parametrize("n", [2, 8, 64, 1024, 4096])
def test_mixed_radix_parity(n, radices):
    """Every radix schedule computes the same transform as jnp.fft."""
    x = rand_complex((3, n))
    got = _stockham_pow2(x, radices=radices)
    np.testing.assert_allclose(got, jnp.fft.fft(x), rtol=3e-4, atol=3e-4)
    gi = _stockham_pow2(x, inverse=True, radices=radices)
    np.testing.assert_allclose(gi, jnp.fft.ifft(x), rtol=3e-4, atol=3e-4)


def test_radix_schedule_structure():
    assert radix_schedule(4096) == (4,) * 6
    # The residual radix-2 stage runs first, at full butterfly width.
    assert radix_schedule(2048) == (2,) + (4,) * 5
    assert stage_count(4096, (2,)) == 12
    assert stage_count(4096, (4, 2)) == 6
    assert stage_count(4096, (8, 4, 2)) == 4
    with pytest.raises(ValueError):
        radix_schedule(12, (4,))          # 3 is not expressible in radix 4


def test_mixed_radix_halves_stage_count():
    """The tentpole claim: >= 1.3x fewer stages than radix-2 at N=2^12."""
    assert stage_count(2**12, (2,)) / stage_count(2**12, (4, 2)) >= 1.3


# ---------------------------------------------------------------------------
# R2C / C2R real transforms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 64, 1024, 4096])
@pytest.mark.parametrize("batch", [(), (3,), (2, 5)])
def test_rfft_matches_reference(n, batch):
    x = jax.random.normal(KEY, (*batch, n))
    np.testing.assert_allclose(rfft(x), jnp.fft.rfft(x),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n", [4, 256, 2048])
def test_irfft_inverts_rfft(n):
    x = jax.random.normal(KEY, (4, n))
    np.testing.assert_allclose(irfft(rfft(x)), x, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(irfft(jnp.fft.rfft(x)),
                               jnp.fft.irfft(jnp.fft.rfft(x)),
                               rtol=2e-4, atol=2e-4)


def test_rfft_float64_precision_path():
    with jax.experimental.enable_x64():
        x = jax.random.normal(KEY, (2, 512), dtype=jnp.float64)
        np.testing.assert_allclose(rfft(x), jnp.fft.rfft(x), rtol=1e-10)
        np.testing.assert_allclose(irfft(rfft(x)), x, rtol=1e-10)


def test_rfft_axis_argument():
    x = jax.random.normal(KEY, (16, 5))
    np.testing.assert_allclose(rfft(x, axis=0), jnp.fft.rfft(x, axis=0),
                               rtol=2e-4, atol=2e-4)


def test_rfft2_matches_reference():
    x = jax.random.normal(KEY, (3, 16, 32))
    np.testing.assert_allclose(rfft2(x), jnp.fft.rfft2(x),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("n", [64, 4096, 2**15, 100])
def test_plan_r2c_all_algorithms(n):
    """R2C plans: kernel route, four-step route, and non-pow2 fallback."""
    x = jax.random.normal(KEY, (2, n))
    plan = plan_for_length(n, "r2c")
    assert plan.kind == "r2c"
    np.testing.assert_allclose(plan(x), jnp.fft.rfft(x),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("n", [64, 4096, 2**15])
def test_plan_c2r_roundtrip(n):
    x = jax.random.normal(KEY, (2, n))
    X = plan_for_length(n, "r2c")(x)
    back = plan_for_length(n, "c2r")(X)
    np.testing.assert_allclose(back, x, rtol=3e-3, atol=3e-3)


def test_plan_c2r_rejects_non_pow2():
    with pytest.raises(ValueError):
        plan_for_length(60, "c2r")
    with pytest.raises(ValueError):
        plan_for_length(64, "hartley")


# ---------------------------------------------------------------------------
# Kernel routing: every plan's pow2 passes execute the Pallas kernel
# ---------------------------------------------------------------------------

class _CountingKernel:
    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        return self.inner(*args, **kwargs)


@pytest.mark.parametrize("n,algorithm", [
    (2**9, "stockham"),       # single fused pass
    (45, "bluestein"),        # two kernel passes at m=128
])
def test_plans_route_through_pallas_kernel(monkeypatch, n, algorithm):
    """Acceptance: each algorithm path demonstrably runs the kernel.

    Jitted paths (bluestein) execute the router at trace time, so each
    case uses a batch shape unique to this test to force a fresh trace.
    """
    counter = _CountingKernel(plan_mod.fft_kernel_c2c)
    monkeypatch.setattr(plan_mod, "_kernel_fft", counter)
    plan = plan_for_length(n)
    assert plan.algorithm == algorithm
    x = rand_complex((7, n))
    np.testing.assert_allclose(plan(x), jnp.fft.fft(x), rtol=3e-3, atol=3e-3)
    assert counter.calls >= (2 if algorithm != "stockham" else 1)


def test_four_step_plan_runs_two_fused_kernel_passes(monkeypatch):
    """Acceptance: the long-N plan is exactly TWO fused kernel passes —
    column FFT + twiddle epilogue, then row FFT + transposed write.  No
    plain kernel launches, no separate twiddle / transpose ops."""
    col = _CountingKernel(plan_mod.fft_kernel_c2c_axis1)
    row = _CountingKernel(plan_mod.fft_kernel_c2c_t)
    plain = _CountingKernel(plan_mod.fft_kernel_c2c)
    monkeypatch.setattr(plan_mod, "_kernel_fft_axis1", col)
    monkeypatch.setattr(plan_mod, "_kernel_fft_t", row)
    monkeypatch.setattr(plan_mod, "_kernel_fft", plain)
    n = 2**14
    plan = plan_for_length(n)
    assert plan.algorithm == "four-step"
    assert plan.passes == 2
    x = rand_complex((3, n))
    np.testing.assert_allclose(plan(x), jnp.fft.fft(x), rtol=3e-3, atol=3e-3)
    assert col.calls == 1 and row.calls == 1
    assert plain.calls == 0          # no hidden unfused passes


def test_r2c_plan_routes_through_pallas_kernel(monkeypatch):
    counter = _CountingKernel(plan_mod.fft_kernel_r2c)
    monkeypatch.setattr(plan_mod, "_kernel_rfft", counter)
    x = jax.random.normal(KEY, (7, 2**9))
    plan = plan_for_length(2**9, "r2c")
    np.testing.assert_allclose(plan(x), jnp.fft.rfft(x), rtol=3e-3, atol=3e-3)
    assert counter.calls == 1


@pytest.mark.parametrize("n", [2**9, 2**14, 45])
def test_plans_fall_back_without_pallas(monkeypatch, n):
    """With the kernel unavailable every plan stays correct (pure JAX)."""
    for hook in ("_kernel_fft", "_kernel_rfft", "_kernel_irfft",
                 "_kernel_fft_t", "_kernel_fft_axis1", "_kernel_rfft_t",
                 "_kernel_transpose"):
        monkeypatch.setattr(plan_mod, hook, None)
    x = rand_complex((5, n))
    np.testing.assert_allclose(plan_for_length(n)(x), jnp.fft.fft(x),
                               rtol=3e-3, atol=3e-3)


def test_pallas_disable_env_skips_kernel(monkeypatch):
    monkeypatch.setenv("REPRO_FFT_DISABLE_PALLAS", "1")
    counter = _CountingKernel(plan_mod.fft_kernel_c2c)
    monkeypatch.setattr(plan_mod, "_kernel_fft", counter)
    x = rand_complex((6, 2**9))
    np.testing.assert_allclose(plan_mod.pow2_fft(x), jnp.fft.fft(x),
                               rtol=3e-4, atol=3e-4)
    assert counter.calls == 0


def test_broken_kernel_falls_back_gracefully(monkeypatch):
    def boom(*args, **kwargs):
        raise RuntimeError("no Pallas backend")
    monkeypatch.setattr(plan_mod, "_kernel_fft", boom)
    x = rand_complex((4, 2**9))
    np.testing.assert_allclose(plan_mod.pow2_fft(x), jnp.fft.fft(x),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Twiddle / chirp caching
# ---------------------------------------------------------------------------

def test_four_step_twiddle_cached_across_calls():
    """The (n2, n1) twiddle matrix materialises once per shape."""
    x = rand_complex((2, 16 * 32), key=jax.random.PRNGKey(9))
    before = plan_mod._four_step_twiddle.cache_info().misses
    four_step_fft(x, 16, 32)
    four_step_fft(x, 16, 32)
    info = plan_mod._four_step_twiddle.cache_info()
    assert info.misses - before <= 1
    assert info.hits >= 1


def test_bluestein_chirp_cached_across_traces():
    """Chirp + filter-spectrum factors build once per (length, direction)."""
    from repro.fft.bluestein import _chirp_factors
    before = _chirp_factors.cache_info().misses
    bluestein_fft(rand_complex((1, 77)))
    bluestein_fft(rand_complex((2, 77)))      # second trace, same length
    info = _chirp_factors.cache_info()
    assert info.misses - before <= 1
    assert info.hits >= 1


def test_bluestein_runs_two_pow2_ffts_per_call(monkeypatch):
    """The cached filter spectrum removes one of the three naive FFTs."""
    counter = _CountingKernel(plan_mod.fft_kernel_c2c)
    monkeypatch.setattr(plan_mod, "_kernel_fft", counter)
    bluestein_fft(rand_complex((3, 51)))      # fresh shape -> fresh trace
    assert counter.calls == 2
    plan = plan_for_length(51)
    assert plan.algorithm == "bluestein"
    assert plan.passes == 2 * plan_for_length(128).passes + 1


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(logn=st.integers(3, 10), seed=st.integers(0, 2**31 - 1))
def test_property_parseval(logn, seed):
    """sum |x|^2 == sum |X|^2 / N (energy conservation)."""
    n = 2**logn
    x = rand_complex((n,), key=jax.random.PRNGKey(seed))
    X = fft(x)
    np.testing.assert_allclose(jnp.sum(jnp.abs(x) ** 2),
                               jnp.sum(jnp.abs(X) ** 2) / n, rtol=1e-3)


@settings(deadline=None, max_examples=20)
@given(logn=st.integers(2, 9), seed=st.integers(0, 2**31 - 1),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_property_linearity(logn, seed, a, b):
    n = 2**logn
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x, y = rand_complex((n,), k1), rand_complex((n,), k2)
    np.testing.assert_allclose(fft(a * x + b * y), a * fft(x) + b * fft(y),
                               rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=10)
@given(logn=st.integers(3, 8), shift=st.integers(1, 7))
def test_property_time_shift(logn, shift):
    """Circular time shift <-> linear phase in frequency."""
    n = 2**logn
    x = rand_complex((n,))
    X = fft(x)
    Xs = fft(jnp.roll(x, -shift))
    phase = jnp.exp(2j * jnp.pi * shift * jnp.arange(n) / n)
    np.testing.assert_allclose(Xs, X * phase, rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=15)
@given(logn=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_property_rfft_is_half_spectrum(logn, seed):
    """rfft(x) == fft(x)[:n/2+1] for real x (Hermitian symmetry), and
    irfft inverts it — across lengths and seeds."""
    n = 2**logn
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    X = rfft(x)
    np.testing.assert_allclose(X, fft(x)[: n // 2 + 1], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(irfft(X), x, rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=10)
@given(logn=st.integers(3, 9), seed=st.integers(0, 2**31 - 1))
def test_property_mixed_radix_schedules_agree(logn, seed):
    """All radix schedules are numerically interchangeable."""
    n = 2**logn
    x = rand_complex((n,), key=jax.random.PRNGKey(seed))
    base = _stockham_pow2(x, radices=(2,))
    for radices in ((4, 2), (8, 4, 2)):
        np.testing.assert_allclose(_stockham_pow2(x, radices=radices), base,
                                   rtol=2e-3, atol=2e-3)


@settings(deadline=None, max_examples=10)
@given(logn=st.integers(4, 10), seed=st.integers(0, 2**31 - 1))
def test_property_impulse_is_flat(logn, seed):
    """FFT of a delta is a flat spectrum (magnitude 1 everywhere)."""
    n = 2**logn
    pos = seed % n
    x = jnp.zeros(n, jnp.complex64).at[pos].set(1.0)
    np.testing.assert_allclose(jnp.abs(fft(x)), jnp.ones(n), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Pulsar pipeline
# ---------------------------------------------------------------------------

def test_power_spectrum_and_stats():
    x = rand_complex((3, 256))
    X = fft(x)
    p = power_spectrum(X)
    assert p.shape == (3, 256)
    assert bool(jnp.all(p >= 0))
    mean, std = spectrum_stats(p)
    assert mean.shape == (3, 1) and std.shape == (3, 1)


def test_harmonic_sum_levels():
    p = jnp.ones((2, 128))
    hs = harmonic_sum(p, 8)
    assert hs.shape == (2, 4, 128)        # h = 1, 2, 4, 8
    # On a flat spectrum (away from the clipped tail) S_h = h.
    np.testing.assert_allclose(hs[:, 0, 1:16], 1.0)
    np.testing.assert_allclose(hs[:, 3, 1:16], 8.0)


def test_pipeline_finds_injected_pulsar():
    """A periodic signal must produce a high-S/N candidate at its bin."""
    n = 4096
    t = jnp.arange(n, dtype=jnp.float32)
    f0 = 128 / n                               # bin 128 fundamental
    key = jax.random.PRNGKey(1)
    noise = jax.random.normal(key, (1, n))
    # A pulse train has power in the fundamental AND its harmonics.
    signal = (jnp.sin(2 * jnp.pi * f0 * t) > 0.95).astype(jnp.float32)
    x = noise + 4.0 * signal[None, :]
    snr = pulsar_pipeline(x, n_harmonics=8)
    assert snr.shape == (1, 4, n)
    assert float(snr[0, :, 128].max()) > 8.0   # strong detection
    # and harmonic summing must help for a pulse train:
    assert float(snr[0, 1:, 128].max()) >= float(snr[0, 0, 128]) - 1.0


def test_pipeline_real_input_r2c_path():
    """The R2C pipeline finds the same pulsar in half the spectrum."""
    n = 4096
    t = jnp.arange(n, dtype=jnp.float32)
    f0 = 128 / n
    noise = jax.random.normal(jax.random.PRNGKey(1), (1, n))
    signal = (jnp.sin(2 * jnp.pi * f0 * t) > 0.95).astype(jnp.float32)
    x = noise + 4.0 * signal[None, :]
    snr = pulsar_pipeline(x, n_harmonics=8, real_input=True)
    assert snr.shape == (1, 4, n // 2 + 1)     # half-spectrum bins
    assert float(snr[0, :, 128].max()) > 8.0   # same detection, half the work


def test_stage_profiles_real_input_cheaper():
    """R2C accounting: the real-input pipeline moves less and flops less."""
    from repro.core.hardware import TESLA_V100
    c2c = stage_profiles(PipelineShape(batch=32, n=2**20), TESLA_V100)
    r2c = stage_profiles(PipelineShape(batch=32, n=2**20, real_input=True),
                         TESLA_V100)
    assert r2c[0].flops < 0.7 * c2c[0].flops
    assert r2c[0].t_mem < 0.7 * c2c[0].t_mem
    # downstream stages shrink with the half-spectrum too
    assert sum(p.t_mem for p in r2c[1:]) < 0.7 * sum(p.t_mem for p in c2c[1:])


def test_stage_profiles_fft_dominant_share():
    """Sec. 5.3: with 2 harmonics the FFT is ~60% of pipeline time."""
    from repro.core.hardware import TESLA_V100
    from repro.fft.pipeline import fft_time_share
    share = fft_time_share(PipelineShape(batch=32, n=2**20, n_harmonics=2),
                           TESLA_V100)
    assert 0.35 <= share <= 0.85
