"""Launch + analysis layer tests: spec fixing, HLO cost model, roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import RooflineTerms, model_flops_for
from repro.configs import ARCHS, get_shape


@pytest.fixture(scope="module")
def mesh():
    # single-device "mesh" shaped (1, 1) still exercises the spec logic
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Axis-size-only stand-in so divisibility logic can test 16x16."""
    def __init__(self, shape): self.shape = shape
    @property
    def axis_names(self): return tuple(self.shape)


class TestFixSharding:
    def setup_method(self):
        from repro.launch.specs import fix_sharding
        self.fix = fix_sharding
        self.mesh = FakeMesh({"data": 16, "model": 16})

    def test_divisible_kept(self):
        assert self.fix((64, 32), P("data", "model"), self.mesh) \
            == P("data", "model")

    def test_small_dim_axis_moves_to_seq(self):
        # kv=2 cannot take the 16-way model axis; seq (32768) absorbs it
        got = self.fix((24, 128, 32768, 2, 64),
                       P(None, "data", None, "model", None), self.mesh)
        assert got == P(None, "data", "model")

    def test_uneven_vocab_moved(self):
        # 50280 % 16 != 0 -> model axis moves to the d dim (1024 % 256 == 0)
        got = self.fix((50280, 1024), P("model", "data"), self.mesh)
        assert got == P(None, ("data", "model"))

    def test_batch_one_dropped(self):
        got = self.fix((1, 524288, 64), P("data", None, "model"),
                       self.mesh)
        # batch axis cannot shard dim of size 1; moved to seq
        assert got[0] is None or got[0] == ()

    def test_axis_never_duplicated(self):
        got = self.fix((16, 16), P(("data", "model"), "model"), self.mesh)
        flat = []
        for e in got:
            if e is None:
                continue
            flat.extend([e] if isinstance(e, str) else list(e))
        assert len(flat) == len(set(flat))


class TestHloAnalyzer:
    def test_scan_trip_count_multiplication(self):
        w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        xs = jax.ShapeDtypeStruct((24, 8, 32), jnp.float32)

        def f(xs, w):
            def body(c, x):
                return c @ w + x @ w, None
            out, _ = jax.lax.scan(body, xs[0], xs)
            return out

        compiled = jax.jit(f).lower(xs, w).compile()
        a = analyze_hlo(compiled.as_text())
        want = 24 * 2 * 2 * 8 * 32 * 32          # 24 iters x 2 dots
        assert a["flops"] == pytest.approx(want, rel=0.01)

    def test_collectives_counted(self):
        # without collectives -> zero
        f = jax.jit(lambda x: x @ x)
        compiled = f.lower(jax.ShapeDtypeStruct((64, 64),
                                                jnp.float32)).compile()
        a = analyze_hlo(compiled.as_text())
        assert a["collective_bytes"] == 0.0
        assert a["flops"] == pytest.approx(2 * 64**3, rel=0.01)

    def test_bytes_positive(self):
        f = jax.jit(lambda x: jnp.sum(x * 2.0))
        compiled = f.lower(jax.ShapeDtypeStruct((1024,),
                                                jnp.float32)).compile()
        a = analyze_hlo(compiled.as_text())
        assert a["bytes"] >= 1024 * 4


class TestRoofline:
    def test_terms_and_bound(self):
        t = RooflineTerms(
            arch="x", shape="train_4k", mesh="16x16", chips=256,
            hlo_flops=1.97e14,            # exactly 1 s of compute
            hbm_bytes=819e9 * 0.5,        # 0.5 s of memory
            collective_bytes=50e9 * 0.25, # 0.25 s of collective
            model_flops=1.97e14 * 256 * 0.5,
        )
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(0.5)
        assert t.collective_s == pytest.approx(0.25)
        assert t.bound == "compute"
        assert t.useful_ratio == pytest.approx(0.5)
        assert t.roofline_fraction == pytest.approx(0.5)

    def test_model_flops_train_vs_decode(self):
        cfg = ARCHS["qwen2-0.5b"]
        tr = model_flops_for(cfg, get_shape("train_4k"))
        de = model_flops_for(cfg, get_shape("decode_32k"))
        n = cfg.param_count()
        assert tr == pytest.approx(6 * n * 256 * 4096)
        assert de == pytest.approx(2 * n * 128)

    def test_moe_uses_active_params(self):
        cfg = ARCHS["dbrx-132b"]
        assert cfg.active_param_count() < 0.45 * cfg.param_count()
        tr = model_flops_for(cfg, get_shape("train_4k"))
        assert tr == pytest.approx(6 * cfg.active_param_count() * 256 * 4096)


class TestMeshAndSpecs:
    def test_mesh_shapes(self):
        # make_mesh(512 devices) only works in the dryrun env; check the
        # shape arithmetic instead.
        from repro.launch.mesh import make_production_mesh
        n = jax.device_count()
        if n == 512:
            m = make_production_mesh(multi_pod=True)
            assert m.devices.shape == (2, 16, 16)

    def test_param_count_sanity(self):
        """Published parameter counts within ~20% for named archs."""
        approx = {
            "qwen2-0.5b": 0.5e9, "codeqwen1.5-7b": 7.3e9,
            "qwen1.5-4b": 4e9, "gemma3-12b": 12e9,
            "musicgen-medium": 1.5e9, "dbrx-132b": 132e9,
            "deepseek-v2-lite-16b": 16e9, "mamba2-370m": 0.37e9,
            "pixtral-12b": 12e9, "zamba2-1.2b": 1.2e9,
        }
        for name, want in approx.items():
            got = ARCHS[name].param_count()
            assert 0.6 * want < got < 1.6 * want, (name, got, want)
