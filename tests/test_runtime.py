"""Training substrate tests: optimizer, data, checkpoint, fault tolerance,
elastic re-mesh, end-to-end loss-goes-down."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.synthetic import SyntheticTokens, synthetic_batches
from repro.models import build_model
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import elastic_remesh_plan
from repro.runtime.fault import FaultTolerantDriver, StragglerMonitor
from repro.train.step import init_train_state, make_train_step


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
        params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0)) == 0.0
    assert float(cosine_schedule(100)) == pytest.approx(3e-4, rel=1e-3)
    assert float(cosine_schedule(10000)) == pytest.approx(3e-5, rel=1e-3)


def test_synthetic_data_deterministic_and_sharded():
    ds = SyntheticTokens(vocab=100, seq_len=16, global_batch=8)
    a = ds.batch(3, host_id=0, n_hosts=2)
    b = ds.batch(3, host_id=0, n_hosts=2)
    c = ds.batch(3, host_id=1, n_hosts=2)
    np.testing.assert_array_equal(a, b)          # deterministic
    assert a.shape == (4, 17)
    assert not np.array_equal(a, c)              # host shards differ
    assert a.max() < 100


def test_train_loop_loss_decreases():
    """A few steps on the tiny qwen2 must reduce loss on a fixed motif."""
    cfg = ARCHS["qwen2-0.5b"].reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, peak_lr=1e-2))
    losses = []
    for i, (inp, lab) in enumerate(
            synthetic_batches(cfg.vocab, 32, 4, 30, seed=7)):
        state, m = step(state, jnp.asarray(inp), jnp.asarray(lab))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_microbatched_step_matches_full_batch():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    model = build_model(cfg)
    state1 = init_train_state(model, jax.random.PRNGKey(0))
    state2 = init_train_state(model, jax.random.PRNGKey(0))
    inp = jnp.asarray(SyntheticTokens(cfg.vocab, 16, 4).batch(0))
    x, y = inp[:, :-1], inp[:, 1:]
    s1, m1 = jax.jit(make_train_step(model))(state1, x, y)
    s2, m2 = jax.jit(make_train_step(model, microbatches=2))(state2, x, y)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": [jnp.ones(4), {"c": jnp.zeros((2, 2))}]}
        mgr.save(5, tree)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        out = mgr.restore(like)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                     tree, out)

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.ones(3)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.latest_step() == 4
        dirs = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert len(dirs) == 2                    # retention enforced

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, n_hosts=2)
        tree = {"w": jnp.ones(3)}
        mgr.save(1, tree)                         # host 0 only -> incomplete
        assert mgr.latest_step() is None


class TestFaultTolerance:
    def _setup(self, tmp_path):
        cfg = ARCHS["qwen2-0.5b"].reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model))
        ds = SyntheticTokens(cfg.vocab, 16, 4, seed=3)

        def data(i):
            b = jnp.asarray(ds.batch(i))
            return b[:, :-1], b[:, 1:]

        return model, state, step, data

    def test_driver_survives_failures(self, tmp_path):
        model, state, step, data = self._setup(tmp_path)
        driver = FaultTolerantDriver(
            train_step=step, state=state, data_iter_fn=data,
            ckpt=CheckpointManager(str(tmp_path)), ckpt_every=5,
            fail_at={7: 0, 13: 1},
        )
        final, log, restarts = driver.run(20)
        assert restarts == 2
        assert int(final.step) == 20
        steps_run = [m["step"] for m in log]
        assert steps_run[-1] == 19
        # Replayed steps were truncated on restore: each step appears
        # exactly once, in order, despite two restarts.
        assert steps_run == list(range(20))

    def test_restart_is_deterministic(self, tmp_path):
        """Replayed steps produce the same loss (pure-function data)."""
        model, state, step, data = self._setup(tmp_path)
        d1 = FaultTolerantDriver(step, state, data,
                                 CheckpointManager(str(tmp_path / "a")),
                                 ckpt_every=5, fail_at={7: 0})
        _, log1, _ = d1.run(10)
        model2, state2, step2, data2 = self._setup(tmp_path)
        d2 = FaultTolerantDriver(step2, state2, data2,
                                 CheckpointManager(str(tmp_path / "b")),
                                 ckpt_every=5)
        _, log2, _ = d2.run(10)
        by_step1 = {m["step"]: m["loss"] for m in log1}
        by_step2 = {m["step"]: m["loss"] for m in log2}
        for s in by_step2:
            assert float(by_step1[s]) == pytest.approx(float(by_step2[s]),
                                                       rel=1e-4)


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4, factor=1.5)
    times = np.array([1.0, 1.0, 1.0, 3.0])
    for _ in range(5):
        flagged = mon.observe(times)
    assert flagged == [3]
    assign = mon.shard_assignment(step=0, excluded=[3])
    total = sorted(s for v in assign.values() for s in v)
    assert total == [0, 1, 2, 3]                 # every shard still owned


def test_straggler_shards_split_half_and_half():
    """A flagged host keeps ceil(half) of its shards; the rest move to the
    fastest healthy host — for every step, not on alternating steps."""
    mon = StragglerMonitor(n_hosts=4, factor=1.5, shards_per_host=4)
    times = np.array([1.0, 0.5, 1.0, 3.0])
    for _ in range(5):
        flagged = mon.observe(times)
    assert flagged == [3]
    for step in range(4):                        # no step-parity coin flip
        assign = mon.shard_assignment(step=step, excluded=[3])
        assert assign[3] == [12, 13]             # straggler keeps half
        assert assign[1] == [4, 5, 6, 7, 14, 15]  # fastest host absorbs rest
        total = sorted(s for v in assign.values() for s in v)
        assert total == list(range(16))          # every shard still owned


def test_straggler_all_flagged_no_reassignment():
    mon = StragglerMonitor(n_hosts=2, shards_per_host=2)
    mon.observe(np.array([1.0, 1.0]))
    assign = mon.shard_assignment(step=0, excluded=[0, 1])
    assert assign == {0: [0, 1], 1: [2, 3]}


class TestElastic:
    def test_shrink_data_axis(self):
        plan = elastic_remesh_plan((16, 16), ("data", "model"), n_failed=3)
        assert plan.new_mesh == (15, 16)
        assert plan.microbatch_multiplier == 2
        assert 0.9 <= plan.throughput_fraction / (15 / 16) <= 1.01

    def test_pod_loss_folds_pod_axis(self):
        plan = elastic_remesh_plan((2, 16, 16), ("pod", "data", "model"),
                                   n_failed=16)
        assert plan.new_mesh[0] == 1
        assert plan.throughput_fraction < 1.0

    def test_model_axis_never_shrinks(self):
        plan = elastic_remesh_plan((16, 16), ("data", "model"), n_failed=20)
        assert plan.new_mesh[1] == 16

    def test_too_many_failures_raise(self):
        with pytest.raises(ValueError):
            elastic_remesh_plan((2, 4), ("data", "model"), n_failed=8)
