"""Crash recovery: exactly-once receipts, bit-identical replay,
snapshot/restore of durable service state, host-level fault domains and
the FaultPlan arrival seam (docs/recovery.md)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hardware import TPU_V5E
from repro.obs.ledger import launches_digest
from repro.power import FleetTelemetry
from repro.power.governor import PowerGovernor
from repro.runtime.faults import (KILL_HOST, OPEN, FaultEvent, FaultPlan,
                                  HostTopology)
from repro.runtime.journal import (ADMIT, SERVED, SHED, JournalRecord,
                                   RequestJournal, read_journal)
from repro.runtime.journal import OPEN as J_OPEN
from repro.serving import FFTService, ReplayResult, replay_journal
from repro.serving.recovery import (ServiceSnapshot, governor_state,
                                    restore_governor)

KEY = jax.random.PRNGKey(7)


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


class FakeTimer:
    def __init__(self, dt=0.0, t0=0.0):
        self.t = t0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


PAYLOADS = {i: rand_complex((2, 256), jax.random.PRNGKey(100 + i))
            for i in range(8)}


def payload_fn(ref, meta):
    return PAYLOADS[ref]


def service(journal=None, n_workers=2, **kw):
    return FFTService(TPU_V5E, devices=[None] * n_workers,
                      timer=FakeTimer(), keep_results=False,
                      journal=journal, **kw)


def recover(journal_dir, n_workers=2, **kw):
    kw.setdefault("payload_fn", payload_fn)
    return FFTService.recover(journal_dir, devices=[None] * n_workers,
                              timer=FakeTimer(), keep_results=False, **kw)


def submit_refs(svc, refs):
    for i in refs:
        svc.submit(PAYLOADS[i], payload_ref=i)


# ---------------------------------------------------------------------------
# exactly-once receipts across a crash
# ---------------------------------------------------------------------------

def test_exactly_once_receipts_across_crash(tmp_path):
    jdir = str(tmp_path / "j")
    svc = service(RequestJournal(jdir))
    submit_refs(svc, range(4))
    first = svc.drain()
    assert len(first) == 4
    svc.snapshot()
    submit_refs(svc, range(4, 6))        # admitted, never drained
    svc.journal.crash()                  # kill -9 mid-wave

    svc2 = recover(jdir)
    # Terminated work replays; in-flight work re-enqueues.
    assert len(svc2.recovered_receipts) == 4
    assert all(r.recovered and r.incarnation == "i2"
               for r in svc2.recovered_receipts)
    assert svc2.replay.admits_total == 6
    assert [req.jseq for req in svc2._pending] == svc2.replay.open_admits
    assert len(svc2._pending) == 2
    second = svc2.drain()
    assert len(second) == 2
    assert {r.request.payload_ref for r in second} == {4, 5}
    svc2.journal.close()

    # The durable log proves it: 6 admits, 6 terminals, no dups, no opens.
    audit = ReplayResult(retain=0)
    _, stats = read_journal(jdir, sink=audit.feed)
    assert stats.invalid == 0
    assert audit.admits_total == 6 and audit.terminals_total == 6
    assert audit.open_admits == [] and audit.duplicate_terminals == 0
    assert audit.duplicate_rate == 0.0


def test_replayed_receipts_bit_identical(tmp_path):
    jdir = str(tmp_path / "j")
    svc = service(RequestJournal(jdir))
    submit_refs(svc, range(4))
    originals = {r.request.payload_ref: r for r in svc.drain()}
    svc.snapshot()
    svc.journal.crash()

    svc2 = recover(jdir)
    assert len(svc2.recovered_receipts) == 4
    for rep in svc2.recovered_receipts:
        orig = originals[rep.request.payload_ref]
        for f in ("status", "reason", "rung", "retries", "batch_id",
                  "worker", "clock_mhz", "modelled_time_s", "energy_j",
                  "boost_energy_j", "realtime_margin"):
            assert getattr(rep, f) == getattr(orig, f), f
        assert rep.launches == orig.launches       # ledger-replayed
        assert rep.recovered and not orig.recovered
        # receipt_for_seq finds the replayed receipt by durable identity.
        assert svc2.receipt_for_seq(orig.request.jseq) is rep
    svc2.journal.close()


def test_recovery_without_payload_fn_sheds_explicitly(tmp_path):
    jdir = str(tmp_path / "j")
    svc = service(RequestJournal(jdir))
    submit_refs(svc, range(2))
    svc.journal.crash()
    svc2 = recover(jdir, payload_fn=None)
    sheds = [r for r in svc2.receipts
             if r.reason == "recovery:payload-unresolvable"]
    assert len(sheds) == 2 and all(r.status == "shed" for r in sheds)
    assert svc2._pending == []
    svc2.journal.close()
    # Those sheds are terminal records too — exactly-once still holds.
    audit = ReplayResult(retain=0)
    read_journal(jdir, sink=audit.feed)
    assert audit.terminals_total == 2 and audit.open_admits == []


def test_double_crash_replays_once_per_request(tmp_path):
    jdir = str(tmp_path / "j")
    svc = service(RequestJournal(jdir))
    submit_refs(svc, range(2))
    svc.drain()
    svc.snapshot()
    svc.journal.crash()
    svc2 = recover(jdir)
    submit_refs(svc2, range(2, 4))
    svc2.drain()
    svc2.journal.crash()                 # crash again before a snapshot
    svc3 = recover(jdir)
    assert svc3.journal.incarnation == "i3"
    assert len(svc3.recovered_receipts) == 4
    refs = sorted(r.request.payload_ref for r in svc3.recovered_receipts)
    assert refs == [0, 1, 2, 3]
    svc3.journal.close()
    audit = ReplayResult(retain=0)
    read_journal(jdir, sink=audit.feed)
    assert audit.admits_total == audit.terminals_total == 4
    assert audit.duplicate_terminals == 0 and audit.incarnations == 3


# ---------------------------------------------------------------------------
# warm-cache recovery reproduces the uncrashed launches digest
# ---------------------------------------------------------------------------

def test_recovered_service_matches_uncrashed_launches_digest(tmp_path):
    def run(crash):
        jdir = str(tmp_path / ("crash" if crash else "clean"))
        svc = service(RequestJournal(jdir))
        submit_refs(svc, range(4))
        receipts = list(svc.drain())
        if crash:
            svc.snapshot()
            svc.journal.crash()
            svc = recover(jdir)
            assert svc.cache.stats.plan_builds > 0   # warm rebuild ran
        submit_refs(svc, range(4, 8))
        receipts += svc.drain()
        svc.journal.close()
        receipts.sort(key=lambda r: r.request.payload_ref)
        return launches_digest(r.launches for r in receipts), receipts

    d_clean, r_clean = run(crash=False)
    d_crash, r_crash = run(crash=True)
    assert d_clean == d_crash
    for a, b in zip(r_clean, r_crash):
        assert (a.status, a.rung, a.reason) == (b.status, b.rung, b.reason)


# ---------------------------------------------------------------------------
# snapshot / restore of durable state
# ---------------------------------------------------------------------------

def test_snapshot_restores_breakers_drift_metrics_and_cache(tmp_path):
    jdir = str(tmp_path / "j")
    tele = FleetTelemetry.for_serving(TPU_V5E, seed=0)
    svc = service(RequestJournal(jdir), telemetry=tele)
    submit_refs(svc, range(3))
    svc.drain()
    br = svc._breaker(1)
    br.state = OPEN
    br.failures = 2
    br.opened_at = 1.5
    br.opens = 3
    dog = tele.watchdog(0)
    dog.health = "degraded"
    dog.unhealthy_entries = 7
    svc.snapshot()
    svc.journal.crash()

    tele2 = FleetTelemetry.for_serving(TPU_V5E, seed=0)
    svc2 = recover(jdir, telemetry=tele2)
    br2 = svc2.breakers[1]
    assert (br2.state, br2.failures, br2.opened_at, br2.opens) == \
        (OPEN, 2, 1.5, 3)
    assert tele2.watchdog(0).health == "degraded"
    assert tele2.watchdog(0).unhealthy_entries == 7
    assert svc2.drift.observations == svc.drift.observations
    # Warm cache: the snapshotted shape keys were rebuilt eagerly.
    assert {k for k, _ in svc2.cache._entries} == \
        {k for k, _ in svc.cache._entries}
    assert svc2.cache.stats.hits == svc.cache.stats.hits
    assert svc2.metrics.render() == svc.metrics.render()
    svc2.journal.close()


def test_governor_state_roundtrip():
    gov = PowerGovernor(TPU_V5E, target_w=100.0,
                        fallback_mhz=TPU_V5E.f_min)
    gov.step(140.0)
    gov.step(None, healthy=False)
    st = governor_state(gov)
    gov2 = PowerGovernor(TPU_V5E, target_w=50.0,
                         fallback_mhz=TPU_V5E.f_min)
    restore_governor(gov2, st)
    for f in ("f_mhz", "integral_w", "mode", "ticks", "moves",
              "fallback_engagements", "target_w"):
        assert getattr(gov2, f) == getattr(gov, f), f


def test_snapshot_requires_journal():
    svc = service(journal=None)
    with pytest.raises(ValueError, match="journal"):
        svc.snapshot()


# ---------------------------------------------------------------------------
# host-level fault domains
# ---------------------------------------------------------------------------

def test_host_topology_partitions_workers():
    topo = HostTopology(8, devices_per_host=4)
    assert topo.n_hosts == 2
    assert topo.host_of(3) == 0 and topo.host_of(4) == 1
    assert topo.workers_of(1) == (4, 5, 6, 7)


def test_host_kill_trips_whole_domain_and_clears_rings(tmp_path):
    topo = HostTopology(4, devices_per_host=2)
    tele = FleetTelemetry.for_serving(TPU_V5E, seed=0)
    plan = FaultPlan([FaultEvent(KILL_HOST)])
    svc = service(n_workers=4, topology=topo, telemetry=tele,
                  fault_plan=plan, sleep_fn=lambda s: None)
    submit_refs(svc, range(4))
    receipts = svc.drain()
    assert svc.host_kills == 1
    assert len(receipts) == 4                    # every request receipted
    # Both workers of the lost host were quarantined at once (breakers
    # tripped straight to open), not one-by-one via failure counting.
    tripped = [w for w, br in svc.breakers.items() if br.opens >= 1]
    assert len(tripped) == 2
    assert topo.host_of(tripped[0]) == topo.host_of(tripped[1])
    # Their telemetry rings were wiped but remember what they had seen.
    for w in tripped:
        ring = tele.rings.get(w)
        if ring is not None and ring.pushed:
            assert len(ring) == 0


def test_host_kill_exhausted_retries_shed_with_host_reason(tmp_path):
    # Three hosts so each retry lands on a live domain: with the frozen
    # FakeTimer a tripped breaker never cools down, so the shed must be
    # reached through three HostLostError catches (attempts 1..3 >
    # max_retries=2), never through the breaker-blocked bounce.
    topo = HostTopology(6, devices_per_host=2)
    plan = FaultPlan([FaultEvent(KILL_HOST),
                      FaultEvent(KILL_HOST), FaultEvent(KILL_HOST)])
    svc = service(n_workers=6, topology=topo, fault_plan=plan,
                  sleep_fn=lambda s: None)
    submit_refs(svc, range(2))
    receipts = svc.drain()
    assert svc.host_kills == 3
    assert all(r.status == "shed" and r.reason == "fault:host-lost"
               for r in receipts)
    assert len(receipts) == 2


# ---------------------------------------------------------------------------
# FaultPlan arrival seam (crash events do not perturb the seeded draws)
# ---------------------------------------------------------------------------

def test_fault_plan_arrival_seam_bit_identical():
    base = FaultPlan.generate(11, n_batches=50)
    seamed = FaultPlan.generate(11, n_batches=50,
                                crash_arrivals=(10, 20),
                                host_kill_batches=(7,))
    extras = [e for e in seamed.events
              if e.kind in ("crash-process", "kill-host")
              and (e.arrival in (10, 20) or e.batch_id == 7)]
    assert len(extras) == 3
    trimmed = [e for e in seamed.events if e not in extras]
    assert [(e.kind, e.batch_id, e.worker, e.arrival) for e in trimmed] \
        == [(e.kind, e.batch_id, e.worker, e.arrival) for e in base.events]


def test_fault_plan_take_by_arrival():
    plan = FaultPlan.generate(0, n_batches=4, crash_arrivals=(5,))
    assert plan.take("crash-process", arrival=4) is None
    assert plan.take("crash-process", arrival=5) is not None
    assert plan.take("crash-process", arrival=5) is None    # one-shot


# ---------------------------------------------------------------------------
# ReplayResult folding (dedup, windows, guarded ratios)
# ---------------------------------------------------------------------------

def term(seq, rseq, status="served", reason=None, rtype=SERVED):
    return JournalRecord(seq=seq, type=rtype,
                         data={"rseq": rseq, "status": status,
                               "reason": reason})


def test_replay_dedup_first_terminal_wins():
    recs = [
        JournalRecord(0, J_OPEN, {"incarnation": "i1"}),
        JournalRecord(1, ADMIT, {"payload_ref": 0}),
        JournalRecord(2, ADMIT, {"payload_ref": 1}),
        term(3, 1, status="served"),
        term(4, 1, status="shed", reason="fault:host-lost", rtype=SHED),
        term(5, 99),                     # terminal for an unknown admit
        term(6, 2, status="shed", reason="fault:host-lost", rtype=SHED),
    ]
    rep = replay_journal(recs)
    assert rep.admits_total == 2 and rep.terminals_total == 2
    assert rep.duplicate_terminals == 1
    assert rep.terminals[1]["status"] == "served"    # first one won
    assert rep.open_admits == []
    assert rep.served == 1 and rep.fault_shed == 1
    assert rep.availability == 0.5
    assert rep.duplicate_rate == pytest.approx(1 / 3)
    assert rep.incarnations == 1


def test_replay_retain_window_keeps_newest():
    recs = [JournalRecord(i, ADMIT, {"payload_ref": i}) for i in range(5)]
    recs += [term(5 + i, i) for i in range(5)]
    rep = replay_journal(recs, retain=2)
    assert rep.terminals_total == 5
    assert sorted(rep.terminals) == [3, 4]           # newest two payloads
    zero = replay_journal(recs, retain=0)
    assert zero.terminals == {} and zero.terminals_total == 5


def test_empty_journal_guarded_conventions():
    rep = ReplayResult()
    assert rep.availability == 1.0
    assert rep.duplicate_rate == 0.0
    assert rep.open_admits == []


def test_replay_tracks_open_admits_in_admit_order():
    recs = [JournalRecord(i, ADMIT, {"payload_ref": i}) for i in range(4)]
    recs.append(term(4, 1))
    rep = replay_journal(recs)
    assert rep.open_admits == [0, 2, 3]
    assert rep.open_admit_data[2]["payload_ref"] == 2
