"""Serving runtime: coalescing, plan/sweep caching, work stealing,
end-to-end correctness against the single-device oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dvfs
from repro.core.hardware import TESLA_V100, TPU_V5E
from repro.core.scheduler import ClockController
from repro.core.workloads import COMPLEX_BYTES
from repro.fft.plan import plan_for_length
from repro.runtime.workqueue import WorkStealingQueue
from repro.serving import FFTService, FFTRequest, coalesce

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def requests(sizes, n):
    return [FFTRequest(x=rand_complex((b, n), jax.random.PRNGKey(i)))
            for i, b in enumerate(sizes)]


# ---------------------------------------------------------------------------
# batch coalescing (Eq. 6 memory budget)
# ---------------------------------------------------------------------------

def test_coalescing_respects_memory_budget():
    n = 256
    budget = 8 * n * COMPLEX_BYTES["fp32"]        # room for 8 transforms
    reqs = requests([3, 3, 3, 3, 3], n)           # 15 transforms total
    batches = coalesce(reqs, device_name="d", batch_bytes=budget)
    assert sum(b.n_transforms for b in batches) == 15
    for b in batches:
        assert b.bytes <= budget
    # FIFO order preserved across the split
    flat = [r.request_id for b in batches for r in b.requests]
    assert flat == [r.request_id for r in reqs]


def test_coalescing_never_mixes_shapes():
    reqs = requests([2, 2], 256) + requests([2], 512)
    batches = coalesce(reqs, device_name="d", batch_bytes=1e9)
    assert len(batches) == 2
    assert {b.key.n for b in batches} == {256, 512}


def test_oversized_single_request_gets_own_batch():
    n = 256
    budget = 4 * n * COMPLEX_BYTES["fp32"]
    reqs = requests([2, 10, 2], n)                # middle one exceeds budget
    batches = coalesce(reqs, device_name="d", batch_bytes=budget)
    # the oversized request is not split, and not merged with others
    oversized = [b for b in batches if b.n_transforms > 4]
    assert len(oversized) == 1 and len(oversized[0].requests) == 1


def test_strictest_latency_budget_governs_batch():
    n = 128
    reqs = requests([1, 1, 1], n)
    reqs[1].latency_budget = 0.30
    reqs[2].latency_budget = 0.05
    (batch,) = coalesce(reqs, device_name="d", batch_bytes=1e9)
    assert batch.latency_budget == pytest.approx(0.05)


# ---------------------------------------------------------------------------
# plan + sweep cache (call counting)
# ---------------------------------------------------------------------------

def test_cache_hits_skip_recomputation():
    plan_calls, sweep_calls = [], []

    def counting_plan(n):
        plan_calls.append(n)
        return plan_for_length(n)

    def counting_sweep(profile, device, power_model=None, **kw):
        sweep_calls.append(profile.name)
        return dvfs.sweep(profile, device, power_model, **kw)

    svc = FFTService(TPU_V5E, plan_fn=counting_plan, sweep_fn=counting_sweep)
    for wave in range(3):                          # repeated-shape stream
        for i in range(4):
            svc.submit(rand_complex((2, 512), jax.random.PRNGKey(wave * 4 + i)))
        svc.drain()
    # one plan build and one sweep ever, despite 12 requests / 3 drains
    assert plan_calls == [512]
    assert len(sweep_calls) == 1
    stats = svc.cache.stats
    assert stats.misses == 1 and stats.hits >= 2
    assert stats.sweeps == 1 and stats.plan_builds == 1


def test_budget_reselects_from_cached_sweep_without_resweep():
    sweep_calls = []

    def counting_sweep(profile, device, power_model=None, **kw):
        sweep_calls.append(profile.name)
        return dvfs.sweep(profile, device, power_model, **kw)

    # N=8192 on the V100: the unconstrained optimum carries a small positive
    # slowdown, so a zero budget must select a higher clock.  Separate
    # drains put the two requests in separate batches (a batch runs at its
    # strictest member budget).
    svc = FFTService(TESLA_V100, sweep_fn=counting_sweep)
    tight = svc.submit(rand_complex((2, 8192)), latency_budget=0.0)
    svc.drain()
    loose = svc.submit(rand_complex((2, 8192), jax.random.PRNGKey(9)),
                       latency_budget=2.0)
    svc.drain()
    assert len(sweep_calls) == 1                  # same shape: one sweep
    rt, rl = svc.receipt(tight), svc.receipt(loose)
    assert rt.clock_mhz > rl.clock_mhz
    entry = svc.cache.entry(tight.shape_key(TESLA_V100.name))
    pt = entry.sweep.at(rt.clock_mhz)
    assert pt.time / entry.sweep.boost.time - 1.0 <= 1e-9


def test_service_default_budget_not_relaxed_by_loose_neighbour():
    """A coalesced request with a loose explicit budget must not strip the
    service-default guarantee from a budget-less neighbour."""
    svc = FFTService(TESLA_V100, time_budget=0.0)
    a = svc.submit(rand_complex((1, 8192)))              # service default
    svc.submit(rand_complex((1, 8192), jax.random.PRNGKey(2)),
               latency_budget=2.0)                       # same batch, loose
    svc.drain()
    ra = svc.receipt(a)
    entry = svc.cache.entry(a.shape_key(TESLA_V100.name))
    pt = entry.sweep.at(ra.clock_mhz)
    assert pt.time / entry.sweep.boost.time - 1.0 <= 1e-9


def test_sweep_optimal_under_budget_monotone():
    from repro.core.workloads import FFTCase, fft_workload
    res = dvfs.sweep(fft_workload(FFTCase(n=2**14), TESLA_V100), TESLA_V100)
    clocks = [res.optimal_under_budget(b).f for b in (0.0, 0.02, 0.10, None)]
    assert clocks == sorted(clocks, reverse=True)
    assert res.optimal_under_budget(None).f == res.optimal.f


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------

def test_work_stealing_balances_queues():
    q = WorkStealingQueue(2)
    for i in range(4):
        q.push(0, f"job{i}")                      # all work on worker 0
    got = [q.pop(1), q.pop(1)]                    # worker 1 must steal
    assert q.steals == 2
    assert got == ["job3", "job2"]                # thief takes from the back
    assert q.pop(0) == "job0"                     # owner pops FIFO
    assert q.pop(0) == "job1"
    assert q.pop(0) is None and q.pending() == 0


def test_push_least_loaded_round_robins():
    q = WorkStealingQueue(3)
    workers = [q.push_least_loaded(i) for i in range(6)]
    assert sorted(workers) == [0, 0, 1, 1, 2, 2]
    assert q.lengths() == [2, 2, 2]


# ---------------------------------------------------------------------------
# end-to-end service
# ---------------------------------------------------------------------------

def test_service_results_match_oracle():
    svc = FFTService(TPU_V5E)
    payloads = [np.asarray(rand_complex((b, 1024), jax.random.PRNGKey(b)))
                for b in (1, 3, 2)]
    reqs = [svc.submit(p) for p in payloads]
    svc.drain()
    for req, p in zip(reqs, payloads):
        r = svc.receipt(req)
        np.testing.assert_allclose(np.asarray(r.result),
                                   np.fft.fft(p, axis=-1),
                                   rtol=3e-3, atol=3e-3)
        assert r.energy_j > 0 and r.boost_energy_j >= r.energy_j
        assert r.latency >= 0 and r.clock_mhz <= TPU_V5E.f_max
    rep = svc.report()
    assert rep.n_requests == 3 and rep.n_transforms == 6
    assert rep.n_batches == 1                     # all coalesced
    assert rep.i_ef >= 1.0
    assert rep.p50_latency_s <= rep.p99_latency_s
    assert rep.joules_per_transform > 0


def test_r2c_batches_execute_real_and_pack_double():
    """R2C payloads stack as real arrays (half the device bytes) and the
    Eq. 6 coalescer fits twice as many of them per memory budget."""
    n = 256
    budget = 8 * n * COMPLEX_BYTES["fp32"]        # 8 complex transforms
    xr = jax.random.normal(KEY, (4, n))
    reqs_c = [FFTRequest(x=rand_complex((4, n))) for _ in range(4)]
    reqs_r = [FFTRequest(x=xr, transform="r2c") for _ in range(4)]
    b_c = coalesce(reqs_c, device_name="d", batch_bytes=budget)
    b_r = coalesce(reqs_r, device_name="d", batch_bytes=budget)
    assert len(b_c) == 2 and len(b_r) == 1        # 16 real transforms fit
    assert b_r[0].bytes == b_c[0].bytes           # same footprint, 2x work
    # and the executor stacks the r2c batch as a real array
    svc = FFTService(TPU_V5E)
    svc.submit(xr, transform="r2c")
    stacked = svc._stack(coalesce(svc._pending, device_name=TPU_V5E.name,
                                  batch_bytes=budget)[0])
    assert stacked.dtype == jnp.float32


def test_service_r2c_requests_halve_energy():
    """R2C requests serve through their own plan/sweep cache entry and
    cost about half the modelled energy of C2C at the same length."""
    n = 1024
    svc = FFTService(TPU_V5E)
    xr = jax.random.normal(KEY, (4, n))
    rc = svc.submit(xr, transform="r2c")
    cc = svc.submit(xr.astype(jnp.complex64))
    svc.drain()
    rec_r, rec_c = svc.receipt(rc), svc.receipt(cc)
    np.testing.assert_allclose(rec_r.result, jnp.fft.rfft(xr),
                               rtol=3e-3, atol=3e-3)
    assert rec_r.request.bytes == rec_c.request.bytes // 2
    assert rec_r.energy_j < 0.7 * rec_c.energy_j
    # distinct transforms must not share a cache entry
    assert len(svc.cache) == 2


def test_service_pulsar_requests():
    """KIND_PULSAR runs the full filterbank pipeline: the receipt's
    result is the packed sifted-candidate array and the receipt carries
    per-stage DVFS shares plus the real-time margin."""
    from repro.data.synthetic import (FilterbankSpec, InjectedPulsar,
                                      synthetic_filterbank)
    from repro.search.pipeline import DispersionPlan
    svc = FFTService(TPU_V5E)
    spec = FilterbankSpec(nchan=8, ntime=512)
    plan = DispersionPlan.from_spec(spec, n_trials=4)
    pulsar = InjectedPulsar(dm=plan.dms[2], k0=90, z=0.0, amp=0.4)
    fb = synthetic_filterbank(spec, (pulsar,), noise=1.0, seed=0)
    req = svc.submit(fb, kind="pulsar", n_harmonics=4, templates=5,
                     dm_trials=4)
    svc.drain()
    r = svc.receipt(req)
    # Packed candidates: (rows, k, 5) = (dm, template, bin, level, snr).
    assert r.result.shape == (1, 16, 5)
    top = np.asarray(r.result)[0, 0]
    assert top[0] == 2                            # the injected DM trial
    assert top[1] == 2                            # z=0 -> centre template
    assert top[2] == 90                           # the injected bin
    assert top[4] > 25.0
    # Per-stage DVFS receipts for all four stages.
    assert [s.name for s in r.stages] == ["dedisp", "fdas",
                                          "harmonic-sum", "sift"]
    assert all(s.clock_mhz > 0 and s.energy_j > 0 for s in r.stages)
    assert r.realtime_margin is not None and r.realtime_margin > 0
    # Plain FFT receipts carry no stage breakdown.
    other = svc.submit(np.asarray(jax.random.normal(KEY, (2, 256)),
                                  dtype=np.complex64))
    svc.drain()
    assert svc.receipt(other).stages is None


def test_clock_controller_pairs_lock_and_reset():
    ctrl = ClockController(TPU_V5E)
    with ctrl.locked(800.0):
        assert ctrl.current_f == 800.0
        with ctrl.locked(600.0):                  # nested lock restores outer
            assert ctrl.current_f == 600.0
        assert ctrl.current_f == 800.0
    assert ctrl.current_f == TPU_V5E.f_max
    assert ctrl.lock_count == 2
    actions = [e.action for e in ctrl.events]
    assert actions == ["lock", "lock", "reset", "reset"]


def test_service_clock_locks_bracket_batches():
    svc = FFTService(TPU_V5E)
    svc.submit(rand_complex((1, 256)))
    svc.submit(rand_complex((1, 512), jax.random.PRNGKey(1)))
    svc.drain()
    rep = svc.report()
    assert rep.n_batches == 2
    assert rep.clock_locks == 2                   # one lock/reset per batch
    assert svc.clock.current_f == TPU_V5E.f_max   # always reset after


def test_malformed_payload_rejected_at_submit():
    svc = FFTService(TPU_V5E)
    with pytest.raises(ValueError, match="payload"):
        svc.submit(np.float32(5.0))               # 0-d scalar
    with pytest.raises(ValueError, match="precision"):
        svc.submit(np.zeros((1, 8), np.complex64), precision="fp8")


def test_failed_batch_requeues_unserved_requests():
    svc = FFTService(TPU_V5E)
    ok = svc.submit(rand_complex((1, 128)))
    bad = svc.submit(rand_complex((1, 256), jax.random.PRNGKey(1)))
    boom = RuntimeError("injected device failure")
    real_execute = svc._execute

    def flaky(batch, worker, device):
        if batch.key.n == 256:
            raise boom
        real_execute(batch, worker, device)

    svc._execute = flaky
    with pytest.raises(RuntimeError):
        svc.drain()
    # the healthy request was served; the failed one is re-queued, and no
    # stale batch lingers in the dispatcher
    assert svc.receipt(ok) is not None
    assert svc.receipt(bad) is None
    assert [r.request_id for r in svc._pending] == [bad.request_id]
    assert svc.dispatcher.queue.pending() == 0
    svc._execute = real_execute
    svc.drain()                                   # next cycle serves it
    assert svc.receipt(bad) is not None


def test_receipt_retention_cap_evicts_oldest():
    svc = FFTService(TPU_V5E, max_retained_receipts=3)
    reqs = [svc.submit(rand_complex((1, 64), jax.random.PRNGKey(i)))
            for i in range(5)]
    svc.drain()
    assert len(svc.receipts) == 3
    assert svc.receipt(reqs[0]) is None           # evicted
    assert svc.receipt(reqs[-1]) is not None
    assert svc.report().n_requests == 3           # report covers the window


# ---------------------------------------------------------------------------
# multi-device sharding vs the single-device oracle (subprocess, slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_service_matches_single_device_oracle():
    from test_distributed import run_with_devices
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.hardware import TPU_V5E
        from repro.serving import FFTService

        mesh = jax.make_mesh((4,), ("data",))
        svc = FFTService(TPU_V5E, mesh=mesh)
        key = jax.random.PRNGKey(0)
        # 5 transforms: not divisible by 4 devices -> exercises padding
        x = (jax.random.normal(key, (5, 512)) +
             1j * jax.random.normal(jax.random.PRNGKey(1), (5, 512))
             ).astype(jnp.complex64)
        req = svc.submit(np.asarray(x))
        svc.drain()
        got = np.asarray(svc.receipt(req).result)
        np.testing.assert_allclose(got, np.fft.fft(np.asarray(x), axis=-1),
                                   rtol=2e-3, atol=2e-3)
        print("sharded ok")
    """, n_devices=4)
