"""Fallback no-op stand-ins for ``hypothesis`` decorators.

``hypothesis`` is an optional test dependency (the ``test`` extra in
pyproject.toml).  When it is absent the property-based tests must skip
cleanly instead of failing the whole suite at collection, so test modules
import the real names and fall back to these:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hyp import given, settings, st
"""
import pytest


class _Strategies:
    """Any strategy constructor (st.integers, st.floats, ...) returns None."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


st = _Strategies()


def settings(*args, **kwargs):
    return lambda fn: fn


def given(*args, **kwargs):
    def deco(fn):
        # Replace with a zero-arg placeholder: the original signature's
        # hypothesis-driven parameters would otherwise look like missing
        # pytest fixtures.
        def placeholder():
            pass

        placeholder.__name__ = fn.__name__
        placeholder.__doc__ = fn.__doc__
        return pytest.mark.skip(reason="hypothesis not installed")(placeholder)

    return deco
