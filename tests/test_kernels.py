"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Each kernel is swept over shapes and dtypes per the deliverable contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fft.ops import (MAX_KERNEL_N, fft_kernel_c2c,
                                   fft_kernel_c2r, fft_kernel_r2c)
from repro.kernels.fft.ref import fft_ref, irfft_ref, rfft_ref
from repro.kernels.harmonic_sum.ops import (harmonic_sum_kernel,
                                            harmonic_sum_plane)
from repro.kernels.harmonic_sum.ref import (harmonic_sum_plane_ref,
                                            harmonic_sum_ref)
from repro.kernels.spectrum.ops import power_spectrum_stats_kernel
from repro.kernels.spectrum.ref import power_spectrum_stats_ref

KEY = jax.random.PRNGKey(42)


def rand_c(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


class TestFFTKernel:
    @pytest.mark.parametrize("n", [8, 64, 512, 2048, 8192])
    @pytest.mark.parametrize("batch", [1, 4, 13])
    def test_matches_oracle(self, n, batch):
        x = rand_c((batch, n))
        got = fft_kernel_c2c(x, interpret=True)
        re, im = fft_ref(x.real, x.imag)
        want = re + 1j * im
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_inverse(self):
        x = rand_c((4, 256))
        y = fft_kernel_c2c(fft_kernel_c2c(x, interpret=True),
                           inverse=True, interpret=True)
        np.testing.assert_allclose(y, x, rtol=3e-4, atol=3e-4)

    def test_multidim_batch(self):
        x = rand_c((2, 3, 128))
        got = fft_kernel_c2c(x, interpret=True)
        np.testing.assert_allclose(got, jnp.fft.fft(x), rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_real_input_promoted(self, dtype):
        x = jax.random.normal(KEY, (4, 64)).astype(dtype)
        got = fft_kernel_c2c(x, interpret=True)
        np.testing.assert_allclose(got, jnp.fft.fft(x.astype(jnp.complex64)),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("radices", [(2,), (4, 2), (8, 4, 2)])
    def test_radix_schedules_match_oracle(self, radices):
        """The kernel's specialised r=2/r=4 and generic r=8 butterflies."""
        x = rand_c((4, 1024))
        got = fft_kernel_c2c(x, interpret=True, radices=radices)
        np.testing.assert_allclose(got, jnp.fft.fft(x), rtol=3e-4, atol=3e-4)

    def test_too_long_raises_with_plan_pointer(self):
        x = rand_c((1, 2 * MAX_KERNEL_N))
        with pytest.raises(ValueError, match="repro.fft.plan"):
            fft_kernel_c2c(x, interpret=True)

    def test_n1_forward_inverse_identity(self):
        """Length-1 DFT is the identity BOTH ways (the old inverse branch
        was a silent ``x / 1`` no-op copy standing in for the real path)."""
        x = rand_c((3, 1))
        fwd = fft_kernel_c2c(x, interpret=True)
        inv = fft_kernel_c2c(x, inverse=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(fwd), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(inv), np.asarray(x))
        # parity with the jnp oracle at n=1 (fft == ifft == identity)
        np.testing.assert_allclose(fwd, jnp.fft.fft(x), rtol=1e-6)
        np.testing.assert_allclose(inv, jnp.fft.ifft(x), rtol=1e-6)

    def test_explicit_tile_b_override(self):
        """The autotuner hook: an explicit tile replaces the heuristic and
        stays numerically identical."""
        x = rand_c((12, 256))
        got = fft_kernel_c2c(x, interpret=True, tile_b=4)
        np.testing.assert_allclose(got, jnp.fft.fft(x), rtol=3e-4, atol=3e-4)

    def test_tile_multiple_batch_skips_padding(self, monkeypatch):
        """A tile-multiple batch must not pay the pad-then-slice trip."""
        import repro.kernels.fft.ops as ops
        called = []
        real_pad = jnp.pad
        monkeypatch.setattr(ops.jnp, "pad",
                            lambda *a, **k: called.append(1) or real_pad(*a, **k))
        x = rand_c((8, 256))          # 8 <= tile -> tile=8, pad=0
        got = fft_kernel_c2c(x, interpret=True)
        np.testing.assert_allclose(got, jnp.fft.fft(x), rtol=3e-4, atol=3e-4)
        assert not called


class TestRealFFTKernels:
    @pytest.mark.parametrize("n", [8, 64, 512, 2048, 8192, 2 * MAX_KERNEL_N])
    @pytest.mark.parametrize("batch", [1, 4, 13])
    def test_r2c_matches_oracle(self, n, batch):
        """R2C accepts up to 2*MAX_KERNEL_N (it packs to N/2 complex)."""
        x = jax.random.normal(KEY, (batch, n), jnp.float32)
        got = fft_kernel_r2c(x, interpret=True)
        re, im = rfft_ref(x)
        np.testing.assert_allclose(got, re + 1j * im, rtol=3e-4, atol=2e-3)

    @pytest.mark.parametrize("n", [8, 256, 4096])
    def test_c2r_matches_oracle(self, n):
        x = rand_c((3, n // 2 + 1))
        # a valid half-spectrum: endpoints real (Hermitian consistency)
        x = x.at[:, 0].set(x[:, 0].real).at[:, -1].set(x[:, -1].real)
        got = fft_kernel_c2r(x, interpret=True)
        np.testing.assert_allclose(got, irfft_ref(x.real, x.imag),
                                   rtol=3e-4, atol=2e-3)

    @pytest.mark.parametrize("n", [64, 1024])
    def test_r2c_c2r_roundtrip(self, n):
        x = jax.random.normal(KEY, (5, n), jnp.float32)
        back = fft_kernel_c2r(fft_kernel_r2c(x, interpret=True),
                              interpret=True)
        np.testing.assert_allclose(back, x, rtol=3e-4, atol=2e-3)

    def test_small_n_falls_back(self):
        x = jax.random.normal(KEY, (4, 2), jnp.float32)
        np.testing.assert_allclose(fft_kernel_r2c(x, interpret=True),
                                   jnp.fft.rfft(x), rtol=3e-4, atol=3e-4)

    def test_r2c_too_long_raises(self):
        x = jax.random.normal(KEY, (1, 4 * MAX_KERNEL_N), jnp.float32)
        with pytest.raises(ValueError, match="repro.fft.plan"):
            fft_kernel_r2c(x, interpret=True)


class TestHarmonicSumKernel:
    @pytest.mark.parametrize("n", [64, 256, 1024, 4096])
    @pytest.mark.parametrize("h", [2, 8, 32])
    def test_matches_oracle(self, n, h):
        p = jax.random.uniform(KEY, (5, n), dtype=jnp.float32)
        got = harmonic_sum_kernel(p, h, interpret=True)
        want = harmonic_sum_ref(p, h)
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_flat_spectrum_values(self):
        """On P == 1, level h sums h in-range copies: S_h[k] = #valid j."""
        n, h = 128, 4
        p = jnp.ones((1, n))
        got = harmonic_sum_kernel(p, h, interpret=True)
        # k=1: all j*k < n for j<=4 -> S = 1, 2, 4 at levels 0..2
        np.testing.assert_allclose(got[0, :, 1], [1.0, 2.0, 4.0])
        # k = n-1: only j=1 in range
        np.testing.assert_allclose(got[0, :, n - 1], [1.0, 1.0, 1.0])

    def test_large_batch_tiling(self):
        p = jax.random.uniform(KEY, (37, 256), dtype=jnp.float32)
        got = harmonic_sum_kernel(p, 8, interpret=True)
        np.testing.assert_allclose(got, harmonic_sum_ref(p, 8), rtol=1e-5,
                                   atol=1e-5)

    def test_single_harmonic_is_identity_ladder(self):
        """n_harmonics=1: one ladder level that IS the input spectrum."""
        p = jax.random.uniform(KEY, (3, 64), dtype=jnp.float32)
        got = harmonic_sum_kernel(p, 1, interpret=True)
        assert got.shape == (3, 1, 64)
        np.testing.assert_allclose(got[:, 0], p, rtol=1e-6)


class TestHarmonicSumPlane:
    """The fused production variant: ladder + normalise + max-reduce in
    VMEM, only the (..., N) statistic and int32 level leave the kernel."""

    @pytest.mark.parametrize("n", [64, 1024])
    @pytest.mark.parametrize("h", [1, 4, 32])
    def test_matches_oracle(self, n, h):
        p = jax.random.uniform(KEY, (5, n), dtype=jnp.float32) * 3.0
        stat, lev = harmonic_sum_plane(p, h, interpret=True)
        stat_r, lev_r = harmonic_sum_plane_ref(p, h)
        assert stat.shape == lev.shape == (5, n)
        assert lev.dtype == jnp.int32
        np.testing.assert_allclose(stat, stat_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(lev), np.asarray(lev_r))

    def test_odd_length_non_divisible_batch(self):
        """Odd N and a prime batch: tiling edges on both axes at once."""
        p = jax.random.uniform(KEY, (11, 3, 129), dtype=jnp.float32)
        stat, lev = harmonic_sum_plane(p, 8, interpret=True)
        stat_r, lev_r = harmonic_sum_plane_ref(p, 8)
        np.testing.assert_allclose(stat, stat_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(lev), np.asarray(lev_r))

    def test_single_harmonic_edge(self):
        """n_harmonics=1: stat == P - 1 (z_1), level 0 everywhere."""
        p = jax.random.uniform(KEY, (2, 64), dtype=jnp.float32)
        stat, lev = harmonic_sum_plane(p, 1, interpret=True)
        np.testing.assert_allclose(stat, p - 1.0, rtol=1e-6, atol=1e-6)
        assert not np.asarray(lev).any()

    def test_planted_harmonic_signal_picks_deep_level(self):
        """Power split across harmonics k, 2k, 4k: summing the ladder to
        level 2 collects all three, so level 2 must win at bin k."""
        n, k = 256, 10
        p = jnp.ones((1, n))
        for m in (1, 2, 4):
            p = p.at[0, m * k].add(30.0)
        stat, lev = harmonic_sum_plane(p, 8, interpret=True)
        assert int(lev[0, k]) == 2
        assert int(jnp.argmax(stat[0])) == k

    def test_agrees_with_demo_ladder(self):
        """The fused plane must equal normalise+max over the demo ladder."""
        p = jax.random.uniform(KEY, (4, 128), dtype=jnp.float32) * 2.0
        ladder = harmonic_sum_kernel(p, 16, interpret=True)
        hs = 2.0 ** jnp.arange(ladder.shape[-2])
        z = (ladder - hs[:, None]) / jnp.sqrt(hs)[:, None]
        stat, lev = harmonic_sum_plane(p, 16, interpret=True)
        np.testing.assert_allclose(stat, z.max(axis=-2), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(lev),
                                      np.asarray(jnp.argmax(z, axis=-2)))


class TestSpectrumKernel:
    @pytest.mark.parametrize("n", [64, 1024, 8192])
    @pytest.mark.parametrize("batch", [1, 7, 16])
    def test_matches_oracle(self, n, batch):
        x = rand_c((batch, n))
        p, mean, std = power_spectrum_stats_kernel(x, interpret=True)
        pr, mr, sr = power_spectrum_stats_ref(x.real, x.imag)
        np.testing.assert_allclose(p, pr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(mean, mr, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(std, sr, rtol=1e-3, atol=1e-5)

    def test_parseval_consistency(self):
        """mean(power) * N == mean |x|^2 (Parseval, ties kernel to FFT)."""
        x = rand_c((2, 512))
        X = fft_kernel_c2c(x, interpret=True)
        _, mean, _ = power_spectrum_stats_kernel(X, interpret=True)
        energy_time = jnp.mean(jnp.abs(x) ** 2, axis=-1)
        np.testing.assert_allclose(mean, energy_time, rtol=1e-4)


class TestKernelPipelineEquivalence:
    """The Pallas pipeline must agree with the pure-JAX pipeline end-to-end."""

    def test_full_pipeline(self):
        from repro.fft.pipeline import harmonic_sum as hs_jax
        from repro.fft.pipeline import power_spectrum as ps_jax

        x = rand_c((3, 1024))
        spec_k = fft_kernel_c2c(x, interpret=True)
        p_k, mean_k, std_k = power_spectrum_stats_kernel(spec_k,
                                                         interpret=True)
        hs_k = harmonic_sum_kernel(p_k, 8, interpret=True)

        spec_j = jnp.fft.fft(x)
        p_j = ps_jax(spec_j)
        np.testing.assert_allclose(p_k, p_j, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(hs_k, harmonic_sum_ref(p_j, 8),
                                   rtol=2e-4, atol=2e-4)


class TestKernelInputValidation:
    """Caller-input guards must survive ``python -O`` (ValueError, not
    assert) and reject empty trailing dims before they reach a kernel."""

    def test_harmonic_sum_rejects_non_pow2_harmonics(self):
        p = jnp.ones((2, 64))
        with pytest.raises(ValueError, match="power of two"):
            harmonic_sum_kernel(p, 12, interpret=True)
        with pytest.raises(ValueError, match="power of two"):
            harmonic_sum_kernel(p, 0, interpret=True)

    def test_harmonic_sum_rejects_empty_trailing_dim(self):
        with pytest.raises(ValueError, match="non-empty trailing"):
            harmonic_sum_kernel(jnp.ones((2, 0)), 8, interpret=True)
        with pytest.raises(ValueError, match="non-empty trailing"):
            harmonic_sum_plane(jnp.ones((2, 0)), 8, interpret=True)

    def test_harmonic_sum_rejects_complex_power(self):
        """Power planes are real (|X|**2); a complex spectrum here is an
        upstream bug, not something to silently .real away."""
        x = jnp.ones((2, 64), jnp.complex64)
        with pytest.raises(ValueError, match="complex dtype"):
            harmonic_sum_kernel(x, 8, interpret=True)
        with pytest.raises(ValueError, match="complex dtype"):
            harmonic_sum_plane(x, 8, interpret=True)

    def test_harmonic_sum_plane_rejects_non_pow2_harmonics(self):
        with pytest.raises(ValueError, match="power of two"):
            harmonic_sum_plane(jnp.ones((2, 64)), 3, interpret=True)

    def test_spectrum_stats_rejects_empty_trailing_dim(self):
        with pytest.raises(ValueError, match="non-empty trailing"):
            power_spectrum_stats_kernel(jnp.ones((2, 0), jnp.complex64),
                                        interpret=True)

    def test_fft_pallas_rejects_non_dividing_tile(self):
        """Kernel-level guards carry the offending shapes (ValueError, not
        assert: asserts vanish under ``python -O``)."""
        from repro.kernels.fft.fft_kernel import fft_pallas
        re = jnp.ones((10, 64))
        with pytest.raises(ValueError, match=r"batch=10.*\(4\)"):
            fft_pallas(re, re, tile_b=4, interpret=True)

    def test_fft_pallas_rejects_non_pow2_length(self):
        from repro.kernels.fft.fft_kernel import fft_pallas
        re = jnp.ones((4, 48))
        with pytest.raises(ValueError, match="power of two, got 48"):
            fft_pallas(re, re, tile_b=4, interpret=True)

    def test_harmonic_sum_pallas_rejects_non_dividing_tile(self):
        from repro.kernels.harmonic_sum.harmonic_sum_kernel import \
            harmonic_sum_pallas
        p = jnp.ones((10, 64))
        with pytest.raises(ValueError, match=r"batch=10.*\(4\)"):
            harmonic_sum_pallas(p, 8, tile_b=4, interpret=True)

    def test_spectrum_pallas_rejects_non_dividing_tile(self):
        from repro.kernels.spectrum.spectrum_kernel import \
            power_spectrum_stats_pallas
        re = jnp.ones((10, 64))
        with pytest.raises(ValueError, match=r"batch=10.*\(4\)"):
            power_spectrum_stats_pallas(re, re, tile_b=4, interpret=True)
