"""End-to-end pulsar-search pipeline tests (repro.search.pipeline).

The acceptance contract: a jitted ``pulsar_search`` recovers every
injected pulsar at its exact (DM trial, template, bin) cell, the
no-signal control yields zero candidates, the graph launches each fused
kernel exactly once (routing counters, the test_plan_nd.py pattern),
per-stage DVFS plans cover all four stages, and the serving cache keys
pulsar entries on the full pipeline configuration + active tuned config.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import TESLA_V100, TPU_V5E
from repro.data.synthetic import (FilterbankSpec, InjectedPulsar,
                                  synthetic_filterbank)
from repro.search.pipeline import (DispersionPlan, plan_pulsar_stages,
                                   pulsar_search, serving_sifted)
from repro.search.sift import sift_candidates
from repro.search.templates import TemplateBank

SPEC = FilterbankSpec(nchan=16, ntime=2048)
PLAN = DispersionPlan.from_spec(SPEC, n_trials=8)
BANK = TemplateBank.linear(zmax=4.0, n_templates=5)


def _search(fb, **kw):
    kw.setdefault("n_harmonics", 8)
    return pulsar_search(fb, PLAN, BANK, **kw)


class TestInjectedRecovery:
    """Satellite 1: exact-cell recovery + the false-positive guard."""

    def test_two_pulsars_recovered_at_exact_cells(self):
        # drifts are (-4, -2, 0, 2, 4): z=2 -> template 3, z=-4 -> 0
        pulsars = (InjectedPulsar(dm=PLAN.dms[3], k0=300, z=2.0, amp=0.12),
                   InjectedPulsar(dm=PLAN.dms[6], k0=611, z=-4.0, amp=0.12))
        fb = synthetic_filterbank(SPEC, pulsars, noise=1.0, seed=2)
        res = _search(fb)
        c = res.candidates
        got = {(int(d), int(t), int(b))
               for d, t, b in zip(c.dm[0], c.template[0], c.bin[0])
               if int(d) >= 0}
        assert got == {(3, 3, 300), (6, 0, 611)}
        # every candidate above threshold, padding zeroed
        kept = np.asarray(c.dm[0]) >= 0
        assert (np.asarray(c.snr[0])[kept] > 25.0).all()
        assert (np.asarray(c.snr[0])[~kept] == 0.0).all()

    def test_no_signal_control_zero_candidates(self):
        fb = synthetic_filterbank(SPEC, (), noise=1.0, seed=3)
        res = _search(fb)
        c = res.candidates
        assert (np.asarray(c.dm) == -1).all()
        assert (np.asarray(c.template) == -1).all()
        assert (np.asarray(c.bin) == -1).all()
        assert (np.asarray(c.snr) == 0.0).all()
        # the raw statistic maximum sits far below the threshold
        assert float(res.stat.max()) < 25.0

    def test_batched_filterbanks_search_independently(self):
        quiet = synthetic_filterbank(SPEC, (), noise=1.0, seed=4)
        loud = synthetic_filterbank(
            SPEC, (InjectedPulsar(dm=PLAN.dms[2], k0=150, amp=0.15),),
            noise=1.0, seed=5)
        res = _search(jnp.stack([quiet, loud]))
        c = res.candidates
        assert (np.asarray(c.dm[0]) == -1).all()
        assert (int(c.dm[1, 0]), int(c.template[1, 0]),
                int(c.bin[1, 0])) == (2, 2, 150)

    def test_rank2_filterbank_accepted(self):
        fb = synthetic_filterbank(SPEC, (), noise=1.0, seed=6)
        res = _search(fb[None])
        assert res.candidates.dm.shape[0] == 1

    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError, match="nchan, ntime"):
            _search(jnp.ones((2, 2, 4, 64)))

    def test_serving_sifted_packing(self):
        fb = synthetic_filterbank(
            SPEC, (InjectedPulsar(dm=PLAN.dms[3], k0=300, amp=0.2),),
            noise=1.0, seed=2)
        res = _search(fb)
        packed = serving_sifted(res)
        assert packed.shape == (1, 16, 5)
        np.testing.assert_allclose(packed[0, 0, :3], [3.0, 2.0, 300.0])
        # padding rows are (-1, -1, -1, -1, 0)
        np.testing.assert_allclose(packed[0, -1], [-1, -1, -1, -1, 0.0])


class TestRoutingCounters:
    """Satellite 2: the jitted graph launches each fused kernel exactly
    once per compile — no hidden re-dedispersion or ladder round-trips."""

    def test_each_fused_kernel_launches_once(self, monkeypatch):
        import repro.search.pipeline as pl
        calls = {"dedisp": 0, "hsum": 0}
        real_d, real_h = pl._kernel_dedisp, pl._kernel_hsum

        def count_d(*a, **k):
            calls["dedisp"] += 1
            return real_d(*a, **k)

        def count_h(*a, **k):
            calls["hsum"] += 1
            return real_h(*a, **k)

        monkeypatch.setattr(pl, "_kernel_dedisp", count_d)
        monkeypatch.setattr(pl, "_kernel_hsum", count_h)
        # fresh static shapes: this exact configuration appears nowhere
        # else, so jit MUST re-trace through the counting wrappers
        spec = FilterbankSpec(nchan=3, ntime=256)
        plan = DispersionPlan.from_spec(spec, n_trials=3)
        bank = TemplateBank.linear(zmax=1.0, n_templates=3)
        fb = synthetic_filterbank(spec, (), noise=1.0, seed=7)
        res = pl.pulsar_search(fb, plan, bank, n_harmonics=2, pool=16)
        res.stat.block_until_ready()
        assert calls == {"dedisp": 1, "hsum": 1}
        # a second identical call reuses the compiled graph: no re-trace
        pl.pulsar_search(fb, plan, bank,
                         n_harmonics=2, pool=16).stat.block_until_ready()
        assert calls == {"dedisp": 1, "hsum": 1}


class TestSift:
    def _volume(self, cells, shape=(1, 4, 3, 512)):
        stat = np.zeros(shape, np.float32)
        for (d, t, b), v in cells:
            stat[0, d, t, b] = v
        return jnp.asarray(stat), jnp.zeros(shape, jnp.int32)

    def test_harmonic_alias_absorbed(self):
        """A cell at 2x the bin within dm_tol is the same pulsar's
        harmonic: only the stronger survives."""
        stat, lev = self._volume([((2, 1, 100), 50.0), ((2, 1, 200), 30.0)])
        c = sift_candidates(stat, lev)
        kept = [(int(d), int(b)) for d, b in zip(c.dm[0], c.bin[0])
                if int(d) >= 0]
        assert kept == [(2, 100)]

    def test_adjacent_dm_leak_absorbed(self):
        stat, lev = self._volume([((2, 1, 100), 50.0), ((3, 1, 101), 30.0)])
        c = sift_candidates(stat, lev)
        assert [(int(d), int(b)) for d, b in zip(c.dm[0], c.bin[0])
                if int(d) >= 0] == [(2, 100)]

    def test_distant_candidates_both_kept(self):
        """Far apart in DM and unrelated in bin: two real candidates."""
        stat, lev = self._volume([((0, 0, 100), 50.0), ((3, 2, 173), 40.0)])
        c = sift_candidates(stat, lev)
        got = {(int(d), int(t), int(b))
               for d, t, b in zip(c.dm[0], c.template[0], c.bin[0])
               if int(d) >= 0}
        assert got == {(0, 0, 100), (3, 2, 173)}

    def test_below_threshold_dropped(self):
        stat, lev = self._volume([((1, 0, 50), 10.0)])
        c = sift_candidates(stat, lev, threshold=25.0)
        assert (np.asarray(c.dm) == -1).all()

    def test_weak_cell_cannot_absorb(self):
        """A sub-threshold stronger cell must not erase a real detection."""
        stat, lev = self._volume([((2, 1, 100), 20.0), ((2, 1, 200), 30.0)])
        c = sift_candidates(stat, lev, threshold=25.0)
        assert [(int(d), int(b)) for d, b in zip(c.dm[0], c.bin[0])
                if int(d) >= 0] == [(2, 200)]

    def test_level_travels_with_candidate(self):
        stat = np.zeros((1, 2, 2, 64), np.float32)
        lev = np.zeros((1, 2, 2, 64), np.int32)
        stat[0, 1, 0, 30] = 40.0
        lev[0, 1, 0, 30] = 3
        c = sift_candidates(jnp.asarray(stat), jnp.asarray(lev))
        assert int(c.level[0, 0]) == 3

    def test_guards(self):
        with pytest.raises(ValueError, match="volume"):
            sift_candidates(jnp.ones((4, 8)), jnp.zeros((4, 8), jnp.int32))
        with pytest.raises(ValueError, match="shapes differ"):
            sift_candidates(jnp.ones((1, 2, 2, 8)),
                            jnp.zeros((1, 2, 2, 9), jnp.int32))


class TestDispersionPlan:
    def test_from_spec_grid(self):
        assert PLAN.n_trials == 8
        assert PLAN.nchan == SPEC.nchan
        assert PLAN.dms[0] == 0.0
        assert PLAN.delays[0] == (0,) * SPEC.nchan
        assert PLAN.max_delay == max(PLAN.delays[-1])
        assert PLAN.delay_array().shape == (8, 16)
        hash(PLAN)                       # static jit argument => hashable

    def test_injection_and_plan_share_delays(self):
        """The exact-recovery mechanism: both sides round identically."""
        np.testing.assert_array_equal(
            PLAN.delay_array()[3], SPEC.delay_samples(PLAN.dms[3]))

    def test_rejects_overflowing_grid(self):
        spec = FilterbankSpec(nchan=8, ntime=128)
        with pytest.raises(ValueError, match="exceed"):
            DispersionPlan.from_spec(spec, dms=(1e5,))

    def test_rejects_bad_trial_counts(self):
        with pytest.raises(ValueError, match="n_trials"):
            DispersionPlan.from_spec(SPEC, n_trials=0)
        with pytest.raises(ValueError, match=">= 1 DM trial"):
            DispersionPlan(dms=(), delays=(), tsamp=1e-4)
        with pytest.raises(ValueError, match="delay rows"):
            DispersionPlan(dms=(0.0, 1.0), delays=((0, 0),), tsamp=1e-4)


class TestStagePlanning:
    """Per-stage DVFS: four stage models, a clock lock per stage, and a
    positive end-to-end real-time margin."""

    def test_workload_has_four_stages(self):
        from repro.core.workloads import PulsarCase, pulsar_search_workload
        case = PulsarCase(nchan=16, ntime=2048, dm_trials=8, templates=5,
                          taps=BANK.taps)
        profs = pulsar_search_workload(case, TESLA_V100)
        assert [p.name for p in profs] == ["dedisp", "fdas",
                                           "harmonic-sum", "sift"]
        for p in profs:
            assert float(p.time(TESLA_V100.f_max, TESLA_V100)) > 0

    def test_plan_pulsar_stages(self):
        sp = plan_pulsar_stages(SPEC, PLAN, BANK, 8, TESLA_V100)
        assert set(sp.locked) == {"dedisp", "fdas", "harmonic-sum", "sift"}
        grid = set(TESLA_V100.frequencies().tolist())
        assert all(c in grid for c in sp.locked.values())
        assert len(sp.report.stages) == 4
        assert all(s.energy > 0 and s.time > 0 for s in sp.report.stages)
        assert sp.realtime_margin > 0
        assert sp.t_acquire == pytest.approx(SPEC.t_acquire)

    def test_total_profile_covers_stage_sum(self):
        """The merged profile the service sweeps must price the same work
        as the per-stage models (same HBM bytes and flops)."""
        from repro.core.workloads import (PulsarCase,
                                          pulsar_search_total_profile,
                                          pulsar_search_workload)
        case = PulsarCase(nchan=16, ntime=2048, dm_trials=8, templates=5,
                          taps=BANK.taps)
        profs = pulsar_search_workload(case, TESLA_V100)
        total = pulsar_search_total_profile(case, TESLA_V100)
        assert total.flops == pytest.approx(sum(p.flops for p in profs))
        assert total.t_mem == pytest.approx(sum(p.t_mem for p in profs))
        assert total.t_cache == pytest.approx(
            sum(p.t_cache for p in profs))


class TestServingPulsarCacheKeys:
    """Satellite 3: one PlanSweepCache entry per (shape, DM count, bank,
    harmonics, active tuned config) — config changes never serve stale
    pipelines."""

    NCHAN, NTIME = 8, 512

    def _cache(self):
        from repro.serving.cache import PlanSweepCache
        return PlanSweepCache(TPU_V5E, batch_bytes=2 ** 24)

    def _key(self, dm_trials=4, templates=5, n_harmonics=4):
        from repro.serving.request import ShapeKey
        return ShapeKey(kind="pulsar", n=self.NCHAN * self.NTIME,
                        precision="fp32", n_harmonics=n_harmonics,
                        device=TPU_V5E.name, transform="r2c",
                        shape=(self.NCHAN, self.NTIME),
                        templates=templates, dm_trials=dm_trials)

    def test_distinct_pipeline_configs_get_distinct_entries(self):
        cache = self._cache()
        base = cache.entry(self._key())
        assert cache.entry(self._key()) is base             # hit
        assert cache.entry(self._key(dm_trials=8)) is not base
        assert cache.entry(self._key(templates=3)) is not base
        assert cache.entry(self._key(n_harmonics=8)) is not base
        assert cache.stats.misses == 4
        assert cache.stats.hits == 1

    def test_entry_carries_stage_plan(self):
        e = self._cache().entry(self._key())
        assert e.plan.n_trials == 4                 # the DispersionPlan
        assert set(e.locked) == {"dedisp", "fdas", "harmonic-sum", "sift"}
        assert len(e.stages.stages) == 4
        assert e.realtime_margin is not None and e.realtime_margin > 0

    def test_retune_rebuilds_pulsar_entry(self):
        """A re-tune of the pipeline's inner R2C must rebuild the entry —
        serving the stale plan would ignore the tuned config (the
        test_tune.py TestServingIntegration contract, pulsar kind)."""
        from repro.tune import (ConfigKey, KernelConfig, TuneRecord,
                                TuningCache, TuningContext, use_tuning)
        cache = self._cache()
        key = self._key()
        e1 = cache.entry(key)
        assert cache.entry(key) is e1
        tuned = TuningCache(device=TPU_V5E.name)
        tuned.put(ConfigKey(TPU_V5E.name, (self.NTIME,), "r2c"),
                  TuneRecord(config=KernelConfig(tile_b=8, source="tuned")))
        with use_tuning(TuningContext(tuned)):
            e2 = cache.entry(key)
            assert e2 is not e1                    # rebuilt, not served stale
            assert cache.entry(key) is e2          # ... and then cached
        assert cache.entry(key) is e1              # context gone -> heuristic
