"""Robustness layer: fault injection, breakers, retries, admission
control, the degradation ladder and the every-request-gets-a-receipt
invariant (docs/robustness.md)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E
from repro.runtime.faults import (CLOSED, FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD,
                                  HALF_OPEN, KILL_DEVICE, OPEN, STALL_WORKER,
                                  CircuitBreaker, DrainDeadlineError,
                                  FaultEvent, FaultPlan, RetryPolicy)
from repro.serving import (RUNG_BOOST_HEURISTIC, RUNG_PURE_JAX, SLO,
                           FFTService, SLOPolicy, max_rung_for_kind)

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


class FakeTimer:
    """Deterministic clock: advances ``dt`` per call (0 = frozen)."""

    def __init__(self, dt=0.0, t0=0.0):
        self.t = t0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t

    def advance(self, dt):
        self.t += dt


def service(n_workers=2, timer=None, **kw):
    return FFTService(TPU_V5E, devices=[None] * n_workers,
                      timer=timer if timer is not None else FakeTimer(),
                      **kw)


# ---------------------------------------------------------------------------
# FaultPlan / RetryPolicy / CircuitBreaker units
# ---------------------------------------------------------------------------

def test_fault_events_fire_exactly_once():
    plan = FaultPlan([FaultEvent(KILL_DEVICE, batch_id=3),
                      FaultEvent(KILL_DEVICE)])
    assert plan.take(KILL_DEVICE, batch_id=1) is not None   # wildcard event
    assert plan.take(KILL_DEVICE, batch_id=3) is not None
    assert plan.take(KILL_DEVICE, batch_id=3) is None       # one-shot
    assert plan.pending() == 0 and plan.fired_count(KILL_DEVICE) == 2


def test_fault_event_worker_constraint():
    plan = FaultPlan([FaultEvent(STALL_WORKER, worker=1, duration=0.5)])
    assert plan.take(STALL_WORKER, batch_id=0, worker=0) is None
    ev = plan.take(STALL_WORKER, batch_id=0, worker=1)
    assert ev is not None and ev.duration == 0.5


def test_fault_plan_generation_is_seed_deterministic():
    a = FaultPlan.generate(seed=7, n_batches=200)
    b = FaultPlan.generate(seed=7, n_batches=200)
    assert a.events == b.events
    # the pinned one-of-each events cover the chaos harness requirement
    kinds = {ev.kind for ev in a.events}
    assert {KILL_DEVICE, FAIL_CLOCK_LOCK, STALL_WORKER} <= kinds


def test_retry_backoff_is_deterministic_and_bounded():
    pol = RetryPolicy(max_retries=3, base_delay_s=0.01, max_delay_s=0.05)
    d = [pol.delay(a, token=42) for a in (1, 2, 3)]
    assert d == [pol.delay(a, token=42) for a in (1, 2, 3)]
    assert d != [pol.delay(a, token=43) for a in (1, 2, 3)]  # per-work jitter
    for attempt, delay in enumerate(d, start=1):
        raw = min(0.01 * 2.0 ** (attempt - 1), 0.05)
        assert 0.5 * raw <= delay < 1.5 * raw


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0)
    assert br.state == CLOSED and br.allow(0.0)
    br.record_failure(0.0)
    assert br.state == CLOSED                     # below threshold
    br.record_failure(0.1)
    assert br.state == OPEN and br.opens == 1
    assert not br.allow(0.5)                      # cooling down
    assert br.would_allow(1.2) and br.state == OPEN   # pure peek
    assert br.allow(1.2) and br.state == HALF_OPEN and br.probes == 1
    assert not br.allow(1.3)                      # one probe in flight
    br.record_failure(1.4)                        # probe failed
    assert br.state == OPEN and br.opens == 2
    assert br.allow(2.5)                          # second probe
    br.record_success()
    assert br.state == CLOSED and br.allow(2.6)


# ---------------------------------------------------------------------------
# device lost mid-batch -> retried elsewhere, no request lost
# ---------------------------------------------------------------------------

def test_device_lost_mid_batch_is_retried_no_request_lost():
    plan = FaultPlan([FaultEvent(KILL_DEVICE, batch_id=0)])
    svc = service(n_workers=2, fault_plan=plan)
    reqs = [svc.submit(rand_complex((2, 256), jax.random.PRNGKey(i)))
            for i in range(3)]
    receipts = svc.drain()
    assert len(receipts) == len(reqs)             # exactly one receipt each
    assert all(r.status == "served" for r in receipts)
    assert all(r.outcome == "retried" and r.retries == 1 for r in receipts)
    assert plan.fired_count(KILL_DEVICE) == 1
    ref = np.fft.fft(np.asarray(reqs[0].x), axis=-1)
    np.testing.assert_allclose(np.asarray(receipts[0].result), ref,
                               rtol=1e-4, atol=1e-3)
    rep = svc.report()
    assert rep.retried == 3 and rep.availability == 1.0


def test_retries_exhausted_sheds_with_receipts():
    plan = FaultPlan([FaultEvent(KILL_DEVICE, batch_id=0)] * 3)
    svc = service(n_workers=2, fault_plan=plan)   # default max_retries=2
    reqs = [svc.submit(rand_complex((1, 256), jax.random.PRNGKey(i)))
            for i in range(2)]
    receipts = svc.drain()
    assert len(receipts) == len(reqs)
    assert all(r.status == "shed" and r.outcome == "shed" for r in receipts)
    assert all(r.reason == "fault:retries-exhausted" for r in receipts)
    rep = svc.report()
    assert rep.shed == 2 and rep.fault_shed == 2
    assert rep.availability == 0.0
    # the service is not wedged: the next wave serves normally
    ok = svc.submit(rand_complex((1, 256)))
    (r,) = svc.drain()
    assert r.status == "served" and svc.receipt(ok) is r


# ---------------------------------------------------------------------------
# clock-lock failure -> boost, not crash
# ---------------------------------------------------------------------------

def test_failed_clock_lock_degrades_to_boost():
    plan = FaultPlan([FaultEvent(FAIL_CLOCK_LOCK, batch_id=0)])
    svc = service(n_workers=1, fault_plan=plan)
    svc.submit(rand_complex((2, 512)))
    (r,) = svc.drain()
    assert r.status == "served"
    assert r.rung == RUNG_BOOST_HEURISTIC
    assert r.reason == "fault:clock-lock-failed"
    assert r.clock_mhz == pytest.approx(TPU_V5E.f_max)
    assert svc.clock.lock_count == 0              # the lock was never taken
    # same shape, next batch: the tuned DVFS path is back
    svc.submit(rand_complex((2, 512), jax.random.PRNGKey(1)))
    (r2,) = svc.drain()
    assert r2.rung == 0 and r2.reason is None
    assert svc.clock.lock_count == 1


def test_plan_build_failure_walks_down_the_ladder():
    plan = FaultPlan([FaultEvent(FAIL_PLAN_BUILD, batch_id=0)])
    svc = service(n_workers=1, fault_plan=plan)
    req = svc.submit(rand_complex((2, 512)))
    (r,) = svc.drain()
    assert r.status == "served" and r.rung == RUNG_BOOST_HEURISTIC
    assert r.reason == "fault:plan-build-failed"
    assert svc.cache.stats.degraded_builds == 1
    ref = np.fft.fft(np.asarray(req.x), axis=-1)
    np.testing.assert_allclose(np.asarray(r.result), ref,
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# stalls, redistribution, drain deadline
# ---------------------------------------------------------------------------

def test_stalled_worker_work_is_redistributed():
    plan = FaultPlan([FaultEvent(STALL_WORKER, batch_id=0, duration=1e9)])
    svc = service(n_workers=2, fault_plan=plan)
    reqs = [svc.submit(rand_complex((1, 256), jax.random.PRNGKey(i)))
            for i in range(2)]
    receipts = svc.drain()
    assert len(receipts) == len(reqs)
    assert all(r.status == "served" for r in receipts)
    assert svc.stalls_honoured == 1
    assert svc.redistributions >= 1
    assert all(r.worker == 1 for r in receipts)   # worker 0 is wedged


def test_drain_deadline_surfaces_stuck_shape():
    plan = FaultPlan([FaultEvent(STALL_WORKER, batch_id=0, duration=1e9)])
    svc = service(n_workers=1, timer=FakeTimer(dt=1.0), fault_plan=plan)
    svc.submit(rand_complex((1, 256)))
    with pytest.raises(DrainDeadlineError) as err:
        svc.drain(deadline_s=25.0)
    assert err.value.deadline_s == 25.0
    assert [k.n for k in err.value.stuck] == [256]
    # the unserved request was re-queued, not dropped
    assert len(svc._pending) == 1


def test_breaker_quarantines_then_readmits_after_probe():
    timer = FakeTimer(dt=0.0, t0=1.0)             # frozen; advanced by hand
    plan = FaultPlan([FaultEvent(KILL_DEVICE, batch_id=0, worker=0)])
    svc = service(n_workers=2, timer=timer, fault_plan=plan,
                  breaker_threshold=1, breaker_cooldown_s=10.0)
    svc.submit(rand_complex((1, 256)))
    (r,) = svc.drain()                            # kill -> open -> retried
    assert r.retries == 1 and svc.breakers[0].state == OPEN
    # while quarantined, new work for worker 0 is pushed to worker 1
    svc.submit(rand_complex((1, 256), jax.random.PRNGKey(1)))
    (r2,) = svc.drain()
    assert r2.worker == 1 and svc.breakers[0].state == OPEN
    # after the cooldown the next batch is the probe; success re-admits
    timer.advance(60.0)
    svc.submit(rand_complex((1, 256), jax.random.PRNGKey(2)))
    (r3,) = svc.drain()
    assert r3.worker == 0 and r3.status == "served"
    assert svc.breakers[0].state == CLOSED
    assert svc.breakers[0].probes == 1
    assert svc.report().breaker_opens == 1


# ---------------------------------------------------------------------------
# admission control and the degradation ladder
# ---------------------------------------------------------------------------

def test_queue_depth_cap_sheds_with_receipts():
    policy = SLOPolicy(default=SLO(max_queue_transforms=4))
    svc = service(n_workers=1, slo=policy)
    reqs = [svc.submit(rand_complex((2, 256), jax.random.PRNGKey(i)))
            for i in range(3)]                    # 6 transforms > cap 4
    receipts = svc.drain()
    assert len(receipts) == 3                     # every request terminated
    by_req = {r.request.request_id: r for r in receipts}
    assert by_req[reqs[0].request_id].status == "served"
    assert by_req[reqs[1].request_id].status == "served"
    shed = by_req[reqs[2].request_id]
    assert shed.status == "shed"
    assert shed.reason == "admission:queue-full"
    rep = svc.report()
    assert rep.shed == 1 and rep.fault_shed == 0
    assert rep.availability == 1.0                # admission sheds excluded


def test_backlog_pressure_degrades_to_boost_heuristic():
    policy = SLOPolicy(default=SLO(deadline_s=1.0, degrade_at=0.0,
                                   degrade_hard_at=None, shed_at=None))
    svc = service(n_workers=1, slo=policy)
    svc.submit(rand_complex((2, 512)))
    (r,) = svc.drain()
    assert r.status == "served" and r.rung == RUNG_BOOST_HEURISTIC
    assert r.reason == "admission:backlog"
    assert r.clock_mhz == pytest.approx(TPU_V5E.f_max)
    assert svc.cache.stats.sweeps == 0            # sweep skipped entirely
    assert svc.cache.stats.degraded_builds == 1


def test_hard_pressure_reaches_pure_jax_rung():
    from repro.fft import plan as plan_mod
    calls = []
    orig = plan_mod._kernel_fft

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    policy = SLOPolicy(default=SLO(deadline_s=1.0, degrade_at=0.0,
                                   degrade_hard_at=0.0, shed_at=None))
    svc = service(n_workers=1, slo=policy)
    req = svc.submit(rand_complex((2, 4096), jax.random.PRNGKey(3)))
    old = plan_mod._kernel_fft
    plan_mod._kernel_fft = counting
    try:
        (r,) = svc.drain()
    finally:
        plan_mod._kernel_fft = old
    assert r.rung == RUNG_PURE_JAX and r.rung_name == "pure-jax"
    assert r.reason == "admission:backlog-hard"
    assert calls == []                            # zero Pallas launches
    ref = np.fft.fft(np.asarray(req.x), axis=-1)
    np.testing.assert_allclose(np.asarray(r.result), ref,
                               rtol=1e-4, atol=1e-2)


def test_deadline_pressure_sheds():
    policy = SLOPolicy(default=SLO(deadline_s=1e-12))
    svc = service(n_workers=1, slo=policy)
    req = svc.submit(rand_complex((2, 256)))
    (r,) = svc.drain()
    assert r.status == "shed" and r.reason == "admission:deadline"
    assert svc.receipt(req) is r
    assert svc.admission.shed == 1


def test_science_kinds_cap_at_boost_heuristic():
    assert max_rung_for_kind("fft") == RUNG_PURE_JAX
    assert max_rung_for_kind("fdas") == RUNG_BOOST_HEURISTIC
    assert max_rung_for_kind("pulsar") == RUNG_BOOST_HEURISTIC


# ---------------------------------------------------------------------------
# reproducibility: same fault-plan seed => same outcomes
# ---------------------------------------------------------------------------

def _chaos_outcomes(seed):
    svc = service(n_workers=2,
                  fault_plan=FaultPlan.generate(
                      seed, n_batches=8, kill_rate=0.2, clock_fail_rate=0.2,
                      plan_fail_rate=0.2, stall_rate=0.1,
                      stall_duration_s=0.0),
                  timer=FakeTimer(dt=1e-4))
    out = []
    for wave in range(8):
        for i in range(2):
            svc.submit(rand_complex((1, 256), jax.random.PRNGKey(wave * 2 + i)))
        out.extend((r.outcome, r.rung, r.reason) for r in svc.drain())
    return out


def test_same_fault_seed_reproduces_outcomes():
    assert _chaos_outcomes(5) == _chaos_outcomes(5)
