"""Paper-faithful validation: the model must reproduce the paper's claims.

Each test pins one published claim (Abstract, Table 3, Figs. 9/11/13-16,
Sec. 6.1/6.2) with a tolerance band.  These bands ARE the reproduction
contract — see DESIGN.md Sec. 2 and EXPERIMENTS.md.
"""
import numpy as np
import pytest

from repro.core.calibration import calibrate, supported_precisions
from repro.core.hardware import JETSON_NANO, TESLA_V100
from repro.core.workloads import is_pow2


@pytest.fixture(scope="module")
def v100_fp32():
    return calibrate(TESLA_V100, "fp32")


@pytest.fixture(scope="module")
def nano_fp32():
    return calibrate(JETSON_NANO, "fp32")


class TestV100:
    def test_mean_optimal_frequency_table3(self, v100_fp32):
        """Table 3: V100 FP32 mean-opt = 945 MHz = 61.8% of 1530 boost."""
        assert 0.55 <= v100_fp32.mean_opt_frac <= 0.70
        assert abs(v100_fp32.mean_opt.f_mean - 945.0) <= 80.0

    def test_precision_independence_of_optimal(self):
        """Table 3/Fig. 9: optimal frequency ~same across FP16/32/64."""
        fracs = [calibrate(TESLA_V100, p).mean_opt_frac
                 for p in supported_precisions(TESLA_V100)]
        assert max(fracs) - min(fracs) <= 0.06

    def test_slowdown_below_10pct(self, v100_fp32):
        """Abstract/Fig. 11: <10% time increase (usually <5%)."""
        slowdowns = [s.slowdown for s in v100_fp32.sweeps]
        assert np.median(slowdowns) <= 0.05
        assert np.quantile(slowdowns, 0.9) <= 0.10

    def test_power_cut_up_to_60pct(self, v100_fp32):
        """Abstract: up to 60% lower power at the optimal clock."""
        assert 0.50 <= v100_fp32.max_power_reduction <= 0.72

    def test_mean_power_cut_50pct(self, v100_fp32):
        """Abstract: ~50% average power cut with one common clock."""
        assert 0.38 <= v100_fp32.mean_power_reduction <= 0.60

    def test_i_ef_vs_base_sec62(self, v100_fp32):
        """Sec. 6.2/Conclusions: ~29-30% efficiency gain vs base clock."""
        assert 1.15 <= v100_fp32.mean_i_ef_base <= 1.45

    def test_i_ef_vs_boost(self, v100_fp32):
        """Conclusions: avg efficiency increase ~60% vs boost (we allow
        the model to land anywhere in a 1.4-2.1x band)."""
        assert 1.40 <= v100_fp32.mean_i_ef_boost <= 2.10

    def test_mean_opt_loss_within_paper_band(self, v100_fp32):
        """Sec. 6.2: one shared clock loses ~5-10 pp vs per-length tuning."""
        assert 0.0 <= v100_fp32.mean_opt.loss_pp <= 16.0

    def test_regime_c_length_8192(self, v100_fp32):
        """Fig. 6: N=8192 on the V100 shows regime (c)."""
        s = next(x for x in v100_fp32.sweeps if "n8192-" in x.profile.name)
        assert s.profile.regime() == "c"
        # regime (c) costs time immediately -> its optimum is a compromise
        assert s.slowdown >= -0.02

    def test_energy_u_shape_all_lengths(self, v100_fp32):
        # Bluestein lengths are excluded — the paper itself treats them as
        # a marginal case with large measurement error (Sec. 4).
        from repro.core.workloads import uses_bluestein
        for s in v100_fp32.sweeps:
            n = int(s.profile.name.split("-")[1][1:])
            if uses_bluestein(n):
                continue
            e = np.array([p.energy for p in s.points])
            assert e.argmin() > 0, s.profile.name   # never boost-optimal


class TestJetson:
    def test_mean_optimal_frequency_table3(self, nano_fp32):
        """Table 3: Nano mean-opt 460.8 MHz (=50% of 921.6); grid step 76.8."""
        assert abs(nano_fp32.mean_opt.f_mean - 460.8) <= 76.8 + 1e-9

    def test_slowdown_around_60pct(self, nano_fp32):
        """Sec. 6.1: ~60% longer execution at the optimal clock."""
        assert 0.30 <= np.median([s.slowdown for s in nano_fp32.sweeps]) <= 0.90

    def test_regime_c_dominates(self, nano_fp32):
        """Fig. 6 bottom: the Nano only exhibits behaviour (c)."""
        pow2 = [s for s in nano_fp32.sweeps
                if is_pow2(int(s.profile.name.split("-")[1][1:]))]
        frac_c = np.mean([s.profile.regime(JETSON_NANO) == "c" for s in pow2])
        assert frac_c >= 0.75

    def test_i_ef_vs_boost_70pct(self, nano_fp32):
        """Conclusions: ~70% efficiency increase for FP32."""
        assert 1.45 <= nano_fp32.mean_i_ef_boost <= 2.0

    def test_nano_v100_efficiency_same_magnitude(self, nano_fp32, v100_fp32):
        """Sec. 6.1 claims the Nano is ~50% MORE efficient than the V100 at
        FP32.  Our TDP-anchored analytic power model reproduces the right
        magnitude but not the sign of the gap (the V100 edges ahead by
        ~30%): absolute cross-device GFLOPS/W depends on rail-level power
        calibration the model cannot recover from public specs alone.
        Documented as a KNOWN DEVIATION in EXPERIMENTS.md §Deviations.
        This test pins what the model does support: the two devices are
        within 2x of each other (same order of magnitude), while every
        within-device claim (optimal clocks, slowdowns, I_ef) matches."""
        nano_eff = np.median([s.optimal.gflops_per_watt
                              for s in nano_fp32.sweeps])
        v100_eff = np.median([s.optimal.gflops_per_watt
                              for s in v100_fp32.sweeps])
        assert 0.5 <= nano_eff / v100_eff <= 2.0

    def test_mean_opt_loss_small(self, nano_fp32):
        assert nano_fp32.mean_opt.loss_pp <= 16.0
