"""Write-ahead request journal: checksummed append, segment rotation,
torn-write recovery (truncate mid-record, segment boundary, corrupt
checksum), repair + quarantine, snapshots (docs/recovery.md)."""
import json
import os

import pytest

from repro.runtime.journal import (ADMIT, OPEN, SERVED, JournalRecord,
                                   RequestJournal, process_incarnation,
                                   read_journal, read_segment_records)


class LogSpy:
    """Captures structured warnings the journal emits."""

    def __init__(self):
        self.events = []

    def __call__(self, event, **kw):
        self.events.append((event, kw))

    def names(self):
        return [e for e, _ in self.events]


def seg_files(path):
    return sorted(n for n in os.listdir(path)
                  if n.startswith("seg-") and n.endswith(".jsonl"))


def fill(journal, n, start=0):
    return [journal.append(ADMIT, {"payload_ref": start + i})
            for i in range(n)]


# ---------------------------------------------------------------------------
# append / replay round trip
# ---------------------------------------------------------------------------

def test_append_replay_roundtrip(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path) as j:
        assert j.incarnation == "i1"
        seqs = fill(j, 5)
    j2 = RequestJournal(path)
    assert j2.incarnation == "i2"
    assert j2.replay_stats.invalid == 0
    # 5 admits + the first incarnation's OPEN record.
    types = [r.type for r in j2.recovered]
    assert types == [OPEN] + [ADMIT] * 5
    assert [r.seq for r in j2.recovered] == [0] + seqs
    assert [r.data["payload_ref"] for r in j2.recovered[1:]] == list(range(5))
    # Seq numbering continues after the last valid record (no reuse).
    assert j2.append(ADMIT, {"payload_ref": 99}) == seqs[-1] + 2  # +OPEN
    j2.close()


def test_append_rejects_unknown_type_and_closed_journal(tmp_path):
    j = RequestJournal(str(tmp_path / "j"))
    with pytest.raises(ValueError, match="unknown record type"):
        j.append("bogus", {})
    j.close()
    with pytest.raises(ValueError, match="closed"):
        j.append(ADMIT, {})


def test_constructor_validation(tmp_path):
    with pytest.raises(ValueError, match="segment_records"):
        RequestJournal(str(tmp_path / "a"), segment_records=0)
    with pytest.raises(ValueError, match="sync"):
        RequestJournal(str(tmp_path / "b"), sync="sometimes")


def test_crash_keeps_line_buffered_records(tmp_path):
    # crash() abandons the fd with no fsync — the kill -9 signature.
    # Line buffering means a *process* crash still loses nothing.
    path = str(tmp_path / "j")
    j = RequestJournal(path)
    fill(j, 3)
    j.crash()
    records, stats = read_journal(path)
    assert stats.invalid == 0
    assert sum(1 for r in records if r.type == ADMIT) == 3


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

def test_segment_rotation_caps_segment_size(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path, segment_records=3) as j:
        fill(j, 8)                       # 9 records with the OPEN
    names = seg_files(path)
    assert len(names) == 3
    for name in names:
        n = sum(1 for _ in read_segment_records(os.path.join(path, name)))
        assert n <= 3
    _, stats = read_journal(path)
    assert stats.records == 9 and stats.invalid == 0


def test_each_incarnation_opens_fresh_segment(tmp_path):
    path = str(tmp_path / "j")
    RequestJournal(path).close()
    RequestJournal(path).close()
    j = RequestJournal(path)
    assert j.incarnation == "i3"
    assert len(seg_files(path)) == 3
    j.close()


def test_incarnations_deterministic_across_reruns(tmp_path):
    for run in range(2):
        path = str(tmp_path / f"j{run}")
        ids = []
        for _ in range(3):
            j = RequestJournal(path)
            ids.append(j.incarnation)
            j.close()
        assert ids == ["i1", "i2", "i3"]


# ---------------------------------------------------------------------------
# torn-write recovery (satellite: mid-record, boundary, bad checksum)
# ---------------------------------------------------------------------------

def test_truncate_mid_record_stops_at_last_valid(tmp_path):
    path = str(tmp_path / "j")
    j = RequestJournal(path)
    fill(j, 4)
    j.crash()
    seg = os.path.join(path, seg_files(path)[-1])
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 7)             # tear the last record mid-line
    spy = LogSpy()
    j2 = RequestJournal(path, log=spy)
    assert [r.data.get("payload_ref") for r in j2.recovered
            if r.type == ADMIT] == [0, 1, 2]     # last admit lost, rest kept
    assert j2.replay_stats.invalid == 1
    assert "journal-torn-record" in spy.names()
    assert "journal-truncated" in spy.names()
    j2.close()


def test_truncation_at_record_boundary_is_clean_loss(tmp_path):
    # A crash can happen to stop exactly at a newline: no invalid record,
    # just fewer of them — the un-fsynced tail simply never happened.
    path = str(tmp_path / "j")
    j = RequestJournal(path)
    fill(j, 4)
    j.crash()
    seg = os.path.join(path, seg_files(path)[-1])
    lines = open(seg, "rb").read().splitlines(keepends=True)
    with open(seg, "wb") as f:
        f.writelines(lines[:-1])
    spy = LogSpy()
    j2 = RequestJournal(path, log=spy)
    assert j2.replay_stats.invalid == 0
    assert spy.names() == []
    assert sum(1 for r in j2.recovered if r.type == ADMIT) == 3
    j2.close()


def test_corrupt_checksum_stops_replay_without_exception(tmp_path):
    path = str(tmp_path / "j")
    j = RequestJournal(path)
    fill(j, 5)
    j.close()
    seg_name = seg_files(path)[-1]
    seg = os.path.join(path, seg_name)
    lines = open(seg, "r", encoding="utf-8").read().splitlines()
    obj = json.loads(lines[3])
    obj["data"]["payload_ref"] = 999     # tamper without re-checksumming
    lines[3] = json.dumps(obj)
    with open(seg, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    spy = LogSpy()
    j2 = RequestJournal(path, log=spy)   # no exception
    assert j2.replay_stats.invalid == 1
    assert j2.replay_stats.torn_segment == seg_name
    names = spy.names()
    assert "journal-torn-record" in names and "journal-truncated" in names
    # The tampered record and everything after it is gone — never
    # resurrected with a wrong payload.
    refs = [r.data["payload_ref"] for r in j2.recovered if r.type == ADMIT]
    assert refs == [0, 1]
    j2.close()


def test_sequence_gap_between_segments_detected(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path, segment_records=3) as j:
        fill(j, 8)
    names = seg_files(path)
    os.remove(os.path.join(path, names[1]))      # lose a middle segment
    spy = LogSpy()
    j2 = RequestJournal(path, log=spy)
    assert j2.replay_stats.invalid == 1
    ev = dict(self_ev for self_ev in spy.events)["journal-torn-record"]
    assert ev["reason"] == "sequence-gap"
    # Replay stopped at the last record of the first surviving segment.
    assert j2.replay_stats.stopped_at_seq == 2
    j2.close()


def test_repair_quarantines_later_segments_and_unstrands_appends(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path, segment_records=3) as j:
        fill(j, 8)
    # Corrupt a record in the FIRST segment: without repair, every
    # future replay would stop at this byte and appends made after it
    # would be stranded forever.
    first = os.path.join(path, seg_files(path)[0])
    lines = open(first, "r", encoding="utf-8").read().splitlines()
    lines[2] = lines[2][:-3] + 'x"}'     # second admit (line 0 is OPEN)
    with open(first, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    spy = LogSpy()
    j2 = RequestJournal(path, segment_records=3, log=spy)
    assert spy.names().count("journal-segment-quarantined") == 2
    quarantined = [n for n in os.listdir(path)
                   if n.endswith(".quarantine")]
    assert len(quarantined) == 2
    fill(j2, 2)
    j2.close()
    # The journal is whole again: a clean audit reaches the new records.
    records, stats = read_journal(path)
    assert stats.invalid == 0
    assert sum(1 for r in records if r.type == ADMIT) == 1 + 2


# ---------------------------------------------------------------------------
# read_journal (audit) and streaming sinks
# ---------------------------------------------------------------------------

def test_read_journal_is_read_only(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path) as j:
        fill(j, 2)
    before = seg_files(path)
    records, stats = read_journal(path)
    assert seg_files(path) == before             # no new segment
    assert sum(1 for r in records if r.type == OPEN) == 1   # no OPEN added
    assert stats.records == 3


def test_read_journal_sink_streams_and_returns_empty_list(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path) as j:
        fill(j, 4)
    eager, _ = read_journal(path)
    streamed = []
    empty, stats = read_journal(path, sink=streamed.append)
    assert empty == []
    assert streamed == eager
    assert stats.records == len(eager)


def test_record_sink_bypasses_recovered_list(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path) as j:
        fill(j, 3)
    streamed = []
    j2 = RequestJournal(path, record_sink=streamed.append)
    assert j2.recovered == []
    assert [r.type for r in streamed] == [OPEN] + [ADMIT] * 3
    assert j2.incarnation == "i2"                # opens still counted
    j2.close()


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_newest_wins(tmp_path):
    path = str(tmp_path / "j")
    j = RequestJournal(path)
    j.write_snapshot({"gen": 1})
    fill(j, 2)
    j.write_snapshot({"gen": 2})
    body = j.load_snapshot()
    assert body["state"] == {"gen": 2}
    assert body["incarnation"] == "i1"
    assert body["seq"] == j.next_seq
    j.close()


def test_corrupt_snapshot_skipped_with_warning(tmp_path):
    path = str(tmp_path / "j")
    spy = LogSpy()
    j = RequestJournal(path, log=spy)
    j.write_snapshot({"gen": 1})
    fill(j, 1)
    newest = j.write_snapshot({"gen": 2})
    with open(newest, "r+", encoding="utf-8") as f:
        doc = f.read().replace('"gen":2', '"gen":3')   # breaks checksum
        f.seek(0)
        f.write(doc)
        f.truncate()
    body = j.load_snapshot()
    assert body["state"] == {"gen": 1}           # fell back to older valid
    assert "journal-snapshot-corrupt" in spy.names()
    j.close()


def test_no_snapshot_returns_none(tmp_path):
    j = RequestJournal(str(tmp_path / "j"))
    assert j.load_snapshot() is None
    j.close()


# ---------------------------------------------------------------------------
# sync modes / misc
# ---------------------------------------------------------------------------

def test_sync_always_mode_appends_fine(tmp_path):
    path = str(tmp_path / "j")
    with RequestJournal(path, sync="always") as j:
        fill(j, 3)
    _, stats = read_journal(path)
    assert stats.records == 4 and stats.invalid == 0


def test_journal_record_line_is_checksummed_json(tmp_path):
    rec = JournalRecord(seq=7, type=SERVED, data={"rseq": 3})
    obj = json.loads(rec.line())
    assert obj["seq"] == 7 and obj["type"] == SERVED
    assert isinstance(obj["c"], str) and len(obj["c"]) == 16


def test_process_incarnation_is_memoised():
    assert process_incarnation() == process_incarnation()
    assert process_incarnation().startswith("proc-")
