import os
import sys

import pytest

# Make the _hyp fallback importable regardless of pytest's import mode.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess integration tests")
