"""Dry-run integration: one real cell lowered+compiled in a subprocess
with 512 forced host devices (the production-mesh contract)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.parametrize("mp", [False, True])
def test_dryrun_smallest_cell(tmp_path, mp):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "qwen2-0.5b", "--shape", "train_4k",
           "--out", str(tmp_path)]
    if mp:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    tag = f"qwen2-0.5b__train_4k__{'2x16x16' if mp else '16x16'}"
    with open(tmp_path / f"{tag}.json") as f:
        art = json.load(f)
    assert art["chips"] == (512 if mp else 256)
    assert art["memory"]["fits_16gb"]
    assert art["flops_per_device"] > 1e12
    # multi-pod must produce cross-pod collectives (gradient all-reduce)
    assert art["collective_bytes_per_device"] > 0
    # useful-compute accounting is sane: HLO flops >= model flops and
    # within ~4x (remat + attention overhead)
    total_hlo = art["flops_per_device"] * art["chips"]
    assert 0.9 * art["model_flops"] <= total_hlo <= 6 * art["model_flops"]
