"""Closed-loop power governance: sampler, watchdog, governor, site.

Covers the repro.power subsystem plus its integration satellites: the
watchdog edge cases ISSUE 8 names (stale-timeout boundary, single-sample
spike vs sustained step, dropout -> recovery re-arm), hypothesis
properties of the governor (output always inside [f_min, f_max];
monotone under a monotone power error), the bit-reproducible fallback
contract, site cap enforcement with priority-ordered shedding, the
telemetered serving receipts, the guarded-ratio conventions and the
sticky-first-sample ClockController trace.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hyp import given, settings, st

from repro.core.energy import guarded_ratio
from repro.core.hardware import TPU_V5E
from repro.core.scheduler import ClockController
from repro.power import (DROPOUT, FRESH, HEALTHY, SPIKE, STALE, SUSPECT,
                         UNHEALTHY, FleetTelemetry, GovernorConfig,
                         PowerGovernor, PowerReading, SimulatedPowerSampler,
                         SiteBudgetScheduler, SitePipeline, TelemetryRing,
                         TelemetryWatchdog)
from repro.runtime.faults import (SENSOR_DROPOUT, SENSOR_SPIKE, SENSOR_STALE,
                                  FaultEvent, FaultPlan)

DEV = TPU_V5E
FALLBACK = 1020.0


def reading(p, t=0.0, dev=0):
    return PowerReading(device_index=dev, t=t, power_w=p)


# ---------------------------------------------------------------------------
# sampler + ring
# ---------------------------------------------------------------------------

class TestSampler:
    def test_same_seed_reproduces_every_reading(self):
        a = SimulatedPowerSampler(DEV, seed=7, drift_w=3.0)
        b = SimulatedPowerSampler(DEV, seed=7, drift_w=3.0)
        for k in range(10):
            assert a.sample(0, 0.1 * k) == b.sample(0, 0.1 * k)

    def test_device_streams_are_interleaving_independent(self):
        a = SimulatedPowerSampler(DEV, seed=3)
        b = SimulatedPowerSampler(DEV, seed=3)
        # a samples device 0 five times, then device 1; b interleaves.
        seq_a = [a.sample(0, 0.1 * k) for k in range(5)]
        b.sample(1, 0.0)
        seq_b = [b.sample(0, 0.1 * k) for k in range(5)]
        assert seq_a == seq_b

    def test_noise_bounded_by_noise_frac(self):
        s = SimulatedPowerSampler(DEV, seed=1, noise_frac=0.02)
        truth = s.truth_w(0)
        for k in range(50):
            r = s.sample(0, 0.0)
            assert abs(r.power_w - truth) <= 0.02 * truth + 1e-9

    def test_fault_plan_corrupts_readings(self):
        plan = FaultPlan(events=[FaultEvent(SENSOR_DROPOUT, batch_id=0),
                                 FaultEvent(SENSOR_SPIKE, batch_id=1),
                                 FaultEvent(SENSOR_STALE, batch_id=3)])
        s = SimulatedPowerSampler(DEV, seed=1, fault_plan=plan)
        assert math.isnan(s.sample(0, 0.0, token=0).power_w)
        assert s.sample(0, 0.1, token=1).power_w == pytest.approx(
            2.0 * DEV.tdp)
        ok = s.sample(0, 0.2, token=2)
        stale = s.sample(0, 0.3, token=3)
        assert stale == ok                   # frozen value AND timestamp

    def test_stale_needs_a_previous_reading(self):
        plan = FaultPlan(events=[FaultEvent(SENSOR_STALE, batch_id=0)])
        s = SimulatedPowerSampler(DEV, seed=1, fault_plan=plan)
        r = s.sample(0, 0.0, token=0)        # nothing to replay yet
        assert r.ok and plan.pending() == 1

    def test_ring_is_bounded_and_counts_drops(self):
        ring = TelemetryRing(capacity=4)
        for k in range(10):
            ring.push(reading(100.0 + k, t=0.1 * k))
        assert len(ring) == 4
        assert ring.pushed == 10 and ring.dropped == 6
        assert ring.latest().power_w == 109.0
        assert [r.power_w for r in ring.window(2)] == [108.0, 109.0]


# ---------------------------------------------------------------------------
# watchdog: classification edge cases + health state machine
# ---------------------------------------------------------------------------

class TestWatchdog:
    def test_stale_timeout_boundary_is_exclusive(self):
        dog = TelemetryWatchdog(DEV, stale_timeout_s=0.05)
        # age == timeout is still fresh; strictly older is stale.
        # (t=0 keeps the age arithmetic exact in binary floating point.)
        assert dog.classify(reading(150.0, t=0.0), now=0.05) == FRESH
        assert dog.classify(reading(150.0, t=0.0), now=0.0500001) == STALE

    def test_dropout_and_envelope_spike(self):
        dog = TelemetryWatchdog(DEV, envelope_frac=1.25)
        assert dog.classify(reading(float("nan")), now=0.0) == DROPOUT
        assert dog.classify(reading(-1.0), now=0.0) == SPIKE
        assert dog.classify(reading(1.25 * DEV.tdp + 1.0), now=0.0) == SPIKE

    def test_single_sample_spike_vs_sustained_step(self):
        # A one-sample glitch is flagged twice (up AND back down); a
        # sustained step is flagged exactly once, then accepted.
        glitch = TelemetryWatchdog(DEV, step_w=50.0)
        labels = [glitch.observe(reading(p, t=0.1 * k), now=0.1 * k)[0]
                  for k, p in enumerate([150.0, 151.0, 230.0, 150.0, 151.0])]
        assert labels == [FRESH, FRESH, SPIKE, SPIKE, FRESH]

        step = TelemetryWatchdog(DEV, step_w=50.0)
        labels = [step.observe(reading(p, t=0.1 * k), now=0.1 * k)[0]
                  for k, p in enumerate([150.0, 151.0, 230.0, 231.0, 230.0])]
        assert labels == [FRESH, FRESH, SPIKE, FRESH, FRESH]

    def test_dropout_recovery_rearm(self):
        dog = TelemetryWatchdog(DEV, unhealthy_after=3, rearm_after=2)
        assert dog.health == HEALTHY
        for k in range(3):
            dog.observe(reading(float("nan"), t=0.1 * k), now=0.1 * k)
        assert dog.health == UNHEALTHY and dog.unhealthy_entries == 1
        # One fresh reading is not enough to re-arm...
        dog.observe(reading(150.0, t=0.3), now=0.3)
        assert dog.health == UNHEALTHY
        # ...two consecutive fresh readings are.
        dog.observe(reading(150.5, t=0.4), now=0.4)
        assert dog.health == HEALTHY and dog.healthy

    def test_suspect_after_one_bad_counts_as_usable(self):
        dog = TelemetryWatchdog(DEV)
        dog.observe(reading(float("nan")), now=0.0)
        assert dog.health == SUSPECT and dog.healthy

    def test_rearm_counter_resets_on_interleaved_bad(self):
        dog = TelemetryWatchdog(DEV, unhealthy_after=2, rearm_after=2)
        dog.observe(reading(float("nan"), t=0.0), now=0.0)
        dog.observe(reading(float("nan"), t=0.1), now=0.1)
        assert dog.health == UNHEALTHY
        dog.observe(reading(150.0, t=0.2), now=0.2)
        dog.observe(reading(float("nan"), t=0.3), now=0.3)   # resets streak
        dog.observe(reading(150.0, t=0.4), now=0.4)
        assert dog.health == UNHEALTHY                       # streak is 1
        dog.observe(reading(150.0, t=0.5), now=0.5)
        assert dog.health == HEALTHY


# ---------------------------------------------------------------------------
# governor: guards, fallback contract, hypothesis properties
# ---------------------------------------------------------------------------

def governor(**kw):
    kw.setdefault("target_w", 150.0)
    kw.setdefault("fallback_mhz", FALLBACK)
    return PowerGovernor(DEV, **kw)


class TestGovernor:
    def test_starts_at_fallback_and_validates_it(self):
        assert governor().f_mhz == FALLBACK
        with pytest.raises(ValueError):
            governor(fallback_mhz=DEV.f_max + 100.0)

    def test_hysteresis_dead_band_holds(self):
        gov = governor(config=GovernorConfig(hysteresis_w=2.0))
        f0 = gov.f_mhz
        assert gov.step(149.0) == f0 and gov.mode == "hold"
        assert gov.integral_w == 0.0         # no windup while holding

    def test_slew_rate_limit_bounds_every_move(self):
        cfg = GovernorConfig(slew_mhz_per_tick=65.0)
        gov = governor(config=cfg)
        prev = gov.f_mhz
        for measured in [50.0, 40.0, 300.0, 30.0, 150.0, 90.0]:
            f = gov.step(measured)
            assert abs(f - prev) <= cfg.slew_mhz_per_tick + 1e-9
            prev = f

    def test_missing_sample_holds_without_windup(self):
        gov = governor()
        gov.step(100.0)                      # build some integral
        integral = gov.integral_w
        f = gov.f_mhz
        assert gov.step(None) == f and gov.mode == "hold"
        assert gov.step(float("nan")) == f
        assert gov.integral_w == integral

    def test_unhealthy_pins_bit_exact_fallback_and_resets(self):
        gov = governor()
        for _ in range(5):
            gov.step(60.0)                   # wind up, move off fallback
        assert gov.f_mhz != FALLBACK and gov.integral_w != 0.0
        f = gov.step(60.0, healthy=False)
        assert f == FALLBACK                 # exact, not approx: stored value
        assert gov.integral_w == 0.0 and gov.in_fallback
        assert gov.fallback_engagements == 1
        gov.step(None, healthy=False)
        assert gov.fallback_engagements == 1  # same engagement, no re-count

    def test_fallback_reproducible_across_runs(self):
        def run():
            gov = governor()
            out = []
            for k in range(20):
                healthy = not 8 <= k < 12
                out.append(gov.step(100.0 + k, healthy=healthy))
            return out
        assert run() == run()

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.one_of(
        st.none(),
        st.floats(min_value=-1e3, max_value=1e4,
                  allow_nan=False, allow_infinity=False)),
        min_size=1, max_size=40),
        st.booleans())
    def test_output_always_within_clock_bounds(self, measured, flip):
        gov = governor()
        for k, m in enumerate(measured):
            healthy = not (flip and k % 3 == 0)
            f = gov.step(m, healthy=healthy)
            assert DEV.f_min <= f <= DEV.f_max

    @settings(max_examples=50, deadline=None)
    @given(st.floats(min_value=0.0, max_value=500.0, allow_nan=False),
           st.floats(min_value=0.0, max_value=500.0, allow_nan=False))
    def test_single_step_monotone_in_power_error(self, m_low, m_high):
        # Lower measured power (larger error) never commands a lower
        # clock than higher measured power, from identical fresh state.
        lo, hi = min(m_low, m_high), max(m_low, m_high)
        f_hi_err = governor().step(lo)       # bigger error: speed up more
        f_lo_err = governor().step(hi)
        assert f_hi_err >= f_lo_err


# ---------------------------------------------------------------------------
# telemetry bundle
# ---------------------------------------------------------------------------

class TestFleetTelemetry:
    def test_fresh_read_exposes_measured_w(self):
        tel = FleetTelemetry(DEV, SimulatedPowerSampler(DEV, seed=2))
        tr = tel.read(0, 0.0)
        assert tr.fresh and tr.measured_w == tr.reading.power_w
        assert tel.healthy(0)

    def test_non_fresh_read_withholds_measured_w(self):
        plan = FaultPlan(events=[FaultEvent(SENSOR_DROPOUT, batch_id=0)])
        tel = FleetTelemetry(
            DEV, SimulatedPowerSampler(DEV, seed=2, fault_plan=plan))
        tr = tel.read(0, 0.0, token=0)
        assert tr.label == DROPOUT and tr.measured_w is None

    def test_summary_aggregates_per_device_watchdogs(self):
        tel = FleetTelemetry(DEV, SimulatedPowerSampler(DEV, seed=2))
        tel.read(0, 0.0)
        tel.read(1, 0.0)
        s = tel.summary()
        assert s["reads"] == 2 and s["labels"][FRESH] == 2
        assert s["health"] == {0: HEALTHY, 1: HEALTHY}

    def test_unread_devices_are_healthy(self):
        tel = FleetTelemetry(DEV, SimulatedPowerSampler(DEV, seed=2))
        assert tel.healthy(5)


# ---------------------------------------------------------------------------
# site budget scheduler
# ---------------------------------------------------------------------------

def make_site(seed=0, fault_plan=None, cap=1400.0, hard=1500.0, n=8):
    pipes = [SitePipeline(name=f"p{i}", device_index=i,
                          priority=(i % 4) + 1, fallback_mhz=FALLBACK,
                          u_core=0.9, u_mem=0.8)
             for i in range(n)]
    return SiteBudgetScheduler(DEV, pipes, site_cap_w=cap, hard_cap_w=hard,
                               seed=seed, fault_plan=fault_plan)


class TestSite:
    def test_cap_never_exceeded_and_converges(self):
        site = make_site()
        ticks = site.run(60, dt=0.1)
        assert max(t.truth_w for t in ticks) <= site.site_cap_w
        assert site.first_converged_tick is not None
        assert site.first_converged_tick <= 40

    def test_digest_reproducible_across_fresh_runs(self):
        a, b = make_site(seed=5), make_site(seed=5)
        a.run(40, dt=0.1)
        b.run(40, dt=0.1)
        assert a.digest() == b.digest()

    def test_sensor_storm_engages_exact_fallback_then_rearms(self):
        plan = FaultPlan(events=[FaultEvent(SENSOR_SPIKE, batch_id=k,
                                            worker=0)
                                 for k in range(10, 14)])
        site = make_site(fault_plan=plan)
        ticks = site.run(30, dt=0.1)
        fb = [k for k, t in enumerate(ticks) if t.modes[0] == "fallback"]
        assert fb, "governor never fell back under the sensor storm"
        assert all(ticks[k].clocks_mhz[0] == FALLBACK for k in fb)
        assert ticks[-1].health[0] == HEALTHY    # re-armed after recovery

    def test_shed_order_is_lowest_priority_first(self):
        # A cap whose budget (headroom * cap = 368 W) cannot hold all
        # eight f_min floors (~430 W) must shed priority-1 names first.
        site = make_site(cap=400.0, hard=450.0)
        shed = [p.name for p in site.shed]
        assert shed, "tight cap must shed"
        survivors = {p.priority for p in site.active}
        victims = {p.priority for p in site.shed}
        assert max(victims) <= min(survivors)

    def test_emergency_rung_floors_sheds_and_restores(self):
        site = make_site()
        site.run(20, dt=0.1)
        pre = len(site.active)
        site.site_cap_w, site.hard_cap_w = 850.0, 900.0
        ticks = site.run(20, dt=0.1)[20:]
        assert site.emergencies >= 1
        assert len(site.active) < pre
        emergency_tick = next(t for t in ticks if t.emergency)
        active_names = set(emergency_tick.active)
        floored = [f for p, f in zip(site.pipelines,
                                     emergency_tick.clocks_mhz)
                   if p.name in active_names]
        assert all(f == DEV.f_min for f in floored)
        assert ticks[-1].truth_w <= site.hard_cap_w

    def test_distinct_devices_required(self):
        pipes = [SitePipeline(name="a", device_index=0, priority=1,
                              fallback_mhz=FALLBACK),
                 SitePipeline(name="b", device_index=0, priority=2,
                              fallback_mhz=FALLBACK)]
        with pytest.raises(ValueError):
            SiteBudgetScheduler(DEV, pipes, site_cap_w=400.0)


# ---------------------------------------------------------------------------
# serving integration: measured_energy_j on receipts
# ---------------------------------------------------------------------------

class TestServingIntegration:
    def _service(self, telemetry):
        from repro.serving.service import FFTService
        return FFTService(DEV, keep_results=False, telemetry=telemetry)

    def _submit(self, svc, n=4):
        rng = np.random.default_rng(0)
        for _ in range(n):
            svc.submit((rng.standard_normal((2, 256))
                        + 1j * rng.standard_normal((2, 256))
                        ).astype(np.complex64))

    def test_unmetered_service_reports_none(self):
        svc = self._service(None)
        self._submit(svc)
        for r in svc.drain():
            assert r.measured_energy_j is None
            assert r.energy_error_frac is None
        assert svc.report().telemetry is None

    def test_fresh_telemetry_prices_receipts_at_measured_power(self):
        tel = FleetTelemetry.for_serving(DEV, seed=9, noise_frac=0.01)
        svc = self._service(tel)
        self._submit(svc)
        receipts = svc.drain()
        assert receipts
        for r in receipts:
            assert r.measured_energy_j is not None
            # within the sampler's noise band of the modelled energy
            assert abs(r.energy_error_frac) <= 0.011
        rep = svc.report()
        assert rep.measured_energy_j > 0.0
        assert rep.telemetry["labels"][FRESH] == rep.telemetry["reads"]

    def test_faulted_telemetry_falls_back_to_modelled_energy(self):
        # Every sample drops out: measured_energy_j must equal the
        # modelled energy_j exactly (never freewheel on bad telemetry).
        plan = FaultPlan(events=[FaultEvent(SENSOR_DROPOUT)
                                 for _ in range(64)])
        tel = FleetTelemetry.for_serving(DEV, seed=9, fault_plan=plan)
        svc = self._service(tel)
        self._submit(svc)
        for r in svc.drain():
            assert r.measured_energy_j == r.energy_j


# ---------------------------------------------------------------------------
# satellites: guarded ratios + sticky-first-sample clock trace
# ---------------------------------------------------------------------------

class TestGuardedRatio:
    def test_zero_over_zero_returns_on_zero(self):
        assert guarded_ratio(0.0, 0.0) == 1.0
        assert guarded_ratio(0.0, 0.0, on_zero=0.0) == 0.0
        assert math.isnan(guarded_ratio(0.0, 0.0, on_zero=float("nan")))

    def test_nonzero_over_zero_is_a_contradiction(self):
        assert math.isnan(guarded_ratio(3.0, 0.0))
        assert math.isnan(guarded_ratio(-1.0, 0.0, on_zero=0.0))

    def test_normal_division(self):
        assert guarded_ratio(3.0, 4.0) == 0.75

    def test_report_conventions(self):
        from repro.serving.cache import CacheStats
        from repro.serving.service import ServiceReport
        empty = ServiceReport(
            n_requests=0, n_transforms=0, n_batches=0, wall_s=0.0,
            energy_j=0.0, boost_energy_j=0.0, p50_latency_s=0.0,
            p99_latency_s=0.0, mean_latency_s=0.0, cache=CacheStats(),
            steals=0, clock_locks=0)
        assert empty.availability == 1.0     # no demand, nothing unserved
        assert empty.i_ef == 1.0
        assert empty.throughput_tps == 0.0
        assert empty.joules_per_transform == 0.0
        assert CacheStats().hit_rate == 0.0  # no lookups, no hits

    def test_shed_receipt_i_ef_is_one(self):
        from repro.serving.request import FFTRequest, RequestReceipt
        req = FFTRequest(x=np.zeros((1, 8), dtype=np.complex64))
        shed = RequestReceipt.make_shed(req, "admission:deadline", 0.0)
        assert shed.i_ef_boost == 1.0


class FakeTimer:
    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


class TestClockTrace:
    def test_trace_starts_from_boost_even_after_eviction(self):
        ctrl = ClockController(DEV, timer=FakeTimer(), max_events=4)
        for f in (900.0, 1000.0, 1100.0, 1200.0):
            with ctrl.locked(f):
                pass
        assert len(ctrl.events) == 4         # deque dropped the oldest
        ts, fs = ctrl.trace()
        assert ts[0] == 0.0 and fs[0] == DEV.f_max
        assert len(ts) == 5                  # sticky first + 4 retained

    def test_unbounded_trace_also_prepends_initial_state(self):
        ctrl = ClockController(DEV, timer=FakeTimer())
        with ctrl.locked(800.0):
            pass
        ts, fs = ctrl.trace()
        assert fs[0] == DEV.f_max and fs[1] == 800.0
        assert fs[-1] == DEV.f_max           # reset restored boost
