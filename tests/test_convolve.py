"""Overlap-save FFT convolution engine: parity, edge cases, routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional test dep: skip property tests
    from _hyp import given, settings, st

from repro.fft import convolve as conv_mod
from repro.fft import plan as plan_mod
from repro.fft.convolve import (ConvPlan, cached_filter_spectra, conv_plan,
                                overlap_save_conv, select_nfft)

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def oracle(x, filters):
    """Direct per-filter full convolution (numpy)."""
    x = np.atleast_2d(np.asarray(x))
    filters = np.atleast_2d(np.asarray(filters))
    return np.stack([[np.convolve(row, f) for f in filters] for row in x])


def assert_close(got, want, rtol=1e-4):
    got, want = np.asarray(got), np.asarray(want)
    rel = np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)
    assert rel < rtol, rel


# ---------------------------------------------------------------------------
# Parity vs the direct oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,taps,t,nfft", [
    (1000, 33, 3, None),       # non-pow2 signal -> padded pow2 segments
    (512, 16, 1, None),        # single filter
    (513, 17, 4, 64),          # explicit segment length
    (64, 8, 2, None),          # signal shorter than the chosen segment
    (100, 129, 2, None),       # filter longer than the signal
])
def test_overlap_save_matches_convolve(n, taps, t, nfft):
    x = rand_complex((2, n))
    h = np.asarray(rand_complex((t, taps), key=jax.random.PRNGKey(7)))
    got = overlap_save_conv(x, h, nfft=nfft)
    assert got.shape == (2, t, n + taps - 1)
    assert_close(got, oracle(x, h))


def test_overlap_save_batch_of_one_and_1d_input():
    x1 = rand_complex((1, 300))
    h = np.asarray(rand_complex((2, 21), key=jax.random.PRNGKey(3)))
    assert_close(overlap_save_conv(x1, h), oracle(x1, h))
    # a bare (n,) row keeps its rank: (T, out) without a batch axis
    x0 = rand_complex((300,), key=jax.random.PRNGKey(4))
    got = overlap_save_conv(x0, h)
    assert got.shape == (2, 320)
    assert_close(got, oracle(x0, h)[0])


def test_real_input_promoted_to_complex():
    x = jax.random.normal(KEY, (2, 200))
    h = np.asarray(rand_complex((2, 15), key=jax.random.PRNGKey(5)))
    assert_close(overlap_save_conv(x, h), oracle(x, h))


def test_filter_longer_than_segment_raises():
    with pytest.raises(ValueError, match="longer than the segment"):
        overlap_save_conv(jnp.zeros(100), np.ones((1, 65)), nfft=64)
    with pytest.raises(ValueError, match="power of two"):
        overlap_save_conv(jnp.zeros(100), np.ones((1, 5)), nfft=48)


def test_auto_selection_handles_long_filters():
    """A filter far longer than the default segment guess just bumps the
    auto-selected segment — no caller-side sizing needed."""
    taps = 700
    x = rand_complex((1, 256))
    h = np.asarray(rand_complex((1, taps), key=jax.random.PRNGKey(9)))
    plan = conv_plan(256, taps, 1)
    assert plan.nfft >= taps
    assert_close(overlap_save_conv(x, h), oracle(x, h))


@settings(deadline=None, max_examples=15)
@given(n=st.integers(16, 600), logtaps=st.integers(2, 6),
       t=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_property_overlap_save_parity(n, logtaps, t, seed):
    taps = 2**logtaps + 1
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = rand_complex((2, n), key=k1)
    h = np.asarray(rand_complex((t, taps), key=k2))
    assert_close(overlap_save_conv(x, h), oracle(x, h), rtol=3e-4)


# ---------------------------------------------------------------------------
# Plan accounting + segment selection
# ---------------------------------------------------------------------------

def test_conv_plan_pass_accounting():
    plan = conv_plan(4096, 32, templates=8)
    assert isinstance(plan, ConvPlan)
    assert plan.forward_passes == 1          # fused bank multiply epilogue
    assert plan.inverse_passes == 8          # one inverse pass per template
    assert plan.step == plan.nfft - plan.taps + 1
    assert plan.n_segments * plan.step >= plan.out_len
    # long signal, short filter: overlap-save beats the direct method
    assert plan.traffic_ratio > 1.0


def test_conv_plan_memoised_and_validated():
    assert conv_plan(1024, 17, 4) is conv_plan(1024, 17, 4)
    with pytest.raises(ValueError):
        conv_plan(1024, 17, 0)
    with pytest.raises(ValueError):
        conv_plan(1024, 65, 1, nfft=64)


def test_select_nfft_bounds():
    for taps, n in [(17, 4096), (65, 1000), (5, 64)]:
        nfft = select_nfft(taps, n, templates=4)
        assert nfft >= taps and nfft & (nfft - 1) == 0
        # never longer than one segment covering the whole padded signal
        assert nfft <= 1 << max(n + taps - 2, 1).bit_length()


def test_filter_spectra_cached_per_key():
    h = np.asarray(rand_complex((3, 9), key=jax.random.PRNGKey(11)))
    before = conv_mod._SPECTRA_BUILDS
    a = cached_filter_spectra(("test-bank", 1), h, 64)
    mid = conv_mod._SPECTRA_BUILDS
    b = cached_filter_spectra(("test-bank", 1), h, 64)
    after = conv_mod._SPECTRA_BUILDS
    assert mid == before + 1 and after == mid    # second call: pure hit
    assert a is b
    # a different segment length is a different artefact
    cached_filter_spectra(("test-bank", 1), h, 128)
    assert conv_mod._SPECTRA_BUILDS == after + 1


# ---------------------------------------------------------------------------
# Kernel routing: fused multiply epilogue, no standalone multiply pass
# ---------------------------------------------------------------------------

class _CountingKernel:
    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.inverse_calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if kwargs.get("inverse"):
            self.inverse_calls += 1
        return self.inner(*args, **kwargs)


def test_conv_routes_fused_mul_plus_one_inverse(monkeypatch):
    """The forward segment FFT carries the bank multiply as a kernel
    epilogue and the T product planes share ONE batched inverse launch —
    no plain forward FFT, no separate multiply, no transpose kernels."""
    mul = _CountingKernel(plan_mod.fft_kernel_c2c_mul)
    fft = _CountingKernel(plan_mod.fft_kernel_c2c)
    tr = _CountingKernel(plan_mod.transpose_kernel)
    monkeypatch.setattr(plan_mod, "_kernel_fft_mul", mul)
    monkeypatch.setattr(plan_mod, "_kernel_fft", fft)
    monkeypatch.setattr(plan_mod, "_kernel_transpose", tr)
    x = rand_complex((3, 777), key=jax.random.PRNGKey(21))
    h = np.asarray(rand_complex((5, 33), key=jax.random.PRNGKey(22)))
    got = overlap_save_conv(x, h)
    assert_close(got, oracle(x, h))
    assert mul.calls == 1                       # fused forward + epilogue
    assert fft.calls == 1 and fft.inverse_calls == 1   # one inverse launch
    assert tr.calls == 0


def test_conv_falls_back_without_pallas(monkeypatch):
    for hook in ("_kernel_fft", "_kernel_rfft", "_kernel_irfft",
                 "_kernel_fft_mul", "_kernel_fft_t", "_kernel_fft_axis1",
                 "_kernel_rfft_t", "_kernel_transpose"):
        monkeypatch.setattr(plan_mod, hook, None)
    x = rand_complex((2, 333), key=jax.random.PRNGKey(23))
    h = np.asarray(rand_complex((3, 17), key=jax.random.PRNGKey(24)))
    assert_close(overlap_save_conv(x, h), oracle(x, h))


def test_fft_mul_kernel_parity():
    from repro.kernels.fft.ops import fft_kernel_c2c_mul
    x = rand_complex((4, 128), key=jax.random.PRNGKey(31))
    bank = np.asarray(rand_complex((3, 128), key=jax.random.PRNGKey(32)))
    got = np.asarray(fft_kernel_c2c_mul(x, bank))
    want = np.fft.fft(np.asarray(x), axis=-1)[:, None, :] * bank[None]
    assert_close(got, want)


def test_fft_mul_kernel_rejects_bad_bank():
    from repro.kernels.fft.ops import fft_kernel_c2c_mul
    with pytest.raises(ValueError, match="filter bank"):
        fft_kernel_c2c_mul(jnp.zeros((2, 64), jnp.complex64),
                           jnp.zeros((3, 32), jnp.complex64))


def test_conv_plan_unfused_beyond_kernel_limit():
    """Segments past the single-pass kernel limit cannot fuse the bank
    multiply; the plan must charge the fallback (FFT passes + ONE
    standalone multiply pass) instead of the fused-epilogue counts."""
    plan = conv_plan(2**15, 6000, templates=2)       # forces nfft > 2^13
    assert plan.nfft > 8192 and not plan.fused
    assert plan.forward_passes > 1                   # + multiply pass
    assert plan.inverse_passes > plan.templates      # four-step inverses
    fused = conv_plan(2**15, 33, templates=2)
    assert fused.fused and fused.forward_passes == 1
