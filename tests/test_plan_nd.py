"""N-D plan-graph engine: parity, pass counts, kernel routing, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional test dep: skip property tests
    from _hyp import given, settings, st

from repro.fft import fft2, fftn, plan_nd, rfft2, rfftn
from repro.fft import plan as plan_mod
from repro.fft.plan_nd import nd_pass_summary

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY, dtype=jnp.complex64):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(dtype)


def assert_close(got, want, rtol=3e-3, atol=3e-3):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Parity vs jnp.fft across length classes (pow2 / four-step / Bluestein)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    (8, 16), (32, 32), (64, 128),          # pow2: fully fused, 2 passes
    (4, 2**14),                            # four-step axis in a 2-D plan
    (12, 32), (16, 100), (45, 39),         # Bluestein axes (one or both)
])
def test_fft2_matches_reference(shape):
    x = rand_complex((3, *shape))
    assert_close(fft2(x), jnp.fft.fft2(x))


@pytest.mark.parametrize("shape", [
    (8, 16), (32, 32), (16, 2**14), (12, 32), (16, 100),
])
def test_rfft2_matches_reference(shape):
    x = jax.random.normal(KEY, (2, *shape))
    assert_close(rfft2(x), jnp.fft.rfft2(x))


@pytest.mark.parametrize("shape", [(4, 8, 16), (8, 8, 8), (4, 12, 16)])
def test_fftn_matches_reference(shape):
    x = rand_complex((2, *shape))
    assert_close(fftn(x, axes=(1, 2, 3)), jnp.fft.fftn(x, axes=(1, 2, 3)))


@pytest.mark.parametrize("shape", [(4, 8, 16), (4, 12, 16)])
def test_rfftn_matches_reference(shape):
    x = jax.random.normal(KEY, (2, *shape))
    assert_close(rfftn(x, axes=(1, 2, 3)), jnp.fft.rfftn(x, axes=(1, 2, 3)))


def test_fftn_default_axes_and_moveaxis_normalisation():
    x = rand_complex((8, 4, 16))
    assert_close(fftn(x), jnp.fft.fftn(x))
    assert_close(fft2(x, axes=(0, 2)), jnp.fft.fft2(x, axes=(0, 2)))


def test_four_step_parity_tight():
    """Acceptance: fused four-step matches jnp.fft.fft at 1e-4 rtol."""
    n = 2**14
    x = rand_complex((2, n), key=jax.random.PRNGKey(5))
    got = np.asarray(plan_mod.plan_for_length(n)(x))
    want = np.fft.fft(np.asarray(x), axis=-1)
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 1e-4, rel


@settings(deadline=None, max_examples=15)
@given(log0=st.integers(1, 6), log1=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_fft2_pow2_parity(log0, log1, seed):
    x = rand_complex((2, 2**log0, 2**log1), key=jax.random.PRNGKey(seed))
    assert_close(fft2(x), jnp.fft.fft2(x))


@settings(deadline=None, max_examples=15)
@given(log0=st.integers(1, 5), log1=st.integers(2, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_rfft2_pow2_parity(log0, log1, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 2**log0, 2**log1))
    assert_close(rfft2(x), jnp.fft.rfft2(x))


@settings(deadline=None, max_examples=10)
@given(n0=st.sampled_from([3, 12, 20, 45]), log1=st.integers(3, 6),
       seed=st.integers(0, 2**31 - 1))
def test_property_fft2_bluestein_axis_parity(n0, log1, seed):
    """One Bluestein axis + one pow2 axis — the mixed plan graph."""
    x = rand_complex((2, n0, 2**log1), key=jax.random.PRNGKey(seed))
    assert_close(fft2(x), jnp.fft.fft2(x))


@settings(deadline=None, max_examples=8)
@given(logn=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_property_fftn_3d_parity(logn, seed):
    n = 2**logn
    x = rand_complex((n, n, n), key=jax.random.PRNGKey(seed))
    assert_close(fftn(x), jnp.fft.fftn(x))


# ---------------------------------------------------------------------------
# Plan-graph structure and pass accounting
# ---------------------------------------------------------------------------

def test_pow2_2d_plan_is_two_fused_passes():
    plan = plan_nd((256, 512))
    assert [n.op for n in plan.nodes] == ["fft_t", "fft_t"]
    assert plan.passes == 2
    # the per-axis moveaxis chain paid 1 (last axis) + 1 + 2 (moveaxis
    # there and back) = 4 -> the acceptance >= 2x pass reduction
    assert plan.chain_passes >= 2 * plan.passes


def test_pow2_r2c_2d_plan_structure():
    plan = plan_nd((256, 512), "r2c")
    assert [n.op for n in plan.nodes] == ["rfft_t", "fft_t"]
    assert plan.passes == 2
    assert plan.out_shape == (256, 257)


def test_pow2_3d_plan_is_three_fused_passes():
    plan = plan_nd((16, 16, 16))
    assert [n.op for n in plan.nodes] == ["fft_t"] * 3
    assert plan.passes == 3
    assert plan.chain_passes == 1 + 3 + 3


def test_bluestein_axis_gets_explicit_transpose_node():
    plan = plan_nd((12, 32))
    ops = [n.op for n in plan.nodes]
    assert ops == ["fft_t", "fft1d", "transpose"]
    assert plan.nodes[1].algorithm == "bluestein"


def test_plan_nd_1d_delegates_to_planner():
    plan = plan_nd((4096,))
    ref = plan_mod.plan_for_length(4096)
    assert plan.passes == ref.passes
    assert plan.algorithm == ref.algorithm
    x = rand_complex((2, 4096))
    assert_close(plan(x), jnp.fft.fft(x))


def test_nd_pass_summary_matches_plan():
    passes, chain, stages = nd_pass_summary((64, 64))
    plan = plan_nd((64, 64))
    assert (passes, chain, stages) == (plan.passes, plan.chain_passes,
                                       plan.stages)


def test_plan_nd_rejects_bad_specs():
    with pytest.raises(ValueError):
        plan_nd((0, 8))
    with pytest.raises(ValueError):
        plan_nd((8, 8), "hartley")


# ---------------------------------------------------------------------------
# Kernel routing: the 2-D path launches exactly its plan's fused passes
# ---------------------------------------------------------------------------

class _CountingKernel:
    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        return self.inner(*args, **kwargs)


def test_fft2_launches_exactly_two_fused_passes(monkeypatch):
    """Acceptance: no hidden fallback to the per-axis chain — the pow2
    2-D path is exactly two fused transpose-write kernel launches."""
    fused = _CountingKernel(plan_mod.fft_kernel_c2c_t)
    plain = _CountingKernel(plan_mod.fft_kernel_c2c)
    tr = _CountingKernel(plan_mod.transpose_kernel)
    monkeypatch.setattr(plan_mod, "_kernel_fft_t", fused)
    monkeypatch.setattr(plan_mod, "_kernel_fft", plain)
    monkeypatch.setattr(plan_mod, "_kernel_transpose", tr)
    x = rand_complex((5, 16, 64))
    assert_close(fft2(x), jnp.fft.fft2(x))
    assert fused.calls == 2
    assert plain.calls == 0
    assert tr.calls == 0


def test_rfft2_launches_fused_r2c_then_c2c(monkeypatch):
    fused_r = _CountingKernel(plan_mod.fft_kernel_r2c_t)
    fused_c = _CountingKernel(plan_mod.fft_kernel_c2c_t)
    monkeypatch.setattr(plan_mod, "_kernel_rfft_t", fused_r)
    monkeypatch.setattr(plan_mod, "_kernel_fft_t", fused_c)
    x = jax.random.normal(KEY, (5, 16, 64))
    assert_close(rfft2(x), jnp.fft.rfft2(x))
    assert fused_r.calls == 1
    assert fused_c.calls == 1


def test_bluestein_axis_routes_tiled_transpose(monkeypatch):
    tr = _CountingKernel(plan_mod.transpose_kernel)
    monkeypatch.setattr(plan_mod, "_kernel_transpose", tr)
    x = rand_complex((4, 12, 32))
    assert_close(fft2(x), jnp.fft.fft2(x))
    assert tr.calls == 1


def test_nd_falls_back_without_pallas(monkeypatch):
    for hook in ("_kernel_fft", "_kernel_rfft", "_kernel_irfft",
                 "_kernel_fft_t", "_kernel_fft_axis1", "_kernel_rfft_t",
                 "_kernel_transpose"):
        monkeypatch.setattr(plan_mod, hook, None)
    x = rand_complex((6, 16, 32))
    assert_close(fft2(x), jnp.fft.fft2(x))
    xr = jax.random.normal(KEY, (6, 16, 32))
    assert_close(rfft2(xr), jnp.fft.rfft2(xr))


# ---------------------------------------------------------------------------
# Cost model threading
# ---------------------------------------------------------------------------

def test_nd_workload_pass_reduction():
    from repro.core.hardware import TESLA_V100
    from repro.core.workloads import FFTCase, fft_workload
    case = FFTCase(shape=(1024, 1024))
    prof = fft_workload(case, TESLA_V100)
    assert prof.t_mem > 0 and prof.flops > 0
    passes, chain, _ = nd_pass_summary((1024, 1024))
    assert passes == 2 and chain == 4
    # the modelled memory time scales with the plan's pass count
    single = fft_workload(FFTCase(n=1024, batch_bytes=case.batch_bytes),
                          TESLA_V100)
    assert prof.t_mem == pytest.approx(2 * single.t_mem, rel=0.02)


def test_nd_workload_r2c_cheaper_per_transform():
    from repro.core.hardware import TESLA_V100
    from repro.core.workloads import FFTCase, fft_workload
    c = FFTCase(shape=(512, 512))
    r = FFTCase(shape=(512, 512), transform="r2c")
    pc = fft_workload(c, TESLA_V100)
    pr = fft_workload(r, TESLA_V100)
    assert pr.t_mem / r.n_fft < 0.6 * (pc.t_mem / c.n_fft)
    assert pr.flops / r.n_fft < 0.6 * (pc.flops / c.n_fft)


def test_absolute_profile_pass_accounting():
    from repro.core.hardware import TESLA_V100
    from repro.core.perf_model import absolute_profile
    two = absolute_profile("two", device=TESLA_V100, hbm_bytes=0.0,
                           flops=1e9, passes=2, pass_bytes=1e9)
    four = absolute_profile("four", device=TESLA_V100, hbm_bytes=0.0,
                            flops=1e9, passes=4, pass_bytes=1e9)
    assert four.t_mem == pytest.approx(2 * two.t_mem)


# ---------------------------------------------------------------------------
# Serving: 2-D shapes are first-class cacheable plans
# ---------------------------------------------------------------------------

def test_service_serves_2d_shapes_with_cached_plans():
    from repro.serving.service import FFTService
    svc = FFTService(batch_bytes=2**24, time_budget=None)
    x = rand_complex((3, 16, 32), key=jax.random.PRNGKey(7))
    xr = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 32))
    r_c2c = svc.submit(x, ndim=2)
    r_r2c = svc.submit(xr, ndim=2, transform="r2c")
    svc.drain()
    assert_close(svc.receipt(r_c2c).result, jnp.fft.fft2(x))
    assert_close(svc.receipt(r_r2c).result, jnp.fft.rfft2(xr))
    assert svc.cache.stats.misses == 2
    # same 2-D shape again: plan + sweep come from the cache
    r2 = svc.submit(x, ndim=2)
    svc.drain()
    assert svc.cache.stats.hits >= 1
    assert svc.receipt(r2).energy_j > 0


def test_2d_and_1d_same_total_points_are_distinct_cache_keys():
    from repro.serving.request import FFTRequest
    a = FFTRequest(x=jnp.zeros((2, 16, 32), jnp.complex64), ndim=2)
    b = FFTRequest(x=jnp.zeros((2, 512), jnp.complex64))
    assert a.n == b.n == 512
    assert a.shape_key("d") != b.shape_key("d")


def test_request_rejects_bad_rank():
    from repro.serving.request import FFTRequest
    with pytest.raises(ValueError):
        FFTRequest(x=jnp.zeros((4, 4), jnp.complex64), ndim=3)
    with pytest.raises(ValueError):
        FFTRequest(x=jnp.zeros((2, 4, 4), jnp.complex64), ndim=2,
                   kind="fdas")
