"""Unit tests for the DVFS model layers (hardware, power, perf, energy)."""
import numpy as np
import pytest

from repro.core import (JETSON_NANO, TESLA_V100, TPU_V5E, DVFSScheduler,
                        FFTCase, PowerModel, WorkloadProfile, evaluate,
                        fft_workload, sweep)
from repro.core.energy import energy_from_trace, fft_flops, ffts_per_batch
from repro.core.hardware import TITAN_V, TITAN_V_DRIVER_CAP_MHZ
from repro.core.scheduler import predicted_pipeline_i_ef
from repro.core.realtime import (RealTimeBudget, devices_required,
                                 extra_hardware)


def test_frequency_grid_matches_table1():
    f = TESLA_V100.frequencies()
    assert f[0] == 1530.0
    assert f[-1] >= 135.0
    assert np.all(np.diff(f) < 0)
    # paper Table 1: steps of 7/8 MHz -> nominal 7.5
    assert np.allclose(np.diff(f)[:-1], -7.5)

    fn = JETSON_NANO.frequencies()
    assert fn[0] == pytest.approx(921.6)
    assert np.allclose(np.diff(fn), -76.8)


def test_voltage_floor_and_monotonicity():
    f = TESLA_V100.frequencies()
    v = TESLA_V100.voltage(f)
    assert v[0] == pytest.approx(1.0)
    assert np.all(np.diff(v) <= 1e-12)           # non-increasing with f desc
    assert v[-1] == pytest.approx(TESLA_V100.v_floor)


def test_power_monotonic_in_frequency():
    pm = PowerModel(TESLA_V100)
    f = TESLA_V100.frequencies()
    p = pm.power(f)
    assert np.all(np.diff(p) <= 1e-9)            # power falls as f falls
    assert p[0] <= TESLA_V100.tdp + 1e-9
    assert p[-1] >= 0


def test_time_model_regimes():
    dev = TESLA_V100
    # regime (b): memory bound with headroom -> flat until the knee
    prof_b = WorkloadProfile("b", t_mem=1.0, t_issue=0.4)
    f = dev.frequencies()
    t = prof_b.time(f, dev)
    assert t[0] == pytest.approx(1.0, rel=0.02)
    knee_f = 0.4 ** (1 / dev.issue_superlinearity) * dev.f_max
    above = f > knee_f * 1.05
    assert np.allclose(t[above], t[0], rtol=0.02)
    assert t[-1] > 2.0                            # deep slowdown at f_min
    assert prof_b.regime() == "b"

    # regime (c): core-clocked resource saturated at f_max
    prof_c = WorkloadProfile("c", t_mem=1.0, t_cache=1.02)
    t_c = prof_c.time(f, dev)
    assert np.all(np.diff(t_c) >= -1e-12)         # rises with every step down
    assert prof_c.regime() == "c"

    # regime (a): contention relief -> slightly faster below f_max
    prof_a = WorkloadProfile("a", t_mem=1.0, t_issue=0.3, contention=0.02)
    t_a = prof_a.time(f, dev)
    assert t_a.min() < t_a[0]
    assert prof_a.regime() == "a"


def test_energy_u_shape_and_optimal_interior():
    """Paper Fig. 7: E(f) is U-shaped with an interior minimum."""
    case = FFTCase(n=2**14)
    prof = fft_workload(case, TESLA_V100)
    res = sweep(prof, TESLA_V100)
    energies = np.array([p.energy for p in res.points])
    i_opt = int(np.argmin(energies))
    assert 0 < i_opt < len(energies) - 1          # interior minimum
    assert res.optimal.energy < res.boost.energy


def test_eq5_eq6_fft_metrics():
    assert fft_flops(1024) == pytest.approx(5 * 1024 * 10)
    assert ffts_per_batch(2e9, 2**14, 8) == int(2e9 // (2**14 * 8))


def test_energy_from_trace_matches_analytic():
    p = np.full(100, 200.0)
    assert energy_from_trace(p, 0.01) == pytest.approx(200.0 * 1.0)


def test_driver_cap_titan_v():
    """Paper Sec. 4: Titan V compute clocks are capped at 1335 MHz."""
    prof = fft_workload(FFTCase(n=2**14), TITAN_V)
    res = sweep(prof, TITAN_V, driver_cap_mhz=TITAN_V_DRIVER_CAP_MHZ)
    assert max(p.f for p in res.points) <= TITAN_V_DRIVER_CAP_MHZ


def test_sweep_respects_time_budget():
    prof = fft_workload(FFTCase(n=2**14), JETSON_NANO)
    tight = sweep(prof, JETSON_NANO, time_budget=0.05)
    loose = sweep(prof, JETSON_NANO)
    assert tight.slowdown <= 0.05 + 1e-9
    assert loose.optimal.energy <= tight.optimal.energy + 1e-12


def test_realtime_sizing():
    assert extra_hardware(0.6) == pytest.approx(0.6)
    assert extra_hardware(0.6, margin=0.6) == pytest.approx(0.0)
    assert devices_required(10, 0.6) == 16
    b = RealTimeBudget(t_acquire=1.0, t_process=0.8)
    assert b.speedup == pytest.approx(1.25)
    assert b.is_realtime(0.2)
    assert not b.is_realtime(0.3)


def test_pipeline_share_arithmetic():
    """Sec. 6.2: 60% FFT share x I_ef 1.5 -> ~1.29 composite gain."""
    assert predicted_pipeline_i_ef(0.60, 1.5) == pytest.approx(1.25, abs=0.05)
    assert predicted_pipeline_i_ef(1.0, 1.5) == pytest.approx(1.5)
    assert predicted_pipeline_i_ef(0.0, 1.5) == pytest.approx(1.0)


def test_scheduler_stage_locking():
    dev = TESLA_V100
    sched = DVFSScheduler(dev)
    fft_prof = fft_workload(FFTCase(n=2**14), dev)
    rest = WorkloadProfile("rest", t_mem=fft_prof.t_mem * 0.6,
                           t_issue=fft_prof.t_mem * 0.55,
                           flops=fft_prof.flops * 0.3)
    opt = sweep(fft_prof, dev).optimal.f
    stages = sched.plan([fft_prof, rest], locked={fft_prof.name: opt})
    rep = sched.evaluate_pipeline(stages)
    assert rep.i_ef > 1.05                       # composite saving exists
    # composite gain must be smaller than the FFT-only gain
    assert rep.i_ef < sweep(fft_prof, dev).i_ef_boost
    t, p, f = sched.power_trace(stages)
    assert len(t) == len(p) == len(f)
    assert set(np.unique(f)) == {opt, dev.f_max}


def test_tpu_device_roofline_constants():
    assert TPU_V5E.peak_flops == pytest.approx(197e12)
    assert TPU_V5E.hbm_bandwidth == pytest.approx(819e9)
    assert TPU_V5E.link_bandwidth == pytest.approx(50e9)
