"""Dedispersion kernel tests: Pallas vs oracle parity, guards, properties.

The kernel unrolls a static (DM, channel) delay table at trace time
(gather-free shift-and-sum, repro.kernels.dedisp); the oracle gathers
with ``take_along_axis``.  Property tests draw random DM tables and
non-divisible batch tiles; they skip cleanly when ``hypothesis`` is not
installed (tests/_hyp.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _hyp import given, settings, st

from repro.data.synthetic import (FilterbankSpec, InjectedPulsar,
                                  synthetic_filterbank)
from repro.kernels.dedisp import dedisperse_kernel, dedisperse_ref
from repro.kernels.dedisp.dedisp_kernel import dedisperse_pallas

KEY = jax.random.PRNGKey(7)


def _rand_fb(shape, key=KEY):
    return jax.random.normal(key, shape, jnp.float32)


def _rand_delays(rng, ndm, nchan, ntime):
    return rng.integers(0, ntime, size=(ndm, nchan), dtype=np.int64)


class TestDedisperseParity:
    @pytest.mark.parametrize("batch", [1, 3])
    @pytest.mark.parametrize("ndm", [1, 5])
    def test_matches_oracle(self, batch, ndm):
        rng = np.random.default_rng(0)
        nchan, n = 8, 256
        fb = _rand_fb((batch, nchan, n))
        delays = _rand_delays(rng, ndm, nchan, n)
        got = dedisperse_kernel(fb, delays, interpret=True)
        want = dedisperse_ref(fb, delays)
        assert got.shape == (batch, ndm, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multidim_lead_axes(self):
        rng = np.random.default_rng(1)
        fb = _rand_fb((2, 3, 4, 128))
        delays = _rand_delays(rng, 6, 4, 128)
        got = dedisperse_kernel(fb, delays, interpret=True)
        assert got.shape == (2, 3, 6, 128)
        np.testing.assert_allclose(got, dedisperse_ref(fb, delays),
                                   rtol=1e-5, atol=1e-5)

    def test_rank2_payload(self):
        """A single (nchan, ntime) filterbank: no batch axis either side."""
        rng = np.random.default_rng(2)
        fb = _rand_fb((4, 64))
        delays = _rand_delays(rng, 3, 4, 64)
        got = dedisperse_kernel(fb, delays, interpret=True)
        assert got.shape == (3, 64)
        np.testing.assert_allclose(got, dedisperse_ref(fb, delays),
                                   rtol=1e-5, atol=1e-5)

    def test_non_divisible_batch_tile(self):
        """A prime batch far above any tile: the ops layer must pad to the
        tile multiple and slice back without corrupting edge rows."""
        rng = np.random.default_rng(3)
        fb = _rand_fb((13, 4, 512))
        delays = _rand_delays(rng, 4, 4, 512)
        got = dedisperse_kernel(fb, delays, interpret=True)
        np.testing.assert_allclose(got, dedisperse_ref(fb, delays),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_delay_is_channel_sum(self):
        fb = _rand_fb((2, 6, 128))
        delays = np.zeros((1, 6), dtype=np.int64)
        got = dedisperse_kernel(fb, delays, interpret=True)
        np.testing.assert_allclose(got[:, 0], fb.sum(axis=1),
                                   rtol=1e-5, atol=1e-5)

    def test_plan_delays_cancel_injection(self):
        """The physics contract the pipeline rests on: dedispersing at the
        injected DM's own rounded delay table re-aligns the pulse exactly,
        so the matched trial carries the most power."""
        spec = FilterbankSpec(nchan=8, ntime=1024)
        dm = 40 * spec.dm_step          # ~40-sample sweep across the band
        fb = synthetic_filterbank(
            spec, (InjectedPulsar(dm=dm, k0=200, amp=0.5),), noise=0.5,
            seed=0)
        delays = np.stack([np.zeros(spec.nchan, np.int64),
                           spec.delay_samples(dm)])
        ts = dedisperse_kernel(fb, delays, interpret=True)
        spec_pow = jnp.abs(jnp.fft.rfft(ts - ts.mean(-1, keepdims=True)))**2
        # the k0 bin dominates only on the matched (second) trial
        assert int(jnp.argmax(spec_pow[1])) == 200
        assert float(spec_pow[1, 200]) > 4 * float(spec_pow[0, 200])


class TestDedisperseGuards:
    """ValueError-with-shapes guards (never assert: ``python -O`` strips
    asserts, and these reject caller input)."""

    def test_rejects_rank1(self):
        with pytest.raises(ValueError, match="nchan, ntime"):
            dedisperse_kernel(jnp.ones((64,)), [[0]], interpret=True)

    def test_rejects_complex(self):
        fb = jnp.ones((2, 4, 64), jnp.complex64)
        with pytest.raises(ValueError, match="must be real"):
            dedisperse_kernel(fb, np.zeros((1, 4), np.int64), interpret=True)

    def test_rejects_empty_axes(self):
        with pytest.raises(ValueError, match="non-empty"):
            dedisperse_kernel(jnp.ones((2, 0, 64)),
                              np.zeros((1, 0), np.int64), interpret=True)
        with pytest.raises(ValueError, match="non-empty"):
            dedisperse_kernel(jnp.ones((2, 4, 0)),
                              np.zeros((1, 4), np.int64), interpret=True)

    def test_rejects_channel_mismatch(self):
        fb = jnp.ones((2, 4, 64))
        with pytest.raises(ValueError, match="covers 3 channels"):
            dedisperse_kernel(fb, np.zeros((2, 3), np.int64), interpret=True)

    def test_rejects_empty_trial_table(self):
        fb = jnp.ones((2, 4, 64))
        with pytest.raises(ValueError, match="no DM trials"):
            dedisperse_kernel(fb, np.zeros((0, 4), np.int64), interpret=True)

    def test_rejects_non_integer_delays(self):
        fb = jnp.ones((2, 4, 64))
        with pytest.raises(ValueError, match="integer samples"):
            dedisperse_kernel(fb, np.zeros((1, 4), np.float32),
                              interpret=True)

    def test_rejects_wrong_table_rank(self):
        fb = jnp.ones((2, 4, 64))
        with pytest.raises(ValueError, match=r"\(n_dm, nchan\) table"):
            dedisperse_kernel(fb, np.zeros(4, np.int64), interpret=True)

    def test_pallas_rejects_non_dividing_tile(self):
        fb = jnp.ones((10, 2, 64))
        delays = ((0, 1),)
        with pytest.raises(ValueError, match=r"batch=10.*\(4\)"):
            dedisperse_pallas(fb, delays, tile_b=4, interpret=True)

    def test_pallas_rejects_out_of_range_delay(self):
        fb = jnp.ones((2, 2, 64))
        with pytest.raises(ValueError, match=r"outside \[0, ntime=64\)"):
            dedisperse_pallas(fb, ((0, 64),), tile_b=1, interpret=True)
        with pytest.raises(ValueError, match="outside"):
            dedisperse_pallas(fb, ((-1, 0),), tile_b=1, interpret=True)


class TestDedisperseProperties:
    @settings(deadline=None, max_examples=15)
    @given(st.integers(1, 9),          # batch (tile edges: primes included)
           st.integers(1, 6),          # nchan
           st.integers(1, 8),          # n_dm
           st.integers(0, 2 ** 31))    # delay-table seed
    def test_random_tables_match_oracle(self, batch, nchan, ndm, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.choice([96, 128, 200]))   # non-pow2 lengths included
        fb = jax.random.normal(jax.random.PRNGKey(seed % 997),
                               (batch, nchan, n), jnp.float32)
        delays = _rand_delays(rng, ndm, nchan, n)
        got = dedisperse_kernel(fb, delays, interpret=True)
        np.testing.assert_allclose(got, dedisperse_ref(fb, delays),
                                   rtol=1e-5, atol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(st.integers(0, 2 ** 31))
    def test_linearity(self, seed):
        """Dedispersion is linear in the filterbank: D(a+b) == D(a)+D(b)."""
        rng = np.random.default_rng(seed)
        a = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 128))
        b = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 128))
        delays = _rand_delays(rng, 3, 4, 128)
        lhs = dedisperse_kernel(a + b, delays, interpret=True)
        rhs = (dedisperse_kernel(a, delays, interpret=True)
               + dedisperse_kernel(b, delays, interpret=True))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
