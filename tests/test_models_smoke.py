"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model

BATCH, SEQ = 2, 32


def _inputs(cfg, batch=BATCH, seq=SEQ):
    if cfg.input_mode == "embeds":
        return jax.random.normal(jax.random.PRNGKey(1),
                                 (batch, seq, cfg.d_model), jnp.float32)
    return jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              cfg.vocab)


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = ARCHS[request.param].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch_setup):
        cfg, model, params = arch_setup
        logits, aux = model.forward(params, _inputs(cfg))
        assert logits.shape == (BATCH, SEQ, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux))

    def test_train_step_reduces_loss(self, arch_setup):
        """One SGD step on a repeated batch must not blow up (and usually
        reduces the loss)."""
        from repro.models.common import cross_entropy
        cfg, model, params = arch_setup
        inp = _inputs(cfg)
        labels = jax.random.randint(jax.random.PRNGKey(2), (BATCH, SEQ), 0,
                                    cfg.vocab)

        def loss_fn(p):
            logits, aux = model.forward(p, inp)
            return cross_entropy(logits, labels) + 0.01 * aux

        l0, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(l0)
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                               params, grads)
        l1 = loss_fn(params2)
        assert np.isfinite(l1)
        assert l1 < l0 + 0.5          # no explosion; usually decreases

    def test_decode_step(self, arch_setup):
        cfg, model, params = arch_setup
        cache_sds = model.cache_shapes(BATCH, SEQ)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             cache_sds)
        if cfg.input_mode == "embeds":
            tok = jax.random.normal(jax.random.PRNGKey(3),
                                    (BATCH, 1, cfg.d_model), jnp.float32)
        else:
            tok = jax.random.randint(jax.random.PRNGKey(3), (BATCH, 1), 0,
                                     cfg.vocab)
        logits, new_cache = model.decode(params, cache, tok)
        assert logits.shape == (BATCH, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # cache structure preserved
        assert (jax.tree.structure(new_cache)
                == jax.tree.structure(cache))

    def test_prefill_matches_cache_shapes(self, arch_setup):
        cfg, model, params = arch_setup
        logits, cache = model.prefill(params, _inputs(cfg))
        assert logits.shape == (BATCH, 1, cfg.vocab)
        sds = model.cache_shapes(BATCH, SEQ)
        got = jax.tree.map(lambda a: a.shape, cache)
        want = jax.tree.map(lambda s: s.shape, sds)
        # SSM conv caches are (W-1)-long regardless of seq; compare
        # structure and let shapes match where defined.
        assert jax.tree.structure(got) == jax.tree.structure(want)

    def test_param_spec_tree_matches_params(self, arch_setup):
        cfg, model, params = arch_setup
        specs = model.param_specs()
        from jax.sharding import PartitionSpec
        jax.tree.map(lambda p, s: None, params, specs,
                     is_leaf=lambda x: isinstance(x, PartitionSpec))


class TestDecodeConsistency:
    """Decode with a prefilled cache must reproduce forward() logits."""

    @pytest.mark.parametrize("name", ["qwen2-0.5b", "mamba2-370m"])
    def test_decode_matches_forward(self, name):
        cfg = ARCHS[name].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                  cfg.vocab)
        logits_full, _ = model.forward(params, toks)
        # prefill on the first 7 tokens, decode token 8 at position 7
        _, cache = model.prefill(params, toks[:, :7])
        if name == "qwen2-0.5b":
            # pad kv cache to length 8 (decode writes at S-1 = 7)
            cache = jax.tree.map(
                lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, 1)] + [(0, 0)] * 2)
                if a.ndim == 5 else a, cache)
        logits_dec, _ = model.decode(params, cache, toks[:, 7:8])
        np.testing.assert_allclose(
            np.asarray(logits_dec[0, 0]), np.asarray(logits_full[0, 7]),
            rtol=2e-2, atol=2e-2)
