"""Property-based tests (hypothesis) for system invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional test dep: skip property tests
    from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core.dvfs import sweep
from repro.core.hardware import TESLA_V100, TPU_V5E
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel


class FakeMesh:
    def __init__(self, shape): self.shape = shape
    @property
    def axis_names(self): return tuple(self.shape)


@settings(deadline=None, max_examples=60)
@given(
    dims=st.lists(st.integers(1, 2**20), min_size=1, max_size=5),
    axes=st.lists(st.sampled_from([None, "data", "model",
                                   ("data",), ("data", "model")]),
                  min_size=0, max_size=5),
)
def test_property_fix_sharding_always_divisible(dims, axes):
    """After fix_sharding, every sharded dim divides exactly and no mesh
    axis appears twice."""
    from repro.launch.specs import _axis_size, fix_sharding
    mesh = FakeMesh({"data": 16, "model": 16})
    # drop duplicate axis uses in the input (invalid spec otherwise)
    seen = set()
    clean = []
    for e in axes[:len(dims)]:
        tup = () if e is None else ((e,) if isinstance(e, str) else e)
        if any(a in seen for a in tup):
            clean.append(None)
        else:
            seen.update(tup)
            clean.append(e)
    spec = P(*clean)
    fixed = fix_sharding(tuple(dims), spec, mesh)
    used = []
    for dim, entry in zip(dims, list(fixed) + [None] * len(dims)):
        if entry is None:
            continue
        tup = (entry,) if isinstance(entry, str) else tuple(entry)
        used.extend(tup)
        assert dim % _axis_size(mesh, tup) == 0
    assert len(used) == len(set(used))


@settings(deadline=None, max_examples=50)
@given(
    t_mem=st.floats(1e-4, 1.0),
    issue_frac=st.floats(0.0, 1.5),
    cache_frac=st.floats(0.0, 1.5),
    coll_frac=st.floats(0.0, 2.0),
)
def test_property_time_monotone_nonincreasing_in_frequency(
        t_mem, issue_frac, cache_frac, coll_frac):
    """t(f) never decreases when the clock drops beyond the contention
    band, for ANY workload mix; and t(f) >= the flat (HBM/ICI) bound."""
    prof = WorkloadProfile("w", t_mem=t_mem, t_issue=issue_frac * t_mem,
                           t_cache=cache_frac * t_mem,
                           t_coll=coll_frac * t_mem)
    for dev in (TESLA_V100, TPU_V5E):
        f = dev.frequencies()
        t = prof.time(f, dev)
        assert np.all(t >= max(t_mem, coll_frac * t_mem) * 0.999)
        # below the voltage knee there is no contention relief: monotone
        knee_mask = f / dev.f_max <= dev.f_vfloor_frac
        tk = t[knee_mask]
        assert np.all(np.diff(tk) >= -1e-12)


@settings(deadline=None, max_examples=50)
@given(
    t_mem=st.floats(1e-4, 1.0),
    issue_frac=st.floats(0.05, 1.2),
)
def test_property_optimal_energy_never_worse_than_boost(t_mem, issue_frac):
    """The swept optimum can never consume more energy than boost, and
    its frequency is on the device grid."""
    prof = WorkloadProfile("w", t_mem=t_mem, t_issue=issue_frac * t_mem,
                           flops=1e9)
    for dev in (TESLA_V100, TPU_V5E):
        res = sweep(prof, dev)
        assert res.optimal.energy <= res.boost.energy * (1 + 1e-9)
        assert any(abs(res.optimal.f - f) < 1e-6
                   for f in dev.frequencies())


@settings(deadline=None, max_examples=40)
@given(u_core=st.floats(0.05, 1.0), u_mem=st.floats(0.0, 1.0))
def test_property_power_bounded_by_tdp_and_positive(u_core, u_mem):
    for dev in (TESLA_V100, TPU_V5E):
        pm = PowerModel(dev)
        p = pm.power(dev.frequencies(), u_core=u_core, u_mem=u_mem)
        assert np.all(p > 0)
        assert np.all(p <= dev.tdp * (1 + 1e-9))


@settings(deadline=None, max_examples=30)
@given(
    budget=st.floats(0.0, 0.5),
    issue_frac=st.floats(0.2, 1.2),
)
def test_property_time_budget_respected(budget, issue_frac):
    """Sec. 2.3 real-time constraint: the constrained optimum never
    exceeds the slowdown budget."""
    prof = WorkloadProfile("w", t_mem=1e-2, t_issue=issue_frac * 1e-2)
    res = sweep(prof, TPU_V5E, time_budget=budget)
    assert res.slowdown <= budget + 1e-9
