"""Multi-device integration tests.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
so the main pytest process keeps its single default device (per the
dry-run isolation contract in the launch package).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_pencil_fft_matches_reference():
    """Distributed four-step FFT over 8 devices == jnp.fft.fft."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.fft.distributed import pencil_fft, untranspose_ref

        mesh = jax.make_mesh((8,), ("model",))
        n1, n2, batch = 64, 128, 2
        key = jax.random.PRNGKey(0)
        x = (jax.random.normal(key, (batch, n1, n2)) +
             1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n1, n2))
             ).astype(jnp.complex64)
        xs = jax.device_put(x, NamedSharding(mesh, P(None, "model", None)))
        y = pencil_fft(xs, mesh, n1=n1, n2=n2)
        got = untranspose_ref(jax.device_get(y), n1, n2)
        want = np.fft.fft(np.asarray(x).reshape(batch, n1 * n2), axis=-1)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        print("pencil ok")
    """)


@pytest.mark.slow
def test_batch_parallel_fft():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.fft.distributed import batch_parallel_fft

        mesh = jax.make_mesh((8,), ("data",))
        x = (jax.random.normal(jax.random.PRNGKey(0), (16, 512)) +
             1j * jax.random.normal(jax.random.PRNGKey(1), (16, 512))
             ).astype(jnp.complex64)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        y = batch_parallel_fft(xs, mesh)
        np.testing.assert_allclose(jax.device_get(y),
                                   np.fft.fft(np.asarray(x), axis=-1),
                                   rtol=2e-3, atol=2e-3)
        print("batch ok")
    """)


@pytest.mark.slow
def test_pencil_rfft_matches_reference():
    """Distributed R2C pencil (packed + sharded Hermitian split) == rfft."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.fft.distributed import assemble_rfft_pencil, pencil_fft

        mesh = jax.make_mesh((8,), ("model",))
        n1, n2, batch = 32, 64, 2
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, n1, n2),
                              jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P(None, "model", None)))
        y = pencil_fft(xs, mesh, n1=n1, n2=n2, kind="r2c")
        got = assemble_rfft_pencil(jax.device_get(y), n1, n2)
        want = np.fft.rfft(np.asarray(x).reshape(batch, n1 * n2), axis=-1)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        print("pencil r2c ok")
    """)


@pytest.mark.slow
def test_batch_parallel_fft_r2c_kind():
    """kind="r2c" shards real batches through the R2C plan (no complex
    cast) and matches jnp.fft.rfft."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.fft.distributed import batch_parallel_fft

        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 512), jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        y = batch_parallel_fft(xs, mesh, kind="r2c")
        assert y.shape == (16, 257), y.shape
        np.testing.assert_allclose(jax.device_get(y),
                                   np.fft.rfft(np.asarray(x), axis=-1),
                                   rtol=2e-3, atol=2e-3)
        print("batch r2c ok")
    """)


@pytest.mark.slow
def test_batch_parallel_fft_2d_plan_graph():
    """Rank-3 payloads shard over the batch and run the N-D plan graph."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.fft.distributed import batch_parallel_fft

        mesh = jax.make_mesh((4,), ("data",))
        x = (jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32)) +
             1j * jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
             ).astype(jnp.complex64)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y = batch_parallel_fft(xs, mesh)
        np.testing.assert_allclose(jax.device_get(y),
                                   np.fft.fft2(np.asarray(x), axes=(-2, -1)),
                                   rtol=2e-3, atol=2e-3)
        print("batch 2d ok")
    """, n_devices=4)


@pytest.mark.slow
def test_pencil_collective_bytes_formula():
    """The analytic all_to_all byte count matches the sharded layout."""
    from repro.fft.distributed import pencil_collective_bytes
    b = pencil_collective_bytes(batch=2, n1=64, n2=128, n_devices=8)
    local = 2 * 64 * 128 / 8 * 8
    assert b == pytest.approx(2 * local * 7 / 8)
    # R2C: two all_to_alls on the packed half-length transform plus the
    # mirror ppermute — strictly cheaper than the complex path.
    r = pencil_collective_bytes(batch=2, n1=64, n2=128, n_devices=8,
                                kind="r2c")
    assert r == pytest.approx(3 * (local / 2) * 7 / 8)
    assert r < b
