"""The unified observability plane (repro.obs): deterministic tracing,
the metrics registry, the kernel launch ledger, drift detection, the
structured logger, and their serving integration (docs/observability.md).
"""
import io

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E
from repro.obs import (DriftDetector, FlightRecorder, LaunchLedger,
                       LaunchRecord, MetricsRegistry, Span, StructuredLogger,
                       Tracer, latency_summary, launches_digest,
                       record_launch, to_chrome_trace, to_jsonl)
from repro.obs import trace as trace_mod
from repro.obs.metrics import Counter, Gauge, Histogram
from repro.power.telemetry import FleetTelemetry
from repro.runtime.faults import (ClockLockError, DeviceLostError,
                                  DrainDeadlineError, PlanBuildError,
                                  WorkerStalledError)
from repro.serving import FFTService

KEY = jax.random.PRNGKey(0)


class FakeTimer:
    """Deterministic clock: advances ``dt`` per call (0 = frozen)."""

    def __init__(self, dt=0.0, t0=0.0):
        self.t = t0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t

    def advance(self, dt):
        self.t += dt


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


# ---------------------------------------------------------------------------
# tracer: nesting, attribute propagation, exporters
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_nesting_and_attr_inheritance(self):
        tr = Tracer(timer=FakeTimer(dt=1.0))
        with tr.span("batch", kind="fft", shape=(4, 64), rung=0,
                     clock_mhz=940.0):
            with tr.span("execute"):
                pass
            with tr.span("account", rung=1):
                pass
        by_name = {s.name: s for s in tr.spans}
        batch, execute, account = (by_name["batch"], by_name["execute"],
                                   by_name["account"])
        # children inherit every parent attr...
        assert execute.attrs["kind"] == "fft"
        assert execute.attrs["shape"] == (4, 64)
        assert execute.attrs["clock_mhz"] == 940.0
        # ...but their own keys win
        assert account.attrs["rung"] == 1 and batch.attrs["rung"] == 0
        assert execute.parent == "batch" and execute.depth == 1
        assert batch.parent is None and batch.depth == 0
        # completion order: children close before the parent
        assert [s.name for s in tr.spans] == ["execute", "account", "batch"]

    def test_durations_come_from_the_injected_clock(self):
        tr = Tracer(timer=FakeTimer(dt=0.5))
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        inner, outer = tr.spans
        # every timer() call advances 0.5: open/open/close/close
        assert inner.duration == pytest.approx(0.5)
        assert outer.duration == pytest.approx(1.5)

    def test_jsonl_digest_reproducible_and_attr_sensitive(self):
        def run(clock):
            tr = Tracer(timer=FakeTimer(dt=1.0))
            with tr.span("batch", clock_mhz=clock):
                with tr.span("execute"):
                    pass
            return tr.spans
        a, b, c = run(940.0), run(940.0), run(600.0)
        assert trace_mod.digest(a) == trace_mod.digest(b)
        assert trace_mod.digest(a) != trace_mod.digest(c)
        # one canonical JSON object per line
        assert len(to_jsonl(a).splitlines()) == 2

    def test_chrome_trace_export(self):
        tr = Tracer(timer=FakeTimer(dt=1.0))
        with tr.span("batch", worker=3, shape=(2, 8)):
            pass
        doc = to_chrome_trace(tr.spans)
        (ev,) = doc["traceEvents"]
        assert ev["ph"] == "X" and ev["tid"] == 3
        assert ev["ts"] == pytest.approx(1e6)       # seconds -> microseconds
        assert ev["dur"] == pytest.approx(1e6)
        assert ev["args"]["shape"] == [2, 8]        # JSON-safe attrs


# ---------------------------------------------------------------------------
# flight recorder: bounded rings + per-fault snapshots
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_is_bounded_per_device(self):
        fr = FlightRecorder(capacity=4)
        for i in range(10):
            fr.push(Span(name=f"s{i}", t_start=float(i),
                         attrs={"worker": i % 2}))
        assert [s.name for s in fr.ring(0)] == ["s2", "s4", "s6", "s8"]
        assert len(fr.ring(1)) == 4
        assert [s.name for s in fr.ring(1)] == ["s3", "s5", "s7", "s9"]

    @pytest.mark.parametrize("make_error", [
        lambda: DeviceLostError(1),
        lambda: ClockLockError("nvml lock refused"),
        lambda: PlanBuildError("no plan for shape"),
        lambda: WorkerStalledError(2, 0.5),
        lambda: DrainDeadlineError(1.0, ["stuck-key"]),
    ], ids=["device-lost", "clock-lock", "plan-build", "worker-stalled",
            "drain-deadline"])
    def test_every_fault_kind_snapshots_live_tracers(self, make_error):
        tr = Tracer(timer=FakeTimer(dt=1.0))
        with tr.span("batch", worker=0):
            pass
        err = make_error()                 # construction triggers snapshot
        assert len(tr.flight.snapshots) == 1
        snap = tr.flight.snapshots[0]
        assert snap.error_type == type(err).__name__
        assert str(err) in snap.message or snap.message == str(err)
        assert [s.name for s in snap.spans[0]] == ["batch"]

    def test_snapshot_captures_spans_still_open_at_failure(self):
        tr = Tracer(timer=FakeTimer(dt=1.0))
        with pytest.raises(DeviceLostError):
            with tr.span("batch", worker=1):
                with tr.span("execute"):
                    raise DeviceLostError(1)
        snap = tr.flight.snapshots[0]
        assert [s.name for s in snap.open_spans] == ["batch", "execute"]

    def test_no_tracer_no_snapshot_no_error(self):
        # fault construction with no live tracer is a silent no-op
        import gc
        gc.collect()                       # drop tracers from other tests
        DeviceLostError(0)


# ---------------------------------------------------------------------------
# launch ledger: trace-time Pallas accounting
# ---------------------------------------------------------------------------

class TestLaunchLedger:
    def test_record_is_noop_without_active_capture(self):
        led = LaunchLedger()
        record_launch("fft-c2c", grid=(1,), tile=(4, 64))
        assert led.records == []

    def test_capture_records_and_counts(self):
        led = LaunchLedger()
        with led.capture():
            record_launch("fft-c2c", grid=(2,), tile=(4, 64),
                          bytes_moved=100, shape=(8, 64))
            record_launch("transpose", bytes_moved=50)
        assert led.counts() == {"fft-c2c": 1, "transpose": 1}
        assert led.total_bytes() == 150
        assert led.records[0] == LaunchRecord(
            kernel="fft-c2c", grid=(2,), tile=(4, 64), bytes_moved=100,
            shape=(8, 64))

    def test_first_capture_wins_for_signatures(self):
        led = LaunchLedger()
        with led.capture(key="obs-test-k"):
            record_launch("fft-c2c")
        with led.capture(key="obs-test-k"):  # warm executable: no records
            pass
        sig = led.signature(key="obs-test-k")
        assert [r.kernel for r in sig] == ["fft-c2c"]
        assert led.signature("never-seen") == []

    def test_signature_survives_fresh_ledger_via_global_store(self):
        # jit executables are cached process-wide, so the signature store
        # is too: a fresh ledger replays what an earlier one captured
        with LaunchLedger().capture(key="obs-test-global"):
            record_launch("fft-c2c", grid=(1,), tile=(4, 64))
        sig = LaunchLedger().signature("obs-test-global")
        assert [r.kernel for r in sig] == ["fft-c2c"]

    def test_launches_digest_over_receipt_signatures(self):
        a = [LaunchRecord(kernel="fft-c2c", grid=(1,), tile=(4, 64))]
        assert launches_digest([a, a]) == launches_digest([list(a), list(a)])
        assert launches_digest([a]) != launches_digest([a, a])

    def test_fft2_plan_launches_exactly_two_fused_passes(self):
        """PR 3's routing-counter claim, read from the ledger: a pow2 2-D
        plan is two transposed-write fused passes, nothing else."""
        from repro.fft.plan_nd import plan_nd
        plan = plan_nd((64, 64))
        x = rand_complex((2, 64, 64))
        led = LaunchLedger()
        with led.capture():
            y = plan.fn(x)                  # eager: one record per launch
        assert led.counts() == {"fft-c2c-t": 2}
        assert led.counts()["fft-c2c-t"] == plan.passes
        np.testing.assert_allclose(np.asarray(y),
                                   np.fft.fft2(np.asarray(x)),
                                   rtol=2e-3, atol=2e-2)

    def test_fused_conv_is_one_forward_plus_t_plane_inverse(self):
        """PR 4's fdas claim: 1 fused forward+multiply launch, and one
        *batched* inverse launch whose rows cover all T template planes
        (the paper's 1 + T HBM passes)."""
        from repro.fft.convolve import conv_plan, overlap_save_conv
        n, taps, t, nfft = 1000, 17, 3, 256
        plan = conv_plan(n, taps, t, nfft)
        x = rand_complex((n,))
        filters = np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (t, taps)))
        led = LaunchLedger()
        with led.capture():
            overlap_save_conv(x, filters, nfft=nfft)
        counts = led.counts()
        assert counts["fft-c2c-mul"] == 1      # forward + bank multiply
        assert counts["fft-c2c"] == 1          # one batched inverse launch
        (inv,) = [r for r in led.records if r.kernel == "fft-c2c"]
        assert inv.shape[0] == plan.n_segments * t
        assert inv.shape[0] // plan.n_segments == plan.inverse_passes == t

    def test_pipeline_launches_each_fused_kernel_once(self):
        """PR 6's claim: the pulsar graph traces one launch per fused
        kernel — dedispersion, the bank multiply, the harmonic plane."""
        from repro.data.synthetic import FilterbankSpec, synthetic_filterbank
        from repro.search.pipeline import DispersionPlan, pulsar_search
        from repro.search.templates import TemplateBank
        spec = FilterbankSpec(nchan=8, ntime=512)
        plan = DispersionPlan.from_spec(spec, n_trials=4)
        bank = TemplateBank.linear(zmax=2.0, n_templates=3)
        fb = synthetic_filterbank(spec, (), noise=1.0, seed=0)
        led = LaunchLedger()
        with led.capture():
            res = pulsar_search(fb, plan, bank, n_harmonics=4)
            jax.block_until_ready(res.stat)
        counts = led.counts()
        assert counts["dedisperse"] == 1
        assert counts["fft-c2c-mul"] == 1
        assert counts["harmonic-sum-plane"] == 1

    def test_ledger_digest_reproducible(self):
        def run():
            led = LaunchLedger()
            with led.capture():
                record_launch("fft-c2c", grid=(2,), tile=(4, 64),
                              bytes_moved=4096, shape=(8, 64))
            return led
        assert run().digest() == run().digest()
        other = LaunchLedger()
        with other.capture():
            record_launch("fft-c2c", grid=(4,), tile=(4, 64))
        assert other.digest() != run().digest()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_is_monotonic(self):
        c = Counter("n")
        c.inc()
        c.inc(3)
        assert c.value == 4
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_histogram_quantiles_are_bucket_bounds(self):
        h = Histogram("lat", buckets=(0.01, 0.1, 1.0))
        assert h.quantile(0.99) == 0.0                 # empty -> 0
        for v in (0.005, 0.005, 0.05, 5.0):
            h.observe(v)
        assert h.n == 4
        assert h.quantile(0.50) == 0.01                # upper bucket bound
        assert h.quantile(0.99) == 1.0                 # overflow -> top bound
        assert h.counts[-1] == 1                       # +Inf bucket

    def test_histogram_rejects_bad_buckets(self):
        with pytest.raises(ValueError):
            Histogram("h", buckets=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))
        with pytest.raises(ValueError):
            Histogram("h", buckets=())

    def test_registry_get_or_create_and_type_guard(self):
        m = MetricsRegistry()
        c = m.counter("repro_x_total", "things")
        assert m.counter("repro_x_total") is c
        assert "repro_x_total" in m and "nope" not in m
        with pytest.raises(TypeError):
            m.gauge("repro_x_total")

    def test_render_is_prometheus_text(self):
        m = MetricsRegistry()
        m.counter("repro_served_total", "served requests").inc(2)
        m.gauge("repro_i_ef").set(1.25)
        h = m.histogram("repro_lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        text = m.render()
        assert "# HELP repro_served_total served requests" in text
        assert "# TYPE repro_served_total counter" in text
        assert "repro_served_total 2" in text
        assert "repro_i_ef 1.25" in text
        assert 'repro_lat_seconds_bucket{le="0.1"} 1' in text
        assert 'repro_lat_seconds_bucket{le="1"} 2' in text      # cumulative
        assert 'repro_lat_seconds_bucket{le="+Inf"} 2' in text
        assert "repro_lat_seconds_count 2" in text
        # deterministic: same registry state, same text
        assert text == m.render()

    def test_latency_summary_empty_convention(self):
        s = latency_summary([])
        assert (s.n, s.mean, s.p50, s.p99) == (0, 0.0, 0.0, 0.0)
        s = latency_summary([], on_empty=float("nan"))
        assert np.isnan(s.p99)
        s = latency_summary([1.0, 2.0])
        assert s.n == 2 and s.mean == pytest.approx(1.5)
        assert s.p50 == pytest.approx(1.5)
        assert s.p99 == pytest.approx(1.99)


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------

class TestDriftDetector:
    def test_silent_below_min_samples_even_with_large_error(self):
        d = DriftDetector(min_samples=4, threshold=0.2)
        for _ in range(3):
            d.observe("k", modelled=1.0, measured=2.0)     # +100% error
        assert not d.alerting("k") and d.drift_alerts == 0

    def test_sustained_error_alerts_noise_does_not(self):
        d = DriftDetector(min_samples=4, threshold=0.2, alpha=0.25)
        for i in range(8):
            d.observe("hot", modelled=1.0, measured=1.5)   # +50% sustained
            # zero-mean noise: alternating +/-10% never crosses 20%
            d.observe("ok", modelled=1.0,
                      measured=1.1 if i % 2 == 0 else 0.9)
        assert d.alerting("hot") and not d.alerting("ok")
        assert d.alerts == ["hot"]
        s = d.summary()
        assert s["drift_alerts"] == 1 and s["tracked_keys"] == 2
        assert s["observations"] == 16
        assert s["worst_ewma_error"] == pytest.approx(0.5, abs=0.01)

    def test_zero_modelled_follows_guarded_ratio(self):
        d = DriftDetector()
        assert d.observe("z", modelled=0.0, measured=0.0) == 0.0

    def test_fill_metrics_publishes_gauges(self):
        d = DriftDetector(min_samples=1, threshold=0.1)
        d.observe(("fft", (64,), 940.0), modelled=1.0, measured=2.0)
        m = MetricsRegistry()
        d.fill_metrics(m)
        text = m.render()
        assert "repro_drift_alerts 1" in text
        assert "repro_drift_tracked_keys 1" in text
        assert "repro_drift_worst_ewma_error 1" in text

    def test_rejects_bad_alpha(self):
        with pytest.raises(ValueError):
            DriftDetector(alpha=0.0)


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------

class TestStructuredLogger:
    def test_silenced_under_pytest_by_default(self):
        buf = io.StringIO()
        StructuredLogger("x", stream=buf).info("event", a=1)
        assert buf.getvalue() == ""        # PYTEST_CURRENT_TEST is set

    def test_env_level_overrides_pytest_silence(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOG_LEVEL", "info")
        buf = io.StringIO()
        log = StructuredLogger("dryrun", stream=buf)
        log.info("lowered", tag="fft-4096", fits=True)
        log.debug("hidden")                # below threshold
        lines = buf.getvalue().splitlines()
        assert len(lines) == 1
        assert lines[0].startswith("INFO")
        assert "dryrun: lowered" in lines[0]
        assert "tag=fft-4096" in lines[0] and "fits=True" in lines[0]

    def test_off_silences_everything(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOG_LEVEL", "off")
        buf = io.StringIO()
        StructuredLogger("x", stream=buf).error("boom")
        assert buf.getvalue() == ""

    def test_unknown_level_raises(self):
        with pytest.raises(ValueError):
            StructuredLogger("x").log("loud", "event")


# ---------------------------------------------------------------------------
# timer injection (runtime.fault) + serving integration
# ---------------------------------------------------------------------------

class TestDriverTimerInjection:
    def test_wall_metrics_deterministic_under_fake_timer(self, tmp_path):
        from repro.runtime.checkpoint import CheckpointManager
        from repro.runtime.fault import FaultTolerantDriver
        driver = FaultTolerantDriver(
            train_step=lambda s, i, l: (s + 1, {}),
            state=jnp.zeros(()),
            data_iter_fn=lambda i: (None, None),
            ckpt=CheckpointManager(str(tmp_path)), ckpt_every=100,
            timer=FakeTimer(dt=0.25),
        )
        _, log, _ = driver.run(3)
        assert [m["wall"] for m in log] == [pytest.approx(0.25)] * 3


class TestServingIntegration:
    def _run(self, *, power_model=None):
        timer = FakeTimer(dt=1e-4)
        tracer = Tracer(timer=timer)
        svc = FFTService(
            TPU_V5E, devices=[None, None], timer=timer, tracer=tracer,
            telemetry=FleetTelemetry.for_serving(TPU_V5E, seed=7,
                                                 noise_frac=0.0,
                                                 power_model=power_model))
        for i in range(4):
            # one drain per submit: four metered batches, so the drift
            # detector sees four observations on the same (kind, shape,
            # clock) key — enough to clear its min_samples gate
            svc.submit(rand_complex((2, 64), jax.random.PRNGKey(i)))
            svc.drain()
        return svc, tracer

    def test_receipts_carry_ledger_backed_launches(self):
        svc, tracer = self._run()
        for r in svc.receipts:
            assert [l.kernel for l in r.launches] == ["fft-c2c"]
            assert all(l.bytes_moved > 0 for l in r.launches)
        # spans nested batch > execute with inherited attrs
        execs = [s for s in tracer.spans if s.name == "execute"]
        assert execs and all(s.parent == "batch" for s in execs)
        assert all(s.attrs["kind"] == "fft" for s in execs)
        rep = svc.report()
        assert rep.drift is not None and rep.drift["observations"] > 0

    def test_trace_digest_reproducible_across_runs(self):
        s1, t1 = self._run()
        s2, t2 = self._run()
        assert trace_mod.digest(t1.spans) == trace_mod.digest(t2.spans)
        # the second service reuses warm jit executables (its ledger
        # records nothing live), yet its receipts replay the same launch
        # signatures from the process-wide store
        assert (launches_digest(r.launches for r in s1.receipts)
                == launches_digest(r.launches for r in s2.receipts))
        assert all(r.launches for r in s2.receipts)

    def test_metrics_text_covers_every_subsystem(self):
        svc, _ = self._run()
        text = svc.metrics_text()
        for series in ("repro_requests_served_total 4",
                       "repro_request_latency_seconds_count 4",
                       "repro_availability 1",
                       "repro_cache_hits", "repro_dispatch_workers 2",
                       "repro_telemetry_reads", "repro_drift_tracked_keys",
                       "repro_kernel_launches_recorded"):
            assert series in text, series

    def test_calibrated_model_stays_silent_miscalibrated_alerts(self):
        import dataclasses as dc
        from repro.core.power_model import PowerModel
        svc, _ = self._run()
        assert svc.drift.drift_alerts == 0            # calibrated sensor
        hot = PowerModel(dc.replace(TPU_V5E, name="hot-v5e",
                                    tdp=2.0 * TPU_V5E.tdp))
        svc2, _ = self._run(power_model=hot)
        assert svc2.drift.observations >= 4
        assert svc2.drift.drift_alerts >= 1           # model disagrees
