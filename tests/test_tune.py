"""Autotuner tests: cache persistence, determinism, plan-routing consults.

Covers the PR's acceptance criteria:
  * round-trip persistence of the on-disk tuning cache; corrupted and
    version-mismatched files fall back to heuristics without crashing;
  * a monkeypatched timer proves identical measurements yield an
    identical chosen config (determinism);
  * plan construction consults the tuning cache exactly once per
    (device, shape, kind) no matter how often plans rebuild;
  * ``REPRO_FFT_DISABLE_TUNING=1`` restores the pre-PR heuristic path
    bit-for-bit (the very same memoised plan objects);
  * the serving cache keys entries on the tuned config, so tuned plans
    are served transparently and never go stale.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import TESLA_V100, TPU_V5E
from repro.fft.convolve import conv_plan, select_nfft
from repro.fft.plan import plan_for_length, plan_with_config
from repro.fft.plan_nd import plan_nd
from repro.tune import (CACHE_VERSION, HEURISTIC, ConfigKey, KernelConfig,
                        TuneRecord, TuningCache, TuningContext, cache_path,
                        common_config, generate_candidates, plan_config,
                        prune_candidates, tune_length, tune_segment,
                        use_tuning)

KEY = jax.random.PRNGKey(0)


def _tuned_cache(device="testdev", entries=()):
    cache = TuningCache(device=device)
    for shape, kind, cfg in entries:
        cache.put(ConfigKey(device, shape, kind), TuneRecord(config=cfg))
    return cache


def rand_c(shape):
    kr, ki = jax.random.split(KEY)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


# ---------------------------------------------------------------------------
# Config / key plumbing
# ---------------------------------------------------------------------------

class TestConfig:
    def test_json_round_trip(self):
        cfg = KernelConfig(tile_b=16, radices=(8, 4, 2), split=(64, 128),
                           segment=1024, source="tuned")
        assert KernelConfig.from_dict(cfg.to_dict()) == cfg
        assert KernelConfig.from_dict(HEURISTIC.to_dict()) == HEURISTIC

    def test_is_heuristic(self):
        assert HEURISTIC.is_heuristic
        assert not KernelConfig(tile_b=8).is_heuristic
        assert not KernelConfig(segment=512).is_heuristic

    def test_key_token_round_trip(self):
        key = ConfigKey("TPU-v5e", (4096, 33, 9), "conv", "fp16")
        assert ConfigKey.from_token(key.token()) == key


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

class TestCachePersistence:
    def test_round_trip(self, tmp_path):
        path = str(tmp_path / "dev.json")
        cache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(tile_b=16, source="tuned")),
            ((512,), "r2c", KernelConfig(radices=(2,), source="tuned")),
        ])
        rec = TuneRecord(config=KernelConfig(tile_b=16, source="tuned"),
                         objective="energy", score=1.5, heuristic_score=2.0,
                         measured_s=0.5, heuristic_s=0.7, candidates=12,
                         measured=5)
        cache.put(ConfigKey("testdev", (1024,), "c2c"), rec)
        cache.save(path)
        loaded = TuningCache.load("testdev", path=path)
        assert len(loaded) == 3
        got = loaded.get(ConfigKey("testdev", (1024,), "c2c"))
        assert got == rec
        assert got.speedup_vs_heuristic == pytest.approx(1.4)

    def test_corrupted_file_falls_back_empty(self, tmp_path):
        path = str(tmp_path / "dev.json")
        with open(path, "w") as f:
            f.write("{ not json !!")
        loaded = TuningCache.load("testdev", path=path)
        assert len(loaded) == 0
        # ... and plan construction on top of it stays heuristic, no crash
        with use_tuning(TuningContext(loaded)):
            plan = plan_for_length(256)
        assert plan is plan_with_config(256)

    def test_version_mismatch_falls_back_empty(self, tmp_path):
        path = str(tmp_path / "dev.json")
        with open(path, "w") as f:
            json.dump({"version": CACHE_VERSION + 1, "entries": {
                "testdev|256|c2c|fp32": {"config": {"tile_b": 4}}}}, f)
        assert len(TuningCache.load("testdev", path=path)) == 0

    def test_malformed_record_falls_back_empty(self, tmp_path):
        path = str(tmp_path / "dev.json")
        with open(path, "w") as f:
            json.dump({"version": CACHE_VERSION,
                       "entries": {"testdev|256|c2c|fp32": 42}}, f)
        assert len(TuningCache.load("testdev", path=path)) == 0

    def test_missing_file_is_empty(self, tmp_path):
        assert len(TuningCache.load("testdev",
                                    path=str(tmp_path / "nope.json"))) == 0

    def test_env_override_controls_path(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "x.json"))
        assert cache_path("anydev") == str(tmp_path / "x.json")
        monkeypatch.delenv("REPRO_TUNE_CACHE")
        assert cache_path("anydev").endswith(
            os.path.join("repro-tune", "anydev.json"))

    def test_atomic_save_creates_dirs(self, tmp_path):
        path = str(tmp_path / "deep" / "nested" / "dev.json")
        cache = _tuned_cache()
        assert cache.save(path) == path
        assert json.load(open(path))["version"] == CACHE_VERSION


# ---------------------------------------------------------------------------
# The tuner proper
# ---------------------------------------------------------------------------

class _FakeClock:
    """Deterministic pseudo-random clock: same call sequence, same times."""

    def __init__(self):
        self.t = 0.0
        self.calls = 0

    def __call__(self):
        self.calls += 1
        self.t += 1e-4 * ((self.calls * 7919) % 13 + 1)
        return self.t


class TestTuner:
    def test_candidates_include_heuristic_first(self):
        cands = generate_candidates(256, "c2c", batch=64)
        assert cands[0] is HEURISTIC
        assert len(cands) == len(set(cands))        # no duplicates
        # the default radix schedule is normalised to None, so no candidate
        # is a functional clone of the heuristic
        assert not any(c.is_heuristic for c in cands[1:])

    def test_prune_keeps_heuristic_and_respects_budget(self):
        cands = generate_candidates(256, "c2c", batch=64)
        kept = prune_candidates(cands, 256, "c2c", TESLA_V100, "energy", 4)
        assert kept[0].config is HEURISTIC
        assert len(kept) <= 4

    def test_monkeypatched_timer_determinism(self):
        """Identical measurements => identical chosen config, bit for bit."""
        results = []
        for _ in range(2):
            cache = TuningCache(device="det-test")
            res = tune_length(256, cache=cache, objective="time",
                              repeats=3, warmup=0, timer=_FakeClock(),
                              save=False)
            results.append(res)
        a, b = results
        assert a.config == b.config
        assert a.record == b.record
        assert a.measurements == b.measurements > 0

    def test_never_regresses_heuristic(self):
        """A timer rigged AGAINST every non-heuristic candidate must make
        the tuner return the heuristic (speedup exactly 1.0)."""
        class RiggedClock(_FakeClock):
            def __call__(self):
                self.calls += 1
                # first measured candidate (the heuristic) looks fast,
                # everything after looks monotonically slower
                self.t += 1e-4 * self.calls
                return self.t

        cache = TuningCache(device="rig-test")
        res = tune_length(128, cache=cache, objective="time", repeats=2,
                          warmup=0, timer=RiggedClock(), save=False)
        assert res.config == HEURISTIC
        assert res.speedup_vs_heuristic == 1.0

    def test_cache_replay_skips_measurement(self, tmp_path):
        path = str(tmp_path / "dev.json")
        cache = TuningCache(device="replay-test")
        first = tune_length(256, cache=cache, objective="time", repeats=2,
                            warmup=0, timer=_FakeClock(), save=False)
        cache.save(path)
        fresh = TuningCache.load("replay-test", path=path)
        again = tune_length(256, cache=fresh)
        assert again.replayed
        assert again.measurements == 0
        assert again.config == first.config

    def test_rejects_unknown_objective_and_kind(self):
        with pytest.raises(ValueError, match="objective"):
            tune_length(64, objective="joules", cache=TuningCache("x"))
        with pytest.raises(ValueError, match="kind"):
            tune_length(64, kind="dct", cache=TuningCache("x"))

    def test_tune_segment_filter_longer_than_kernel_limit(self):
        """Filters too long for any single-pass segment fall through to
        multi-pass segments (no empty candidate list / IndexError)."""
        res = tune_segment(2**15, 5000, 2, cache=TuningCache("long-test"),
                           save=False)
        assert res.config.segment >= 5000
        assert res.config.segment & (res.config.segment - 1) == 0

    def test_tune_segment_model_choice_persists(self, tmp_path):
        path = str(tmp_path / "dev.json")
        cache = TuningCache(device="seg-test")
        res = tune_segment(4096, 64, 8, cache=cache, save=False)
        assert res.config.segment >= 64
        assert res.config.segment & (res.config.segment - 1) == 0
        cache.save(path)
        fresh = TuningCache.load("seg-test", path=path)
        again = tune_segment(4096, 64, 8, cache=fresh)
        assert again.replayed and again.config == res.config


# ---------------------------------------------------------------------------
# Plan routing: consult-once + bit-for-bit disable
# ---------------------------------------------------------------------------

class TestPlanRouting:
    def test_plan_consults_cache_exactly_once_per_key(self):
        cache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(tile_b=16, source="tuned"))])
        ctx = TuningContext(cache)
        with use_tuning(ctx):
            for _ in range(7):
                plan_for_length(256)
            assert ctx.consults == 1
            assert cache.lookups == 1
            plan_for_length(256, "r2c")            # distinct (shape, kind)
            assert ctx.consults == 2
            plan_for_length(512)                   # distinct shape
            assert ctx.consults == 3
            for _ in range(5):
                plan_nd((64, 64))                  # N-D key, same context
            assert ctx.consults == 4

    def test_tuned_plan_applies_config(self):
        cfg = KernelConfig(radices=(2,), source="tuned")
        cache = _tuned_cache(entries=[((256,), "c2c", cfg)])
        with use_tuning(TuningContext(cache)):
            plan = plan_for_length(256)
        assert plan.radices == (2,) * 8            # radix-2 schedule applied
        x = rand_c((5, 256))
        np.testing.assert_allclose(plan(x), jnp.fft.fft(x),
                                   rtol=3e-3, atol=3e-3)

    def test_tuned_four_step_split_applies(self):
        n = 2**14
        cfg = KernelConfig(split=(2**5, 2**9), source="tuned")
        cache = _tuned_cache(entries=[((n,), "c2c", cfg)])
        with use_tuning(TuningContext(cache)):
            plan = plan_for_length(n)
        assert plan.algorithm == "four-step"
        # the tuned (32, 512) cut, not the balanced (128, 128): the plan's
        # recorded first-pass schedule covers n1 = 32 -> (4, 4, 2)
        assert plan.radices == (2, 4, 4)
        x = rand_c((2, n))
        np.testing.assert_allclose(plan(x), jnp.fft.fft(x),
                                   rtol=3e-3, atol=3e-3)

    def test_bluestein_plan_threads_config_into_inner_ffts(self, monkeypatch):
        """Non-pow2 (Bluestein) plans must actually execute their tuned
        config — otherwise the tuner times byte-identical executables."""
        import repro.fft.plan as plan_mod
        calls = []
        orig = plan_mod.fft_kernel_c2c

        def spy(x, **kw):
            calls.append(kw)
            return orig(x, **kw)

        monkeypatch.setattr(plan_mod, "_kernel_fft", spy)
        cfg = KernelConfig(radices=(2,), tile_b=4, source="tuned")
        plan = plan_with_config(45, "c2c", cfg)
        assert plan.algorithm == "bluestein"
        x = rand_c((3, 45))
        np.testing.assert_allclose(plan(x), jnp.fft.fft(x),
                                   rtol=3e-3, atol=3e-3)
        assert any(kw.get("radices") == (2,) and kw.get("tile_b") == 4
                   for kw in calls)

    def test_no_heuristic_clone_candidates(self):
        """Explicit copies of the heuristic's resolved tile / balanced
        split are excluded — they could beat the heuristic on noise."""
        from repro.kernels.common import batch_tile
        from repro.tune.tuner import _split_candidates, _tile_candidates
        from repro.fft.plan import _four_step_split
        n, batch = 256, 64
        heuristic_tile = min(batch_tile(n, 4, buffers=8), batch)
        assert heuristic_tile not in [
            t for t in _tile_candidates(n, batch) if t is not None]
        n4 = 2**15
        assert _four_step_split(n4) not in _split_candidates(n4)[1:]

    def test_invalid_tuned_split_falls_back_to_balanced(self):
        n = 2**14
        cfg = KernelConfig(split=(3, n // 3), source="tuned")  # not pow2
        plan = plan_with_config(n, "c2c", cfg)
        ref = plan_with_config(n)
        assert plan.stages == ref.stages

    def test_disable_env_restores_heuristic_bit_for_bit(self, monkeypatch):
        """The escape hatch returns the SAME memoised heuristic plan object
        the pre-tuner path built — not an equivalent copy."""
        heuristic = plan_with_config(256)
        cache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(tile_b=4, radices=(2,),
                                         source="tuned"))])
        ctx = TuningContext(cache)
        with use_tuning(ctx):
            tuned = plan_for_length(256)
            assert tuned is not heuristic
            monkeypatch.setenv("REPRO_FFT_DISABLE_TUNING", "1")
            assert plan_for_length(256) is heuristic
            assert plan_nd((256,)) .fn is not None  # no crash on N-D either
            monkeypatch.delenv("REPRO_FFT_DISABLE_TUNING")
            assert plan_for_length(256) is tuned

    def test_no_context_is_heuristic_path(self):
        assert plan_config((256,), "c2c") is None
        assert plan_for_length(256) is plan_with_config(256)

    def test_conv_plan_uses_tuned_segment(self):
        n, taps, t = 2048, 33, 4
        cache = _tuned_cache(entries=[
            ((n, taps, t), "conv", KernelConfig(segment=1024,
                                                source="tuned"))])
        with use_tuning(TuningContext(cache)):
            plan = conv_plan(n, taps, t)
        assert plan.nfft == 1024
        # untuned / disabled path keeps the cost-model selection
        assert conv_plan(n, taps, t).nfft == select_nfft(taps, n, t)

    def test_conv_plan_ignores_invalid_tuned_segment(self):
        n, taps, t = 2048, 33, 4
        cache = _tuned_cache(entries=[
            ((n, taps, t), "conv", KernelConfig(segment=16,  # < taps
                                                source="tuned"))])
        with use_tuning(TuningContext(cache)):
            assert conv_plan(n, taps, t).nfft == select_nfft(taps, n, t)

    def test_common_default_serves_untuned_keys(self):
        cache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(radices=(8, 4, 2),
                                         source="tuned"))])
        ctx = TuningContext(cache)
        ctx.common = KernelConfig(radices=(8, 4, 2), source="common")
        with use_tuning(ctx):
            tuned = plan_for_length(256)           # its own entry
            untuned = plan_for_length(1024)        # falls back to common
        assert tuned.radices == (4, 8, 8)          # residual radix first
        assert untuned.radices == (2, 8, 8, 8)     # common schedule applied


# ---------------------------------------------------------------------------
# Common config (paper Sec. 4, software axis)
# ---------------------------------------------------------------------------

class TestCommonConfig:
    def test_empty_cache_raises(self):
        with pytest.raises(ValueError, match="no tuned"):
            common_config(TuningCache("empty"))

    def test_heuristic_only_cache_yields_heuristic(self):
        cache = _tuned_cache(entries=[((256,), "c2c", HEURISTIC),
                                      ((512,), "c2c", HEURISTIC)])
        cfg, regret = common_config(cache)
        assert cfg.is_heuristic
        assert regret == pytest.approx(0.0)

    def test_portable_axes_only(self):
        cache = _tuned_cache(entries=[
            ((2**14,), "c2c", KernelConfig(tile_b=16, radices=(8, 4, 2),
                                           split=(32, 512),
                                           source="tuned"))])
        cfg, regret = common_config(cache)
        assert cfg.split is None and cfg.segment == 0
        assert regret >= 0.0


# ---------------------------------------------------------------------------
# Serving integration: the plan/sweep cache keys on the tuned config
# ---------------------------------------------------------------------------

class TestServingIntegration:
    def _service_cache(self):
        from repro.serving.cache import PlanSweepCache
        return PlanSweepCache(TPU_V5E, batch_bytes=2**24)

    def _key(self, n=256):
        from repro.serving.request import ShapeKey
        return ShapeKey(kind="fft", n=n, precision="fp32",
                        device=TPU_V5E.name)

    def test_retune_invalidates_entries_transparently(self):
        cache = self._service_cache()
        key = self._key()
        e1 = cache.entry(key)
        assert cache.entry(key) is e1              # heuristic entry cached
        tcache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(radices=(2,), source="tuned"))])
        with use_tuning(TuningContext(tcache)):
            e2 = cache.entry(key)                  # tuned entry, new build
            assert e2 is not e1
            assert e2.plan.radices == (2,) * 8
            assert cache.entry(key) is e2          # ... and then cached
        assert cache.entry(key) is e1              # context gone -> heuristic

    def test_fdas_entries_key_on_tuned_conv_segment(self):
        """A conv-segment re-tune must rebuild FDAS entries, not serve the
        plan/sweep priced under the old segment."""
        from repro.search.templates import TemplateBank
        from repro.serving.request import ShapeKey
        n, templates = 2048, 5
        key = ShapeKey(kind="fdas", n=n, precision="fp32",
                       device=TPU_V5E.name, templates=templates)
        bank = TemplateBank.linear(zmax=(templates - 1) / 2.0,
                                   n_templates=templates)
        cache = self._service_cache()
        e1 = cache.entry(key)
        assert cache.entry(key) is e1
        tuned = _tuned_cache(entries=[
            ((n // 2 + 1, bank.taps, templates), "conv",
             KernelConfig(segment=512, source="tuned"))])
        with use_tuning(TuningContext(tuned)):
            e2 = cache.entry(key)
            assert e2 is not e1
            assert e2.plan.nfft == 512             # tuned segment applied
        assert cache.entry(key) is e1              # context gone -> heuristic

    def test_serving_consults_tuning_once_per_shape(self):
        tcache = _tuned_cache(entries=[
            ((256,), "c2c", KernelConfig(tile_b=8, source="tuned"))])
        ctx = TuningContext(tcache)
        cache = self._service_cache()
        with use_tuning(ctx):
            for _ in range(6):
                cache.entry(self._key())
        # one consult for the serving key + plan build combined: the
        # context memoises, however many layers ask
        assert ctx.consults == 1
        assert cache.stats.plan_builds == 1
        assert cache.stats.sweeps == 1