"""FDAS subsystem: plane parity, kernel routing, recovery, DVFS, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fft import plan as plan_mod
from repro.search import (TemplateBank, acceleration_response,
                          extract_candidates, fdas_conv_plan, fdas_search,
                          matched_filter_plane, matched_filter_taps)

KEY = jax.random.PRNGKey(0)


def rand_complex(shape, key=KEY):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def direct_plane(spec, bank):
    """Pad-to-full-length jnp.fft oracle for the matched-filter plane."""
    spec = np.atleast_2d(np.asarray(spec))
    nbins = spec.shape[-1]
    taps = bank.time_domain()
    m = 1 << (nbins + bank.taps - 2).bit_length()
    xs = np.asarray(jnp.fft.fft(jnp.asarray(spec), m, axis=-1))
    hs = np.asarray(jnp.fft.fft(jnp.asarray(taps), m, axis=-1))
    full = np.asarray(jnp.fft.ifft(jnp.asarray(xs[:, None, :] * hs[None]),
                                   axis=-1))
    return full[..., bank.offset:bank.offset + nbins]


def accelerated_series(n, k0, z, *, amp=0.3, noise=0.5, seed=1):
    """Real time series with a tone starting at bin k0, drifting z bins."""
    s = np.arange(n) / n
    rng = np.random.default_rng(seed)
    x = (amp * np.cos(2 * np.pi * (k0 * s + 0.5 * z * s * s))
         + noise * rng.standard_normal(n))
    return jnp.asarray(x.astype(np.float32))[None, :]


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def test_zero_drift_template_is_a_delta():
    t = acceleration_response(0.0, 32)
    peak = np.argmax(np.abs(t))
    assert peak == 32 // 2                       # centred window, u = 0
    assert np.abs(t)[peak] > 0.99
    assert np.abs(np.delete(t, peak)).max() < 0.05


def test_matched_taps_unit_energy():
    for z in (0.0, 3.0, -7.5):
        h = matched_filter_taps(z, 48)
        assert np.sum(np.abs(h) ** 2) == pytest.approx(1.0, rel=1e-6)


def test_bank_construction():
    bank = TemplateBank.linear(zmax=8, n_templates=9)
    assert bank.n_templates == 9
    assert bank.drifts[0] == -8.0 and bank.drifts[-1] == 8.0
    assert bank.taps >= 2 * 8
    assert TemplateBank.linear(zmax=0).drifts == (0.0,)
    with pytest.raises(ValueError):
        TemplateBank.linear(zmax=-1)
    # hashable -> usable as a static jit argument
    assert hash(bank) == hash(TemplateBank.linear(zmax=8, n_templates=9))


# ---------------------------------------------------------------------------
# Matched-filter plane: parity vs the direct oracle (acceptance <= 1e-4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbins", [513, 1025, 700])
def test_plane_matches_direct_oracle(nbins):
    bank = TemplateBank.linear(zmax=4, n_templates=5)
    spec = rand_complex((2, nbins), key=jax.random.PRNGKey(nbins))
    got = np.asarray(matched_filter_plane(spec, bank))
    want = direct_plane(spec, bank)
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel <= 1e-4, rel
    assert got.shape == (2, 5, nbins)


# ---------------------------------------------------------------------------
# Kernel routing: the bank runs as fused multiply epilogues (acceptance)
# ---------------------------------------------------------------------------

class _CountingKernel:
    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.forward_calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if not kwargs.get("inverse"):
            self.forward_calls += 1
        return self.inner(*args, **kwargs)


def test_plane_runs_fused_epilogues_no_multiply_pass(monkeypatch):
    """Forward segment FFTs carry the template bank as in-kernel multiply
    epilogues: ONE fused forward launch, ONE batched inverse launch over
    the T planes, and no plain forward C2C (which would imply a chained
    standalone multiply) or transpose kernels anywhere."""
    mul = _CountingKernel(plan_mod.fft_kernel_c2c_mul)
    fft = _CountingKernel(plan_mod.fft_kernel_c2c)
    tr = _CountingKernel(plan_mod.transpose_kernel)
    monkeypatch.setattr(plan_mod, "_kernel_fft_mul", mul)
    monkeypatch.setattr(plan_mod, "_kernel_fft", fft)
    monkeypatch.setattr(plan_mod, "_kernel_transpose", tr)
    bank = TemplateBank.linear(zmax=3, n_templates=7)
    spec = rand_complex((2, 801), key=jax.random.PRNGKey(41))
    got = matched_filter_plane(spec, bank)
    assert mul.calls == 1 and mul.forward_calls == 1
    assert fft.calls == 1 and fft.forward_calls == 0     # the inverse only
    assert tr.calls == 0
    rel = (np.abs(np.asarray(got) - direct_plane(spec, bank)).max()
           / np.abs(direct_plane(spec, bank)).max())
    assert rel <= 1e-4


def test_fdas_search_routes_r2c_then_fused_conv(monkeypatch):
    rfft = _CountingKernel(plan_mod.fft_kernel_r2c)
    mul = _CountingKernel(plan_mod.fft_kernel_c2c_mul)
    monkeypatch.setattr(plan_mod, "_kernel_rfft", rfft)
    monkeypatch.setattr(plan_mod, "_kernel_fft_mul", mul)
    bank = TemplateBank.linear(zmax=2, n_templates=5)
    x = accelerated_series(1024, 200, 2.0, seed=5)
    res = fdas_search(x, bank, threshold=5.0)
    assert rfft.calls == 1                       # one R2C front-end pass
    assert mul.calls == 1                        # one fused forward launch
    assert res.power.shape == (1, 5, 513)


def test_fdas_falls_back_without_pallas(monkeypatch):
    for hook in ("_kernel_fft", "_kernel_rfft", "_kernel_irfft",
                 "_kernel_fft_mul", "_kernel_fft_t", "_kernel_fft_axis1",
                 "_kernel_rfft_t", "_kernel_transpose"):
        monkeypatch.setattr(plan_mod, hook, None)
    bank = TemplateBank.linear(zmax=2, n_templates=5)
    spec = rand_complex((1, 700), key=jax.random.PRNGKey(43))
    got = np.asarray(matched_filter_plane(spec, bank))
    want = direct_plane(spec, bank)
    assert np.abs(got - want).max() / np.abs(want).max() <= 1e-4


# ---------------------------------------------------------------------------
# End-to-end search: injected accelerated pulsar recovery (acceptance)
# ---------------------------------------------------------------------------

def test_injected_pulsar_recovered_at_correct_cell():
    n, k0, z = 4096, 300, 6.0
    bank = TemplateBank.linear(zmax=8, n_templates=9)   # drifts step 2
    res = fdas_search(accelerated_series(n, k0, z), bank, threshold=8.0)
    power = np.asarray(res.power)[0]
    t_hit, b_hit = np.unravel_index(int(power.argmax()), power.shape)
    assert bank.drifts[t_hit] == z
    assert abs(b_hit - k0) <= 1
    # ... and it is the top candidate
    c = res.candidates
    assert int(c.template[0, 0]) == t_hit
    assert abs(int(c.bin[0, 0]) - k0) <= 1
    assert float(c.power[0, 0]) > 50.0


def test_zero_drift_tone_prefers_zero_template():
    n = 2048
    s = np.arange(n) / n
    x = jnp.asarray(np.cos(2 * np.pi * 500 * s).astype(np.float32))[None]
    bank = TemplateBank.linear(zmax=4, n_templates=9)
    res = fdas_search(x, bank, threshold=5.0)
    power = np.asarray(res.power)[0]
    t_hit, b_hit = np.unravel_index(int(power.argmax()), power.shape)
    assert bank.drifts[t_hit] == 0.0 and b_hit == 500


def test_extract_candidates_threshold_masking():
    power = jnp.zeros((1, 3, 100)).at[0, 1, 40].set(50.0).at[0, 2, 7].set(9.0)
    c = extract_candidates(power, threshold=8.0, max_candidates=4)
    assert c.template[0, 0] == 1 and c.bin[0, 0] == 40
    assert c.template[0, 1] == 2 and c.bin[0, 1] == 7
    # below-threshold slots are masked
    assert int(c.template[0, 2]) == -1 and float(c.power[0, 2]) == 0.0


def test_fdas_conv_plan_accounting():
    bank = TemplateBank.linear(zmax=8, n_templates=9)
    plan = fdas_conv_plan(2**13, bank)
    assert plan.forward_passes == 1
    assert plan.inverse_passes == bank.n_templates
    assert plan.traffic_ratio > 1.0


# ---------------------------------------------------------------------------
# Cost model + scheduler threading
# ---------------------------------------------------------------------------

def test_conv_case_and_workload():
    from repro.core import ConvCase, TESLA_V100, conv_workload
    case = ConvCase(n=4097, templates=9, taps=32)
    prof = conv_workload(case, TESLA_V100)
    assert prof.t_mem > 0 and prof.t_issue > 0 and prof.flops > 0
    # doubling the bank scales the plane roughly linearly
    big = conv_workload(ConvCase(n=4097, templates=18, taps=32), TESLA_V100)
    assert 1.5 < big.t_mem / prof.t_mem < 2.5
    with pytest.raises(ValueError):
        ConvCase(n=0, templates=1, taps=1)
    with pytest.raises(ValueError):
        ConvCase(n=16, templates=0, taps=1)


def test_fdas_workload_stages_and_scheduler():
    from repro.core import (ConvCase, TESLA_V100, fdas_total_profile,
                            fdas_workload, sweep)
    from repro.core.scheduler import DVFSScheduler
    case = ConvCase(n=2**12 + 1, templates=9, taps=32)
    profs = fdas_workload(case, TESLA_V100, series_n=2**13)
    assert [p.name for p in profs] == ["fdas-fft", "fdas-conv",
                                       "fdas-detect"]
    # the FFT-class stages dominate this pipeline (the point of FDAS as a
    # DVFS workload): their time share exceeds the Sec. 5.3 demo's
    times = [p.time(TESLA_V100.f_max, TESLA_V100) for p in profs]
    assert (times[0] + times[1]) / sum(times) > 0.5
    sched = DVFSScheduler(TESLA_V100)
    f_opt = sweep(profs[1], TESLA_V100).optimal.f
    rep = sched.evaluate_pipeline(
        sched.plan(profs, locked={"fdas-conv": f_opt}))
    assert rep.i_ef > 1.0
    total = fdas_total_profile(case, TESLA_V100, series_n=2**13)
    assert total.t_mem == pytest.approx(sum(p.t_mem for p in profs))


# ---------------------------------------------------------------------------
# Serving: FDAS as a first-class request kind
# ---------------------------------------------------------------------------

def test_service_serves_fdas_requests():
    from repro.serving import FFTService, KIND_FDAS
    svc = FFTService(batch_bytes=2**24, time_budget=None)
    n = 2048
    x = np.asarray(accelerated_series(n, 150, 2.0, seed=3))
    r = svc.submit(x, kind=KIND_FDAS, templates=9)
    svc.drain()
    rec = svc.receipt(r)
    assert rec is not None and rec.energy_j > 0
    # candidates arrive as a (batch, k, 3) array: template, bin, power
    assert rec.result.shape == (1, 16, 3)
    top_template, top_bin, top_power = np.asarray(rec.result[0, 0])
    bank_drifts = np.linspace(-4, 4, 9)
    assert bank_drifts[int(top_template)] == 2.0
    assert abs(int(top_bin) - 150) <= 1
    assert top_power > 8.0


def test_fdas_cache_keyed_on_n_segment_templates():
    from repro.serving import FFTService, KIND_FDAS
    svc = FFTService(batch_bytes=2**24, time_budget=None)
    x = np.random.default_rng(0).standard_normal((1, 1024)).astype(np.float32)
    svc.submit(x, kind=KIND_FDAS, templates=5)
    svc.submit(x, kind=KIND_FDAS, templates=9)          # different bank
    svc.submit(x, kind=KIND_FDAS, templates=5, segment=128)  # pinned nfft
    svc.drain()
    assert svc.cache.stats.misses == 3
    assert svc.cache.stats.sweeps == 3
    svc.submit(x, kind=KIND_FDAS, templates=5)          # repeat: cache hit
    svc.drain()
    assert svc.cache.stats.hits >= 1
    assert svc.cache.stats.sweeps == 3                  # no re-sweep


def test_fdas_request_validation():
    from repro.serving.request import FFTRequest, KIND_FDAS
    with pytest.raises(ValueError, match="templates"):
        FFTRequest(x=jnp.zeros((2, 64)), kind=KIND_FDAS, templates=0)
    with pytest.raises(ValueError):
        FFTRequest(x=jnp.zeros((2, 8, 8)), kind=KIND_FDAS, ndim=2)
    # fdas keys carry (n, segment, templates); plain FFTs zero them out
    a = FFTRequest(x=jnp.zeros((2, 64)), kind=KIND_FDAS, templates=5)
    b = FFTRequest(x=jnp.zeros((2, 64)), kind=KIND_FDAS, templates=9)
    assert a.shape_key("d") != b.shape_key("d")
    c = FFTRequest(x=jnp.zeros((2, 64)), templates=5)
    assert c.shape_key("d").templates == 0
