"""Fault-tolerant training driver: checkpoint/restart + straggler handling.

The driver wraps a train loop with:
  * periodic checkpoints (every ``ckpt_every`` steps),
  * failure detection — on this container failures are injected via
    :class:`SimulatedFailure` (step-indexed); on a real pod the same hook
    is wired to the JAX distributed heartbeat / coordinator errors,
  * restart-from-latest on failure, re-running at most ``ckpt_every``
    steps (exactly-once side effects are the data pipeline's job: batch i
    is a pure function of i, see repro.data.synthetic),
  * straggler mitigation: per-step wall-times feed an EWMA; hosts slower
    than ``straggler_factor`` x median get their data shards reassigned
    (deterministic work-stealing — shard mapping is pure function of
    (step, host set), no coordination state).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.runtime.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Injected node failure (step-indexed) for CPU-side testing."""


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    factor: float = 1.5
    alpha: float = 0.3
    shards_per_host: int = 1

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)

    def observe(self, host_times: np.ndarray) -> list[int]:
        """Update EWMA; return hosts flagged as stragglers."""
        self.ewma = np.where(self.ewma == 0, host_times,
                             (1 - self.alpha) * self.ewma
                             + self.alpha * host_times)
        med = float(np.median(self.ewma))
        return [h for h in range(self.n_hosts)
                if self.ewma[h] > self.factor * med]

    def shard_assignment(self, step: int, excluded: list[int]
                         ) -> dict[int, list[int]]:
        """Deterministic shard->host map with stragglers' load halved.

        Host ``h`` owns shards ``[h * shards_per_host, (h+1) *
        shards_per_host)``.  Shards of flagged hosts are split
        half-and-half: the straggler keeps the first ceil(half) (it is
        slow, not dead) and the fastest *non-flagged* host this step takes
        the rest.  The map is a pure function of (EWMA state, excluded),
        so every host computes the same reassignment with no coordination.
        """
        spH = self.shards_per_host
        assign = {h: [h * spH + i for i in range(spH)]
                  for h in range(self.n_hosts)}
        if not excluded:
            return assign
        healthy = [h for h in range(self.n_hosts) if h not in excluded]
        if not healthy:
            return assign                 # everyone is slow: nobody to help
        fastest = min(healthy, key=lambda h: (self.ewma[h], h))
        for h in excluded:
            shards = assign[h]
            keep = len(shards) - len(shards) // 2
            assign[h], moved = shards[:keep], shards[keep:]
            assign[fastest] = assign[fastest] + moved
        return assign


@dataclasses.dataclass
class FaultTolerantDriver:
    train_step: Callable[..., tuple[Any, dict]]
    state: Any
    data_iter_fn: Callable[[int], tuple]   # step -> (inputs, labels)
    ckpt: CheckpointManager
    ckpt_every: int = 10
    max_restarts: int = 3
    fail_at: dict[int, int] | None = None  # step -> host that "dies"
    # Injectable monotonic clock (the serving layer's timer= idiom), so
    # fault-path wall metrics are deterministic under a FakeTimer.
    timer: Callable[[], float] = time.monotonic

    def run(self, n_steps: int, *, start_step: int = 0):
        """Run to n_steps, surviving injected failures via restore."""
        metrics_log = []
        restarts = 0
        step = start_step
        while step < n_steps:
            try:
                if self.fail_at and step in self.fail_at:
                    failed_host = self.fail_at.pop(step)
                    raise SimulatedFailure(
                        f"host {failed_host} lost at step {step}")
                inputs, labels = self.data_iter_fn(step)
                t0 = self.timer()
                self.state, metrics = self.train_step(self.state, inputs,
                                                      labels)
                metrics["wall"] = self.timer() - t0
                metrics["step"] = step
                metrics_log.append(metrics)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, self.state)
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    self.state = self.ckpt.restore(self.state, latest)
                    step = latest
                else:
                    step = start_step
                # Drop metrics from rolled-back steps: they re-run after
                # the restore, and each step must appear exactly once.
                metrics_log = [m for m in metrics_log if m["step"] < step]
        # final checkpoint
        self.ckpt.save(step, self.state)
        return self.state, metrics_log, restarts
