"""Serving-side fault-injection plane: deterministic fault plans,
per-device circuit breakers and jittered-backoff retry policies.

``repro.runtime.fault`` injects *training-loop* failures by step index
(:class:`SimulatedFailure`).  This module generalises the idea for the
serving runtime: a :class:`FaultPlan` is a deterministic, seed-generated
schedule of one-shot fault events keyed on the serving layer's own
deterministic identifiers (batch ids, worker slots) —

  kill-device       the device executing a batch dies mid-batch
  fail-clock-lock   the DVFS lock acquisition (ClockController.locked)
                    fails; the batch must degrade to boost, not crash
  fail-plan-build   the tuned plan/sweep build for a shape fails; the
                    service walks down the degradation ladder
  stall-worker      a worker wedges for ``duration`` seconds; its queued
                    work must be redistributed
  sensor-dropout    a device's power-sensor read fails (NaN reading);
                    the telemetry watchdog must classify it, and the
                    power governor must never act on it
  sensor-spike      the power sensor returns an impossible value (far
                    outside the TDP envelope — a wedged I2C transaction)
  sensor-stale      the power sensor keeps replaying an old reading with
                    a frozen timestamp (the sampling daemon died)
  kill-host         a whole simulated host (:class:`HostTopology` fault
                    domain) dies: every co-hosted device, its breakers
                    and its telemetry rings go down together
  crash-process     the serving process itself dies; only the
                    write-ahead journal (repro.runtime.journal) survives
                    — recovery is ``FFTService.recover``'s job, not an
                    in-process handler's

Because events are keyed on batch ids (assigned in deterministic FIFO
order by ``FFTService.drain``) rather than wall-clock time, a chaos run
with the same fault-plan seed reproduces the exact same set of
kill/degrade/shed outcomes — the bit-reproducibility the chaos benchmark
gates on.  On real hardware the same exception types are raised by the
XLA device runtime / NVML instead of the plan; everything downstream
(breakers, retries, the degradation ladder) is identical.

Barbosa et al. (2016) frame SKA power management as a *monitored,
failure-aware control problem*; the circuit breaker here is that control
loop's actuator: a device that keeps failing is quarantined (open), then
probed after a cooldown (half-open) and re-admitted only on a successful
probe (closed).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.runtime.fault import SimulatedFailure

#: Fault kinds a plan can schedule.
KILL_DEVICE = "kill-device"
FAIL_CLOCK_LOCK = "fail-clock-lock"
FAIL_PLAN_BUILD = "fail-plan-build"
STALL_WORKER = "stall-worker"
SENSOR_DROPOUT = "sensor-dropout"
SENSOR_SPIKE = "sensor-spike"
SENSOR_STALE = "sensor-stale"
KILL_HOST = "kill-host"          # a whole host (fault domain) dies
CRASH_PROCESS = "crash-process"  # the serving process itself dies

FAULT_KINDS = (KILL_DEVICE, FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD, STALL_WORKER,
               SENSOR_DROPOUT, SENSOR_SPIKE, SENSOR_STALE, KILL_HOST,
               CRASH_PROCESS)

#: The telemetry-plane subset (consumed by repro.power samplers, not by
#: the serving execution path).
SENSOR_KINDS = (SENSOR_DROPOUT, SENSOR_SPIKE, SENSOR_STALE)


def _notify_obs(exc: BaseException) -> None:
    """Snapshot live flight recorders (repro.obs.trace) for ``exc``.

    Imported lazily so the fault plane stays importable without the
    observability package and never pays for it when no tracer exists.
    """
    try:
        from repro.obs.trace import notify_fault
    except ImportError:                      # pragma: no cover
        return
    notify_fault(exc)


class FaultError(SimulatedFailure):
    """Base class for injected serving faults (a SimulatedFailure kin).

    Constructing any subclass notifies the observability plane, so every
    live tracer's flight recorder snapshots its last-N spans at the
    moment of failure (the postmortem record).
    """

    def __init__(self, *args):
        super().__init__(*args)
        _notify_obs(self)


class DeviceLostError(FaultError):
    """The device executing a batch died mid-batch."""

    def __init__(self, worker: int, detail: str = ""):
        self.worker = worker
        super().__init__(f"device behind worker {worker} lost{detail}")


@dataclasses.dataclass(frozen=True)
class HostTopology:
    """Devices grouped into simulated hosts (the fault domains).

    ``devices_per_host`` consecutive worker slots share one host: one
    power feed, one PCIe/NIC complex, one telemetry daemon.  A host-level
    fault (:class:`HostLostError`) therefore takes down every device in
    the group together — their breakers trip as a unit and their
    telemetry rings are wiped, exactly what a real node loss does.  The
    default (1 device per host) makes every device its own fault domain,
    which degenerates to the PR 7 per-device behaviour.
    """

    n_workers: int
    devices_per_host: int = 1

    def __post_init__(self):
        if self.n_workers < 1 or self.devices_per_host < 1:
            raise ValueError(
                f"need n_workers >= 1 and devices_per_host >= 1, got "
                f"{self.n_workers}/{self.devices_per_host}")

    @property
    def n_hosts(self) -> int:
        return -(-self.n_workers // self.devices_per_host)

    def host_of(self, worker: int) -> int:
        if not 0 <= worker < self.n_workers:
            raise ValueError(f"worker {worker} outside fleet of "
                             f"{self.n_workers}")
        return worker // self.devices_per_host

    def workers_of(self, host: int) -> tuple[int, ...]:
        if not 0 <= host < self.n_hosts:
            raise ValueError(f"host {host} outside {self.n_hosts} hosts")
        lo = host * self.devices_per_host
        return tuple(range(lo, min(lo + self.devices_per_host,
                                   self.n_workers)))


class HostLostError(DeviceLostError):
    """The whole host behind ``worker`` died (all its devices with it).

    Subclasses :class:`DeviceLostError` — for the executing batch a host
    loss *is* a device loss — but handlers that know the topology catch
    it first and quarantine every co-hosted device together.
    """

    def __init__(self, worker: int, host: int, workers: tuple[int, ...]):
        self.host = host
        self.workers = tuple(workers)
        super().__init__(worker,
                         detail=f" with host {host} (workers "
                                f"{list(self.workers)})")


class ProcessCrashError(FaultError):
    """The serving process itself dies (kill -9, OOM, power cut).

    No in-process handler can catch a real one — the chaos harness
    *simulates* it by abandoning the live service object mid-stream and
    rebuilding from the write-ahead journal
    (``FFTService.recover``, repro.serving.recovery).
    """

    def __init__(self, arrival: int | None = None):
        self.arrival = arrival
        super().__init__(
            f"process crash injected at journal seq {arrival}")


class ClockLockError(FaultError):
    """The DVFS clock-lock acquisition failed (NVML/driver error)."""


class PlanBuildError(FaultError):
    """A plan or sweep build failed for a shape."""


class WorkerStalledError(FaultError):
    """A worker is wedged; its queued work needs redistribution."""

    def __init__(self, worker: int, duration: float):
        self.worker = worker
        self.duration = duration
        super().__init__(f"worker {worker} stalled for {duration:g}s")


class DrainDeadlineError(RuntimeError):
    """drain() exceeded its deadline with work still stuck in queues.

    ``stuck`` names the shape keys of the batches that never executed —
    the first one is the batch a wedged worker is sitting on.
    """

    def __init__(self, deadline_s: float, stuck: list):
        self.deadline_s = deadline_s
        self.stuck = list(stuck)
        first = self.stuck[0] if self.stuck else None
        super().__init__(
            f"drain() exceeded its {deadline_s:g}s deadline with "
            f"{len(self.stuck)} batch(es) stuck; first stuck shape: {first}")
        _notify_obs(self)


@dataclasses.dataclass
class FaultEvent:
    """One scheduled one-shot fault.

    ``batch_id``/``worker``/``arrival`` are match constraints: a ``None``
    field matches anything.  ``arrival`` keys on the *journal sequence
    number* of a request (``FFTRequest.jseq``, assigned at admit by
    repro.runtime.journal) — the seam that lets plans target a point in
    the arrival stream rather than only the batch ids the FIFO
    coalescer happens to assign.  ``duration`` only applies to stalls.
    """

    kind: str
    batch_id: int | None = None
    worker: int | None = None
    duration: float = 0.0
    arrival: int | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; have {FAULT_KINDS}")

    def matches(self, batch_id: int | None, worker: int | None,
                arrival: int | None = None) -> bool:
        if self.batch_id is not None and self.batch_id != batch_id:
            return False
        if self.worker is not None and self.worker != worker:
            return False
        if self.arrival is not None and self.arrival != arrival:
            return False
        return True


@dataclasses.dataclass
class FaultPlan:
    """A deterministic schedule of one-shot fault events.

    ``take(kind, ...)`` pops (and returns) the first still-pending event
    of ``kind`` matching the given identifiers, or None — so each event
    fires exactly once, in a deterministic order.  ``fired`` keeps the
    consumed events for receipts/diagnostics.
    """

    events: list[FaultEvent] = dataclasses.field(default_factory=list)
    seed: int | None = None

    def __post_init__(self):
        self.fired: list[FaultEvent] = []

    def take(self, kind: str, *, batch_id: int | None = None,
             worker: int | None = None,
             arrival: int | None = None) -> FaultEvent | None:
        for i, ev in enumerate(self.events):
            if ev.kind == kind and ev.matches(batch_id, worker, arrival):
                self.fired.append(self.events.pop(i))
                return self.fired[-1]
        return None

    def pending(self, kind: str | None = None) -> int:
        return sum(1 for ev in self.events
                   if kind is None or ev.kind == kind)

    def fired_count(self, kind: str | None = None) -> int:
        return sum(1 for ev in self.fired
                   if kind is None or ev.kind == kind)

    def drop_consumed(self, *, batch_before: int | None = None,
                      arrival_before: int | None = None) -> int:
        """Discard events a *previous incarnation* already consumed.

        After a process crash the recovering harness regenerates the same
        seeded plan, then drops every event pinned to a batch id below
        the journal-restored ``_next_batch_id`` (all earlier batches were
        polled for every kind, so their pinned events fired before the
        crash) or to an arrival seq already admitted.  Returns the number
        dropped.  Dropped events are *not* added to ``fired`` — they
        fired in another incarnation's plan object; callers that need
        cross-incarnation fired totals sum per-incarnation counts.
        """
        def consumed(ev: FaultEvent) -> bool:
            if (batch_before is not None and ev.batch_id is not None
                    and ev.batch_id < batch_before):
                return True
            return (arrival_before is not None and ev.arrival is not None
                    and ev.arrival < arrival_before)

        before = len(self.events)
        self.events = [ev for ev in self.events if not consumed(ev)]
        return before - len(self.events)

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_batches: int,
        kill_rate: float = 0.01,
        clock_fail_rate: float = 0.01,
        plan_fail_rate: float = 0.005,
        stall_rate: float = 0.005,
        stall_duration_s: float = 0.02,
        sensor_dropout_rate: float = 0.01,
        sensor_spike_rate: float = 0.01,
        sensor_stale_rate: float = 0.005,
        ensure_one_of_each: bool = True,
        crash_arrivals: tuple = (),
        host_kill_batches: tuple = (),
    ) -> "FaultPlan":
        """A seed-deterministic plan over ``n_batches`` batch ids.

        Each batch id draws each fault kind independently at its rate;
        ``ensure_one_of_each`` additionally pins one of each execution
        fault (kill, clock-lock failure, stall) — and, when the run is
        long enough, one of each telemetry sensor fault — onto the
        earliest batch ids so even tiny runs satisfy the chaos harness's
        non-trivial-plan requirement.

        ``crash_arrivals`` / ``host_kill_batches`` pin CRASH_PROCESS
        events on journal arrival seqs and KILL_HOST events on batch ids.
        Both are appended *after* the per-batch draws without consuming
        the RNG stream, so the default (empty) plan is bit-identical to
        what this function generated before the seams existed.
        """
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        pinned = 0
        if ensure_one_of_each and n_batches >= 3:
            events.append(FaultEvent(KILL_DEVICE, batch_id=0))
            events.append(FaultEvent(FAIL_CLOCK_LOCK, batch_id=1))
            events.append(FaultEvent(STALL_WORKER, batch_id=2,
                                     duration=stall_duration_s))
            pinned = 3
            if n_batches >= 6:
                events.append(FaultEvent(SENSOR_DROPOUT, batch_id=3))
                events.append(FaultEvent(SENSOR_SPIKE, batch_id=4))
                events.append(FaultEvent(SENSOR_STALE, batch_id=5))
                pinned = 6
        rates = (kill_rate, clock_fail_rate, plan_fail_rate, stall_rate,
                 sensor_dropout_rate, sensor_spike_rate, sensor_stale_rate)
        kinds = (KILL_DEVICE, FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD,
                 STALL_WORKER, SENSOR_DROPOUT, SENSOR_SPIKE, SENSOR_STALE)
        draws = rng.random((n_batches, len(kinds)))
        for b in range(pinned, n_batches):
            for col, (kind, rate) in enumerate(zip(kinds, rates)):
                if draws[b, col] < rate:
                    duration = stall_duration_s if kind == STALL_WORKER \
                        else 0.0
                    events.append(FaultEvent(kind, batch_id=b,
                                             duration=duration))
        for a in crash_arrivals:
            events.append(FaultEvent(CRASH_PROCESS, arrival=int(a)))
        for b in host_kill_batches:
            events.append(FaultEvent(KILL_HOST, batch_id=int(b)))
        return cls(events=events, seed=seed)


@dataclasses.dataclass
class RetryPolicy:
    """Retry-with-jittered-backoff, deterministically.

    The jitter is a pure function of (seed, token, attempt) — a hash, not
    a shared RNG — so concurrent retries for different batches never
    perturb each other's delays and a re-run reproduces them exactly.
    Delays follow capped exponential backoff with +/-50% jitter.
    """

    max_retries: int = 2
    base_delay_s: float = 0.001
    max_delay_s: float = 0.1
    seed: int = 0

    def delay(self, attempt: int, token: int = 0) -> float:
        """Backoff before retry ``attempt`` (1-based) of work ``token``."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        raw = min(self.base_delay_s * 2.0 ** (attempt - 1), self.max_delay_s)
        h = hashlib.blake2b(
            f"{self.seed}:{token}:{attempt}".encode(), digest_size=8)
        frac = int.from_bytes(h.digest(), "big") / 2.0 ** 64
        return raw * (0.5 + frac)              # in [0.5, 1.5) * raw


# Circuit-breaker states.
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


@dataclasses.dataclass
class CircuitBreaker:
    """Per-device circuit breaker: quarantine after repeated failures,
    probe after a cooldown, re-admit only on a successful probe.

      closed     traffic flows; failures count against the threshold
      open       quarantined; no traffic until ``cooldown_s`` elapses
      half-open  one probe admitted; success -> closed, failure -> open

    Timestamps come from the caller's timer so tests and the chaos
    harness can drive the state machine with fake clocks.
    """

    failure_threshold: int = 2
    cooldown_s: float = 0.05

    def __post_init__(self):
        self.state = CLOSED
        self.failures = 0               # consecutive failures while closed
        self.opened_at: float | None = None
        self.opens = 0                  # times the breaker tripped
        self.probes = 0                 # half-open probes admitted

    def allow(self, now: float) -> bool:
        """May this device receive work at time ``now``?"""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.opened_at is not None and \
                    now - self.opened_at >= self.cooldown_s:
                self.state = HALF_OPEN
                self.probes += 1
                return True             # the single probe
            return False
        # half-open: the probe is in flight; no further traffic until it
        # reports back.
        return False

    def would_allow(self, now: float) -> bool:
        """Like :meth:`allow` but pure — no state transition, no probe.

        Used when *choosing* a redistribution target, so that scanning
        candidate workers never consumes a quarantined device's single
        half-open probe allowance.
        """
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            return (self.opened_at is not None
                    and now - self.opened_at >= self.cooldown_s)
        return False

    def record_failure(self, now: float) -> None:
        if self.state == HALF_OPEN:
            self.state = OPEN           # failed probe: quarantine again
            self.opened_at = now
            self.opens += 1
            return
        self.failures += 1
        if self.failures >= self.failure_threshold:
            self.state = OPEN
            self.opened_at = now
            self.opens += 1

    def record_success(self) -> None:
        self.state = CLOSED
        self.failures = 0
        self.opened_at = None

    def trip(self, now: float) -> None:
        """Quarantine immediately, bypassing the failure count.

        Host-level faults (:class:`HostLostError`) kill every device in
        the fault domain at once; devices that were not even executing
        have no failures to count, they are simply *gone* until the host
        returns — modelled as an immediate open with the usual cooldown
        playing the reboot time.  Idempotent while already open.
        """
        if self.state != OPEN:
            self.state = OPEN
            self.opens += 1
        self.opened_at = now
        self.failures = 0
