"""Elastic scaling: remap a checkpoint onto a shrunk/grown mesh.

At 1000+ nodes, waiting for a replacement node is wasteful; the elastic
plan answers "which mesh do we rebuild with the devices we still have,
and is it worth it":

  * the ``model`` axis is load-bearing (weights are sharded over it) —
    we keep it intact and shrink the ``data``/``pod`` axes, because DP
    replicas are interchangeable;
  * batch invariance: global_batch stays fixed; surviving replicas take
    proportionally more microbatches (gradient accumulation), trading
    step time for numerical identity with the pre-failure run;
  * restore path: repro.runtime.checkpoint restores by shape + device_put
    with the NEW mesh's shardings — the manifest is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_mesh: tuple[int, ...]
    new_mesh: tuple[int, ...]
    axis_names: tuple[str, ...]
    microbatch_multiplier: int     # extra grad-accum per surviving replica
    throughput_fraction: float     # expected step-rate vs original


def elastic_remesh_plan(mesh_shape: tuple[int, ...],
                        axis_names: tuple[str, ...],
                        n_failed: int) -> RemeshPlan:
    """Shrink the data-parallel axis to absorb ``n_failed`` devices.

    The model axis is preserved (weight shards must remain complete);
    whole DP replicas are retired — each retired replica costs
    ``model_axis`` devices, so we retire ceil(n_failed / model) replicas.
    """
    assert "data" in axis_names
    data_idx = axis_names.index("data")
    model = 1
    if "model" in axis_names:
        model = mesh_shape[axis_names.index("model")]
    replicas = 1
    for i, a in enumerate(axis_names):
        if a != "model":
            replicas *= mesh_shape[i]

    retired = -(-n_failed // model)            # ceil
    new_replicas = replicas - retired
    if new_replicas < 1:
        raise ValueError("not enough devices left for one replica")

    # fold pods into the data axis if a pod was lost
    new_shape = list(mesh_shape)
    if "pod" in axis_names:
        pod_idx = axis_names.index("pod")
        new_shape[pod_idx] = 1
        new_shape[data_idx] = new_replicas
    else:
        new_shape[data_idx] = new_replicas

    # keep global batch: each survivor accumulates more microbatches
    mult = -(-replicas // new_replicas)
    return RemeshPlan(
        old_mesh=tuple(mesh_shape),
        new_mesh=tuple(new_shape),
        axis_names=axis_names,
        microbatch_multiplier=mult,
        throughput_fraction=new_replicas / replicas,
    )
