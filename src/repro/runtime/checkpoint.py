"""Sharded, atomic, step-tagged checkpointing.

Design for thousands of nodes (DESIGN.md §Fault tolerance):
  * each host writes ONLY its local shards (``host_shard`` extracts the
    addressable portion) — no gather, no single-writer bottleneck;
  * writes go to a temp directory + atomic rename, so a node failure
    mid-write never corrupts the latest-complete pointer;
  * the manifest records the pytree structure, global shapes and the mesh
    it was saved under, so restore onto a DIFFERENT mesh (elastic restart)
    re-shards automatically via jax.device_put;
  * retention: keep the last K checkpoints (bounded disk).

On this container everything runs single-host; the multi-host paths are
the same code with host_id/n_hosts > 1 (exercised by unit tests that fake
multiple hosts into separate directories).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    def key_str(path):
        return "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
    return [(key_str(p), leaf) for p, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: int = 0, n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        tmp = os.path.join(self.dir, f".tmp-{step}-{self.host_id}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        leaves, _ = _flatten_with_paths(tree)
        manifest = {}
        for name, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            fn = name.replace("/", "__") + f".host{self.host_id}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest[name] = {"file": fn, "shape": list(arr.shape),
                              "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, f"manifest.host{self.host_id}.json"),
                  "w") as f:
            json.dump({"step": step, "leaves": manifest,
                       "n_hosts": self.n_hosts}, f)
        # atomic publish (host 0 renames; other hosts move files in)
        os.makedirs(final, exist_ok=True)
        for fn in os.listdir(tmp):
            os.replace(os.path.join(tmp, fn), os.path.join(final, fn))
        shutil.rmtree(tmp, ignore_errors=True)
        # completion marker per host; checkpoint is valid when all present
        open(os.path.join(final, f"DONE.host{self.host_id}"), "w").close()
        self._gc()
        return final

    # ------------------------------------------------------------------
    def _complete(self, path: str) -> bool:
        return all(
            os.path.exists(os.path.join(path, f"DONE.host{h}"))
            for h in range(self.n_hosts))

    def latest_step(self) -> int | None:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and self._complete(
                    os.path.join(self.dir, d)):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure (and shardings) of ``tree_like``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path,
                               f"manifest.host{self.host_id}.json")) as f:
            manifest = json.load(f)["leaves"]
        leaves, treedef = _flatten_with_paths(tree_like)
        out = []
        for name, like in leaves:
            info = manifest[name]
            arr = np.load(os.path.join(path, info["file"]))
            target_dtype = (like.dtype if hasattr(like, "dtype")
                            else arr.dtype)
            arr = arr.astype(target_dtype)
            if hasattr(like, "sharding"):
                out.append(jax.device_put(arr, like.sharding))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)

    # ------------------------------------------------------------------
    def _gc(self):
        done = sorted(
            d for d in os.listdir(self.dir)
            if d.startswith("step_") and self._complete(
                os.path.join(self.dir, d)))
        for d in done[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
