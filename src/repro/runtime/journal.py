"""Crash-consistent write-ahead request journal (append-only JSONL).

Barbosa et al. (2016) make *continuity of operations* a first-class SKA
requirement: an edge pipeline is expected to survive years of unattended
operation, which means surviving its own process dying mid-wave.  The
serving layer's in-memory state (pending requests, receipts, breaker and
watchdog health) evaporates with the process; this module is the durable
record it is rebuilt from.

Design — a classic write-ahead log, sized for the chaos harness's 10^6
request streams:

  records     one JSON object per line.  Every record carries a
              monotonically increasing sequence number ``seq`` (the
              journal's identity space — request ids are process-local
              and reset across restarts, journal seqs never do), a type
              tag and a per-record blake2b checksum over
              ``"{seq}:{type}:{canonical-json(data)}"``.
  segments    the log is split into ``seg-NNNNNN.jsonl`` files of at most
              ``segment_records`` records.  Rotation is atomic and
              fsync'd: the outgoing segment is flushed + fsync'd before
              the next one opens, so every *closed* segment is durable
              in full.  Each process incarnation starts a fresh segment
              (closed segments are never appended to again).
  replay      segments are read in order and records are validated
              (checksum, JSON shape, seq continuity).  The first invalid
              record — a torn tail from a crash mid-write, a corrupted
              checksum, a truncated segment — stops replay at the last
              valid record with a structured warning; later records are
              *not* trusted (a corrupt record's successors are garbage
              until proven otherwise).  No exception: a torn tail is the
              expected crash signature, not an error.  Opening for write
              also *repairs*: the torn segment is truncated at the last
              valid record and later segments are quarantined, so the
              new incarnation's appends are reachable by every future
              replay instead of being stranded behind the bad byte.
  snapshots   ``write_snapshot`` persists a JSON state dict atomically
              (tmp file + fsync + rename) next to the segments, stamped
              with the journal seq it covers; ``load_snapshot`` returns
              the newest checksum-valid one.

Record types (the request lifecycle the serving layer logs):

  open      a process incarnation opened the journal
  admit     a request entered the service (write-ahead: logged at
            submit).  The record's ``seq`` is the request's durable
            identity (``FFTRequest.jseq``).
  assign    a coalesced batch was formed (batch id + member seqs)
  served    a request terminated in a served receipt
  shed      a request terminated in a shed receipt

Exactly-once receipts follow from the admit/terminal pairing: a request
whose admit record has no terminal record by replay time was in flight
when the process died and is re-enqueued on recovery; one with a
terminal record is *replayed* (bit-identical status/reason/rung),
never re-executed.  See ``repro.serving.recovery``.

``sync`` policy: ``"rotate"`` (default) fsyncs on rotation, snapshot and
close — the contract the module name promises, at ~10^6-records/minute
append rates; ``"always"`` additionally fsyncs every append (tests,
small control-plane journals).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import time
from typing import Callable, Iterator

from repro.obs.log import get_logger

__all__ = ["ADMIT", "ASSIGN", "SERVED", "SHED", "OPEN", "TERMINAL_TYPES",
           "JournalRecord", "ReplayStats", "RequestJournal",
           "read_segment_records", "read_journal", "process_incarnation"]

# Record types.
OPEN = "open"
ADMIT = "admit"
ASSIGN = "assign"
SERVED = "served"
SHED = "shed"

#: Types that terminate a request's lifecycle (exactly one per request).
TERMINAL_TYPES = (SERVED, SHED)

_TYPES = (OPEN, ADMIT, ASSIGN, SERVED, SHED)

_SEGMENT_RE = re.compile(r"^seg-(\d{6})\.jsonl$")
_SNAPSHOT_RE = re.compile(r"^snap-(\d+)\.json$")

_DIGEST_SIZE = 8                 # 16 hex chars per record checksum


def _canonical(data: dict) -> str:
    return json.dumps(data, sort_keys=True, separators=(",", ":"))


def _checksum(seq: int, rtype: str, data: dict) -> str:
    payload = f"{seq}:{rtype}:{_canonical(data)}".encode()
    return hashlib.blake2b(payload, digest_size=_DIGEST_SIZE).hexdigest()


_PROCESS_INCARNATION: str | None = None


def process_incarnation() -> str:
    """A memoised id for THIS process incarnation (benchmark envelopes).

    Journal-attached services stamp receipts with the journal's own
    deterministic incarnation counter; artifacts emitted by journal-less
    processes (most ``BENCH_*.json``) carry this process-level id so any
    two artifacts can be told apart by which incarnation produced them.
    """
    global _PROCESS_INCARNATION
    if _PROCESS_INCARNATION is None:
        h = hashlib.blake2b(
            f"{os.getpid()}:{time.time_ns()}".encode(), digest_size=6)
        _PROCESS_INCARNATION = f"proc-{h.hexdigest()}"
    return _PROCESS_INCARNATION


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One validated journal record."""

    seq: int
    type: str
    data: dict

    def line(self) -> str:
        return _canonical({"seq": self.seq, "type": self.type,
                           "data": self.data,
                           "c": _checksum(self.seq, self.type, self.data)})


@dataclasses.dataclass
class ReplayStats:
    """What replaying the on-disk journal found."""

    segments: int = 0            # segment files visited
    records: int = 0             # checksum-valid records replayed
    invalid: int = 0             # records rejected (torn/corrupt); replay
    #                              stops at the first one, so this is 0 or 1
    stopped_at_seq: int = -1     # seq of the last valid record (-1: none)
    torn_segment: str | None = None   # file the invalid record was in


def read_segment_records(path: str) -> Iterator[tuple[str, int]]:
    """Yield (raw_line, byte_offset) for each newline-terminated line.

    A final line without a trailing newline is still yielded — whether it
    is a torn tail is the *checksum's* call, not the framing's (a crash
    can tear mid-record but can also happen to stop exactly at a record
    boundary).
    """
    offset = 0
    with open(path, "r", encoding="utf-8", errors="replace") as f:
        for line in f:
            yield line, offset
            offset += len(line.encode("utf-8", errors="replace"))


def _parse_record(line: str) -> JournalRecord | None:
    """Validate one raw line; None for anything not checksum-perfect."""
    try:
        obj = json.loads(line)
    except (ValueError, TypeError):
        return None
    if not isinstance(obj, dict):
        return None
    seq, rtype, data, c = (obj.get("seq"), obj.get("type"),
                           obj.get("data"), obj.get("c"))
    if (not isinstance(seq, int) or rtype not in _TYPES
            or not isinstance(data, dict) or not isinstance(c, str)):
        return None
    if _checksum(seq, rtype, data) != c:
        return None
    return JournalRecord(seq=seq, type=rtype, data=data)


def read_journal(path: str, *, log: Callable[..., None] | None = None,
                 sink: Callable[["JournalRecord"], None] | None = None,
                 ) -> tuple[list["JournalRecord"], ReplayStats]:
    """Read-only replay of a journal directory (audit, no side effects).

    Same validation as opening a :class:`RequestJournal` — checksum,
    shape, strict seq continuity, stop at the first bad record with a
    structured warning — but appends nothing: no OPEN record, no new
    segment, no incarnation minted, no repair.  This is what end-of-run
    audits use to prove the exactly-once contract from the durable log
    alone.

    With a ``sink``, each validated record is streamed to the callback
    and the returned list is empty — a 10^6-request journal audits in
    O(1) record memory this way.
    """
    warn = log if log is not None else get_logger("journal").warning
    records: list[JournalRecord] = []
    stats = ReplayStats()
    names = sorted(n for n in os.listdir(path) if _SEGMENT_RE.match(n))
    expect = 0
    for name in names:
        stats.segments += 1
        for line, _ in read_segment_records(os.path.join(path, name)):
            rec = _parse_record(line)
            if rec is None or rec.seq != expect:
                stats.invalid += 1
                stats.torn_segment = name
                warn("journal-torn-record", segment=name,
                     expected_seq=expect, valid_records=stats.records,
                     reason=("checksum-or-framing" if rec is None
                             else "sequence-gap"))
                return records, stats
            if sink is not None:
                sink(rec)
            else:
                records.append(rec)
            stats.records += 1
            stats.stopped_at_seq = rec.seq
            expect = rec.seq + 1
    return records, stats


class RequestJournal:
    """An append-only, checksummed, segment-rotated request journal.

    Opening a journal directory replays whatever is already there (see
    :attr:`recovered` / :attr:`replay_stats`), continues the sequence
    numbering after the last valid record, and starts a *new* segment
    for this incarnation.  ``incarnation`` is ``"i<N>"`` where N counts
    journal opens — deterministic, so a re-run of the same crash
    schedule mints the same incarnation ids.
    """

    def __init__(self, path: str, *, segment_records: int = 100_000,
                 sync: str = "rotate",
                 log: Callable[..., None] | None = None,
                 record_sink: Callable[[JournalRecord], None] | None = None):
        if segment_records < 1:
            raise ValueError(
                f"segment_records must be >= 1, got {segment_records}")
        if sync not in ("rotate", "always"):
            raise ValueError(f"sync must be 'rotate' or 'always', "
                             f"got {sync!r}")
        self.path = path
        self.segment_records = segment_records
        self.sync = sync
        self._warn = log if log is not None else get_logger("journal").warning
        # With a ``record_sink`` replay streams each validated record to
        # the callback and retains nothing (O(1) journal memory at any
        # history length — the 10^6-request harness recovers this way);
        # without one, validated records collect in ``recovered``.
        self._sink = record_sink
        os.makedirs(path, exist_ok=True)
        self.recovered: list[JournalRecord] = []
        self.replay_stats = ReplayStats()
        self._opens = 0
        self._replay()
        self._next_seq = self.replay_stats.stopped_at_seq + 1
        self.incarnation = f"i{self._opens + 1}"
        self._segment_index = self._last_segment_index() + 1
        self._records_in_segment = 0
        self._file = None
        self._open_segment()
        self.append(OPEN, {"incarnation": self.incarnation})

    # ------------------------------------------------------------------ #
    # segments on disk
    # ------------------------------------------------------------------ #

    def _segment_files(self) -> list[str]:
        names = [n for n in os.listdir(self.path) if _SEGMENT_RE.match(n)]
        return sorted(names)

    def _last_segment_index(self) -> int:
        names = self._segment_files()
        if not names:
            return -1
        return int(_SEGMENT_RE.match(names[-1]).group(1))

    def _segment_path(self, index: int) -> str:
        return os.path.join(self.path, f"seg-{index:06d}.jsonl")

    def _open_segment(self) -> None:
        # Line-buffered: every record reaches the kernel as soon as it is
        # written, so a *process* crash (kill -9) loses nothing buffered
        # in userspace — fsync (rotate/flush/close) is what protects
        # against *machine* crashes.
        self._file = open(self._segment_path(self._segment_index), "a",
                          encoding="utf-8", buffering=1)
        self._records_in_segment = 0

    def _close_segment(self, *, fsync: bool = True) -> None:
        if self._file is None:
            return
        self._file.flush()
        if fsync:
            os.fsync(self._file.fileno())
        self._file.close()
        self._file = None

    def rotate(self) -> None:
        """Atomically seal the active segment and open the next one.

        The outgoing segment is flushed and fsync'd *before* the new one
        opens — after rotate() returns, every record written so far is
        durable regardless of what happens to the new segment.
        """
        self._close_segment(fsync=True)
        self._segment_index += 1
        self._open_segment()

    # ------------------------------------------------------------------ #
    # append path
    # ------------------------------------------------------------------ #

    def append(self, rtype: str, data: dict) -> int:
        """Append one record; returns its sequence number."""
        if rtype not in _TYPES:
            raise ValueError(f"unknown record type {rtype!r}; have {_TYPES}")
        if self._file is None:
            raise ValueError("journal is closed")
        if self._records_in_segment >= self.segment_records:
            self.rotate()
        rec = JournalRecord(seq=self._next_seq, type=rtype, data=data)
        self._file.write(rec.line() + "\n")
        if self.sync == "always":
            self._file.flush()
            os.fsync(self._file.fileno())
        self._next_seq += 1
        self._records_in_segment += 1
        return rec.seq

    def flush(self) -> None:
        """Flush + fsync the active segment (durability barrier)."""
        if self._file is not None:
            self._file.flush()
            os.fsync(self._file.fileno())

    def close(self) -> None:
        self._close_segment(fsync=True)

    def crash(self) -> None:
        """Simulate the owning process dying (chaos-harness hook).

        The active segment is abandoned WITHOUT a durability barrier —
        no fsync, no rotation seal — exactly the on-disk state a
        ``kill -9`` leaves behind with line-buffered writes.  The
        journal object is unusable afterwards; recovery happens by
        opening the directory again.
        """
        if self._file is not None:
            self._file.close()
            self._file = None

    @property
    def next_seq(self) -> int:
        return self._next_seq

    # ------------------------------------------------------------------ #
    # replay
    # ------------------------------------------------------------------ #

    def _replay(self) -> None:
        """Validate every on-disk record, stopping at the first bad one.

        Seq continuity is part of validity: a record whose seq is not
        exactly (last seq + 1) means an earlier record went missing (a
        truncated segment, an out-of-order copy) and everything from the
        gap on is untrusted.

        Repair: the torn segment is truncated at the last valid record
        and any LATER segments are quarantined (renamed out of the
        replay set) — without this, the next incarnation would append
        perfectly good records *behind* the torn tail and every future
        replay would stop at the same bad byte, never reaching them.
        The bad bytes are never silently resurrected; truncation +
        quarantine is logged.
        """
        stats = self.replay_stats
        expect = 0
        names = self._segment_files()
        for idx, name in enumerate(names):
            seg = os.path.join(self.path, name)
            stats.segments += 1
            for line, offset in read_segment_records(seg):
                rec = _parse_record(line)
                if rec is None or rec.seq != expect:
                    stats.invalid += 1
                    stats.torn_segment = name
                    self._warn(
                        "journal-torn-record",
                        segment=name, expected_seq=expect,
                        valid_records=stats.records,
                        reason=("checksum-or-framing" if rec is None
                                else "sequence-gap"))
                    self._repair(name, offset, names[idx + 1:])
                    return
                if rec.type == OPEN:
                    self._opens += 1
                if self._sink is not None:
                    self._sink(rec)
                else:
                    self.recovered.append(rec)
                stats.records += 1
                stats.stopped_at_seq = rec.seq
                expect = rec.seq + 1
            if stats.invalid:
                return

    def _repair(self, torn: str, offset: int, later: list[str]) -> None:
        """Truncate the torn segment; quarantine everything after it."""
        seg = os.path.join(self.path, torn)
        with open(seg, "r+b") as f:
            f.truncate(offset)
            f.flush()
            os.fsync(f.fileno())
        self._warn("journal-truncated", segment=torn, at_byte=offset)
        for name in later:
            src = os.path.join(self.path, name)
            os.replace(src, src + ".quarantine")
            self._warn("journal-segment-quarantined", segment=name)

    # ------------------------------------------------------------------ #
    # snapshots
    # ------------------------------------------------------------------ #

    def write_snapshot(self, state: dict) -> str:
        """Atomically persist a JSON state snapshot covering seqs < now.

        The journal is fsync'd first (a snapshot must never be *ahead* of
        the durable log it summarises), then the snapshot is written to a
        temp file, fsync'd and renamed into place — a crash at any point
        leaves either the old snapshot set or the complete new one.
        """
        self.flush()
        seq = self._next_seq
        body = {"seq": seq, "incarnation": self.incarnation, "state": state}
        payload = _canonical(body)
        doc = _canonical({
            "body": body,
            "c": hashlib.blake2b(payload.encode(),
                                 digest_size=_DIGEST_SIZE).hexdigest()})
        final = os.path.join(self.path, f"snap-{seq}.json")
        tmp = final + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(doc)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        return final

    def load_snapshot(self) -> dict | None:
        """The newest checksum-valid snapshot body, or None.

        Returns ``{"seq": ..., "incarnation": ..., "state": {...}}``.
        Corrupt snapshot files are skipped with a warning — the journal
        alone is always sufficient to recover, a snapshot only shortcuts
        state reconstruction.
        """
        names = [(int(_SNAPSHOT_RE.match(n).group(1)), n)
                 for n in os.listdir(self.path) if _SNAPSHOT_RE.match(n)]
        for _, name in sorted(names, reverse=True):
            try:
                with open(os.path.join(self.path, name),
                          encoding="utf-8") as f:
                    doc = json.loads(f.read())
                body = doc["body"]
                want = doc["c"]
            except (ValueError, TypeError, KeyError, OSError):
                self._warn("journal-snapshot-corrupt", snapshot=name)
                continue
            got = hashlib.blake2b(_canonical(body).encode(),
                                  digest_size=_DIGEST_SIZE).hexdigest()
            if got != want:
                self._warn("journal-snapshot-corrupt", snapshot=name)
                continue
            return body
        return None

    # ------------------------------------------------------------------ #
    # context manager sugar
    # ------------------------------------------------------------------ #

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
