from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import (FaultTolerantDriver, SimulatedFailure,
                                 StragglerMonitor)
from repro.runtime.faults import (FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD,
                                  KILL_DEVICE, STALL_WORKER, CircuitBreaker,
                                  ClockLockError, DeviceLostError,
                                  DrainDeadlineError, FaultError, FaultEvent,
                                  FaultPlan, PlanBuildError, RetryPolicy,
                                  WorkerStalledError)
from repro.runtime.elastic import elastic_remesh_plan
from repro.runtime.workqueue import WorkStealingQueue

__all__ = ["CheckpointManager", "CircuitBreaker", "ClockLockError",
           "DeviceLostError", "DrainDeadlineError", "FAIL_CLOCK_LOCK",
           "FAIL_PLAN_BUILD", "FaultError", "FaultEvent", "FaultPlan",
           "FaultTolerantDriver", "KILL_DEVICE", "PlanBuildError",
           "RetryPolicy", "STALL_WORKER", "SimulatedFailure",
           "StragglerMonitor", "WorkerStalledError", "elastic_remesh_plan",
           "WorkStealingQueue"]
