from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import (FaultTolerantDriver, SimulatedFailure,
                                 StragglerMonitor)
from repro.runtime.faults import (CRASH_PROCESS, FAIL_CLOCK_LOCK,
                                  FAIL_PLAN_BUILD, KILL_DEVICE, KILL_HOST,
                                  STALL_WORKER, CircuitBreaker,
                                  ClockLockError, DeviceLostError,
                                  DrainDeadlineError, FaultError, FaultEvent,
                                  FaultPlan, HostLostError, HostTopology,
                                  PlanBuildError, ProcessCrashError,
                                  RetryPolicy, WorkerStalledError)
from repro.runtime.elastic import elastic_remesh_plan
from repro.runtime.journal import (JournalRecord, ReplayStats,
                                   RequestJournal, process_incarnation,
                                   read_journal)
from repro.runtime.workqueue import WorkStealingQueue

__all__ = ["CheckpointManager", "CircuitBreaker", "ClockLockError",
           "CRASH_PROCESS", "DeviceLostError", "DrainDeadlineError",
           "FAIL_CLOCK_LOCK", "FAIL_PLAN_BUILD", "FaultError", "FaultEvent",
           "FaultPlan", "FaultTolerantDriver", "HostLostError",
           "HostTopology", "JournalRecord", "KILL_DEVICE", "KILL_HOST",
           "PlanBuildError", "ProcessCrashError", "ReplayStats",
           "RequestJournal", "RetryPolicy", "STALL_WORKER",
           "SimulatedFailure", "StragglerMonitor", "WorkerStalledError",
           "elastic_remesh_plan", "process_incarnation", "read_journal",
           "WorkStealingQueue"]
