from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import FaultTolerantDriver, SimulatedFailure
from repro.runtime.elastic import elastic_remesh_plan
from repro.runtime.workqueue import WorkStealingQueue

__all__ = ["CheckpointManager", "FaultTolerantDriver", "SimulatedFailure",
           "elastic_remesh_plan", "WorkStealingQueue"]
