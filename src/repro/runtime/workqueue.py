"""Work-stealing queue for multi-device dispatch.

The serving layer places coalesced FFT batches on per-device queues; an
idle device steals from the back of the longest queue (classic
Cilk/Blumofe-Leiserson discipline: owners pop FIFO from the front, thieves
take LIFO from the back, so stolen work is the freshest — and on this
workload the largest remaining — item).

The queue is cooperative and deterministic: the serving drain loop drives
workers round-robin on one host, matching how this repository simulates
multi-device behaviour elsewhere (see repro.runtime.fault's deterministic
shard reassignment).  The same interface maps onto one consumer thread per
accelerator in a threaded deployment.
"""
from __future__ import annotations

import collections
from typing import Any


class WorkStealingQueue:
    """Per-worker deques with steal-from-longest balancing."""

    def __init__(self, n_workers: int):
        if n_workers < 1:
            raise ValueError("need at least one worker")
        self._queues: list[collections.deque] = [
            collections.deque() for _ in range(n_workers)
        ]
        self.steals = 0
        self.pushes = 0

    @property
    def n_workers(self) -> int:
        return len(self._queues)

    def push(self, worker: int, item: Any) -> None:
        """Enqueue ``item`` on ``worker``'s own queue (back)."""
        self._queues[worker].append(item)
        self.pushes += 1

    def push_least_loaded(self, item: Any,
                          allowed: list[int] | None = None) -> int:
        """Enqueue on the currently shortest queue; returns the worker.

        ``allowed`` restricts the candidate workers — how the serving
        layer redistributes work away from quarantined or stalled
        devices.  An empty/None ``allowed`` considers every worker.
        """
        candidates = list(allowed) if allowed else range(self.n_workers)
        worker = min(candidates, key=lambda w: len(self._queues[w]))
        self.push(worker, item)
        return worker

    def pop(self, worker: int) -> Any | None:
        """Owner pop: FIFO from own queue, else steal from the longest.

        Returns None when no work is available anywhere.
        """
        own = self._queues[worker]
        if own:
            return own.popleft()
        victim = max(range(self.n_workers), key=lambda w: len(self._queues[w]))
        if self._queues[victim]:
            self.steals += 1
            return self._queues[victim].pop()      # thief takes the back
        return None

    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    def items(self) -> list[Any]:
        """Every queued item (in worker order), without removing them."""
        return [item for q in self._queues for item in q]

    def clear(self) -> list[Any]:
        """Remove and return every queued item (in worker order)."""
        items: list[Any] = []
        for q in self._queues:
            items.extend(q)
            q.clear()
        return items

    def lengths(self) -> list[int]:
        return [len(q) for q in self._queues]
