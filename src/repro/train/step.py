"""The jitted training step: loss, grads, AdamW, with microbatching.

DVFS integration (the paper's technique as a first-class feature): the
launcher wraps this step with a clock plan from
``repro.core.scheduler.DVFSScheduler`` — the step's roofline profile
(from the dry-run artifact) decides the energy-optimal clock, and the
runtime locks/unlocks around dispatch exactly like the paper's Sec. 5.3
NVML calls around the cuFFT invocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.common import chunked_cross_entropy
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_specs(model: Model):
    from jax.sharding import PartitionSpec as P
    from repro.optim.adamw import optimizer_specs
    ps = model.param_specs()
    return TrainState(params=ps, opt=optimizer_specs(ps), step=P())


def make_train_step(model: Model, *, microbatches: int = 1,
                    aux_weight: float = 0.01,
                    peak_lr: float = 3e-4) -> Callable:
    """Build the jittable train_step(state, inputs, labels) -> (state, metrics).

    ``microbatches`` > 1 accumulates gradients over sequential microbatches
    (lax.scan) — activation memory drops by the factor, HBM traffic for
    weights repeats per microbatch: the classic trade the §Perf iterations
    measure.
    """
    cfg = model.cfg

    def loss_fn(params, inp, labels):
        hidden, aux = model.forward_hidden(params, inp)
        ce = chunked_cross_entropy(
            lambda h: model.unembed(params, h), hidden, labels)
        return ce + aux_weight * aux

    def train_step(state: TrainState, inp, labels):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, inp,
                                                      labels)
        else:
            mb_inp = inp.reshape(microbatches, inp.shape[0] // microbatches,
                                 *inp.shape[1:])
            mb_lab = labels.reshape(microbatches,
                                    labels.shape[0] // microbatches,
                                    *labels.shape[1:])

            def mb_body(acc, mb):
                i, l = mb
                loss, grads = jax.value_and_grad(loss_fn)(state.params, i, l)
                return (acc[0] + loss,
                        jax.tree.map(jnp.add, acc[1], grads)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (loss, grads), _ = jax.lax.scan(mb_body, (0.0, zero),
                                            (mb_inp, mb_lab))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        lr = cosine_schedule(state.opt.step, peak_lr=peak_lr)
        new_params, new_opt, gnorm = adamw_update(state.params, grads,
                                                  state.opt, lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return train_step
