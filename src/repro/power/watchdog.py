"""Telemetry watchdog: reading classification + per-device health states.

Barbosa et al. (2016) frame SKA power management as a *monitored,
failure-aware* control problem: a feedback loop that trusts a lying
sensor is worse than no loop at all.  The watchdog sits between the
sampler and the governor and answers two questions per reading:

  classification    what is THIS reading?
      fresh      a numeric value, recent timestamp, inside the TDP
                 envelope, no impossible jump from the last credible one
      stale      the timestamp is older than ``stale_timeout_s`` (the
                 sensor stopped producing; age == timeout is still fresh
                 — the boundary is exclusive)
      dropout    the value is NaN (the sampling call failed)
      spike      the value is outside the plausible envelope
                 (negative, or above ``envelope_frac * TDP``) or jumps
                 more than ``step_w`` from the last credible reading

  health            can the GOVERNOR act on this device's telemetry?
      healthy    feedback allowed
      suspect    >= 1 consecutive non-fresh reading; feedback holds its
                 last output but takes no new moves
      unhealthy  ``unhealthy_after`` consecutive non-fresh readings; the
                 governor MUST fall back to the static sweep optimum
                 (repro.power.governor's hard rule)

  healthy --bad--> suspect --bad x N--> unhealthy
     ^                |                    |
     +--- fresh x M --+<------ fresh ------+        (re-arm)

  (the same shape as the serving circuit breaker's
  closed -> open -> half-open -> closed loop, with M = ``rearm_after``
  consecutive fresh readings playing the successful-probe role)

Baseline rule for step detection: envelope violations and dropouts never
become the comparison baseline (they are garbage, not a new level); a
*step* reading does — a genuine load shift is flagged exactly once and
the new level is then accepted, while a one-sample glitch is flagged on
the way up AND on the way back down.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hardware import DeviceSpec
from repro.power.sampler import PowerReading

# Reading classifications.
FRESH = "fresh"
STALE = "stale"
DROPOUT = "dropout"
SPIKE = "spike"

LABELS = (FRESH, STALE, DROPOUT, SPIKE)

# Device health states.
HEALTHY = "healthy"
SUSPECT = "suspect"
UNHEALTHY = "unhealthy"


@dataclasses.dataclass
class TelemetryWatchdog:
    """Classifies one device's readings and tracks its telemetry health."""

    device: DeviceSpec
    stale_timeout_s: float = 0.05     # max credible reading age
    envelope_frac: float = 1.25       # plausible ceiling: frac * TDP
    step_w: float | None = None       # max credible jump; None: TDP / 2
    unhealthy_after: int = 3          # consecutive bad -> unhealthy
    rearm_after: int = 2              # consecutive fresh -> healthy again

    def __post_init__(self):
        if self.step_w is None:
            self.step_w = 0.5 * self.device.tdp
        if self.unhealthy_after < 1 or self.rearm_after < 1:
            raise ValueError(
                "unhealthy_after and rearm_after must be >= 1, got "
                f"{self.unhealthy_after}/{self.rearm_after}")
        self.health = HEALTHY
        self.baseline: PowerReading | None = None   # last credible reading
        self._bad = 0                 # consecutive non-fresh
        self._good = 0                # consecutive fresh since last bad
        self.counts = {label: 0 for label in LABELS}
        self.unhealthy_entries = 0    # times health fell to unhealthy

    # ------------------------------------------------------------------ #
    # classification (pure: no state change)
    # ------------------------------------------------------------------ #

    def classify(self, reading: PowerReading, now: float) -> str:
        """Label ``reading`` as seen at time ``now`` — no state change."""
        if math.isnan(reading.power_w):
            return DROPOUT
        if now - reading.t > self.stale_timeout_s:
            return STALE
        p = reading.power_w
        if p < 0.0 or p > self.envelope_frac * self.device.tdp:
            return SPIKE
        if (self.baseline is not None
                and abs(p - self.baseline.power_w) > self.step_w):
            return SPIKE
        return FRESH

    # ------------------------------------------------------------------ #
    # health state machine
    # ------------------------------------------------------------------ #

    def observe(self, reading: PowerReading, now: float) -> tuple[str, str]:
        """Classify ``reading``, update health; returns (label, health)."""
        label = self.classify(reading, now)
        self.counts[label] += 1
        if label == FRESH:
            self.baseline = reading
            self._good += 1
            self._bad = 0
            if self.health != HEALTHY and self._good >= self.rearm_after:
                self.health = HEALTHY
        else:
            if label == SPIKE and reading.ok and \
                    0.0 <= reading.power_w <= self.envelope_frac * \
                    self.device.tdp:
                # A step discontinuity (not an envelope violation): accept
                # the new level as baseline after flagging the jump once.
                self.baseline = reading
            self._good = 0
            self._bad += 1
            if self._bad >= self.unhealthy_after:
                if self.health != UNHEALTHY:
                    self.unhealthy_entries += 1
                self.health = UNHEALTHY
            elif self.health == HEALTHY:
                self.health = SUSPECT
        return label, self.health

    @property
    def healthy(self) -> bool:
        """May the governor run feedback on this device's telemetry?

        Suspect telemetry still counts as usable (the governor holds
        rather than moves); only UNHEALTHY forces the static fallback.
        """
        return self.health != UNHEALTHY
