"""Power telemetry sampling: the NVML-style contract and the CI backend.

On real hardware a :class:`PowerSampler` wraps one call per device —
``nvmlDeviceGetPowerUsage`` (board power, mW) on NVIDIA parts, the
platform power API on TPUs.  The contract is deliberately minimal:

  * ``sample(device_index, now)`` returns ONE timestamped board-power
    reading for ONE device;
  * the sampler never raises for a sick sensor — it *reports* the
    sickness (NaN power, a frozen timestamp, an impossible value) and
    the :class:`repro.power.watchdog.TelemetryWatchdog` classifies it;
  * readings are cheap; callers poll at control-tick rate (the paper's
    Fig. 19 view is 10 ms nvidia-smi sampling).

This container has no power sensor, so CI runs
:class:`SimulatedPowerSampler`: the repository's analytic
:class:`repro.core.power_model.PowerModel` evaluated at each device's
*current* clock and utilisation, plus deterministic seeded measurement
noise and a bounded thermal-drift term.  Sensor faults (dropout / spike /
stale) are injected from the same deterministic
:class:`repro.runtime.faults.FaultPlan` machinery the chaos harness uses,
so a seeded run reproduces the exact same telemetry stream bit for bit.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import math
from typing import Callable, Iterator

from repro.core.hardware import DeviceSpec
from repro.core.power_model import PowerModel


@dataclasses.dataclass(frozen=True)
class PowerReading:
    """One timestamped board-power sample for one device.

    ``power_w`` is NaN for a sensor dropout (the NVML call failed or
    returned garbage); a *stale* sensor keeps returning an old reading,
    visible as a frozen ``t`` — classification is the watchdog's job,
    the reading just carries the evidence.
    """

    device_index: int
    t: float                    # sampler timestamp [s, caller's clock]
    power_w: float              # board power [W]; NaN = dropout

    @property
    def ok(self) -> bool:
        """Is the raw value at least a number?  (Not a health verdict.)"""
        return not math.isnan(self.power_w)


class PowerSampler:
    """Abstract NVML-style per-device power sampler."""

    def sample(self, device_index: int, now: float, *,
               token: int | None = None) -> PowerReading:
        """One board-power reading for ``device_index`` at time ``now``.

        ``token`` is an optional deterministic identifier of the sampling
        occasion (a batch id, a control-tick index) that fault-injection
        backends match scheduled sensor faults against; hardware backends
        ignore it.
        """
        raise NotImplementedError


class TelemetryRing:
    """Bounded ring buffer of :class:`PowerReading`.

    Long-running services poll forever; the ring keeps the most recent
    ``capacity`` readings and drops the oldest — the watchdog and the
    governor only ever need a short recent window.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: collections.deque[PowerReading] = collections.deque(
            maxlen=capacity)
        self.pushed = 0             # lifetime count (>= len = some dropped)

    def push(self, reading: PowerReading) -> None:
        self._buf.append(reading)
        self.pushed += 1

    def latest(self) -> PowerReading | None:
        return self._buf[-1] if self._buf else None

    def window(self, k: int) -> list[PowerReading]:
        """The most recent ``k`` readings, oldest first."""
        if k < 0:
            raise ValueError(f"window size must be >= 0, got {k}")
        return list(self._buf)[-k:] if k else []

    def clear(self) -> None:
        """Drop every buffered reading (the host behind the device died).

        ``pushed`` keeps counting lifetime samples, so ``dropped``
        reflects the wipe — a host-kill leaves forensic evidence in the
        counters even though the readings themselves are gone.
        """
        self._buf.clear()

    @property
    def dropped(self) -> int:
        return self.pushed - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[PowerReading]:
        return iter(self._buf)


def _hash_frac(seed: int, device_index: int, ordinal: int) -> float:
    """Deterministic uniform [0, 1) — a pure hash, not a shared RNG.

    Like :class:`repro.runtime.faults.RetryPolicy`, per-device noise is a
    function of (seed, device, sample ordinal) so interleaving samples
    across devices never perturbs any device's noise stream and a re-run
    reproduces every reading exactly.
    """
    h = hashlib.blake2b(f"{seed}:{device_index}:{ordinal}".encode(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "big") / 2.0 ** 64


class SimulatedPowerSampler(PowerSampler):
    """Deterministic simulated backend: model power + seeded noise/drift.

    ``clock_fn(device_index)`` supplies each device's current core clock
    [MHz] and ``utilisation_fn(device_index)`` its ``(u_core, u_mem)``
    pair; both can be overridden per call (the serving layer knows the
    locked clock of the batch it just ran).  Truth power comes from
    :class:`repro.core.power_model.PowerModel`; the measured value adds

      * multiplicative noise, uniform in ``+/- noise_frac`` (sensor LSB
        and sampling-window jitter), and
      * additive thermal drift ``drift_w * (1 - exp(-t / drift_tau_s))``
        (boards read hotter as they soak — the reason static operating
        points need a watchdog at all).

    ``fault_plan`` events of the SENSOR_* kinds (matched on
    ``batch_id=token`` / ``worker=device_index``) corrupt the reading:
    dropout -> NaN, spike -> an out-of-envelope value, stale -> the
    device's previous reading replayed verbatim (frozen timestamp).
    """

    #: Spike magnitude as a multiple of TDP — far outside any credible
    #: envelope, the way a wedged I2C transaction reads.
    SPIKE_FACTOR = 2.0

    def __init__(
        self,
        device: DeviceSpec,
        *,
        clock_fn: Callable[[int], float] | None = None,
        utilisation_fn: Callable[[int], tuple[float, float]] | None = None,
        power_model: PowerModel | None = None,
        seed: int = 0,
        noise_frac: float = 0.01,
        drift_w: float = 0.0,
        drift_tau_s: float = 30.0,
        fault_plan=None,
    ):
        self.device = device
        self.power_model = power_model or PowerModel(device)
        self._clock_fn = clock_fn or (lambda i: device.f_max)
        self._util_fn = utilisation_fn or (lambda i: (1.0, 1.0))
        self.seed = seed
        self.noise_frac = noise_frac
        self.drift_w = drift_w
        self.drift_tau_s = drift_tau_s
        self.faults = fault_plan
        self._ordinal: dict[int, int] = {}
        self._last: dict[int, PowerReading] = {}

    def truth_w(self, device_index: int, *, f_mhz: float | None = None,
                u_core: float | None = None,
                u_mem: float | None = None) -> float:
        """Noiseless model power at the device's current operating point."""
        f = self._clock_fn(device_index) if f_mhz is None else f_mhz
        uc, um = self._util_fn(device_index)
        if u_core is not None:
            uc = u_core
        if u_mem is not None:
            um = u_mem
        return float(self.power_model.power(f, u_core=uc, u_mem=um))

    def sample(self, device_index: int, now: float, *,
               token: int | None = None, f_mhz: float | None = None,
               u_core: float | None = None,
               u_mem: float | None = None) -> PowerReading:
        ordinal = self._ordinal.get(device_index, 0)
        self._ordinal[device_index] = ordinal + 1
        if self.faults is not None:
            from repro.runtime.faults import (SENSOR_DROPOUT, SENSOR_SPIKE,
                                              SENSOR_STALE)
            if self.faults.take(SENSOR_DROPOUT, batch_id=token,
                                worker=device_index):
                reading = PowerReading(device_index, now, float("nan"))
                self._last[device_index] = reading
                return reading
            if self.faults.take(SENSOR_SPIKE, batch_id=token,
                                worker=device_index):
                reading = PowerReading(device_index, now,
                                       self.SPIKE_FACTOR * self.device.tdp)
                self._last[device_index] = reading
                return reading
            prev = self._last.get(device_index)
            if prev is not None and self.faults.take(
                    SENSOR_STALE, batch_id=token, worker=device_index):
                return prev             # frozen: old value, old timestamp
        truth = self.truth_w(device_index, f_mhz=f_mhz,
                             u_core=u_core, u_mem=u_mem)
        noise = (2.0 * _hash_frac(self.seed, device_index, ordinal) - 1.0
                 ) * self.noise_frac
        drift = self.drift_w * (1.0 - math.exp(-max(now, 0.0)
                                               / self.drift_tau_s))
        reading = PowerReading(device_index, now,
                               truth * (1.0 + noise) + drift)
        self._last[device_index] = reading
        return reading
