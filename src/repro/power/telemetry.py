"""Per-device telemetry bundles: sampler + ring buffer + watchdog.

:class:`FleetTelemetry` is the one object the governor and the serving
layer talk to.  Each ``read()`` takes one sample for one device, pushes
it into that device's bounded :class:`repro.power.sampler.TelemetryRing`,
runs it through that device's
:class:`repro.power.watchdog.TelemetryWatchdog`, and returns the
classified result — so every consumer sees the same health verdict for
the same reading.
"""
from __future__ import annotations

import dataclasses

from repro.core.hardware import DeviceSpec
from repro.power.sampler import (PowerReading, PowerSampler,
                                 SimulatedPowerSampler, TelemetryRing)
from repro.power.watchdog import FRESH, TelemetryWatchdog


@dataclasses.dataclass(frozen=True)
class TelemetryRead:
    """One classified telemetry read: the evidence plus the verdict.

    ``measured_w`` is the power value a consumer may *act* on: the raw
    reading when the watchdog labelled it fresh, else ``None`` — the
    never-freewheel contract starts here, by refusing to hand suspect
    numbers downstream.
    """

    reading: PowerReading
    label: str                  # watchdog classification of THIS reading
    health: str                 # device health AFTER observing it
    measured_w: float | None    # actionable power [W]; None unless fresh

    @property
    def fresh(self) -> bool:
        return self.label == FRESH


class FleetTelemetry:
    """Sampler + per-device ring + per-device watchdog for a fleet."""

    def __init__(
        self,
        device: DeviceSpec,
        sampler: PowerSampler,
        *,
        ring_capacity: int = 256,
        stale_timeout_s: float = 0.05,
        envelope_frac: float = 1.25,
        step_w: float | None = None,
        unhealthy_after: int = 3,
        rearm_after: int = 2,
    ):
        self.device = device
        self.sampler = sampler
        self.ring_capacity = ring_capacity
        self._watchdog_kw = dict(
            stale_timeout_s=stale_timeout_s, envelope_frac=envelope_frac,
            step_w=step_w, unhealthy_after=unhealthy_after,
            rearm_after=rearm_after)
        self.rings: dict[int, TelemetryRing] = {}
        self.watchdogs: dict[int, TelemetryWatchdog] = {}
        self.reads = 0

    @classmethod
    def for_serving(cls, device: DeviceSpec, *, seed: int = 0,
                    fault_plan=None, noise_frac: float = 0.01,
                    drift_w: float = 0.0,
                    stale_timeout_s: float = 1e-6,
                    power_model=None) -> "FleetTelemetry":
        """A simulated-backend fleet bundle for the serving layer.

        Serving samples at batch-completion times on the simulated clock,
        where successive samples are microseconds apart — the default
        50 ms stale timeout would never classify a replayed reading as
        stale, so the serving preset tightens it to 1 us.

        ``power_model`` overrides the sampler's truth model — pass a
        deliberately miscalibrated one to exercise the serving drift
        detector (repro.obs.drift) against a sensor whose physics
        disagree with the accounting model.
        """
        sampler = SimulatedPowerSampler(device, seed=seed,
                                        noise_frac=noise_frac,
                                        drift_w=drift_w,
                                        power_model=power_model,
                                        fault_plan=fault_plan)
        return cls(device, sampler, stale_timeout_s=stale_timeout_s)

    def _ring(self, device_index: int) -> TelemetryRing:
        if device_index not in self.rings:
            self.rings[device_index] = TelemetryRing(self.ring_capacity)
        return self.rings[device_index]

    def watchdog(self, device_index: int) -> TelemetryWatchdog:
        if device_index not in self.watchdogs:
            self.watchdogs[device_index] = TelemetryWatchdog(
                self.device, **self._watchdog_kw)
        return self.watchdogs[device_index]

    def read(self, device_index: int, now: float, *,
             token: int | None = None, f_mhz: float | None = None,
             u_core: float | None = None,
             u_mem: float | None = None) -> TelemetryRead:
        """Sample, record, classify — one telemetry read for one device.

        The operating-point overrides (``f_mhz``/``u_core``/``u_mem``)
        are forwarded to simulated backends, which have no hardware to
        inspect; hardware-style samplers measure reality and ignore them.
        """
        if isinstance(self.sampler, SimulatedPowerSampler):
            reading = self.sampler.sample(device_index, now, token=token,
                                          f_mhz=f_mhz, u_core=u_core,
                                          u_mem=u_mem)
        else:
            reading = self.sampler.sample(device_index, now, token=token)
        self.reads += 1
        self._ring(device_index).push(reading)
        label, health = self.watchdog(device_index).observe(reading, now)
        measured = reading.power_w if label == FRESH else None
        return TelemetryRead(reading=reading, label=label, health=health,
                             measured_w=measured)

    def healthy(self, device_index: int) -> bool:
        """Governor-may-feedback verdict (devices never read are healthy)."""
        dog = self.watchdogs.get(device_index)
        return True if dog is None else dog.healthy

    def fill_metrics(self, registry) -> None:
        """Publish fleet telemetry counters into a MetricsRegistry."""
        s = self.summary()
        registry.gauge("repro_telemetry_reads",
                       "power samples taken fleet-wide").set(s["reads"])
        registry.gauge("repro_telemetry_unhealthy_entries",
                       "device entries into the unhealthy state").set(
                           s["unhealthy_entries"])
        for label, n in sorted(s["labels"].items()):
            registry.gauge(
                f"repro_telemetry_label_{label.replace('-', '_')}",
                f"samples the watchdog classified {label}").set(n)

    def summary(self) -> dict:
        """Aggregate label counts and health states across the fleet."""
        counts: dict[str, int] = {}
        health = {}
        unhealthy_entries = 0
        for idx, dog in sorted(self.watchdogs.items()):
            for label, n in dog.counts.items():
                counts[label] = counts.get(label, 0) + n
            health[idx] = dog.health
            unhealthy_entries += dog.unhealthy_entries
        return {
            "reads": self.reads,
            "labels": counts,
            "health": health,
            "unhealthy_entries": unhealthy_entries,
        }
