"""Guarded PI feedback from measured power to a core-clock setpoint.

The paper's Sec. 5.3 pipeline *brackets* each stage with a static NVML
clock lock chosen by an offline sweep; this module closes the loop the
way Barbosa et al.'s operations model asks for — steer the clock so
*measured* board power tracks a target — while keeping every guard that
makes feedback safe on flaky telemetry:

  hysteresis     errors inside a dead band take no action (no limit
                 cycling on sensor noise)
  anti-windup    the integral term is clamped, and does not accumulate
                 while the loop holds (dead band, missing sample)
  slew limit     one control tick moves the clock at most
                 ``slew_mhz_per_tick`` (real drivers reprogram PLLs; big
                 jumps glitch the part and the power estimate)
  clamping       the output is always inside ``[f_min, f_max]``

and one hard rule, the **fallback contract**: when the watchdog says the
device's telemetry is unhealthy, the governor pins the clock to the
cached static sweep optimum (``fallback_mhz``, the PR 5
``dvfs.sweep`` result) and zeroes its integral state.  Same inputs, same
bits: the fallback clock is a stored grid value, not a computed one, so
a faulted run is exactly as reproducible as a healthy one.  The loop
*never freewheels* on telemetry it cannot trust.

The setpoint is continuous (not snapped to the device's ``f_step`` grid):
snapping a slew-limited loop to a coarse grid makes it limit-cycle
between adjacent grid points around the target.  Real drivers snap at
the PLL; the simulated plant accepts any clock in range.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hardware import DeviceSpec

# Controller modes, recorded per tick.
MODE_FEEDBACK = "feedback"      # took (or was free to take) a PI move
MODE_HOLD = "hold"              # dead band / missing sample: no move
MODE_FALLBACK = "fallback"      # unhealthy telemetry: pinned to static


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """PI gains and guard parameters (defaults sized for ~200 W parts)."""

    kp_mhz_per_w: float = 4.0       # proportional gain
    ki_mhz_per_w: float = 1.0       # integral gain (per tick)
    hysteresis_w: float = 1.5       # dead band on |power error|
    slew_mhz_per_tick: float = 65.0  # max clock move per control tick
    integral_clamp_w: float = 50.0  # anti-windup bound on the integral

    def __post_init__(self):
        if self.hysteresis_w < 0 or self.slew_mhz_per_tick <= 0:
            raise ValueError(
                "hysteresis_w must be >= 0 and slew_mhz_per_tick > 0, got "
                f"{self.hysteresis_w}/{self.slew_mhz_per_tick}")


class PowerGovernor:
    """One device's guarded feedback loop: measured power -> clock."""

    def __init__(self, device: DeviceSpec, *, target_w: float,
                 fallback_mhz: float, config: GovernorConfig | None = None,
                 f0_mhz: float | None = None):
        if not (device.f_min <= fallback_mhz <= device.f_max):
            raise ValueError(
                f"fallback_mhz {fallback_mhz} outside "
                f"[{device.f_min}, {device.f_max}]")
        self.device = device
        self.target_w = float(target_w)
        self.fallback_mhz = float(fallback_mhz)
        self.config = config or GovernorConfig()
        self.f_mhz = float(f0_mhz if f0_mhz is not None else fallback_mhz)
        self.f_mhz = min(max(self.f_mhz, device.f_min), device.f_max)
        self.integral_w = 0.0
        self.mode = MODE_HOLD
        self.ticks = 0
        self.moves = 0
        self.fallback_engagements = 0   # transitions INTO fallback

    def set_target(self, target_w: float) -> None:
        """Retarget (site reallocation); feedback state carries over."""
        self.target_w = float(target_w)

    def step(self, measured_w: float | None, *,
             healthy: bool = True) -> float:
        """One control tick; returns the new clock setpoint [MHz]."""
        self.ticks += 1
        cfg = self.config
        if not healthy:
            if self.mode != MODE_FALLBACK:
                self.fallback_engagements += 1
            self.mode = MODE_FALLBACK
            self.f_mhz = self.fallback_mhz
            self.integral_w = 0.0
            return self.f_mhz
        if measured_w is None or math.isnan(measured_w):
            # Healthy device, missing sample (e.g. a lone suspect read):
            # hold the last setpoint, accumulate nothing.
            self.mode = MODE_HOLD
            return self.f_mhz
        error = self.target_w - measured_w      # +ve: room to speed up
        if abs(error) <= cfg.hysteresis_w:
            self.mode = MODE_HOLD
            return self.f_mhz
        self.mode = MODE_FEEDBACK
        self.integral_w = min(max(self.integral_w + error,
                                  -cfg.integral_clamp_w),
                              cfg.integral_clamp_w)
        delta = cfg.kp_mhz_per_w * error + cfg.ki_mhz_per_w * self.integral_w
        delta = min(max(delta, -cfg.slew_mhz_per_tick),
                    cfg.slew_mhz_per_tick)
        f = min(max(self.f_mhz + delta, self.device.f_min),
                self.device.f_max)
        if f != self.f_mhz:
            self.moves += 1
        self.f_mhz = f
        return self.f_mhz

    @property
    def in_fallback(self) -> bool:
        return self.mode == MODE_FALLBACK
