"""Closed-loop power governance (live telemetry, not offline sweeps).

The paper's DVFS savings (Secs. 4-5) come from *offline* frequency sweeps
locked in at dispatch time; Barbosa et al. (2016) and astroCAMP argue
SKA-scale operation needs *live* power monitoring with co-designed budget
enforcement, because static operating points drift with temperature,
contention and sensor failure.  This package closes the loop — and keeps
it safe when its own sensors lie, stall or disappear:

  sampler    PowerSampler NVML-style contract + a deterministic simulated
             backend for CI (core.power_model + clock state + seeded
             noise/drift), feeding bounded per-device telemetry rings
  watchdog   TelemetryWatchdog: fresh/stale/dropout/spike classification
             with a healthy/suspect/unhealthy per-device state machine
  telemetry  FleetTelemetry: per-device sampler + ring + watchdog bundle
  governor   PowerGovernor: guarded PI feedback over measured power with
             hysteresis, anti-windup and slew-rate-limited clock moves;
             on watchdog-unhealthy telemetry it falls back
             bit-reproducibly to the cached static sweep optimum
  site       SiteBudgetScheduler: fleet-level site power-cap enforcement
             (priority-weighted budget allocation, clock trading,
             lowest-priority-first shedding, an emergency clock-floor
             rung on hard-cap breach)

See docs/power.md for the control-loop diagram and the fallback contract.
"""
from repro.power.governor import GovernorConfig, PowerGovernor
from repro.power.sampler import (PowerReading, PowerSampler,
                                 SimulatedPowerSampler, TelemetryRing)
from repro.power.site import SiteBudgetScheduler, SitePipeline, SiteTick
from repro.power.telemetry import FleetTelemetry, TelemetryRead
from repro.power.watchdog import (DROPOUT, FRESH, HEALTHY, SPIKE, STALE,
                                  SUSPECT, UNHEALTHY, TelemetryWatchdog)

__all__ = [
    "DROPOUT", "FRESH", "FleetTelemetry", "GovernorConfig", "HEALTHY",
    "PowerGovernor", "PowerReading", "PowerSampler", "SPIKE", "STALE",
    "SUSPECT", "SimulatedPowerSampler", "SiteBudgetScheduler",
    "SitePipeline", "SiteTick", "TelemetryRead", "TelemetryRing",
    "TelemetryWatchdog", "UNHEALTHY",
]
