"""Fleet-level site power-cap enforcement over governed pipelines.

Barbosa et al. (2016): an SKA site has a *contracted* power envelope;
the computing must fit inside whatever the dishes and cryostats leave.
:class:`SiteBudgetScheduler` enforces a total site cap across a
simulated fleet by trading clock headroom between co-scheduled
pipelines:

  allocation   each active pipeline gets a power target
               ``floor + share`` where ``floor`` is its power at
               ``f_min`` and the remaining budget (after a safety
               ``headroom`` factor) is split in proportion to SLO
               priority, capped at the pipeline's full-boost draw;
  shedding     if even the floors don't fit, pipelines are shed
               lowest-priority-first until they do (a shed pipeline's
               device sits at ``f_min`` drawing idle power);
  feedback     each active device runs its own guarded
               :class:`repro.power.governor.PowerGovernor` against its
               target, fed by watchdog-classified telemetry — a device
               with unhealthy sensors pins to its static sweep optimum
               (the fallback contract), it is not exempt from the cap;
  emergency    if estimated site power still breaches ``hard_cap_w``,
               the emergency rung fires: every active clock floors to
               ``f_min``, the lowest-priority pipeline is shed, and the
               budget is reallocated over the survivors.

Everything is deterministic for a given seed — the run digest hashes
per-tick clocks, health and membership, and must be identical across
fresh runs (a benchmark self-gate).
"""
from __future__ import annotations

import dataclasses
import hashlib

from repro.core.hardware import DeviceSpec
from repro.core.power_model import PowerModel
from repro.power.governor import GovernorConfig, PowerGovernor
from repro.power.sampler import SimulatedPowerSampler
from repro.power.telemetry import FleetTelemetry


@dataclasses.dataclass(frozen=True)
class SitePipeline:
    """One co-scheduled pipeline: a device, a priority, an operating mix.

    ``priority`` ranks SLO importance — HIGHER survives longer under
    budget pressure (shed order is lowest first).  ``fallback_mhz`` is
    the pipeline's cached static sweep optimum (the PR 5
    ``dvfs.sweep().optimal`` clock), the governor's never-freewheel
    target.
    """

    name: str
    device_index: int
    priority: int
    fallback_mhz: float
    u_core: float = 1.0
    u_mem: float = 1.0


@dataclasses.dataclass(frozen=True)
class SiteTick:
    """One control tick's outcome for the whole site."""

    t: float
    clocks_mhz: tuple[float, ...]       # per pipeline (input order)
    targets_w: tuple[float, ...]        # 0.0 for shed pipelines
    truth_w: float                      # noiseless model site power
    estimated_w: float                  # telemetry-side site estimate
    active: tuple[str, ...]             # pipeline names still scheduled
    health: tuple[str, ...]             # per pipeline watchdog health
    modes: tuple[str, ...]              # per pipeline governor mode
    converged: bool
    emergency: bool                     # emergency rung fired THIS tick


class SiteBudgetScheduler:
    """Enforce a total site power cap across governed pipelines."""

    def __init__(
        self,
        device: DeviceSpec,
        pipelines: list[SitePipeline],
        *,
        site_cap_w: float,
        hard_cap_w: float | None = None,
        headroom: float = 0.92,
        convergence_tol_w: float = 3.0,
        seed: int = 0,
        noise_frac: float = 0.01,
        drift_w: float = 0.0,
        fault_plan=None,
        telemetry: FleetTelemetry | None = None,
        governor_config: GovernorConfig | None = None,
    ):
        if not pipelines:
            raise ValueError("need at least one pipeline")
        if len({p.device_index for p in pipelines}) != len(pipelines):
            raise ValueError("pipelines must use distinct devices")
        if not 0.0 < headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], got {headroom}")
        self.device = device
        self.pipelines = list(pipelines)
        self.site_cap_w = float(site_cap_w)
        self.hard_cap_w = float(hard_cap_w) if hard_cap_w is not None \
            else float(site_cap_w)
        self.headroom = headroom
        self.convergence_tol_w = convergence_tol_w
        self.power_model = PowerModel(device)
        self.governors = {
            p.name: PowerGovernor(device, target_w=0.0,
                                  fallback_mhz=p.fallback_mhz,
                                  config=governor_config)
            for p in pipelines
        }
        if telemetry is None:
            sampler = SimulatedPowerSampler(
                device, clock_fn=self._clock_of,
                utilisation_fn=self._util_of, seed=seed,
                noise_frac=noise_frac, drift_w=drift_w,
                fault_plan=fault_plan)
            telemetry = FleetTelemetry(device, sampler)
        self.telemetry = telemetry
        self.active: list[SitePipeline] = []
        self.shed: list[SitePipeline] = []
        self.targets: dict[str, float] = {}
        self.history: list[SiteTick] = []
        self.emergencies = 0
        self._tick_index = 0
        self.allocate()

    # ------------------------------------------------------------------ #
    # plant view (what each device is doing right now)
    # ------------------------------------------------------------------ #

    def _by_device(self, device_index: int) -> SitePipeline | None:
        for p in self.pipelines:
            if p.device_index == device_index:
                return p
        return None

    def _is_active(self, p: SitePipeline) -> bool:
        return any(q.name == p.name for q in self.active)

    def _clock_of(self, device_index: int) -> float:
        p = self._by_device(device_index)
        if p is None or not self._is_active(p):
            return self.device.f_min
        return self.governors[p.name].f_mhz

    def _util_of(self, device_index: int) -> tuple[float, float]:
        p = self._by_device(device_index)
        if p is None or not self._is_active(p):
            return (0.0, 0.0)       # shed: idle draw only
        return (p.u_core, p.u_mem)

    def _pipe_power(self, p: SitePipeline, f_mhz: float, *,
                    idle: bool = False) -> float:
        uc, um = (0.0, 0.0) if idle else (p.u_core, p.u_mem)
        return float(self.power_model.power(f_mhz, u_core=uc, u_mem=um))

    def truth_site_w(self) -> float:
        """Noiseless model power of the whole site at current clocks."""
        total = 0.0
        for p in self.pipelines:
            if self._is_active(p):
                total += self._pipe_power(p, self.governors[p.name].f_mhz)
            else:
                total += self._pipe_power(p, self.device.f_min, idle=True)
        return total

    # ------------------------------------------------------------------ #
    # budget allocation + shedding
    # ------------------------------------------------------------------ #

    def _shed_order(self, candidates: list[SitePipeline]) -> SitePipeline:
        """The next victim: lowest priority first, name as tiebreak."""
        return min(candidates, key=lambda p: (p.priority, p.name))

    def allocate(self) -> None:
        """(Re)split the budget over active pipelines; shed if needed."""
        budget = self.headroom * self.site_cap_w
        active = [p for p in self.pipelines
                  if not any(q.name == p.name for q in self.shed)]
        idle_w = {p.name: self._pipe_power(p, self.device.f_min, idle=True)
                  for p in self.pipelines}
        floor_w = {p.name: self._pipe_power(p, self.device.f_min)
                   for p in self.pipelines}
        # Shed until the floors (+ idle draw of shed devices) fit.
        while active:
            committed = (sum(floor_w[p.name] for p in active)
                         + sum(idle_w[p.name] for p in self.pipelines
                               if not any(q.name == p.name for q in active)))
            if committed <= budget or len(active) == 1:
                break
            victim = self._shed_order(active)
            active = [p for p in active if p.name != victim.name]
            self.shed.append(victim)
        self.active = active
        spare = budget - sum(floor_w[p.name] for p in active) \
            - sum(idle_w[p.name] for p in self.pipelines
                  if not self._is_active(p))
        spare = max(spare, 0.0)
        total_priority = sum(p.priority for p in active) or 1
        self.targets = {}
        for p in self.pipelines:
            if not self._is_active(p):
                self.targets[p.name] = 0.0
                continue
            boost = self._pipe_power(p, self.device.f_max)
            share = spare * p.priority / total_priority
            target = min(floor_w[p.name] + share, boost)
            self.targets[p.name] = target
            self.governors[p.name].set_target(target)

    def emergency(self) -> None:
        """Hard-cap breach rung: floor every clock, shed, reallocate."""
        self.emergencies += 1
        for p in self.active:
            gov = self.governors[p.name]
            gov.f_mhz = self.device.f_min
            gov.integral_w = 0.0
        if len(self.active) > 1:
            victim = self._shed_order(self.active)
            self.shed.append(victim)
        self.allocate()

    # ------------------------------------------------------------------ #
    # the control loop
    # ------------------------------------------------------------------ #

    def tick(self, t: float) -> SiteTick:
        """One site control tick at time ``t`` (seconds)."""
        token = self._tick_index
        self._tick_index += 1
        est = 0.0
        for p in self.pipelines:
            gov = self.governors[p.name]
            if not self._is_active(p):
                est += self._pipe_power(p, self.device.f_min, idle=True)
                continue
            tr = self.telemetry.read(p.device_index, t, token=token,
                                     f_mhz=gov.f_mhz, u_core=p.u_core,
                                     u_mem=p.u_mem)
            healthy = self.telemetry.healthy(p.device_index)
            gov.step(tr.measured_w, healthy=healthy)
            # Site estimate: trust fresh measurements, substitute the
            # model at the *pre-step* clock otherwise (never freewheel
            # the cap check on a lying sensor either).
            est += tr.measured_w if tr.measured_w is not None \
                else self._pipe_power(p, gov.f_mhz)
        fired = False
        if est > self.hard_cap_w:
            self.emergency()
            fired = True
            est = self.truth_site_w()   # post-rung model estimate
        truth = self.truth_site_w()
        tick = SiteTick(
            t=t,
            clocks_mhz=tuple(self._clock_of(p.device_index)
                             for p in self.pipelines),
            targets_w=tuple(self.targets[p.name] for p in self.pipelines),
            truth_w=truth,
            estimated_w=est,
            active=tuple(p.name for p in self.pipelines
                         if self._is_active(p)),
            health=tuple(self.telemetry.watchdog(p.device_index).health
                         for p in self.pipelines),
            modes=tuple(self.governors[p.name].mode
                        for p in self.pipelines),
            converged=self._converged(),
            emergency=fired,
        )
        self.history.append(tick)
        return tick

    def _converged(self) -> bool:
        """Every active device settled: on target, pinned, or fallback."""
        for p in self.active:
            gov = self.governors[p.name]
            if gov.in_fallback:
                continue            # pinned to static optimum: settled
            if gov.f_mhz in (self.device.f_min, self.device.f_max):
                continue            # railed at a clock bound: settled
            truth = self._pipe_power(p, gov.f_mhz)
            if abs(truth - self.targets[p.name]) > self.convergence_tol_w:
                return False
        return bool(self.active)

    def run(self, n_ticks: int, dt: float = 0.1) -> list[SiteTick]:
        """Run ``n_ticks`` control ticks; returns the tick history."""
        for k in range(n_ticks):
            self.tick(self._tick_index * dt)
        return self.history

    @property
    def first_converged_tick(self) -> int | None:
        for k, tick in enumerate(self.history):
            if tick.converged:
                return k
        return None

    def digest(self) -> str:
        """Reproducibility digest over the whole run's observable state."""
        h = hashlib.blake2b(digest_size=16)
        for tick in self.history:
            clocks = ",".join(f"{f:.3f}" for f in tick.clocks_mhz)
            h.update(f"{clocks}|{','.join(tick.health)}|"
                     f"{','.join(tick.active)}|{int(tick.emergency)}"
                     .encode())
        return h.hexdigest()
