"""Persistent per-device tuning cache — tune once per machine, ever.

astroCAMP's argument for SKA-scale deployability is that benchmark
configurations must be *reproducible artefacts*, not rediscovered state:
a tuning result is only useful if the next process (and the next month's
service restart) replays it without re-measuring.  The cache is a
versioned JSON file per device,

    ``~/.cache/repro-tune/<device>.json``   (override: ``REPRO_TUNE_CACHE``)

mapping :meth:`repro.tune.config.ConfigKey.token` strings to the chosen
:class:`~repro.tune.config.KernelConfig` plus its measurement record.
Loads are forgiving by design: a missing, corrupted, or version-mismatched
file yields an *empty* cache (heuristic fallback) — a stale artefact must
never crash a serving process.  Writes are atomic (tmp + rename) so a
crashed tuner can't leave a half-written file behind.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
from typing import Any

from repro.tune.config import ConfigKey, KernelConfig

#: Bump when the on-disk schema changes; older files fall back to empty.
CACHE_VERSION = 1

#: Environment override for the cache file path (tests, CI, containers).
CACHE_ENV = "REPRO_TUNE_CACHE"


def default_device_name() -> str:
    """A filesystem-safe identifier of the local accelerator."""
    try:
        import jax
        kind = jax.devices()[0].device_kind
    except Exception:                                  # pragma: no cover
        kind = "cpu"
    return re.sub(r"[^A-Za-z0-9_.-]+", "-", str(kind)).strip("-") or "cpu"


def cache_path(device: str | None = None) -> str:
    """Resolve the on-disk cache location for ``device``."""
    override = os.environ.get(CACHE_ENV, "")
    if override:
        return override
    base = os.path.join(os.path.expanduser("~"), ".cache", "repro-tune")
    return os.path.join(base, f"{device or default_device_name()}.json")


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One persisted tuning outcome: the choice plus its evidence."""

    config: KernelConfig
    heuristic: KernelConfig = KernelConfig()
    objective: str = "time"
    score: float = 0.0              # chosen config's objective score
    heuristic_score: float = 0.0    # heuristic config's objective score
    measured_s: float = 0.0         # chosen config's wall seconds/call
    heuristic_s: float = 0.0        # heuristic config's wall seconds/call
    candidates: int = 0             # generated configs
    measured: int = 0               # survivors actually timed

    @property
    def speedup_vs_heuristic(self) -> float:
        """Measured heuristic wall over chosen wall (>= 1.0 by contract)."""
        if self.measured_s <= 0.0:
            return 1.0
        return self.heuristic_s / self.measured_s

    def to_dict(self) -> dict[str, Any]:
        return {
            "config": self.config.to_dict(),
            "heuristic": self.heuristic.to_dict(),
            "objective": self.objective,
            "score": self.score,
            "heuristic_score": self.heuristic_score,
            "measured_s": self.measured_s,
            "heuristic_s": self.heuristic_s,
            "candidates": self.candidates,
            "measured": self.measured,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TuneRecord":
        return cls(
            config=KernelConfig.from_dict(d["config"]),
            heuristic=KernelConfig.from_dict(d.get("heuristic") or {}),
            objective=str(d.get("objective", "time")),
            score=float(d.get("score", 0.0)),
            heuristic_score=float(d.get("heuristic_score", 0.0)),
            measured_s=float(d.get("measured_s", 0.0)),
            heuristic_s=float(d.get("heuristic_s", 0.0)),
            candidates=int(d.get("candidates", 0)),
            measured=int(d.get("measured", 0)),
        )


class TuningCache:
    """In-memory view of one device's persisted tuning results."""

    def __init__(self, device: str | None = None,
                 entries: dict[str, TuneRecord] | None = None):
        self.device = device or default_device_name()
        self._entries: dict[str, TuneRecord] = dict(entries or {})
        self.lookups = 0            # test hook: underlying consults

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: ConfigKey) -> bool:
        return key.token() in self._entries

    def get(self, key: ConfigKey) -> TuneRecord | None:
        self.lookups += 1
        return self._entries.get(key.token())

    def put(self, key: ConfigKey, record: TuneRecord) -> None:
        self._entries[key.token()] = record

    def keys(self) -> list[ConfigKey]:
        return [ConfigKey.from_token(t) for t in self._entries]

    def records(self) -> dict[str, TuneRecord]:
        return dict(self._entries)

    # -- persistence --------------------------------------------------------

    @classmethod
    def load(cls, device: str | None = None,
             path: str | None = None) -> "TuningCache":
        """Load the device's cache; ANY failure yields an empty cache.

        Corrupted JSON, a schema-version mismatch, or records that no
        longer parse all degrade to "never tuned" — callers fall back to
        the heuristics and may re-tune, they never crash.
        """
        device = device or default_device_name()
        path = path or cache_path(device)
        try:
            with open(path) as f:
                raw = json.load(f)
            if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
                return cls(device)
            entries = {
                token: TuneRecord.from_dict(rec)
                for token, rec in raw.get("entries", {}).items()
            }
            return cls(device, entries)
        except (OSError, ValueError, KeyError, TypeError):
            return cls(device)

    def save(self, path: str | None = None) -> str:
        """Atomically persist the cache; returns the path written."""
        path = path or cache_path(self.device)
        payload = {
            "version": CACHE_VERSION,
            "device": self.device,
            "entries": {t: r.to_dict() for t, r in self._entries.items()},
        }
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                                   prefix=".repro-tune-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path
