"""The TuningContext hook — how plans find their tuned configuration.

``repro.fft.plan`` / ``plan_nd`` / ``convolve`` call :func:`plan_config`
while *building* a plan.  The resolution order is:

  1. ``REPRO_FFT_DISABLE_TUNING=1``  ->  ``None`` — the pre-tuner
     heuristic path, bit-for-bit (plan builders memoise on the config,
     so the disabled path shares the exact heuristic plan objects).
  2. no active context               ->  ``None`` (same heuristic path).
  3. active context                  ->  the tuned
     :class:`~repro.tune.config.KernelConfig` for
     ``(device, shape, kind, dtype)``, or ``None`` when the cache has no
     entry (heuristic fallback when absent).

A context consults its underlying :class:`~repro.tune.cache.TuningCache`
**exactly once** per distinct key and memoises the answer — repeated plan
builds, serving-cache rebuilds, and jit retraces never re-read the cache
(``consults`` is the counter the routing tests pin).

This module deliberately imports nothing from ``repro.fft`` so the
planners can import it without a cycle.
"""
from __future__ import annotations

import contextlib
import os

from repro.tune.cache import TuningCache
from repro.tune.config import ConfigKey, KernelConfig

#: Escape hatch: restores the pre-tuner heuristics everywhere.
DISABLE_ENV = "REPRO_FFT_DISABLE_TUNING"


def tuning_enabled() -> bool:
    return os.environ.get(DISABLE_ENV, "") not in ("1", "true")


class TuningContext:
    """Memoised view of one device's tuning cache for plan construction."""

    def __init__(self, cache: TuningCache | None = None,
                 device: str | None = None, dtype: str = "fp32"):
        self.cache = cache if cache is not None else TuningCache.load(device)
        self.device = device or self.cache.device
        self.dtype = dtype
        self.consults = 0           # underlying cache reads (memo misses)
        #: Optional Sec.-4-style common config served to *untuned* keys
        #: (set by ``repro.tune.tuner.install_common_default``).
        self.common: KernelConfig | None = None
        self._memo: dict[ConfigKey, KernelConfig | None] = {}

    def key_for(self, shape: tuple[int, ...], kind: str = "c2c",
                dtype: str | None = None) -> ConfigKey:
        return ConfigKey(device=self.device, shape=tuple(shape), kind=kind,
                         dtype=dtype or self.dtype)

    def config_for(self, shape: tuple[int, ...], kind: str = "c2c",
                   dtype: str | None = None) -> KernelConfig | None:
        """The tuned config for a key, or None (heuristic) when untuned."""
        key = self.key_for(shape, kind, dtype)
        if key in self._memo:
            return self._memo[key]
        self.consults += 1
        record = self.cache.get(key)
        cfg = None
        if record is not None and not record.config.is_heuristic:
            cfg = record.config
        elif record is None and self.common is not None \
                and not self.common.is_heuristic:
            cfg = self.common           # Sec. 4: one shared setting
        self._memo[key] = cfg
        return cfg

    def invalidate(self) -> None:
        """Drop memoised answers (after re-tuning into the same cache)."""
        self._memo.clear()


_ACTIVE: TuningContext | None = None


def get_tuning_context() -> TuningContext | None:
    return _ACTIVE


def set_tuning_context(ctx: TuningContext | None) -> TuningContext | None:
    """Install ``ctx`` process-wide; returns the previous context."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, ctx
    return prev


@contextlib.contextmanager
def use_tuning(ctx: TuningContext | None):
    """Scoped installation — tests and the tuner's measurement loop."""
    prev = set_tuning_context(ctx)
    try:
        yield ctx
    finally:
        set_tuning_context(prev)


def plan_config(shape: tuple[int, ...], kind: str = "c2c",
                dtype: str = "fp32") -> KernelConfig | None:
    """What the planners call: the active tuned config or None.

    ``None`` means "run the heuristics" — both the disabled path and the
    no-context/no-entry paths return it, so plan memoisation collapses
    all three onto the single pre-tuner plan object.
    """
    if not tuning_enabled():
        return None
    ctx = get_tuning_context()
    if ctx is None:
        return None
    return ctx.config_for(tuple(shape), kind, dtype)
