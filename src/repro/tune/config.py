"""Kernel-configuration records the autotuner searches over and persists.

A :class:`KernelConfig` is everything a plan needs to parameterise its
kernel launches away from the built-in heuristics:

  tile_b    batch tile of the 1-D batched kernels (``kernels.fft.ops``
            recomputes ``batch_tile`` when this is None)
  radices   butterfly schedule of every fused pass (None = DEFAULT_RADICES)
  split     the four-step (n1, n2) factorisation for long transforms
            (None = the balanced ``_four_step_split`` heuristic)
  segment   overlap-save nfft for the convolution engine (0 = the
            ``select_nfft`` cost-model choice)

Configs are frozen/hashable so plan builders can key their memoisation on
them, and JSON-round-trippable so the on-disk tuning cache can persist
them.  :class:`ConfigKey` identifies what a config was tuned *for*:
``(device, shape, kind, dtype)`` — the same axes the paper sweeps clocks
per (device, length, precision).
"""
from __future__ import annotations

import dataclasses
from typing import Any

#: Where a config came from — surfaced in receipts/benchmarks.
SOURCE_HEURISTIC = "heuristic"
SOURCE_TUNED = "tuned"
SOURCE_COMMON = "common"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the kernel-configuration space (None = heuristic)."""

    tile_b: int | None = None
    radices: tuple[int, ...] | None = None
    split: tuple[int, int] | None = None
    segment: int = 0
    source: str = SOURCE_HEURISTIC

    @property
    def is_heuristic(self) -> bool:
        """True when every axis defers to the built-in heuristics."""
        return (self.tile_b is None and self.radices is None
                and self.split is None and self.segment == 0)

    def to_dict(self) -> dict[str, Any]:
        return {
            "tile_b": self.tile_b,
            "radices": list(self.radices) if self.radices else None,
            "split": list(self.split) if self.split else None,
            "segment": self.segment,
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "KernelConfig":
        radices = d.get("radices")
        split = d.get("split")
        return cls(
            tile_b=d.get("tile_b"),
            radices=tuple(int(r) for r in radices) if radices else None,
            split=tuple(int(s) for s in split) if split else None,  # type: ignore[arg-type]
            segment=int(d.get("segment") or 0),
            source=str(d.get("source", SOURCE_TUNED)),
        )


#: The all-heuristic config (what every plan ran before the autotuner).
HEURISTIC = KernelConfig()


@dataclasses.dataclass(frozen=True)
class ConfigKey:
    """What a config was tuned for: (device, shape, kind, dtype)."""

    device: str
    shape: tuple[int, ...]
    kind: str = "c2c"
    dtype: str = "fp32"

    def token(self) -> str:
        """Stable string form used as the JSON cache key."""
        dims = "x".join(str(d) for d in self.shape)
        return f"{self.device}|{dims}|{self.kind}|{self.dtype}"

    @classmethod
    def from_token(cls, token: str) -> "ConfigKey":
        device, dims, kind, dtype = token.split("|")
        shape = tuple(int(d) for d in dims.split("x") if d)
        return cls(device=device, shape=shape, kind=kind, dtype=dtype)
