"""repro.tune — energy-aware kernel-configuration autotuning.

The paper tunes the *clock* per (device, length, precision) by sweep and
measurement; this package tunes the *kernel configuration* per
``(device, shape, kind, dtype)`` the same way: generate candidates,
prune them with the analytic cost model, measure the survivors with the
shared benchmark timing methodology, score under a time or energy
objective, and persist the choice to a per-device on-disk cache so
tuning happens once per machine.

Entry points:

  tune_length / tune_segment    tune one key (replay from cache if tuned)
  common_config                 the Sec.-4 single-best-config result
  install_common_default        install it for every untuned shape
  TuningContext / use_tuning    what the planners consult
  TuningCache                   the persistent artefact
  time_fn                       the shared timing helper
"""
from repro.tune.cache import (CACHE_ENV, CACHE_VERSION, TuneRecord,
                              TuningCache, cache_path, default_device_name)
from repro.tune.config import (HEURISTIC, ConfigKey, KernelConfig,
                               SOURCE_COMMON, SOURCE_HEURISTIC, SOURCE_TUNED)
from repro.tune.context import (DISABLE_ENV, TuningContext,
                                get_tuning_context, plan_config,
                                set_tuning_context, tuning_enabled,
                                use_tuning)
from repro.tune.timing import time_fn
from repro.tune.tuner import (TuneResult, common_config,
                              generate_candidates, install_common_default,
                              prune_candidates, tune_length, tune_segment)

__all__ = [
    "CACHE_ENV", "CACHE_VERSION", "DISABLE_ENV", "HEURISTIC",
    "ConfigKey", "KernelConfig", "SOURCE_COMMON", "SOURCE_HEURISTIC",
    "SOURCE_TUNED", "TuneRecord", "TuneResult", "TuningCache",
    "TuningContext", "cache_path", "common_config", "default_device_name",
    "generate_candidates", "get_tuning_context", "install_common_default",
    "plan_config", "prune_candidates", "set_tuning_context", "time_fn",
    "tune_length", "tune_segment", "tuning_enabled", "use_tuning",
]
