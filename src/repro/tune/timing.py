"""Shared warm-up/repeat wall-clock timing — one methodology everywhere.

The benchmark harness (``benchmarks/run.py``) and the autotuner must time
kernels *identically*, or "speedup vs heuristic" claims compare apples to
oranges.  Both call :func:`time_fn`: warm-up calls first (JIT compilation
and cache priming are not the steady state), then ``repeats`` timed calls
reduced with ``reduce`` (default ``min`` — best-of-n is robust to
scheduler noise on shared CPUs; pass ``statistics.median``/``mean`` for
other conventions).

``timer`` is injectable so tests can prove determinism: two tuning runs
fed the same fake clock must choose the same config.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2,
            reduce: Callable[[Sequence[float]], float] = min,
            timer: Callable[[], float] = time.perf_counter) -> float:
    """Wall seconds per call of ``fn(*args)`` after warm-up."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = timer()
        jax.block_until_ready(fn(*args))
        samples.append(timer() - t0)
    return reduce(samples)
