"""Energy-aware autotuner: cost-model-pruned kernel-configuration search.

The paper finds each FFT length's best *clock* by measurement (sweep,
then argmin J/transform under a latency bound); this module applies the
same discipline to the *software* configuration axes the clock sweep
holds fixed: batch tile, butterfly radix schedule, the four-step
``(n1, n2)`` split, and the overlap-save segment length.

The search is staged so measurement stays cheap:

  1. **Generate** every candidate :class:`KernelConfig` for the key
     (schedules x splits/segments x batch tiles).
  2. **Prune with the cost model** (``core.workloads`` pass/traffic
     accounting + ``core.dvfs.sweep``): candidates are ranked by modelled
     boost-clock time (objective ``"time"``) or modelled J/transform at
     the DVFS-optimal clock (objective ``"energy"``) and only the top
     few survive — nothing untimed is ever worse than unranked.
  3. **Measure survivors** with the shared warm-up/repeat methodology
     (:func:`repro.tune.timing.time_fn` — identical to the benchmark
     harness), always including the heuristic config.
  4. **Score**: ``time`` = measured wall; ``energy`` = model power at the
     workload's DVFS-optimal clock x measured wall (J/call).  Whatever
     the objective, a config that measures *slower* than the heuristic is
     rejected — the heuristic's latency is the real-time bound (Sec. 2.3),
     so the tuner may return the heuristic but can never regress it.

Results persist to the per-device :class:`~repro.tune.cache.TuningCache`;
a second run replays the cached choice with **zero** measurements.
:func:`common_config` is the paper's Sec. 4 result on the software axis:
the single configuration minimising average modelled regret across every
tuned length, installable as the global default for untuned shapes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import dvfs
from repro.core.hardware import TESLA_V100, DeviceSpec
from repro.core.workloads import ConvCase, FFTCase, conv_workload, \
    fft_workload
from repro.fft.radix import DEFAULT_RADICES, is_pow2, next_pow2
from repro.tune.cache import TuneRecord, TuningCache
from repro.tune.config import (HEURISTIC, SOURCE_COMMON, SOURCE_TUNED,
                               ConfigKey, KernelConfig)
from repro.tune.context import TuningContext, use_tuning
from repro.tune.timing import time_fn

#: Butterfly schedules the engine can execute (repro.fft.radix).
RADIX_CANDIDATES = ((4, 2), (2,), (8, 4, 2))

#: Batch tiles worth trying (f32 sublane is 8 on TPU; heuristic rides too).
TILE_CANDIDATES = (8, 16, 32, 64)

#: Survivors the measurement stage accepts per key (heuristic always rides).
DEFAULT_MEASURE_BUDGET = 5

#: Transform kinds :func:`tune_length` understands; "conv" tunes the
#: overlap-save segment of ``repro.fft.convolve`` instead of an FFT plan.
FFT_KINDS = ("c2c", "r2c", "c2r")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One generated config plus its cost-model ranking scores."""

    config: KernelConfig
    model_time: float           # modelled boost-clock seconds per batch
    model_j: float              # modelled J/transform at the optimal clock
    opt_power_w: float          # model power at the DVFS-optimal clock


@dataclasses.dataclass
class TuneResult:
    """Outcome of one :func:`tune_length` call."""

    key: ConfigKey
    record: TuneRecord
    measurements: int           # timed executions THIS call (0 on replay)
    replayed: bool              # served from the persistent cache
    survivors: tuple[KernelConfig, ...] = ()

    @property
    def config(self) -> KernelConfig:
        return self.record.config

    @property
    def speedup_vs_heuristic(self) -> float:
        return self.record.speedup_vs_heuristic


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

def _split_candidates(n: int) -> list[tuple[int, int] | None]:
    """Four-step (n1, n2) factorisations to try for a long pow2 length.

    The balanced heuristic cut is represented by None only — an explicit
    duplicate of it would be a functional clone of the heuristic that
    could "win" on timing noise.
    """
    from repro.fft.plan import MAX_SINGLE_PASS, _four_step_split
    if not is_pow2(n) or n <= MAX_SINGLE_PASS:
        return [None]
    splits: list[tuple[int, int] | None] = [None]    # heuristic balanced cut
    balanced = _four_step_split(n)
    log = n.bit_length() - 1
    for k in range(max(log // 2 - 1, 1), min(log // 2 + 2, log)):
        n1 = 1 << k
        n2 = n // n1
        if (max(n1, n2) <= MAX_SINGLE_PASS and (n1, n2) != balanced
                and (n1, n2) not in splits):
            splits.append((n1, n2))
    return splits


def _tile_candidates(n: int, batch: int) -> list[int | None]:
    """Batch tiles to try: the heuristic (None) plus explicit lane multiples
    that fit the measurement batch and a conservative VMEM budget.

    The tile the heuristic would resolve to is excluded — an explicit copy
    of it is functionally the heuristic and must never beat it on noise.
    """
    from repro.kernels.common import batch_tile
    heuristic_tile = min(batch_tile(n, 4, buffers=8), batch)
    tiles: list[int | None] = [None]
    for t in TILE_CANDIDATES:
        if (t <= batch and t != heuristic_tile
                and t * n * 4 * 8 <= 16 * 2**20 and t not in tiles):
            tiles.append(t)
    return tiles


def generate_candidates(n: int, kind: str, batch: int) -> list[KernelConfig]:
    """The full config space for one key (heuristic config first)."""
    configs: list[KernelConfig] = [HEURISTIC]
    for radices in RADIX_CANDIDATES:
        # The default schedule IS the heuristic radix choice — normalise
        # it to None so a functionally-identical config can never "beat"
        # the heuristic on timing noise.
        rad = None if radices == DEFAULT_RADICES else radices
        for split in _split_candidates(n):
            for tile in _tile_candidates(n, batch):
                cfg = KernelConfig(tile_b=tile, radices=rad, split=split,
                                   source=SOURCE_TUNED)
                if cfg.is_heuristic or cfg in configs:
                    continue
                configs.append(cfg)
    return configs


def _segment_candidates(n: int, taps: int) -> list[int]:
    """Pow2 overlap-save segment lengths bracketing the signal.

    Mirrors :func:`repro.fft.convolve.select_nfft`'s bounds: the kernel
    cap only applies when some single-pass segment can hold the filter at
    all — longer filters fall through to multi-pass segments instead of
    producing an empty candidate list.
    """
    from repro.fft.plan import MAX_KERNEL_N
    lo = next_pow2(max(2 * taps, 16))
    hi = max(lo, next_pow2(n + taps - 1))
    if lo <= MAX_KERNEL_N:
        hi = min(hi, MAX_KERNEL_N)
    out = []
    nfft = lo
    while nfft <= hi:
        out.append(nfft)
        nfft *= 2
    return out


# ---------------------------------------------------------------------------
# Cost-model pruning
# ---------------------------------------------------------------------------

def _model_candidate(cfg: KernelConfig, n: int, kind: str,
                     model_device: DeviceSpec) -> Candidate:
    """Rank one config with the analytic pass/traffic model + DVFS sweep."""
    case = FFTCase(n=n, transform=kind if kind in FFT_KINDS else "c2c",
                   radices=cfg.radices or DEFAULT_RADICES)
    res = dvfs.sweep(fft_workload(case, model_device), model_device)
    per = dvfs.energy_per_transform(res, case.n_fft)
    return Candidate(config=cfg, model_time=res.boost.time,
                     model_j=per["optimal_j"], opt_power_w=res.optimal.power)


def prune_candidates(configs: Sequence[KernelConfig], n: int, kind: str,
                     model_device: DeviceSpec, objective: str,
                     budget: int) -> list[Candidate]:
    """Keep the ``budget`` model-best candidates; the heuristic always
    survives (it anchors the never-regress guarantee)."""
    ranked = [_model_candidate(c, n, kind, model_device) for c in configs]
    score = (lambda c: c.model_time) if objective == "time" \
        else (lambda c: c.model_j)
    head, tail = ranked[0], sorted(ranked[1:], key=score)
    return [head] + tail[:max(budget - 1, 1)]


# ---------------------------------------------------------------------------
# Measurement + choice
# ---------------------------------------------------------------------------

def _fft_executable(n: int, kind: str, cfg: KernelConfig) -> Callable:
    import jax
    from repro.fft.plan import plan_with_config
    return jax.jit(plan_with_config(n, kind, cfg).fn)


def _fft_operand(n: int, kind: str, batch: int):
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    if kind == "r2c":
        return jax.random.normal(key, (batch, n), jnp.float32)
    if kind == "c2r":
        half = jax.random.normal(key, (batch, n // 2 + 1))
        return (half + 0.5j * half).astype(jnp.complex64)
    x = jax.random.normal(key, (batch, n))
    return (x + 1j * jax.random.normal(jax.random.PRNGKey(1), (batch, n))
            ).astype(jnp.complex64)


def tune_length(
    n: int,
    kind: str = "c2c",
    *,
    objective: str = "energy",
    cache: TuningCache | None = None,
    model_device: DeviceSpec = TESLA_V100,
    batch: int | None = None,
    measure_budget: int = DEFAULT_MEASURE_BUDGET,
    repeats: int = 3,
    warmup: int = 1,
    timer: Callable[[], float] = time.perf_counter,
    force: bool = False,
    save: bool = True,
) -> TuneResult:
    """Tune one ``(device, (n,), kind, dtype)`` key end to end.

    Replays the persisted choice with zero measurements when the cache
    already holds the key (pass ``force=True`` to re-measure).  ``timer``
    is injectable (determinism tests feed a fake clock).
    """
    if objective not in ("time", "energy"):
        raise ValueError(f"unknown objective {objective!r}; "
                         "have ('time', 'energy')")
    if kind not in FFT_KINDS:
        raise ValueError(f"unknown transform kind {kind!r}; have {FFT_KINDS}")
    cache = cache if cache is not None else TuningCache.load()
    key = ConfigKey(device=cache.device, shape=(int(n),), kind=kind)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(key=key, record=hit, measurements=0,
                              replayed=True)

    batch = batch or max(2**14 // n, 8)
    candidates = generate_candidates(n, kind, batch)
    survivors = prune_candidates(candidates, n, kind, model_device,
                                 objective, measure_budget)

    # Measure every survivor under a *disabled* tuning context so the plan
    # builders resolve exactly the config under test, nothing else.
    walls: list[float] = []
    with use_tuning(None):
        operand = _fft_operand(n, kind, batch)
        for cand in survivors:
            fn = _fft_executable(n, kind, cand.config)
            walls.append(time_fn(fn, operand, repeats=repeats,
                                 warmup=warmup, timer=timer))

    def score(i: int) -> float:
        if objective == "time":
            return walls[i]
        return survivors[i].opt_power_w * walls[i]      # J/call at f_opt

    best = min(range(len(survivors)), key=score)
    # Never regress the heuristic's wall time: its latency is the bound.
    if walls[best] > walls[0]:
        best = 0
    chosen = survivors[best].config
    if best != 0:
        chosen = dataclasses.replace(chosen, source=SOURCE_TUNED)
    record = TuneRecord(
        config=chosen,
        heuristic=HEURISTIC,
        objective=objective,
        score=score(best),
        heuristic_score=score(0),
        measured_s=walls[best],
        heuristic_s=walls[0],
        candidates=len(candidates),
        measured=len(survivors),
    )
    cache.put(key, record)
    if save:
        cache.save()
    return TuneResult(key=key, record=record,
                      measurements=len(survivors) * (repeats + warmup),
                      replayed=False,
                      survivors=tuple(c.config for c in survivors))


def tune_segment(
    n: int,
    taps: int,
    templates: int = 1,
    *,
    cache: TuningCache | None = None,
    model_device: DeviceSpec = TESLA_V100,
    save: bool = True,
) -> TuneResult:
    """Pick the overlap-save ``nfft`` by full cost-model sweep (no wall
    measurement: ``conv_workload`` prices every candidate's actual pass
    structure, and segments only change modelled traffic/FLOPs).

    Persisted under kind ``"conv"`` with shape ``(n, taps, templates)``;
    ``repro.fft.convolve.conv_plan`` consults it before ``select_nfft``.
    """
    cache = cache if cache is not None else TuningCache.load()
    key = ConfigKey(device=cache.device, shape=(int(n), int(taps),
                                                int(templates)), kind="conv")
    if (hit := cache.get(key)) is not None:
        return TuneResult(key=key, record=hit, measurements=0, replayed=True)

    def seg_j(nfft: int) -> float:
        case = ConvCase(n=n, templates=templates, taps=taps, nfft=nfft)
        res = dvfs.sweep(conv_workload(case, model_device), model_device)
        return res.optimal.energy / case.n_rows

    segments = _segment_candidates(n, taps)
    scored = sorted(segments, key=seg_j)
    from repro.fft.convolve import select_nfft
    heuristic_seg = select_nfft(taps, n, templates)
    record = TuneRecord(
        config=KernelConfig(segment=scored[0], source=SOURCE_TUNED),
        heuristic=KernelConfig(segment=0),
        objective="energy",
        score=seg_j(scored[0]),
        heuristic_score=seg_j(heuristic_seg),
        candidates=len(segments),
        measured=0,
    )
    cache.put(key, record)
    if save:
        cache.save()
    return TuneResult(key=key, record=record, measurements=0, replayed=False)


# ---------------------------------------------------------------------------
# The paper's Sec. 4 "common configuration" result, on the software axis
# ---------------------------------------------------------------------------

def common_config(
    cache: TuningCache,
    *,
    model_device: DeviceSpec = TESLA_V100,
) -> tuple[KernelConfig, float]:
    """The single config minimising average modelled regret across every
    tuned FFT length — the software mirror of the paper's one-common-clock
    result (Sec. 4: one well-chosen setting recovers ~50% of the savings).

    Only the length-portable axes (``tile_b``, ``radices``) generalise;
    splits and segments stay per-length.  Returns ``(config, regret)``
    where ``regret`` is the mean relative J/transform excess over each
    length's own tuned optimum (0.0 = no loss anywhere).
    """
    keys = [k for k in cache.keys() if k.kind in FFT_KINDS
            and len(k.shape) == 1]
    if not keys:
        raise ValueError("no tuned FFT lengths in the cache")
    pool: list[KernelConfig] = [HEURISTIC]
    for k in keys:
        rec = cache.get(k)
        portable = KernelConfig(tile_b=rec.config.tile_b,
                                radices=rec.config.radices,
                                source=SOURCE_COMMON)
        if portable not in pool:
            pool.append(portable)

    def model_j(cfg: KernelConfig, key: ConfigKey) -> float:
        case = FFTCase(n=key.shape[0], transform=key.kind,
                       radices=cfg.radices or DEFAULT_RADICES)
        res = dvfs.sweep(fft_workload(case, model_device), model_device)
        return dvfs.energy_per_transform(res, case.n_fft)["optimal_j"]

    # One sweep per (config, key): the regret loop reuses these figures.
    j = {(c, k): model_j(c, k) for c in pool for k in keys}
    best_per_key = {k: min(j[(c, k)] for c in pool) for k in keys}
    regrets = []
    for cfg in pool:
        regrets.append(float(np.mean(
            [j[(cfg, k)] / best_per_key[k] - 1.0 for k in keys])))
    i = int(np.argmin(regrets))
    cfg = pool[i]
    if cfg is not HEURISTIC:
        cfg = dataclasses.replace(cfg, source=SOURCE_COMMON)
    return cfg, regrets[i]


def install_common_default(
    cache: TuningCache | None = None,
    *,
    model_device: DeviceSpec = TESLA_V100,
) -> TuningContext:
    """Build a context whose untuned keys fall back to the common config
    (instead of the heuristics) and install it process-wide."""
    from repro.tune.context import set_tuning_context
    cache = cache if cache is not None else TuningCache.load()
    ctx = TuningContext(cache)
    try:
        common, _ = common_config(cache, model_device=model_device)
    except ValueError:
        common = None
    ctx.common = common
    set_tuning_context(ctx)
    return ctx
