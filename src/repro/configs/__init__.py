"""Config registry: ``--arch <id>`` resolution for launch/dryrun/train."""
from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, TRAIN_4K, ArchConfig, MLAConfig,
                                MoEConfig, ShapeSpec, SSMConfig, shapes_for)

from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.codeqwen1_5_7b import CONFIG as CODEQWEN1_5_7B
from repro.configs.qwen1_5_4b import CONFIG as QWEN1_5_4B
from repro.configs.gemma3_12b import CONFIG as GEMMA3_12B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        QWEN2_0_5B, CODEQWEN1_5_7B, QWEN1_5_4B, GEMMA3_12B, MUSICGEN_MEDIUM,
        DBRX_132B, DEEPSEEK_V2_LITE, MAMBA2_370M, PIXTRAL_12B, ZAMBA2_1_2B,
    )
}

SHAPES: dict[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError as e:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from e


def get_shape(name: str) -> ShapeSpec:
    try:
        return SHAPES[name]
    except KeyError as e:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}") from e


def all_cells() -> list[tuple[ArchConfig, ShapeSpec]]:
    """Every (architecture x applicable shape) dry-run cell."""
    return [(cfg, shp) for cfg in ARCHS.values() for shp in shapes_for(cfg)]
