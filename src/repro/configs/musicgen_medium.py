"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only transformer over
EnCodec tokens.  The EnCodec audio frontend is the STUB: ``input_specs``
supplies the discrete codec tokens (vocab 2048) directly."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64,
    rope_theta=10000.0,
)
