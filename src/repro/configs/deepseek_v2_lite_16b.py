"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE.

The assignment line reads "MoE 64e top-6 ... 2 shared+160 routed top-6";
the published V2-Lite config is 64 routed + 2 shared experts, top-6 (160
routed is the full V2) — we implement the published Lite values and note
the discrepancy here.  First layer uses a dense FFN (d_ff 10944); routed
experts have d_ff 1408.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=None,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  group_size=256),
    n_dense_layers=1, dense_d_ff=10944,
)
