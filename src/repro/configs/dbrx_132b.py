"""DBRX-132B [hf:databricks/dbrx-base; unverified] — fine-grained MoE,
16 experts top-4, GQA kv=8."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)
