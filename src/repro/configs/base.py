"""Architecture & shape configuration schema.

One :class:`ArchConfig` per assigned architecture (exact published configs)
plus the paper's own FFT-pipeline workload.  ``reduced()`` produces the
small same-family config used by the CPU smoke tests; the full configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int               # per-expert FFN width
    n_shared: int = 0              # always-on shared experts (DeepSeek)
    # GShard-style dispatch group size: every ``group_size`` tokens route
    # independently, keeping the one-hot dispatch tensor O(T * E * C/group)
    # instead of O(T^2) — the standard GShard/Switch trick.
    group_size: int = 256
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128           # N (SSD state size per head)
    head_dim: int = 64             # P
    expand: int = 2                # inner width = expand * d_model
    chunk: int = 256               # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None            # None -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # Sliding-window pattern (gemma3): window size + one global layer per
    # ``local_per_global`` locals.  None -> all-global attention.
    sliding_window: int | None = None
    local_per_global: int = 0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba2): a shared attention block after every k SSM layers.
    shared_attn_every: int = 0
    # First N layers use a dense FFN even in MoE models (DeepSeek).
    n_dense_layers: int = 0
    dense_d_ff: int | None = None
    input_mode: Literal["tokens", "embeds"] = "tokens"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"                # activation/param dtype (dry-run)
    max_context: int | None = None         # documented context limit
    # Sub-quadratic decode? (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def param_count(self) -> float:
        """Approximate total parameters (for 6ND roofline accounting)."""
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        if self.ssm is not None and self.family == "ssm":
            inner = self.ssm.expand * d
            per_layer = d * (2 * inner) + inner * d + inner * (
                2 * self.ssm.state_dim) + inner
            return l * per_layer + 2 * self.vocab * d
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim
                                                       + m.v_head_dim)
                    + d * self.n_heads * qk
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        ffn_dense = 3 * d * (self.dense_d_ff or self.d_ff)
        if self.moe is not None:
            ffn_moe = 3 * d * self.moe.d_ff_expert * (
                self.moe.n_experts + self.moe.n_shared) + d * self.moe.n_experts
            n_moe = l - self.n_dense_layers
            ffn_total = self.n_dense_layers * ffn_dense + n_moe * ffn_moe
        else:
            ffn_total = l * 3 * d * self.d_ff
        total = l * attn + ffn_total + 2 * self.vocab * d
        if self.shared_attn_every:
            # hybrid: SSM backbone + one shared attention block
            inner = self.ssm.expand * d
            ssm_per_layer = d * (2 * inner) + inner * d + inner * (
                2 * self.ssm.state_dim) + inner
            total = l * ssm_per_layer + attn + l * 2 * d * d // 8 \
                + 2 * self.vocab * d
        return float(total)

    def active_param_count(self) -> float:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim
                                                       + m.v_head_dim)
                    + d * self.n_heads * qk
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        act_ffn = 3 * d * self.moe.d_ff_expert * (self.moe.top_k
                                                  + self.moe.n_shared)
        dense_ffn = 3 * d * (self.dense_d_ff or self.d_ff)
        n_moe = l - self.n_dense_layers
        return float(l * attn + self.n_dense_layers * dense_ffn
                     + n_moe * act_ffn + 2 * self.vocab * d)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 4) if
            self.n_kv_heads < self.n_heads else 4,
            head_dim=16, d_ff=128, vocab=256, dtype="float32",
        )
        if self.n_kv_heads == self.n_heads:
            kw["n_kv_heads"] = 4
        else:
            kw["n_kv_heads"] = 2
        upd: dict = dict(kw)
        if self.moe is not None:
            upd["moe"] = MoEConfig(
                n_experts=4, top_k=2, d_ff_expert=32,
                n_shared=min(self.moe.n_shared, 1), group_size=8,
            )
            upd["n_dense_layers"] = min(self.n_dense_layers, 1)
            upd["dense_d_ff"] = 128 if self.dense_d_ff else None
            upd["n_layers"] = 3
        if self.mla is not None:
            upd["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                   qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            upd["ssm"] = SSMConfig(state_dim=16, head_dim=8, expand=2,
                                   chunk=16)
        if self.sliding_window:
            upd["sliding_window"] = 8
        if self.local_per_global:
            upd["local_per_global"] = 1
            upd["n_layers"] = 4                 # 2 groups of (1 local + 1 global)
        if self.shared_attn_every:
            upd["shared_attn_every"] = 2
            upd["n_layers"] = 5
        return dataclasses.replace(self, **upd)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: what gets lowered for an architecture."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig) -> tuple[ShapeSpec, ...]:
    """long_500k only for sub-quadratic (SSM/hybrid) archs — DESIGN.md §4."""
    if cfg.subquadratic:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
