"""The paper's own workload as a selectable config: batched C2C FFTs.

This is the (non-LM) "architecture" the paper studies; the dry-run lowers
the distributed pencil FFT on the production mesh exactly like the LM
cells (see repro.launch.fft_dryrun).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FFTBenchConfig:
    name: str = "fft-bench"
    # paper Sec. 4: ~2 GB of complex64 input per batch
    batch_bytes: float = 2e9
    lengths: tuple[int, ...] = tuple(2**k for k in range(5, 23))
    precisions: tuple[str, ...] = ("fp32", "fp64", "fp16")
    # distributed (pencil) case: one transform of n1*n2 points, n1 sharded
    pencil_n1: int = 4096
    pencil_n2: int = 8192
    pencil_batch: int = 64


CONFIG = FFTBenchConfig()
