"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified] — VLM.

Backbone only (mistral-nemo-style decoder); the pixtral-ViT vision
frontend is the STUB: ``input_specs`` supplies precomputed patch
embeddings (batch, seq, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1e6,
    input_mode="embeds",
)
