"""Mamba2-370M [arXiv:2405.21060; unverified] — SSD (state-space duality),
attention-free; runs the long_500k cell (O(1)/token decode)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256),
    subquadratic=True,
)
