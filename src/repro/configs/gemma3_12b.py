"""Gemma3-12B [hf:google/gemma-3 family; unverified] — 5:1 local:global
sliding-window attention, 128k context, 262k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, head_dim=256,
    rope_theta=1e6,
    sliding_window=1024, local_per_global=5,
    max_context=131072, tie_embeddings=True,
)
