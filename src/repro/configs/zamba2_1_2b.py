"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone with a SHARED
attention block applied every 6 SSM layers; runs long_500k."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6,
    subquadratic=True,
)
