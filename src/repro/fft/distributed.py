"""Distributed FFTs over a device mesh (shard_map + collectives).

Two parallel regimes, matching how the paper's workload scales out:

* **Batch parallel** (:func:`batch_parallel_fft`) — the paper's own setting:
  many independent transforms, sharded over the ``data`` axis.  No
  communication at all; this is why the paper can say "FFTs which fit into
  GPU memory can be easily distributed amongst the GPUs" (Sec. 2.3).

* **Pencil / four-step** (:func:`pencil_fft`) — one transform too long for
  a device (the SKA long_500k class): view N = n1 * n2, shard n1 across the
  ``model`` axis, and turn the four-step algorithm's transpose into
  ``jax.lax.all_to_all``.  This is the TPU-native analogue of cuFFT's
  multi-kernel long plans, and the piece whose collective term shows up in
  the roofline analysis.

The output of :func:`pencil_fft` is in *transposed* layout — element
``[k1, k2]`` of the local (n1_local, n2) block holds bin ``k2 * n1 + k1``
(FFTW's MPI transposed-output convention).  Use :func:`untranspose_ref`
on gathered results when validating.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pad_rows(x: jax.Array, rows: int) -> jax.Array:
    """Zero-pad the leading (batch) dimension up to ``rows``."""
    pad = rows - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)


def batch_parallel_fft(x: jax.Array, mesh: Mesh, *, axis: str = "data",
                       fft_fn=None) -> jax.Array:
    """Batched FFT with the batch dimension sharded over ``axis``.

    Batches that do not divide the axis size are zero-padded to the next
    multiple, transformed, and sliced back — the serving layer coalesces
    requests into arbitrary batch sizes, so divisibility cannot be assumed.
    """
    from repro.fft.plan import plan_for_length
    fft_fn = fft_fn or plan_for_length(x.shape[-1])
    d = mesh.shape[axis]
    b = x.shape[0]
    x = pad_rows(x, b + (-b) % d)
    spec = P(axis, None)
    fn = shard_map(
        lambda v: fft_fn(v), mesh=mesh, in_specs=(spec,), out_specs=spec
    )
    out = fn(x)
    return out[:b] if out.shape[0] != b else out


@functools.partial(jax.jit, static_argnames=("n1", "n2", "axis", "mesh"))
def _pencil_body(x, *, n1, n2, axis, mesh):
    from repro.fft.stockham import _stockham_pow2

    def local(v):                           # v: (batch, n1/D, n2)
        d = jax.lax.psum(1, axis)
        p = jax.lax.axis_index(axis)
        # ---- transpose 1: gather full n1, scatter n2 -------------------
        v = jax.lax.all_to_all(v, axis, split_axis=2, concat_axis=1,
                               tiled=True)      # (batch, n1, n2/D)
        # ---- FFT over n1 ----------------------------------------------
        v = jnp.swapaxes(v, -1, -2)             # (batch, n2/D, n1)
        v = _stockham_pow2(v)
        # ---- twiddle: exp(-2*pi*i*j*k/n), j = global n2 index ----------
        n = n1 * n2
        j_local = jnp.arange(n2 // d) + p * (n2 // d)
        k = jnp.arange(n1)
        tw = jnp.exp(-2j * jnp.pi * (j_local[:, None] * k[None, :]) / n)
        v = v * tw.astype(v.dtype)
        v = jnp.swapaxes(v, -1, -2)             # (batch, n1, n2/D)
        # ---- transpose 2: back to n1-sharded ---------------------------
        v = jax.lax.all_to_all(v, axis, split_axis=1, concat_axis=2,
                               tiled=True)      # (batch, n1/D, n2)
        # ---- FFT over n2 ------------------------------------------------
        v = _stockham_pow2(v)                   # rows are contiguous
        return v

    spec = P(None, axis, None)
    return shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)(x)


def pencil_fft(x: jax.Array, mesh: Mesh, *, n1: int, n2: int,
               axis: str = "model") -> jax.Array:
    """Four-step FFT of length n1*n2 with n1 sharded over ``axis``.

    ``x``: (batch, n1, n2) complex, sharded P(None, axis, None).
    Returns the transform in transposed layout (see module docstring).
    """
    assert x.shape[-2:] == (n1, n2), (x.shape, n1, n2)
    return _pencil_body(x, n1=n1, n2=n2, axis=axis, mesh=mesh)


def untranspose_ref(y: jax.Array, n1: int, n2: int) -> jax.Array:
    """Reorder a gathered transposed-layout result into natural order."""
    batch = y.shape[:-2]
    # y[k1, k2] holds bin k2*n1+k1  ->  natural[k] with k = k2*n1+k1
    return jnp.swapaxes(y, -1, -2).reshape(*batch, n1 * n2)


def pencil_collective_bytes(batch: int, n1: int, n2: int,
                            n_devices: int, elem_bytes: int = 8) -> float:
    """Analytic all_to_all traffic per device for the DVFS/roofline model.

    Two all_to_alls; each moves the device's local block (minus the
    diagonal chunk that stays put): (D-1)/D of batch*n1*n2/D elements.
    """
    local = batch * n1 * n2 / n_devices * elem_bytes
    return 2.0 * local * (n_devices - 1) / n_devices
