"""Distributed FFTs over a device mesh (shard_map + collectives).

Two parallel regimes, matching how the paper's workload scales out:

* **Batch parallel** (:func:`batch_parallel_fft`) — the paper's own setting:
  many independent transforms, sharded over the ``data`` axis.  No
  communication at all; this is why the paper can say "FFTs which fit into
  GPU memory can be easily distributed amongst the GPUs" (Sec. 2.3).

* **Pencil / four-step** (:func:`pencil_fft`) — one transform too long for
  a device (the SKA long_500k class): view N = n1 * n2, shard n1 across the
  ``model`` axis, and turn the four-step algorithm's transpose into
  ``jax.lax.all_to_all``.  This is the TPU-native analogue of cuFFT's
  multi-kernel long plans, and the piece whose collective term shows up in
  the roofline analysis.

The output of :func:`pencil_fft` is in *transposed* layout — element
``[k1, k2]`` of the local (n1_local, n2) block holds bin ``k2 * n1 + k1``
(FFTW's MPI transposed-output convention).  Use :func:`untranspose_ref`
on gathered results when validating.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pad_rows(x: jax.Array, rows: int) -> jax.Array:
    """Zero-pad the leading (batch) dimension up to ``rows``."""
    pad = rows - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)


def batch_parallel_fft(x: jax.Array, mesh: Mesh, *, axis: str = "data",
                       fft_fn=None, kind: str = "c2c") -> jax.Array:
    """Batched FFT with the batch dimension sharded over ``axis``.

    Batches that do not divide the axis size are zero-padded to the next
    multiple, transformed, and sliced back — the serving layer coalesces
    requests into arbitrary batch sizes, so divisibility cannot be assumed.

    ``kind="r2c"`` routes real-input batches through the R2C plan (half
    the FLOPs and HBM traffic per shard) instead of silently casting to
    complex; N-D payloads (rank > 2) route through the plan graph
    (:mod:`repro.fft.plan_nd`), so sharded 2-D transforms get the fused
    transpose-write passes too.
    """
    if fft_fn is None:
        if x.ndim > 2:
            from repro.fft.plan_nd import plan_nd
            fft_fn = plan_nd(tuple(x.shape[1:]), kind)
        else:
            from repro.fft.plan import plan_for_length
            fft_fn = plan_for_length(x.shape[-1], kind)
    d = mesh.shape[axis]
    b = x.shape[0]
    x = pad_rows(x, b + (-b) % d)
    spec = P(axis, *([None] * (x.ndim - 1)))
    fn = shard_map(
        lambda v: fft_fn(v), mesh=mesh, in_specs=(spec,), out_specs=spec
    )
    out = fn(x)
    return out[:b] if out.shape[0] != b else out


@functools.partial(jax.jit, static_argnames=("n1", "n2", "axis", "mesh"))
def _pencil_body(x, *, n1, n2, axis, mesh):
    from repro.fft.plan import pow2_fft

    def local(v):                           # v: (batch, n1/D, n2)
        d = jax.lax.psum(1, axis)
        p = jax.lax.axis_index(axis)
        # ---- transpose 1: gather full n1, scatter n2 -------------------
        v = jax.lax.all_to_all(v, axis, split_axis=2, concat_axis=1,
                               tiled=True)      # (batch, n1, n2/D)
        # ---- FFT over n1 (plan-graph routed: Pallas when available) ----
        v = jnp.swapaxes(v, -1, -2)             # (batch, n2/D, n1)
        v = pow2_fft(v)
        # ---- twiddle: exp(-2*pi*i*j*k/n), j = global n2 index ----------
        n = n1 * n2
        j_local = jnp.arange(n2 // d) + p * (n2 // d)
        k = jnp.arange(n1)
        tw = jnp.exp(-2j * jnp.pi * (j_local[:, None] * k[None, :]) / n)
        v = v * tw.astype(v.dtype)
        v = jnp.swapaxes(v, -1, -2)             # (batch, n1, n2/D)
        # ---- transpose 2: back to n1-sharded ---------------------------
        v = jax.lax.all_to_all(v, axis, split_axis=1, concat_axis=2,
                               tiled=True)      # (batch, n1/D, n2)
        # ---- FFT over n2 ------------------------------------------------
        v = pow2_fft(v)                         # rows are contiguous
        return v

    spec = P(None, axis, None)
    return shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)(x)


@functools.partial(jax.jit, static_argnames=("n1", "n2p", "axis", "mesh"))
def _pencil_split_body(z, *, n1, n2p, axis, mesh):
    """Distributed Hermitian split of a packed-pencil result.

    ``z``: the transposed-layout C2C pencil transform of the *packed*
    real signal — (batch, n1/D, n2p) sharded P(None, axis, None), where
    element [k1, k2] holds Z[k2*n1 + k1] of the length M = n1*n2p packed
    transform.  The split needs Z[(M-k) mod M]: a global index reversal,
    realised as local flips plus a shard-reversing ``ppermute`` and a
    one-row global roll — O(local block) interconnect, no gather.
    """
    d = mesh.shape[axis]
    m = n1 * n2p

    def local(zt):                              # zt: (batch, L, n2p)
        p = jax.lax.axis_index(axis)
        l = zt.shape[-2]
        rows = p * l + jnp.arange(l)            # global k1 of each row
        # ---- G[k1] = Z row (n1 - k1) mod n1: reverse + roll by one -----
        rev = jax.lax.ppermute(zt[:, ::-1, :], axis,
                               perm=[(q, d - 1 - q) for q in range(d)])
        last = jax.lax.ppermute(rev[:, -1:, :], axis,
                                perm=[(q, (q + 1) % d) for q in range(d)])
        g = jnp.concatenate([last, rev[:, :-1, :]], axis=-2)
        # ---- k2 mirror: flip, with an extra roll on the k1 == 0 row ----
        flip = g[..., ::-1]
        rolled = jnp.roll(flip, 1, axis=-1)
        g = jnp.where((rows == 0)[None, :, None], rolled, flip)
        zm = jnp.conj(g)                        # Z[(M - k) mod M]*
        # ---- split: X[k] = (Z+Zm)/2 - i/2 * w^k * (Z-Zm) ---------------
        k = (jnp.arange(n2p)[None, :] * n1 + rows[:, None])   # (L, n2p)
        w = jnp.exp(-1j * jnp.pi * k / m)       # w_N^k, N = 2M
        x = 0.5 * (zt + zm) - 0.5j * w.astype(zt.dtype) * (zt - zm)
        # ---- Nyquist bin X[M] = Re(Z[0]) - Im(Z[0]), shard 0 row 0 -----
        z0 = zt[:, :1, :1]
        nyq = (z0.real - z0.imag).astype(zt.dtype)
        col = jnp.where((rows == 0)[None, :, None],
                        jnp.broadcast_to(nyq, (zt.shape[0], l, 1)), 0.0)
        return jnp.concatenate([x, col], axis=-1)

    spec = P(None, axis, None)
    return shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)(z)


def pencil_fft(x: jax.Array, mesh: Mesh, *, n1: int, n2: int,
               axis: str = "model", kind: str = "c2c") -> jax.Array:
    """Four-step FFT of length n1*n2 with n1 sharded over ``axis``.

    ``x``: (batch, n1, n2), sharded P(None, axis, None).

    ``kind="c2c"`` (default) returns the transform in transposed layout
    (see module docstring).  ``kind="r2c"`` takes REAL input and runs the
    packed real algorithm end to end distributed: adjacent reals pack
    into a length-M = n1*n2/2 complex pencil (HALF the FFT FLOPs, HBM
    traffic and all_to_all payload of the complex path), then the
    Hermitian split runs sharded — the spectral mirror Z[(M-k) mod M] is
    one shard-reversing ppermute plus a one-row roll, not a gather.  The
    result is (batch, n1/D-sharded n1, n2/2+1): element [k1, k2] holds
    half-spectrum bin X[k2*n1 + k1] for k2 < n2/2 (packed transposed
    layout), and the final column holds the Nyquist bin X[M] in row
    k1 = 0 (zeros elsewhere).  :func:`assemble_rfft_pencil` reorders a
    gathered result into ``jnp.fft.rfft`` natural order for validation.
    ``n2/2`` must divide evenly over the mesh axis.
    """
    assert x.shape[-2:] == (n1, n2), (x.shape, n1, n2)
    if kind == "r2c":
        d = mesh.shape[axis]
        if n2 % 2:
            raise ValueError(
                f"pencil r2c packs adjacent reals: n2 must be even, got {n2}")
        if (n2 // 2) % d:
            raise ValueError(
                f"pencil r2c needs n2/2 ({n2 // 2}) divisible by the "
                f"{d}-device mesh axis {axis!r}")
        batch = x.shape[:-2]
        v = jnp.real(x).astype(jnp.float32)
        v = v.reshape(*batch, n1, n2 // 2, 2)
        z = jax.lax.complex(v[..., 0], v[..., 1])     # packed rows
        z = _pencil_body(z, n1=n1, n2=n2 // 2, axis=axis, mesh=mesh)
        return _pencil_split_body(z, n1=n1, n2p=n2 // 2, axis=axis,
                                  mesh=mesh)
    if kind != "c2c":
        raise ValueError(f"unknown pencil transform kind {kind!r}")
    return _pencil_body(x, n1=n1, n2=n2, axis=axis, mesh=mesh)


def untranspose_ref(y: jax.Array, n1: int, n2: int) -> jax.Array:
    """Reorder a gathered transposed-layout result into natural order."""
    batch = y.shape[:-2]
    # y[k1, k2] holds bin k2*n1+k1  ->  natural[k] with k = k2*n1+k1
    return jnp.swapaxes(y, -1, -2).reshape(*batch, n1 * n2)


def assemble_rfft_pencil(y, n1: int, n2: int):
    """Reconstruct ``jnp.fft.rfft`` natural order from a gathered r2c
    pencil result (validation helper, host-side numpy).

    ``y``: (..., n1, n2/2+1) from ``pencil_fft(..., kind="r2c")`` —
    element [k1, k2] is half-spectrum bin X[k2*n1 + k1] for k2 < n2/2;
    the final column carries the Nyquist bin X[n1*n2/2] in row 0.
    """
    import numpy as np
    y = np.asarray(y)
    m = n1 * n2 // 2
    k = np.arange(m)
    k2, k1 = np.divmod(k, n1)
    body = y[..., k1, k2]
    nyq = y[..., 0:1, n2 // 2]
    return np.concatenate([body, nyq], axis=-1)


def pencil_collective_bytes(batch: int, n1: int, n2: int,
                            n_devices: int, elem_bytes: int = 8,
                            kind: str = "c2c") -> float:
    """Analytic all_to_all traffic per device for the DVFS/roofline model.

    C2C: two all_to_alls; each moves the device's local block (minus the
    diagonal chunk that stays put): (D-1)/D of batch*n1*n2/D elements.
    R2C: the same two all_to_alls on the HALF-length packed transform,
    plus the Hermitian-split mirror ppermute (one half-size local block)
    — ~70% of the c2c traffic on top of half the FLOPs and HBM passes.
    """
    local = batch * n1 * n2 / n_devices * elem_bytes
    if kind == "r2c":
        packed = local / 2.0
        return (2.0 * packed + packed) * (n_devices - 1) / n_devices
    return 2.0 * local * (n_devices - 1) / n_devices
