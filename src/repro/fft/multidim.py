"""Multi-dimensional FFTs — the paper's Eq. (2), compiled as plan graphs.

The 2-D (and higher) DFT factorises into independent 1-D DFTs along each
axis; cuFFT does exactly this (paper Sec. 2.1).  Naively that costs a
``moveaxis`` + 1-D transform + ``moveaxis`` back per axis — three HBM
round trips of the whole batch each.  Here every transform routes through
:mod:`repro.fft.plan_nd`: the hand-off transpose is fused into the FFT
kernel's write (one pass per pow2 axis, total), so ``fft2`` of pow2
shapes costs 2 HBM passes instead of 4+, and only non-pow2 (Bluestein)
axes pay an explicit tiled-transpose node.

Public API mirrors ``jnp.fft``: fft2 / rfft2 / fftn / rfftn, with
``axes=`` supported by normalising the transform axes to the trailing
positions first (a real transpose only when they are not already there).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fft.plan_nd import plan_nd


def _run(x: jax.Array, axes: tuple[int, ...], kind: str) -> jax.Array:
    x = jnp.asarray(x)
    axes = tuple(a % x.ndim for a in axes)
    if len(set(axes)) != len(axes):
        if kind == "r2c":
            # np.fft.rfftn's repeated-axes behaviour is a zero-padding
            # accident of its s= bookkeeping; reject rather than imitate.
            raise ValueError(f"repeated axes {axes} in a real transform")
        # numpy fftn semantics: a repeated axis is transformed repeatedly;
        # compile each occurrence as its own single-axis plan.
        for ax in axes:
            x = _run(x, (ax,), "c2c")
        return x
    trailing = tuple(range(x.ndim - len(axes), x.ndim))
    moved = axes != trailing
    if moved:
        x = jnp.moveaxis(x, axes, trailing)
    plan = plan_nd(tuple(x.shape[-len(axes):]), kind)
    y = plan(x)
    if moved:
        y = jnp.moveaxis(y, trailing, axes)
    return y


def fft2(x: jax.Array, axes: tuple[int, int] = (-2, -1)) -> jax.Array:
    """2-D C2C FFT over ``axes`` — two fused kernel passes at pow2 shapes."""
    return _run(x, axes, "c2c")


def rfft2(x: jax.Array, axes: tuple[int, int] = (-2, -1)) -> jax.Array:
    """2-D FFT of real input: R2C along ``axes[1]``, C2C along ``axes[0]``.

    Matches ``jnp.fft.rfft2``: output has ``n // 2 + 1`` bins along
    ``axes[1]``.  The R2C pass halves both FLOPs and HBM traffic of the
    innermost (largest) transform set, and its Hermitian split runs as a
    kernel epilogue on the same fused pass as the hand-off transpose.
    """
    return _run(x, axes, "r2c")


def fftn(x: jax.Array, axes: tuple[int, ...] | None = None) -> jax.Array:
    """N-D C2C FFT over ``axes`` (default: all) — one fused pass per pow2
    axis; the axis cycle restores the original order for free."""
    axes = tuple(range(jnp.asarray(x).ndim)) if axes is None else tuple(axes)
    return _run(x, axes, "c2c")


def rfftn(x: jax.Array, axes: tuple[int, ...] | None = None) -> jax.Array:
    """N-D FFT of real input: R2C on the last of ``axes``, C2C on the rest
    (the ``jnp.fft.rfftn`` convention)."""
    axes = tuple(range(jnp.asarray(x).ndim)) if axes is None else tuple(axes)
    return _run(x, axes, "r2c")
