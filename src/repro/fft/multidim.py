"""Multi-dimensional FFTs by axis decomposition — the paper's Eq. (2).

The 2-D (and higher) DFT factorises into independent 1-D DFTs along each
axis; cuFFT does exactly this (paper Sec. 2.1), so studying the 1-D
transform covers the higher-dimensional cases.  We expose fft2/fftn (and
the real-input rfft2) built on the 1-D planner, so every length class
(pow2/four-step/Bluestein) is usable per axis and every pow2 pass routes
through the Pallas kernel (repro.fft.plan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fft.plan import plan_for_length


def _fft_along(x: jax.Array, axis: int, kind: str = "c2c") -> jax.Array:
    plan = plan_for_length(x.shape[axis], kind)
    moved = jnp.moveaxis(x, axis, -1)
    return jnp.moveaxis(plan(moved), -1, axis)


def fft2(x: jax.Array, axes: tuple[int, int] = (-2, -1)) -> jax.Array:
    """2-D C2C FFT over ``axes`` (two sets of 1-D transforms, Eq. 2)."""
    a0, a1 = axes
    return _fft_along(_fft_along(x, a1), a0)


def rfft2(x: jax.Array, axes: tuple[int, int] = (-2, -1)) -> jax.Array:
    """2-D FFT of real input: R2C along the last axis, C2C along the other.

    Matches ``jnp.fft.rfft2``: output has ``n // 2 + 1`` bins along
    ``axes[1]``.  The R2C pass halves both FLOPs and HBM traffic of the
    innermost (largest) transform set.
    """
    a0, a1 = axes
    return _fft_along(_fft_along(x, a1, "r2c"), a0)


def fftn(x: jax.Array, axes: tuple[int, ...] | None = None) -> jax.Array:
    axes = tuple(range(x.ndim)) if axes is None else axes
    for ax in axes:
        x = _fft_along(x, ax)
    return x
