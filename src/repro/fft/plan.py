"""FFT planning — pick the algorithm per length, like cuFFT's planner.

The paper leans on cuFFT's dispatch (Cooley-Tukey for smooth lengths,
Bluestein otherwise, multi-kernel plans for long transforms).  Our planner
mirrors it:

  pow2, fits one kernel   -> single fused Stockham pass
  pow2, long              -> four-step decomposition (two passes + twiddle)
  non-pow2                -> Bluestein (three pow2 FFTs)

``plan.passes`` feeds the DVFS workload model (HBM traffic = 2 bytes moved
per pass), keeping the analytic model and the implementation consistent.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.fft.bluestein import bluestein_fft
from repro.fft.stockham import _stockham_pow2, fft as _fft

# Longest transform a single fused pass keeps resident (complex64 in VMEM;
# 2^13 c64 = 64 KiB per transform — matches the paper's single-kernel range).
MAX_SINGLE_PASS = 2**13


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    n: int
    algorithm: str              # "stockham" | "four-step" | "bluestein"
    passes: int                 # HBM read+write passes (DVFS model input)
    fn: Callable[[jax.Array], jax.Array]

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fn(x)


def four_step_fft(x: jax.Array, n1: int, n2: int) -> jax.Array:
    """Long FFT as (n1 x n2) decomposition — Bailey's four-step algorithm.

    1. view as (n1, n2), FFT the columns (length n1, stride n2)
    2. twiddle by exp(-2*pi*i*j*k/n)
    3. FFT the rows (length n2)
    4. read out transposed: out[k2*n1 + k1]

    On a single device both inner FFTs are batched Stockham passes; the
    distributed version (repro.fft.distributed) turns the transpose into an
    all_to_all across the mesh — cuFFT's multi-kernel plan, TPU-style.
    """
    n = n1 * n2
    assert x.shape[-1] == n
    batch = x.shape[:-1]
    v = x.reshape(*batch, n1, n2)
    # columns: transpose so the transform axis is last, FFT, transpose back
    v = jnp.swapaxes(v, -1, -2)                 # (..., n2, n1)
    v = _stockham_pow2(v)                        # FFT over n1
    j = jnp.arange(n2)[:, None]
    k = jnp.arange(n1)[None, :]
    tw = jnp.exp(-2j * jnp.pi * (j * k) / n).astype(v.dtype)
    v = v * tw
    v = _stockham_pow2(jnp.swapaxes(v, -1, -2))  # (..., n1, n2), FFT over n2
    out = jnp.swapaxes(v, -1, -2).reshape(*batch, n)
    return out


@functools.lru_cache(maxsize=None)
def plan_for_length(n: int) -> FFTPlan:
    """Build (or return the memoised) plan for length ``n``.

    Plans are immutable and shape-keyed, so planning runs once per length
    per process — the serving layer's plan cache builds on this, and
    repeated pipeline construction never re-derives the decomposition.
    """
    if _is_pow2(n):
        if n <= MAX_SINGLE_PASS:
            return FFTPlan(n, "stockham", 1, _fft)
        n1 = 1 << (int(math.log2(n)) // 2)
        n2 = n // n1
        return FFTPlan(
            n, "four-step", 2,
            lambda x, n1=n1, n2=n2: four_step_fft(x, n1, n2),
        )
    # Bluestein: 3 pow2 FFTs of length m >= 2n-1 plus pointwise passes.
    m = 1 << (2 * n - 2).bit_length()
    inner = plan_for_length(m)
    return FFTPlan(n, "bluestein", 3 * inner.passes + 1, bluestein_fft)
