"""FFT planning — pick the algorithm and kernel route per length.

The paper leans on cuFFT's dispatch (Cooley-Tukey for smooth lengths,
Bluestein otherwise, multi-kernel plans for long transforms).  Our planner
mirrors it:

  pow2, fits one kernel   -> single fused Stockham pass (Pallas kernel)
  pow2, long              -> four-step decomposition (two kernel passes
                             + cached twiddle)
  non-pow2                -> Bluestein (pow2 FFTs, cached chirp/filter)

plus real-valued plans (``kind="r2c"``/``"c2r"``): N real points packed
into an N/2 complex transform with a fused Hermitian split/merge — ~2x
FLOP and HBM savings for real telescope voltages.

**Routing**: every plan's power-of-two passes execute the fused Pallas
kernel (``repro.kernels.fft``) via :func:`pow2_fft`, falling back to the
pure-JAX Stockham engine when Pallas is unavailable (import failure, a
lowering error, or ``REPRO_FFT_DISABLE_PALLAS=1``).  Tests monkeypatch
the module-level ``_kernel_fft``/``_kernel_rfft``/``_kernel_irfft`` hooks
to count kernel invocations or force the fallback.

**Tuning**: plan construction consults the active
:class:`repro.tune.TuningContext` (exactly once per (device, shape, kind)
— the context memoises) for a tuned :class:`repro.tune.KernelConfig`
overriding the batch-tile / radix-schedule / four-step-split heuristics;
``REPRO_FFT_DISABLE_TUNING=1`` or the absence of a context restores the
heuristic plans bit-for-bit (they are the same memoised objects).

``plan.passes`` feeds the DVFS workload model (HBM traffic = 2 bytes moved
per pass), keeping the analytic model and the implementation consistent.
All twiddle/chirp constants are memoised per length (here, in
``repro.fft.radix`` and ``repro.fft.bluestein``), so planning and repeated
pipeline builds never re-materialise them; the serving layer's
``PlanSweepCache`` builds on the same memoisation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.fft.bluestein import bluestein_fft
from repro.fft.radix import DEFAULT_RADICES, radix_schedule, stage_count
from repro.fft.stockham import (_as_complex, _irfft_merge, _pack_real,
                                _rfft_split, _stockham_pow2, _unpack_real)
from repro.tune.config import KernelConfig
from repro.tune.context import plan_config as _tuned_plan_config

# Longest transform a single fused pass keeps resident (complex64 in VMEM;
# 2^13 c64 = 64 KiB per transform — matches the paper's single-kernel range).
MAX_SINGLE_PASS = 2**13

# ---------------------------------------------------------------------------
# Pallas kernel routing (monkeypatchable hooks + env kill-switch)
# ---------------------------------------------------------------------------

try:
    from repro.kernels.fft.ops import (MAX_KERNEL_N, fft_kernel_c2c,
                                       fft_kernel_c2c_axis1,
                                       fft_kernel_c2c_mul,
                                       fft_kernel_c2c_t, fft_kernel_c2r,
                                       fft_kernel_r2c, fft_kernel_r2c_t,
                                       transpose_kernel)
    _kernel_fft: Callable | None = fft_kernel_c2c
    _kernel_rfft: Callable | None = fft_kernel_r2c
    _kernel_irfft: Callable | None = fft_kernel_c2r
    _kernel_fft_t: Callable | None = fft_kernel_c2c_t
    _kernel_fft_axis1: Callable | None = fft_kernel_c2c_axis1
    _kernel_rfft_t: Callable | None = fft_kernel_r2c_t
    _kernel_transpose: Callable | None = transpose_kernel
    _kernel_fft_mul: Callable | None = fft_kernel_c2c_mul
except Exception:                                     # pragma: no cover
    MAX_KERNEL_N = MAX_SINGLE_PASS
    _kernel_fft = _kernel_rfft = _kernel_irfft = None
    _kernel_fft_t = _kernel_fft_axis1 = None
    _kernel_rfft_t = _kernel_transpose = None
    _kernel_fft_mul = None


def _pallas_enabled() -> bool:
    return os.environ.get("REPRO_FFT_DISABLE_PALLAS", "") not in ("1", "true")


@contextlib.contextmanager
def pallas_disabled():
    """Force the pure-JAX engine inside the block (tracing included).

    The serving layer's bottom degradation rung traces its fallback
    executables under this, so they capture the ``REPRO_FFT_DISABLE_PALLAS``
    path permanently regardless of the ambient environment.
    """
    prev = os.environ.get("REPRO_FFT_DISABLE_PALLAS")
    os.environ["REPRO_FFT_DISABLE_PALLAS"] = "1"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_FFT_DISABLE_PALLAS", None)
        else:
            os.environ["REPRO_FFT_DISABLE_PALLAS"] = prev


def _kernel_overrides(config: KernelConfig | None) -> dict:
    """Kwargs a tuned config contributes to a kernel entry-point call.

    None (heuristic) contributes nothing, so the disabled/untuned path
    issues byte-identical kernel calls to the pre-tuner code.
    """
    if config is None:
        return {}
    kw = {}
    if config.tile_b:
        kw["tile_b"] = config.tile_b
    if config.radices:
        kw["radices"] = config.radices
    return kw


def _resolve_split(n: int, config: KernelConfig | None) -> tuple[int, int]:
    """The four-step (n1, n2) cut: the tuned one when valid, else balanced."""
    if config is not None and config.split:
        n1, n2 = config.split
        if n1 * n2 == n and _is_pow2(n1) and _is_pow2(n2):
            return n1, n2
    return _four_step_split(n)


def pow2_fft(x: jax.Array, *, inverse: bool = False,
             config: KernelConfig | None = None) -> jax.Array:
    """C2C FFT of a pow2 length, routed through the Pallas kernel.

    Single-kernel lengths run the fused mixed-radix kernel (pure-JAX
    Stockham on fallback); longer lengths recurse through the four-step
    decomposition so *every* pow2 pass of every plan lands on the kernel.
    ``config`` (a tuned :class:`repro.tune.KernelConfig`) overrides the
    batch tile / radix schedule / four-step split heuristics.
    """
    n = x.shape[-1]
    if n > MAX_SINGLE_PASS:
        if inverse:
            return jnp.conj(pow2_fft(jnp.conj(x), config=config)) / n
        n1, n2 = _resolve_split(n, config)
        return four_step_fft(x, n1, n2, config=config)
    kern = _kernel_fft
    if kern is not None and n <= MAX_KERNEL_N and _pallas_enabled():
        try:
            return kern(x, inverse=inverse, **_kernel_overrides(config))
        except Exception:                             # graceful fallback
            pass
    return _stockham_pow2(x, inverse=inverse)


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def fft_mul(x: jax.Array, bank,
            config: KernelConfig | None = None) -> jax.Array:
    """Forward pow2 C2C FFT fused with a (T, N) filter-bank multiply.

    (..., N) in -> (..., T, N) out: out[..., t, :] = FFT(x) * bank[t].
    The overlap-save convolution engine's forward pass: the bank multiply
    rides the FFT kernel as an in-VMEM epilogue (``fft_kernel_c2c_mul``),
    so a T-template matched-filter plane costs forward + T inverse passes
    with zero standalone multiply passes.  The fallback (Pallas missing
    or disabled) pays the routed FFT plus ONE XLA broadcast multiply —
    numerically identical, one extra HBM round trip of the plane.
    """
    x = _as_complex(x)
    n = x.shape[-1]
    kern = _kernel_fft_mul
    if (kern is not None and _is_pow2(n) and 1 < n <= MAX_KERNEL_N
            and _pallas_enabled()):
        try:
            return kern(x, bank, **_kernel_overrides(config))
        except Exception:                             # graceful fallback
            pass
    y = pow2_fft(x, config=config)
    return y[..., None, :] * jnp.asarray(bank).astype(y.dtype)


# ---------------------------------------------------------------------------
# Fused-epilogue pass primitives (the plan graph's node executors)
# ---------------------------------------------------------------------------

def fft_transposed(x: jax.Array, *, twiddle=None, inverse: bool = False,
                   config: KernelConfig | None = None) -> jax.Array:
    """C2C FFT along the last axis with the last two axes swapped on write.

    One fused kernel pass: (..., R, C) -> (..., C, R).  ``twiddle`` (an
    (R, C) complex table) rides along as a kernel epilogue — the four-step
    inter-pass multiply costs zero extra HBM passes.  Falls back to
    routed-FFT + XLA multiply + XLA transpose when Pallas is unavailable
    (numerically identical, just more memory passes).
    """
    x = _as_complex(x)
    n = x.shape[-1]
    kern = _kernel_fft_t
    if (kern is not None and _is_pow2(n) and n <= MAX_KERNEL_N
            and n > 1 and _pallas_enabled()):
        try:
            return kern(x, twiddle=twiddle, inverse=inverse,
                        **_kernel_overrides(config))
        except Exception:                             # graceful fallback
            pass
    y = _routed_1d(x, n, inverse, config)
    if twiddle is not None:
        y = y * jnp.asarray(twiddle).astype(y.dtype)
    return jnp.swapaxes(y, -1, -2)


def _routed_1d(x: jax.Array, n: int, inverse: bool,
               config: KernelConfig | None = None) -> jax.Array:
    """Last-axis C2C of any length, honouring ``inverse`` (conj trick for
    the non-pow2 plans, which only run forward)."""
    if _is_pow2(n):
        return pow2_fft(x, inverse=inverse, config=config)
    plan = plan_for_length(n)
    if inverse:
        return jnp.conj(plan(jnp.conj(x))) / n
    return plan(x)


def fft_column(x: jax.Array, *, twiddle=None, inverse: bool = False,
               config: KernelConfig | None = None) -> jax.Array:
    """C2C FFT over axis -2, layout preserved: (..., R, C) -> (..., R, C).

    One fused kernel pass (transpose-read + FFT + optional twiddle
    epilogue + transpose-write, all in VMEM) — the column pass of the
    four-step algorithm.  ``twiddle`` is a (C, R) table multiplying output
    ``[..., k, j]`` by ``twiddle[j, k]``.  Falls back to XLA transpose +
    routed FFT + multiply when Pallas is unavailable.
    """
    x = _as_complex(x)
    r = x.shape[-2]
    kern = _kernel_fft_axis1
    if (kern is not None and _is_pow2(r) and 1 < r <= MAX_KERNEL_N
            and _pallas_enabled()):
        try:
            return kern(x, twiddle=twiddle, inverse=inverse,
                        **_kernel_overrides(config))
        except Exception:                             # graceful fallback
            pass
    y = _routed_1d(jnp.swapaxes(x, -1, -2), r, inverse, config)
    if twiddle is not None:
        y = y * jnp.asarray(twiddle).astype(y.dtype)
    return jnp.swapaxes(y, -1, -2)


def rfft_transposed(x: jax.Array,
                    config: KernelConfig | None = None) -> jax.Array:
    """R2C FFT along the last axis, transposed write: (..., R, C) real ->
    (..., C/2+1, R) — one fused pass (pack + half-length FFT + Hermitian
    split + transpose all in VMEM)."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    n = x.shape[-1]
    kern = _kernel_rfft_t
    if (kern is not None and _is_pow2(n) and 4 <= n
            and n // 2 <= MAX_KERNEL_N and _pallas_enabled()):
        try:
            return kern(x, **_kernel_overrides(config))
        except Exception:
            pass
    return jnp.swapaxes(plan_with_config(n, "r2c", config)(x), -1, -2)


def tiled_transpose(x: jax.Array) -> jax.Array:
    """Swap the last two axes in one tiled kernel pass (read row tiles,
    write column tiles); XLA transpose on fallback."""
    kern = _kernel_transpose
    if kern is not None and _pallas_enabled():
        try:
            return kern(x)
        except Exception:
            pass
    return jnp.swapaxes(x, -1, -2)


def _four_step_split(n: int) -> tuple[int, int]:
    n1 = 1 << (int(math.log2(n)) // 2)
    return n1, n // n1


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    n: int
    algorithm: str              # "stockham" | "four-step" | "bluestein"
    passes: int                 # HBM read+write passes (DVFS model input)
    fn: Callable[[jax.Array], jax.Array]
    kind: str = "c2c"           # "c2c" | "r2c" | "c2r"
    stages: int = 0             # butterfly stages per fused pass
    radices: tuple[int, ...] = ()

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fn(x)


@functools.lru_cache(maxsize=None)
def _four_step_twiddle(n1: int, n2: int) -> np.ndarray:
    """The (n2, n1) inter-pass twiddle matrix, materialised once per shape.

    complex128 so the x64 path keeps full precision; consumers cast to the
    working dtype at trace time.
    """
    j = np.arange(n2)[:, None]
    k = np.arange(n1)[None, :]
    return np.exp(-2j * np.pi * (j * k) / (n1 * n2))


def four_step_fft(x: jax.Array, n1: int, n2: int,
                  config: KernelConfig | None = None) -> jax.Array:
    """Long FFT as (n1 x n2) decomposition — Bailey's four-step algorithm,
    run as TWO fused kernel passes.

    View x as v[j1, j2] (row-major).  With outputs indexed k = k2*n1 + k1:

      pass 1: FFT the columns (length n1, axis -2, transpose-read in
              VMEM) -> V[k1, j2]; multiply the inter-pass twiddle
              exp(-2*pi*i*j2*k1/n) as a kernel epilogue; write back in
              the same layout -> T[k1, j2]
      pass 2: FFT the rows of T (length n2) -> Y[k1, k2]; write
              transposed -> out[k2, k1], which flattens to natural order.

    The unfused formulation costs kernel + XLA-twiddle + three XLA
    transposes (five HBM round trips of the batch); the fused pair costs
    exactly two.  Both passes route through the Pallas kernels
    (:func:`fft_column`, :func:`fft_transposed`), falling back to routed
    :func:`pow2_fft` + XLA ops when Pallas is unavailable.  The
    distributed version (repro.fft.distributed) turns the transpose into
    an all_to_all across the mesh — cuFFT's multi-kernel plan, TPU-style.
    """
    n = n1 * n2
    assert x.shape[-1] == n
    batch = x.shape[:-1]
    v = x.reshape(*batch, n1, n2)
    tw = _four_step_twiddle(n1, n2)              # (n2, n1): w^{j2*k1}
    v = fft_column(v, twiddle=tw, config=config)  # (..., n1, n2): T[k1, j2]
    v = fft_transposed(v, config=config)         # (..., n2, n1), natural
    return v.reshape(*batch, n)


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def _c2c_fn(x: jax.Array,
            config: KernelConfig | None = None) -> jax.Array:
    return pow2_fft(_as_complex(x), config=config)


def _r2c_fn(x: jax.Array, n: int,
            config: KernelConfig | None = None) -> jax.Array:
    """Routed R2C: fused kernel when the packed length fits, else pack ->
    routed pow2 C2C -> split (so long real transforms still hit the kernel
    once per four-step pass)."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    m = n // 2
    kern = _kernel_rfft
    if (kern is not None and 4 <= n and m <= MAX_KERNEL_N
            and _pallas_enabled()):
        try:
            return kern(x, **_kernel_overrides(config))
        except Exception:
            pass
    if m < 1:
        return _as_complex(x)
    return _rfft_split(
        pow2_fft(_pack_real(x.astype(jnp.float32)), config=config), n)


def _c2r_fn(x: jax.Array, n: int,
            config: KernelConfig | None = None) -> jax.Array:
    """Routed C2R inverse of :func:`_r2c_fn` (1/N normalised)."""
    x = _as_complex(x)
    m = n // 2
    kern = _kernel_irfft
    if (kern is not None and 4 <= n and m <= MAX_KERNEL_N
            and _pallas_enabled()):
        try:
            return kern(x, **_kernel_overrides(config))
        except Exception:
            pass
    return _unpack_real(
        pow2_fft(_irfft_merge(x, n), inverse=True, config=config))


def plan_for_length(n: int, kind: str = "c2c") -> FFTPlan:
    """Build (or return the memoised) plan for length ``n``.

    ``kind`` selects the transform: ``"c2c"`` (default), ``"r2c"`` (real
    input, N/2+1 bins out) or ``"c2r"`` (the inverse).  Plans are immutable
    and shape-keyed, so planning runs once per (length, kind, config) per
    process — the serving layer's plan cache builds on this, and repeated
    pipeline construction never re-derives the decomposition or twiddles.

    The active :class:`repro.tune.TuningContext` (if any) supplies the
    tuned kernel config; it memoises its own lookups, so the tuning cache
    is consulted exactly once per (device, shape, kind) no matter how
    often plans rebuild.  ``REPRO_FFT_DISABLE_TUNING=1`` (or no context)
    resolves to ``None`` — the pre-tuner heuristic plan object itself.
    """
    return _plan_for_length(int(n), kind, _tuned_plan_config((n,), kind))


def plan_with_config(n: int, kind: str = "c2c",
                     config: KernelConfig | None = None) -> FFTPlan:
    """Build the plan for an *explicit* config, bypassing the active
    tuning context (the autotuner's measurement loop, plan_nd threading).
    A heuristic-equivalent config collapses onto the heuristic plan."""
    if config is not None and config.is_heuristic:
        config = None
    return _plan_for_length(int(n), kind, config)


@functools.lru_cache(maxsize=None)
def _plan_for_length(n: int, kind: str,
                     config: KernelConfig | None) -> FFTPlan:
    if kind not in ("c2c", "r2c", "c2r"):
        raise ValueError(f"unknown transform kind {kind!r}")
    radices = (config.radices if config is not None and config.radices
               else DEFAULT_RADICES)
    if kind != "c2c":
        return _real_plan(n, kind, config)
    if _is_pow2(n):
        schedule = radix_schedule(min(n, MAX_SINGLE_PASS), radices)
        if n <= MAX_SINGLE_PASS:
            return FFTPlan(n, "stockham", 1,
                           functools.partial(_c2c_fn, config=config),
                           stages=len(schedule), radices=schedule)
        n1, n2 = _resolve_split(n, config)
        return FFTPlan(
            n, "four-step", 2,
            lambda x, n1=n1, n2=n2, c=config: four_step_fft(
                _as_complex(x), n1, n2, config=c),
            stages=stage_count(n1, radices) + stage_count(n2, radices),
            radices=radix_schedule(n1, radices),
        )
    # Bluestein: the filter-spectrum FFT is precomputed and cached per
    # length (repro.fft.bluestein), so only 2 pow2 FFTs of length
    # m >= 2n-1 run per call, plus pointwise chirp passes.  The config
    # rides into those inner FFTs (the heuristic path keeps the bare
    # bluestein_fft object so disabled tuning stays bit-for-bit).
    m = 1 << (2 * n - 2).bit_length()
    inner = _plan_for_length(m, "c2c", config)
    fn = (bluestein_fft if config is None
          else functools.partial(bluestein_fft, config=config))
    return FFTPlan(n, "bluestein", 2 * inner.passes + 1, fn,
                   stages=inner.stages, radices=inner.radices)


def _real_plan(n: int, kind: str, config: KernelConfig | None) -> FFTPlan:
    if not _is_pow2(n):
        if kind == "c2r":
            raise ValueError(
                f"c2r plans need a power-of-two length, got {n}")
        # r2c fallback: full C2C plan + slice to the half spectrum.
        inner = _plan_for_length(n, "c2c", config)
        return FFTPlan(
            n, inner.algorithm, inner.passes,
            lambda x: inner.fn(_as_complex(x))[..., :n // 2 + 1],
            kind="r2c", stages=inner.stages, radices=inner.radices)
    m = max(n // 2, 1)
    inner = _plan_for_length(m, "c2c", config) if m > 1 else None
    passes = inner.passes if inner else 1
    stages = inner.stages if inner else 0
    radices = inner.radices if inner else ()
    alg = inner.algorithm if inner else "stockham"
    fn = (functools.partial(_r2c_fn, n=n, config=config) if kind == "r2c"
          else functools.partial(_c2r_fn, n=n, config=config))
    return FFTPlan(n, alg, passes, fn, kind=kind, stages=stages,
                   radices=radices)
