"""Mixed-radix machinery shared by the pure-JAX and Pallas FFT engines.

Everything here is host-side (numpy) and memoised: radix schedules,
per-stage twiddle tables, the small DFT matrices of each butterfly, and
the R2C/C2R split twiddles.  Consumers embed the returned numpy arrays as
constants at trace time, so twiddles are materialised **once per length
per process** — never re-derived inside a trace and never recomputed per
call (the paper's memory-bound argument, Sec. 5, makes every avoided HBM
or transcendental pass count).

Radix choice: a radix-r Stockham stage decides log2(r) output bits at
once, so a radix-4 + radix-2-tail schedule halves the stage count of the
radix-2 engine (log4 N vs log2 N), and radix-8 cuts it to a third.  Fewer
stages means less VMEM/shared-memory traffic per transform — the
``t_cache`` term of the DVFS model (repro.core.perf_model).
"""
from __future__ import annotations

import functools
import math

import numpy as np

#: Default schedule for the TPU engine: radix-4 stages with a radix-2 tail.
DEFAULT_RADICES = (4, 2)


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (for n >= 1)."""
    return 1 << max(n - 1, 0).bit_length()

#: The cuFFT-flavoured schedule the paper's GPU measurements correspond to.
CUFFT_RADICES = (8, 4, 2)

#: Real FLOPs per point per stage of a radix-r DIF butterfly (classic
#: operation counts: 5 N log2 N total for radix-2, 4.25 N log2 N for
#: radix-4, ~4.08 N log2 N for radix-8; each stage decides log2(r) bits).
STAGE_FLOPS_PER_POINT = {2: 5.0, 4: 8.5, 8: 12.25}


@functools.lru_cache(maxsize=None)
def radix_schedule(n: int, radices: tuple[int, ...] = DEFAULT_RADICES
                   ) -> tuple[int, ...]:
    """Greedy largest-first factorisation of ``n`` into allowed radices.

    With 2 in ``radices`` every power of two factors; other lengths raise.
    """
    if n < 1:
        raise ValueError(f"FFT length must be >= 1, got {n}")
    schedule: list[int] = []
    m = n
    allowed = sorted(set(radices), reverse=True)
    while m > 1:
        for r in allowed:
            if m % r == 0:
                schedule.append(r)
                m //= r
                break
        else:
            raise ValueError(
                f"length {n} has no factorisation into radices {radices}")
    # Run the small residual radix (the "tail") FIRST, while the butterfly
    # width h = M/r is still large: a radix-2 stage at h=1 degenerates to
    # scalar-wide vectors (slow on the VPU and in interpret mode alike),
    # whereas at h = N/2 it is as lane-parallel as every other stage.
    return tuple(sorted(schedule))


def stage_count(n: int, radices: tuple[int, ...] = DEFAULT_RADICES) -> int:
    """Stages a single fused kernel runs for length ``n``."""
    return len(radix_schedule(n, radices))


def mixed_radix_flop_count(n: int,
                           radices: tuple[int, ...] = DEFAULT_RADICES,
                           batch: int = 1) -> float:
    """Real FLOPs actually executed by the mixed-radix engine.

    Lower than the paper's 5 N log2 N reporting convention (Eq. 5) for
    radices above 2 — higher radices do the same transform with fewer
    twiddle multiplies.
    """
    per_point = sum(STAGE_FLOPS_PER_POINT[r] for r in radix_schedule(n, radices))
    return per_point * n * batch


def r2c_flop_count(n: int, radices: tuple[int, ...] = DEFAULT_RADICES,
                   batch: int = 1) -> float:
    """FLOPs of the packed R2C path: an N/2 complex FFT plus the split."""
    m = n // 2
    if m < 1:
        return 0.0
    inner = mixed_radix_flop_count(m, radices) if m > 1 else 0.0
    return (inner + 10.0 * (m + 1)) * batch


@functools.lru_cache(maxsize=None)
def dft_matrix(r: int, inverse: bool = False) -> np.ndarray:
    """The (r, r) DFT matrix of one radix-r butterfly (complex128)."""
    sign = 1.0 if inverse else -1.0
    k = np.arange(r)
    return np.exp(sign * 2j * np.pi * np.outer(k, k) / r)


@functools.lru_cache(maxsize=None)
def stage_twiddles(n: int, radices: tuple[int, ...] = DEFAULT_RADICES,
                   inverse: bool = False) -> tuple[np.ndarray, ...]:
    """Per-stage twiddle tables: one (r-1, h) complex128 array per stage.

    Stage with current sub-length M and h = M/r: branch k (1..r-1) gets
    w_M^{k*j}, j in [0, h).  Computed once per (n, radices, sign) and
    embedded as constants by the tracing consumer.
    """
    sign = 1.0 if inverse else -1.0
    tables: list[np.ndarray] = []
    m = n
    for r in radix_schedule(n, radices):
        h = m // r
        j = np.arange(h)
        k = np.arange(1, r)
        tables.append(np.exp(sign * 2j * np.pi * np.outer(k, j) / m))
        m = h
    return tuple(tables)


@functools.lru_cache(maxsize=None)
def packed_stage_twiddles(n: int,
                          radices: tuple[int, ...] = DEFAULT_RADICES
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Forward twiddles packed for the Pallas kernel: (rows, n) re/im f32.

    Row layout: stages in execution order, branches k = 1..r-1 within a
    stage; each row holds its h = M/r twiddles left-aligned, zero-padded
    to n.  The kernel slices ``[row, :h]`` at statically known offsets.
    Inverse transforms conjugate in-kernel (negate the im plane).
    """
    tables = stage_twiddles(n, radices, False)
    rows = sum(t.shape[0] for t in tables)
    re = np.zeros((max(rows, 1), n), np.float32)
    im = np.zeros((max(rows, 1), n), np.float32)
    row = 0
    for t in tables:
        k, h = t.shape
        re[row:row + k, :h] = t.real
        im[row:row + k, :h] = t.imag
        row += k
    return re, im


@functools.lru_cache(maxsize=None)
def rfft_split_twiddles(n: int) -> np.ndarray:
    """W[k] = exp(-2*pi*i*k/n), k = 0..n/2 — the R2C split / C2R merge
    factors (complex128; cast to the working dtype at trace time)."""
    k = np.arange(n // 2 + 1)
    return np.exp(-2j * np.pi * k / n)
