"""N-D plan-graph FFT execution engine — transpose-free multi-dim plans.

The paper treats cuFFT's N-D transforms as factored 1-D passes (Sec. 2.1,
Eq. 2); what it does *not* spell out is the memory cost of the hand-off
between axes.  The naive per-axis chain (``moveaxis`` + 1-D FFT +
``moveaxis`` back) pays three HBM round trips of the whole batch per
non-contiguous axis.  This module compiles an (axis-lengths, kind) spec
into a **plan graph**: a minimal sequence of batched kernel passes where
the hand-off transpose rides the FFT pass as a fused epilogue
(``repro.kernels.fft`` transposed-write kernels), and only axes that
cannot fuse (non-pow2 / Bluestein) get an explicit tiled-transpose node.

Node vocabulary (each node = one batched device pass unless noted):

  fft_t       fused C2C FFT + transposed write      1 HBM pass
  rfft_t      fused R2C + transposed write          1 HBM pass
  fft1d       1-D routed plan on the last axis      plan.passes HBM passes
  transpose   tiled last-two-axes transpose         1 HBM pass

Execution model: the k transform axes are kept trailing; every fused pass
views the tensor as (B, R, C) with C the current last axis, transforms C
and writes (B, C, R) — a cyclic rotation of the transform block.  After k
fused passes every axis has been transformed *and* the original order is
restored, so a pow2 2-D FFT costs exactly 2 passes (vs 4+ for the chain)
and a pow2 3-D FFT costs 3.

The 1-D case degenerates to :func:`repro.fft.plan.plan_for_length`, so
consumers (pipeline, serving, distributed) can route every transform —
any rank — through this one entry point.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax

from repro.fft.plan import (MAX_KERNEL_N, FFTPlan, _is_pow2,
                            plan_with_config)
from repro.fft import plan as _plan_mod
from repro.tune.config import KernelConfig
from repro.tune.context import plan_config as _tuned_plan_config


@dataclasses.dataclass(frozen=True)
class PassNode:
    """One node of the plan graph: a single batched device pass."""

    op: str                     # "fft_t" | "rfft_t" | "fft1d" | "transpose"
    n: int = 0                  # transform length along the processed axis
    kind: str = "c2c"           # transform kind of this pass
    hbm_passes: int = 1         # HBM read+write round trips of the batch
    algorithm: str = "fused"    # 1-D algorithm for fft1d nodes
    stages: int = 0             # butterfly stages the pass runs in VMEM


@dataclasses.dataclass(frozen=True)
class NDPlan:
    """A compiled N-D plan: node sequence + analytic pass accounting.

    ``passes`` is the plan graph's total HBM round trips; ``chain_passes``
    is what the per-axis ``moveaxis`` chain would have paid for the same
    spec (the pre-plan-graph implementation) — the benchmark's before /
    after numbers come straight from these two fields.
    """

    shape: tuple[int, ...]      # transform-axes lengths, in axis order
    kind: str                   # "c2c" | "r2c"
    nodes: tuple[PassNode, ...]
    passes: int
    chain_passes: int
    stages: int                 # total butterfly stages across all passes
    out_shape: tuple[int, ...]  # transform-axes lengths of the output
    fn: Callable[[jax.Array], jax.Array]

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.fn(x)

    @property
    def n(self) -> int:
        return math.prod(self.shape)

    @property
    def algorithm(self) -> str:
        return "plan-graph" if len(self.shape) > 1 else self.nodes[0].algorithm


def _fusable_c2c(n: int) -> bool:
    return _is_pow2(n) and 1 < n <= MAX_KERNEL_N


def _fusable_r2c(n: int) -> bool:
    return _is_pow2(n) and 4 <= n and n // 2 <= MAX_KERNEL_N


def _axis_kind(kind: str, is_last_axis: bool) -> str:
    return "r2c" if (kind == "r2c" and is_last_axis) else "c2c"


def plan_nd(shape: tuple[int, ...], kind: str = "c2c") -> NDPlan:
    """Compile (and memoise) the plan graph for transform-axes ``shape``.

    ``kind="r2c"`` runs R2C on the last axis and C2C on the rest (the
    numpy ``rfftn`` convention).  Transform axes must be the trailing axes
    of the operand, in order; :mod:`repro.fft.multidim` normalises
    arbitrary ``axes=`` arguments before calling in.

    The active tuning context supplies a tuned kernel config for the
    whole graph (one consult per distinct (shape, kind), memoised by the
    context); the disabled/untuned path compiles the heuristic graph.
    """
    shape = tuple(shape)
    return _plan_nd(shape, kind, _tuned_plan_config(shape, kind))


def plan_nd_with_config(shape: tuple[int, ...], kind: str = "c2c",
                        config=None) -> NDPlan:
    """The plan graph for an *explicit* config, bypassing the tuning
    context — ``config=None`` is the pure heuristic graph (what the
    serving layer's degraded boost-heuristic rung executes)."""
    if config is not None and config.is_heuristic:
        config = None
    return _plan_nd(tuple(shape), kind, config)


@functools.lru_cache(maxsize=None)
def _plan_nd(shape: tuple[int, ...], kind: str,
             config: KernelConfig | None = None) -> NDPlan:
    if kind not in ("c2c", "r2c"):
        raise ValueError(f"unknown N-D transform kind {kind!r}")
    if not shape or any(n < 1 for n in shape):
        raise ValueError(f"bad transform shape {shape!r}")
    if len(shape) == 1:
        return _plan_1d(shape, kind, config)

    nodes: list[PassNode] = []
    chain = 0
    # Axes are processed last-first; each fused pass rotates the transform
    # block one step right, so after k passes the order is restored.
    for step, axis in enumerate(reversed(range(len(shape)))):
        na = shape[axis]
        akind = _axis_kind(kind, axis == len(shape) - 1)
        plan1 = plan_with_config(na, akind, config) if na > 1 else None
        # What the per-axis moveaxis chain paid: the 1-D plan's passes,
        # plus a moveaxis there and back for every non-trailing axis.
        chain += (plan1.passes if plan1 else 1) + (0 if step == 0 else 2)
        if na == 1:
            nodes.append(PassNode("transpose", n=1, kind=akind))
            continue
        if akind == "r2c" and _fusable_r2c(na):
            nodes.append(PassNode("rfft_t", n=na, kind="r2c",
                                  stages=plan1.stages))
        elif akind == "c2c" and _fusable_c2c(na):
            nodes.append(PassNode("fft_t", n=na, kind="c2c",
                                  stages=plan1.stages))
        else:
            # Non-fusable axis (Bluestein, long four-step, tiny r2c): run
            # the routed 1-D plan in place, then rotate with an explicit
            # tiled transpose so the cycle invariant holds.
            nodes.append(PassNode("fft1d", n=na, kind=akind,
                                  hbm_passes=plan1.passes,
                                  algorithm=plan1.algorithm,
                                  stages=plan1.stages))
            nodes.append(PassNode("transpose", n=na, kind=akind))

    out_shape = tuple(
        n // 2 + 1 if (kind == "r2c" and i == len(shape) - 1 and n > 1)
        else n
        for i, n in enumerate(shape))
    node_t = tuple(nodes)
    return NDPlan(
        shape=shape, kind=kind, nodes=node_t,
        passes=sum(nd.hbm_passes for nd in node_t),
        chain_passes=chain,
        stages=sum(nd.stages for nd in node_t),
        out_shape=out_shape,
        fn=functools.partial(_run_graph, shape=shape, kind=kind,
                             nodes=node_t, config=config),
    )


def _plan_1d(shape: tuple[int, ...], kind: str,
             config: KernelConfig | None = None) -> NDPlan:
    """Rank-1 spec: wrap the 1-D planner as a single-node graph."""
    (n,) = shape
    plan1: FFTPlan = plan_with_config(n, kind, config)
    node = PassNode("fft1d", n=n, kind=kind, hbm_passes=plan1.passes,
                    algorithm=plan1.algorithm, stages=plan1.stages)
    out = (n // 2 + 1 if kind == "r2c" and n > 1 else n,)
    return NDPlan(shape=shape, kind=kind, nodes=(node,),
                  passes=plan1.passes, chain_passes=plan1.passes,
                  stages=plan1.stages, out_shape=out, fn=plan1.fn)


def _run_graph(x: jax.Array, *, shape: tuple[int, ...], kind: str,
               nodes: tuple[PassNode, ...],
               config: KernelConfig | None = None) -> jax.Array:
    """Execute a compiled node sequence on ``x`` (transform axes trailing).

    The node executors are the routed pass primitives in
    :mod:`repro.fft.plan` (``fft_transposed`` / ``rfft_transposed`` /
    ``tiled_transpose``), which read the monkeypatchable kernel hooks at
    trace time — tests count kernel launches per pass exactly as they do
    for 1-D plans.
    """
    k = len(shape)
    if x.shape[-k:] != shape:
        raise ValueError(
            f"operand trailing axes {x.shape[-k:]} != plan shape {shape}")
    lead = x.shape[:-k]
    cur = list(shape)
    b = math.prod(lead) if lead else 1
    for node in nodes:
        r = math.prod(cur[:-1])
        c = cur[-1]
        if node.op == "fft_t":
            y = _plan_mod.fft_transposed(x.reshape(b, r, c), config=config)
            cur = [cur[-1]] + cur[:-1]
        elif node.op == "rfft_t":
            y = _plan_mod.rfft_transposed(x.reshape(b, r, c), config)
            cur = [c // 2 + 1] + cur[:-1]
        elif node.op == "fft1d":
            plan1 = plan_with_config(c, node.kind, config)
            y = plan1(x.reshape(b, r, c))
            cur = cur[:-1] + [y.shape[-1]]
            x = y
            continue
        elif node.op == "transpose":
            y = _plan_mod.tiled_transpose(x.reshape(b, r, c))
            cur = [cur[-1]] + cur[:-1]
        else:                                         # pragma: no cover
            raise AssertionError(f"unknown node op {node.op!r}")
        x = y
    return x.reshape(*lead, *cur)


def nd_pass_summary(shape: tuple[int, ...], kind: str = "c2c"
                    ) -> tuple[int, int, int]:
    """(plan passes, per-axis-chain passes, total stages) for a spec.

    The analytic cost model (``repro.core.workloads.fft_workload``) calls
    this instead of building execution closures itself.
    """
    plan = plan_nd(tuple(shape), kind)
    return plan.passes, plan.chain_passes, plan.stages
