"""Batched overlap-save segmented FFT convolution engine.

Long-signal convolution against a bank of T short filters is the workload
that dominates Fourier-domain acceleration searches (White, Adámek &
Armour 2022): every dedispersed spectrum is matched-filtered by every
acceleration template.  Running it as one pad-to-full-length FFT per
filter wastes both FLOPs and HBM traffic; the classical fix is
**overlap-save**: split the signal into length-``nfft`` segments that
overlap by ``taps - 1`` points, convolve each segment circularly in the
Fourier domain, and discard the wrapped prefix of every segment.

Three cost levers, mirroring the rest of the FFT substrate:

* **Segment-length auto-selection** (:func:`select_nfft`): the cost model
  charges each candidate pow2 segment its mixed-radix FLOPs
  (``repro.fft.radix``) plus a memory-bound traffic term, per *valid*
  output point — long segments amortise the ``taps - 1`` overlap, short
  segments keep the per-pass FFT cheap; the optimum sits in between.
* **Cached filter spectra**: the bank's zero-padded forward FFTs are
  computed host-side with numpy and memoised per (bank key, nfft) —
  exactly the Bluestein chirp/filter-spectrum pattern
  (``repro.fft.bluestein._chirp_factors``), so a serving process
  materialises each bank's spectra once, ever.
* **Fused multiply epilogue**: the forward segment FFT routes through
  :func:`repro.fft.plan.fft_mul`, which applies the whole (T, nfft)
  complex-multiply bank *inside* the forward kernel
  (``fft_kernel_c2c_mul``).  The matched-filter plane therefore costs one
  forward pass plus T inverse passes of the segment batch, with **zero**
  standalone multiply passes (the fallback path pays one XLA multiply).

``conv_plan`` exposes the pass/traffic accounting (overlap-save vs the
direct pad-to-full-length plan) that ``core.workloads.conv_workload`` and
``benchmarks/run.py fdas`` consume.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.fft.radix import (DEFAULT_RADICES, is_pow2,
                             mixed_radix_flop_count, next_pow2)

#: Complex bytes per point at the engine's working precision (complex64).
_ELEM = 8

#: Flop-equivalent weight of one complex point of HBM traffic in the
#: segment-selection cost (the engine is memory-bound, paper Sec. 5).
_MEM_WEIGHT = 16.0


# ---------------------------------------------------------------------------
# Segment-length selection (cost model)
# ---------------------------------------------------------------------------

def _segment_cost(nfft: int, taps: int, templates: int,
                  radices: tuple[int, ...]) -> float:
    """Modelled cost per valid output point of one overlap-save segment.

    One forward FFT feeds all T filters (the fused epilogue), then each
    filter pays an inverse FFT and a 6-flop/point complex multiply; the
    traffic term charges the forward read, the T-plane product write, and
    the inverse read+write (``_MEM_WEIGHT`` flops per complex point).
    """
    step = nfft - taps + 1
    flops = ((1 + templates) * mixed_radix_flop_count(nfft, radices)
             + 6.0 * templates * nfft)
    traffic_pts = nfft * (1.0 + 3.0 * templates)
    return (flops + _MEM_WEIGHT * traffic_pts) / step


@functools.lru_cache(maxsize=None)
def select_nfft(taps: int, n: int, templates: int = 1,
                radices: tuple[int, ...] = DEFAULT_RADICES) -> int:
    """Pick the pow2 segment length minimising modelled cost per output.

    Candidates run from the smallest segment with a useful valid region
    (``2 * taps`` rounded up) to one covering the whole padded signal —
    a single segment degenerates overlap-save into the direct method, so
    the selection can never do worse than either endpoint.
    """
    from repro.fft.plan import MAX_KERNEL_N      # lazy: avoids import cycle

    if taps < 1:
        raise ValueError(f"filter length must be >= 1, got {taps}")
    if n < 1:
        raise ValueError(f"signal length must be >= 1, got {n}")
    lo = next_pow2(max(2 * taps, 16))
    hi = max(lo, next_pow2(n + taps - 1))
    if lo <= MAX_KERNEL_N:
        # Prefer segments the fused multiply-epilogue kernel can serve;
        # only filters too long for any single-pass segment go beyond.
        hi = min(hi, MAX_KERNEL_N)
    best, best_cost = lo, float("inf")
    nfft = lo
    while nfft <= hi:
        cost = _segment_cost(nfft, taps, templates, radices)
        if cost < best_cost:
            best, best_cost = nfft, cost
        nfft *= 2
    return best


# ---------------------------------------------------------------------------
# Plan: segmentation + pass/traffic accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Accounting for one (signal length, filter bank) overlap-save plan.

    ``forward_passes``/``inverse_passes`` count HBM round trips of the
    segment batch: the fused multiply epilogue keeps the forward side at
    ONE pass regardless of T, and each template's plane pays one inverse
    pass.  ``traffic_ratio`` is direct-method bytes over overlap-save
    bytes — the before/after figure ``BENCH_fdas.json`` persists.
    """

    n: int                      # input points per row
    taps: int                   # filter length
    templates: int              # bank size T
    nfft: int                   # segment FFT length (pow2)
    step: int                   # valid output points per segment
    n_segments: int
    out_len: int                # full linear convolution length
    forward_passes: int         # 1: fused FFT + T-filter multiply epilogue
    inverse_passes: int         # T: one inverse pass per template plane
    os_bytes: float             # overlap-save HBM bytes per row
    direct_bytes: float         # pad-to-full-length method, per row
    fused: bool = True          # segment fits the multiply-epilogue kernel

    @property
    def traffic_ratio(self) -> float:
        return self.direct_bytes / self.os_bytes

    @property
    def passes_per_template(self) -> float:
        """Amortised kernel passes each template costs (forward shared)."""
        return self.inverse_passes / self.templates + (
            self.forward_passes / self.templates)


def conv_plan(n: int, taps: int, templates: int = 1, nfft: int = 0,
              radices: tuple[int, ...] = DEFAULT_RADICES) -> ConvPlan:
    """Build (or return the memoised) overlap-save plan.

    ``nfft=0`` defers the segment length to the active tuning context
    (``repro.tune``: key ``(device, (n, taps, templates), "conv")``) and
    falls back to the :func:`select_nfft` cost model when the key is
    untuned or tuning is disabled.  An explicit ``nfft`` must be a power
    of two no shorter than the filter — a filter longer than its segment
    has no valid output points.
    """
    if nfft == 0:
        from repro.tune.context import plan_config
        cfg = plan_config((n, taps, templates), "conv")
        if (cfg is not None and cfg.segment and is_pow2(cfg.segment)
                and cfg.segment >= taps):
            nfft = cfg.segment
    return _conv_plan(n, taps, templates, nfft, radices)


@functools.lru_cache(maxsize=None)
def _conv_plan(n: int, taps: int, templates: int = 1, nfft: int = 0,
               radices: tuple[int, ...] = DEFAULT_RADICES) -> ConvPlan:
    from repro.fft.plan import (MAX_KERNEL_N,    # lazy: avoids import cycle
                                plan_for_length)

    if templates < 1:
        raise ValueError(f"filter bank needs >= 1 filters, got {templates}")
    if nfft == 0:
        nfft = select_nfft(taps, n, templates, radices)
    if not is_pow2(nfft):
        raise ValueError(f"segment length must be a power of two, got {nfft}")
    if nfft < taps:
        raise ValueError(
            f"filter ({taps} taps) is longer than the segment (nfft={nfft}); "
            "overlap-save needs nfft >= taps (pass nfft=0 to auto-select)")
    step = nfft - taps + 1
    out_len = n + taps - 1
    n_segments = max(math.ceil(out_len / step), 1)
    t = templates
    seg_pts = n_segments * nfft

    # Segments beyond the single-pass kernel limit cannot fuse the bank
    # multiply (plan.fft_mul falls back to routed FFT + one XLA multiply),
    # so the accounting must charge the plan that actually executes.
    fused = nfft <= MAX_KERNEL_N
    seg_passes = plan_for_length(nfft).passes    # 1 in the fused regime
    if fused:
        forward_passes, inverse_passes = 1, t
        # Fused forward pass (read segments, write the T-plane product),
        # T inverse passes (read+write), and the assemble/trim pass.
        os_bytes = _ELEM * (seg_pts * (1 + t)
                            + 2.0 * t * seg_pts
                            + t * seg_pts + t * out_len)
    else:
        forward_passes = seg_passes + 1          # + standalone multiply
        inverse_passes = t * seg_passes
        os_bytes = _ELEM * (2.0 * seg_pts * seg_passes
                            + seg_pts * (1 + t)  # standalone multiply pass
                            + 2.0 * t * seg_pts * seg_passes
                            + t * seg_pts + t * out_len)

    # Direct method: pad to the full pow2 length M, forward FFT, a
    # STANDALONE multiply pass per bank, T inverse FFTs, trim.
    m = next_pow2(out_len)
    m_passes = plan_for_length(m).passes
    direct_bytes = _ELEM * (2.0 * m * m_passes     # forward FFT passes
                            + m * (1 + t)          # standalone multiply
                            + 2.0 * t * m * m_passes   # inverse FFT passes
                            + t * m + t * out_len)     # trim
    return ConvPlan(n=n, taps=taps, templates=t, nfft=nfft, step=step,
                    n_segments=n_segments, out_len=out_len,
                    forward_passes=forward_passes,
                    inverse_passes=inverse_passes,
                    os_bytes=os_bytes, direct_bytes=direct_bytes,
                    fused=fused)


# ---------------------------------------------------------------------------
# Filter-spectrum cache (the Bluestein pattern, per bank)
# ---------------------------------------------------------------------------

_SPECTRA_CACHE: dict[tuple, np.ndarray] = {}
_SPECTRA_BUILDS = 0            # test hook: numpy FFTs actually executed


def cached_filter_spectra(key, filters: np.ndarray, nfft: int) -> np.ndarray:
    """(T, nfft) forward spectra of a zero-padded bank, memoised per key.

    ``key`` must uniquely identify the bank's *values* (e.g. the template
    bank's defining parameters) — the cache never hashes array contents.
    Computed host-side with numpy (complex128) and embedded as constants
    at trace time, exactly like the Bluestein chirp/filter cache.
    """
    global _SPECTRA_BUILDS
    cache_key = (key, int(nfft))
    hit = _SPECTRA_CACHE.get(cache_key)
    if hit is not None:
        return hit
    spectra = _bank_spectra(np.asarray(filters), nfft)
    _SPECTRA_BUILDS += 1
    _SPECTRA_CACHE[cache_key] = spectra
    return spectra


def _bank_spectra(filters: np.ndarray, nfft: int) -> np.ndarray:
    filters = np.atleast_2d(filters)
    t, taps = filters.shape
    if taps > nfft:
        raise ValueError(
            f"filter ({taps} taps) is longer than the segment (nfft={nfft})")
    padded = np.zeros((t, nfft), np.complex128)
    padded[:, :taps] = filters
    return np.fft.fft(padded, axis=-1)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def overlap_save_conv(x: jax.Array, filters, *, nfft: int | None = None,
                      cache_key=None) -> jax.Array:
    """Full linear convolution of each row with a T-filter bank.

    ``x`` is (..., n) real or complex; ``filters`` is a (T, taps) (or
    (taps,)) host-side array of time-domain taps.  Returns the full
    convolution, shape (..., T, n + taps - 1) — row r of the output block
    equals ``jnp.convolve(x, filters[r])``.

    The forward segment FFT carries the whole bank multiply as a fused
    kernel epilogue (:func:`repro.fft.plan.fft_mul`), the T product
    planes share one batched inverse pass, and the filter spectra are
    cached per (``cache_key``, nfft) when a key is given.

    Non-pow2 signal lengths need no special casing: segments are always
    pow2 (padded with zeros past the signal end), so every FFT pass stays
    on the fused-kernel route.
    """
    from repro.fft import plan as _plan_mod      # lazy: avoids import cycle

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    filters_np = np.atleast_2d(np.asarray(filters))
    t, taps = filters_np.shape
    n = x.shape[-1]
    plan = conv_plan(n, taps, t, 0 if nfft is None else int(nfft))
    nfft, step, nseg = plan.nfft, plan.step, plan.n_segments

    if cache_key is not None:
        spectra = cached_filter_spectra(cache_key, filters_np, nfft)
    else:
        spectra = _bank_spectra(filters_np, nfft)

    # Segment the (taps-1)-front-padded signal into overlapping windows.
    pad_front = taps - 1
    total = (nseg - 1) * step + nfft
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1)
                 + [(pad_front, total - pad_front - n)])
    idx = (np.arange(nseg)[:, None] * step
           + np.arange(nfft)[None, :])               # (nseg, nfft) windows
    segs = xp[..., idx]                              # (..., nseg, nfft)

    # Forward FFT + fused bank multiply: one pass, T product planes.
    prod = _plan_mod.fft_mul(segs, spectra)          # (..., nseg, T, nfft)
    # One batched inverse pass over all T planes.
    y = _plan_mod.pow2_fft(prod, inverse=True)
    # Discard each segment's wrapped prefix, assemble the valid runs.
    valid = jnp.moveaxis(y[..., taps - 1:], -3, -2)  # (..., T, nseg, step)
    out = valid.reshape(*valid.shape[:-2], nseg * step)
    return out[..., :plan.out_len]
