"""The paper's demonstration pipeline (Sec. 5.3): pulsar search stages.

  FFT -> power spectrum -> mean/std normalisation -> harmonic sum -> S/N

The paper uses this pipeline to show that locking the clock to the mean
optimal frequency *only around the FFT call* yields the share-weighted
energy saving (Table 4).  Here each stage is a pure-JAX function (with
Pallas kernel variants in ``repro.kernels``), and the whole pipeline is
jittable end to end.  ``stage_profiles`` exports the per-stage workload
profiles that ``repro.core.scheduler`` consumes to build the clock plan.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.fft.plan_nd import plan_nd


MAX_HARMONICS = 32


def power_spectrum(spectrum: jax.Array, n: int | None = None) -> jax.Array:
    """|X|^2 / N of an FFT output (batch, n).

    ``n`` overrides the normalisation length — pass the original transform
    length when ``spectrum`` is an R2C half-spectrum (n/2+1 bins).
    """
    if n is None:
        n = spectrum.shape[-1]
    return (spectrum.real**2 + spectrum.imag**2) / n


def spectrum_stats(power: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-spectrum mean and std (the pipeline's normalisation stage)."""
    mean = jnp.mean(power, axis=-1, keepdims=True)
    std = jnp.std(power, axis=-1, keepdims=True)
    return mean, std


def harmonic_sum(power: jax.Array, n_harmonics: int = MAX_HARMONICS
                 ) -> jax.Array:
    """Harmonic-summed spectra: S_h[k] = sum_{j=1..h} P[j*k].

    Returns (batch, n_levels, n) where level i holds h = 2^i harmonics
    (h in {1, 2, 4, ..., n_harmonics}), the standard levels used in
    Fourier-domain pulsar searches [Adamek & Armour 2019].
    """
    n = power.shape[-1]
    levels = int(math.log2(n_harmonics)) + 1
    outs = []
    acc = power
    h = 1
    outs.append(acc)
    for _ in range(levels - 1):
        h *= 2
        # add harmonics j = h/2+1 .. h in one shot via gathered indices
        js = jnp.arange(h // 2 + 1, h + 1)
        k = jnp.arange(n)
        idx = jnp.minimum(js[:, None] * k[None, :], n - 1)   # (h/2, n)
        acc = acc + jnp.sum(power[..., idx], axis=-2)
        outs.append(acc)
    return jnp.stack(outs, axis=-2)                          # (batch, L, n)


def candidate_snr(hsums: jax.Array, mean: jax.Array, std: jax.Array
                  ) -> jax.Array:
    """S/N per harmonic level: (S_h - h*mu) / (sqrt(h)*sigma)."""
    levels = hsums.shape[-2]
    h = (2.0 ** jnp.arange(levels))[:, None]
    return (hsums - h * mean[..., None, :]) / (jnp.sqrt(h) * std[..., None, :])


@functools.partial(jax.jit, static_argnames=("n_harmonics", "real_input"))
def pulsar_pipeline(x: jax.Array, n_harmonics: int = MAX_HARMONICS,
                    real_input: bool = False) -> jax.Array:
    """End-to-end pipeline on a batch of time series (batch, n).

    Returns the S/N spectra (batch, levels, n); a search would threshold
    these for candidates.  ``real_input=True`` runs the R2C plan instead —
    telescope voltages are real, so the FFT stage does half the work and
    the downstream stages see the n/2+1-bin half-spectrum (Sec. 5.3's
    pipeline, at the cost model's ``r2c`` accounting).
    """
    n = x.shape[-1]
    # Route through the plan graph (rank-1 degenerates to the 1-D planner,
    # so kernel routing and pass accounting stay identical).
    if real_input:
        plan = plan_nd((n,), "r2c")
        spec = plan(jnp.real(x).astype(jnp.float32))
    else:
        plan = plan_nd((n,), "c2c")
        spec = plan(x.astype(jnp.complex64))
    p = power_spectrum(spec, n)
    mean, std = spectrum_stats(p)
    hs = harmonic_sum(p, n_harmonics)
    return candidate_snr(hs, mean, std)


# ---------------------------------------------------------------------------
# DVFS integration: per-stage workload profiles for the clock scheduler.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineShape:
    batch: int
    n: int
    n_harmonics: int = MAX_HARMONICS
    elem_bytes: int = 8          # complex64 input
    real_input: bool = False     # R2C front end: half-spectrum downstream


def stage_profiles(shape: PipelineShape, device: DeviceSpec
                   ) -> list[WorkloadProfile]:
    """Analytic traffic/FLOP model of each stage, feeding the scheduler.

    Mirrors the paper's Sec. 5.3 accounting: with more harmonics summed,
    the non-FFT share grows and the composite saving shrinks (Table 4).
    With ``real_input`` the FFT stage uses the R2C cost model (half the
    FLOPs/traffic, Eq. 5/6 at N/2) and every downstream stage processes
    the n/2+1-bin half-spectrum.
    """
    from repro.core.workloads import FFTCase, fft_workload

    b, n = shape.batch, shape.n
    transform = "r2c" if shape.real_input else "c2c"
    elem = shape.elem_bytes // 2 if shape.real_input else shape.elem_bytes
    # Downstream stages see n bins (C2C) or n/2+1 bins (R2C half-spectrum).
    data = float(b * (n // 2 + 1 if shape.real_input else n))

    fft_prof = fft_workload(
        FFTCase(n=n, precision="fp32",
                batch_bytes=float(b * n) * elem,
                transform=transform, name="fft"),
        device,
    )

    def simple(name: str, bytes_moved: float, flops: float,
               issue_eff: float = 0.6) -> WorkloadProfile:
        return WorkloadProfile(
            name=name,
            t_mem=bytes_moved / device.hbm_bandwidth,
            t_issue=flops / (device.peak_flops * issue_eff),
            t_compute=flops / device.peak_flops,
            flops=flops,
        )

    # |X|^2: read c64, write f32; 3 flops/point.
    power = simple("power", data * (8 + 4), 3 * data)
    # mean/std: read f32, two reduction passes fused into one read.
    stats = simple("stats", data * 4, 4 * data)
    # harmonic sum: each doubling reads the base spectrum h/2 more times
    # (gather traffic) + writes one level.
    levels = int(math.log2(shape.n_harmonics))
    gather_reads = sum(2**i for i in range(levels))          # 1+2+...  ~ h-1
    hsum_bytes = data * 4 * (gather_reads + levels + 1)
    hsum = simple("harmonic_sum", hsum_bytes, data * (shape.n_harmonics - 1),
                  issue_eff=0.3)
    # S/N: read levels+stats, write levels.
    snr = simple("snr", data * 4 * 2 * (levels + 1), 4 * data * (levels + 1))
    return [fft_prof, power, stats, hsum, snr]


def total_profile(shape: PipelineShape, device: DeviceSpec) -> WorkloadProfile:
    """All five stages merged into one profile for service-level accounting.

    Component times sum across stages (stages run back to back, so the
    pipeline's memory time is the sum of stage memory times, etc.).  The
    merged profile slightly under-reports time at low clocks relative to
    evaluating stages separately — each stage's bound is taken after the
    merge — but keeps the serving cache to one sweep per pipeline shape.
    """
    profs = stage_profiles(shape, device)
    t_mem = sum(p.t_mem for p in profs)
    # Contention inflates t_mem per stage; the merged equivalent is the
    # t_mem-weighted average of the stage contention terms.
    contention = (sum(p.contention * p.t_mem for p in profs) / t_mem
                  if t_mem > 0 else 0.0)
    return WorkloadProfile(
        name=f"pulsar-b{shape.batch}-n{shape.n}-h{shape.n_harmonics}",
        t_mem=t_mem,
        t_issue=sum(p.t_issue for p in profs),
        t_cache=sum(p.t_cache for p in profs),
        t_compute=sum(p.t_compute for p in profs),
        t_coll=sum(p.t_coll for p in profs),
        contention=contention,
        flops=sum(p.flops for p in profs),
    )


def fft_time_share(shape: PipelineShape, device: DeviceSpec) -> float:
    """Fraction of pipeline time spent in the FFT at boost clock (Table 4)."""
    profs = stage_profiles(shape, device)
    times = [p._t0(device) for p in profs]
    return times[0] / sum(times)
