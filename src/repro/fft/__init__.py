"""TPU-native FFT substrate: the paper's workload, reimplemented openly.

  radix        mixed-radix schedules + memoised twiddle/split tables
  stockham     batched mixed-radix Stockham FFT (pure jnp, no gathers)
               with R2C/C2R real transforms
  bluestein    arbitrary-length FFT via chirp-z (paper Sec. 2.1),
               chirp/filter factors cached per length
  multidim     2-D/3-D transforms by axis decomposition (paper Eq. 2)
  plan_nd      N-D plan-graph compiler: fused transpose-write passes
  convolve     batched overlap-save segmented FFT convolution (filter
               banks as fused multiply epilogues, cached filter spectra)
  distributed  pencil/four-step FFT across a device mesh (shard_map)
  pipeline     the paper's pulsar-search pipeline (Sec. 5.3)
  plan         per-length algorithm choice + Pallas kernel routing
"""
from repro.fft.bluestein import bluestein_fft
from repro.fft.convolve import (ConvPlan, conv_plan, overlap_save_conv,
                                select_nfft)
from repro.fft.multidim import fft2, fftn, rfft2, rfftn
from repro.fft.stockham import fft, ifft, irfft, rfft
from repro.fft.plan import (fft_mul, plan_for_length, plan_with_config,
                            pow2_fft, FFTPlan)
from repro.fft.plan_nd import NDPlan, plan_nd

__all__ = ["fft", "ifft", "rfft", "irfft", "fft2", "rfft2", "fftn",
           "rfftn", "bluestein_fft", "plan_for_length", "plan_with_config",
           "pow2_fft", "fft_mul", "FFTPlan", "NDPlan", "plan_nd",
           "ConvPlan", "conv_plan", "overlap_save_conv", "select_nfft"]
