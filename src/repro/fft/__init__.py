"""TPU-native FFT substrate: the paper's workload, reimplemented openly.

  stockham     batched radix-2 Stockham autosort FFT (pure jnp, no gathers)
  bluestein    arbitrary-length FFT via chirp-z (paper Sec. 2.1)
  multidim     2-D/3-D transforms by axis decomposition (paper Eq. 2)
  distributed  pencil/four-step FFT across a device mesh (shard_map)
  pipeline     the paper's pulsar-search pipeline (Sec. 5.3)
"""
from repro.fft.bluestein import bluestein_fft
from repro.fft.multidim import fft2
from repro.fft.stockham import fft, ifft
from repro.fft.plan import plan_for_length, FFTPlan

__all__ = ["fft", "ifft", "fft2", "bluestein_fft", "plan_for_length", "FFTPlan"]
