"""Bluestein (chirp-z) FFT for arbitrary lengths — paper Sec. 2.1.

cuFFT falls back to Bluestein's algorithm when the length has a prime
factor above 127; we use it for every non-power-of-two length, converting
one length-N DFT into power-of-two FFTs of length M >= 2N-1 plus pointwise
chirp multiplies.  This matches the paper's observation that Bluestein
lengths cost ~3x and use many kernels (their Sec. 4 notes eleven GPU
kernels for N=139^2).

Two cost levers over the naive formulation:

* the chirp AND the filter's spectrum ``fb = FFT(b)`` are precomputed with
  numpy and memoised per (length, direction) — rebuilding them per call
  (or per trace) is pure waste, and caching ``fb`` removes one of the
  three runtime FFTs outright (2 pow2 FFTs per call instead of 3);
* the two remaining pow2 FFTs route through :func:`repro.fft.plan.pow2_fft`
  and therefore execute the fused Pallas kernel (with pure-JAX fallback),
  exactly like every other plan's passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


@functools.lru_cache(maxsize=None)
def _chirp_factors(n: int, inverse: bool
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(chirp, fb): the length-N chirp and the FFT of the chirp filter.

    Computed once per (length, direction) with numpy (complex128) and
    embedded as constants at trace time — the filter FFT never runs on
    device.
    """
    m = _next_pow2(2 * n - 1)
    sign = 1.0 if inverse else -1.0
    k = np.arange(n)
    # exp(sign * i*pi*k^2/n); k^2 mod 2n keeps the argument small & exact.
    chirp = np.exp(sign * 1j * np.pi * ((k * k) % (2 * n)) / n)
    b = np.zeros(m, np.complex128)
    b[:n] = np.conj(chirp)
    b[m - n + 1:] = np.conj(chirp)[1:][::-1]
    return chirp, np.fft.fft(b)


@functools.partial(jax.jit, static_argnames=("inverse", "config"))
def bluestein_fft(x: jax.Array, *, inverse: bool = False,
                  config=None) -> jax.Array:
    """C2C DFT of arbitrary length along the last axis via chirp-z.

    ``config`` (a hashable :class:`repro.tune.KernelConfig`, static) rides
    into the two inner pow2 FFTs so tuned tiles/radices actually execute
    for Bluestein lengths too.
    """
    from repro.fft.plan import pow2_fft          # lazy: avoids import cycle

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    m = _next_pow2(2 * n - 1)
    chirp_np, fb_np = _chirp_factors(n, inverse)
    chirp = jnp.asarray(chirp_np).astype(x.dtype)
    fb = jnp.asarray(fb_np).astype(x.dtype)

    a = jnp.zeros((*x.shape[:-1], m), dtype=x.dtype).at[..., :n].set(x * chirp)
    fa = pow2_fft(a, config=config)
    conv = pow2_fft(fa * fb, inverse=True, config=config)
    out = conv[..., :n] * chirp
    if inverse:
        out = out / n
    return out
