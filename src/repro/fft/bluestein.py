"""Bluestein (chirp-z) FFT for arbitrary lengths — paper Sec. 2.1.

cuFFT falls back to Bluestein's algorithm when the length has a prime
factor above 127; we use it for every non-power-of-two length, converting
one length-N DFT into three power-of-two FFTs of length M >= 2N-1 plus
pointwise chirp multiplies.  This matches the paper's observation that
Bluestein lengths cost ~3x and use many kernels (their Sec. 4 notes eleven
GPU kernels for N=139^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.fft.stockham import _stockham_pow2


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("inverse",))
def bluestein_fft(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """C2C DFT of arbitrary length along the last axis via chirp-z."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    m = _next_pow2(2 * n - 1)
    sign = 1.0 if inverse else -1.0
    k = jnp.arange(n)
    # exp(sign * i*pi*k^2/n); k^2 mod 2n keeps the argument small & exact.
    chirp = jnp.exp(sign * 1j * jnp.pi * ((k * k) % (2 * n)) / n).astype(x.dtype)

    a = jnp.zeros((*x.shape[:-1], m), dtype=x.dtype).at[..., :n].set(x * chirp)
    b = jnp.zeros(m, dtype=x.dtype)
    b = b.at[:n].set(jnp.conj(chirp))
    b = b.at[m - n + 1:].set(jnp.conj(chirp)[1:][::-1])

    fa = _stockham_pow2(a)
    fb = _stockham_pow2(b)
    conv = _stockham_pow2(fa * fb, inverse=True)
    out = conv[..., :n] * chirp
    if inverse:
        out = out / n
    return out
