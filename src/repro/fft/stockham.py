"""Batched Stockham autosort FFT in pure JAX.

Why Stockham on TPU: the classic Cooley-Tukey in-place FFT needs a
bit-reversal permutation (a gather — expensive and layout-hostile on TPU).
The Stockham autosort formulation replaces every permutation with a
*reshape*: the transform carries a (L, M) factorisation of the length where
the L axis accumulates already-decided output bits in natural order.  All
data movement is therefore affine and XLA lowers each stage to elementwise
ops + reshapes — exactly what the VPU wants, and what the Pallas kernel in
``repro.kernels.fft`` tiles into VMEM.

The decimation-in-frequency radix-2 step for one length-M transform:

  out[2k]   = F_{M/2}(a + b)[k]               a = x[:M/2], b = x[M/2:]
  out[2k+1] = F_{M/2}((a - b) * w)[k]         w = exp(-2*pi*i*j/M)

Keeping X shaped (..., L, M): stage t stacks the new output bit in front of
the L axis, so after log2(N) stages L enumerates outputs in natural order.

Cost: 5 N log2 N real FLOPs — exactly the paper's Eq. (5) convention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@functools.partial(jax.jit, static_argnames=("inverse",))
def _stockham_pow2(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Radix-2 Stockham FFT along the last axis (power-of-two length)."""
    n = x.shape[-1]
    assert _is_pow2(n), n
    sign = 1.0 if inverse else -1.0
    batch = x.shape[:-1]
    y = x.reshape(*batch, 1, n)                     # (..., L=1, M=n)
    m = n
    l = 1
    while m > 1:
        h = m // 2
        a = y[..., :h]                              # (..., L, M/2)
        b = y[..., h:]
        w = jnp.exp(sign * 1j * jnp.pi * jnp.arange(h) / h).astype(x.dtype)
        even = a + b
        odd = (a - b) * w
        # New output bit is the LEAST significant of the undecided bits ->
        # stack it *before* L so the combined index is bit * L + l.
        y = jnp.stack([even, odd], axis=-3)         # (..., 2, L, M/2)
        y = y.reshape(*batch, 2 * l, h)
        l, m = 2 * l, h
    out = y.reshape(*batch, n)
    if inverse:
        out = out / n
    return out


def fft(x: jax.Array, axis: int = -1) -> jax.Array:
    """Forward C2C FFT along ``axis``; power-of-two lengths only.

    Non-power-of-two lengths are handled by :mod:`repro.fft.bluestein`
    (wired together in :mod:`repro.fft.plan`).
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if axis != -1 and axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
        return jnp.moveaxis(_stockham_pow2(x), -1, axis)
    return _stockham_pow2(x)


def ifft(x: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse C2C FFT along ``axis`` (normalised by 1/N)."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if axis != -1 and axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
        return jnp.moveaxis(_stockham_pow2(x, inverse=True), -1, axis)
    return _stockham_pow2(x, inverse=True)


def fft_flop_count(n: int, batch: int = 1) -> float:
    """5 N log2 N per transform — the paper's Eq. (5) accounting."""
    return 5.0 * n * math.log2(n) * batch
