"""Batched mixed-radix Stockham autosort FFT in pure JAX.

Why Stockham on TPU: the classic Cooley-Tukey in-place FFT needs a
bit-reversal permutation (a gather — expensive and layout-hostile on TPU).
The Stockham autosort formulation replaces every permutation with a
*reshape*: the transform carries a (L, M) factorisation of the length where
the L axis accumulates already-decided output digits in natural order.  All
data movement is therefore affine and XLA lowers each stage to elementwise
ops + reshapes — exactly what the VPU wants, and what the Pallas kernel in
``repro.kernels.fft`` tiles into VMEM.

The decimation-in-frequency radix-r step for one length-M transform
(h = M/r, x_p = x[p*h:(p+1)*h], omega_r = exp(-2*pi*i/r)):

  out[r*t + k] = F_h( (sum_p x_p * omega_r^{p*k}) * w^{k*j} )[t]
  w = exp(-2*pi*i/M)

Keeping X shaped (..., L, M): each stage stacks the new output digit in
front of the L axis (branch k lands at index k*L + l), so after the full
radix schedule L enumerates outputs in natural order.  A radix-4 stage
decides two bits at once — the (4, 2)-schedule halves the stage count of
the radix-2 engine; (8, 4, 2) cuts it to a third.

Twiddles come from :mod:`repro.fft.radix`'s per-length caches and are
embedded as constants at trace time — never recomputed inside a trace.

R2C packs N real points into an N/2 complex FFT plus an O(N) split pass
(~2x FLOP and HBM savings); C2R is the exact inverse (merge + N/2 inverse
FFT + interleave).

Cost: 5 N log2 N real FLOPs at radix 2 — the paper's Eq. (5) convention;
see :func:`repro.fft.radix.mixed_radix_flop_count` for executed counts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.fft.radix import (DEFAULT_RADICES, dft_matrix, radix_schedule,
                             rfft_split_twiddles, stage_twiddles)


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _as_complex(x: jax.Array) -> jax.Array:
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    return x


@functools.partial(jax.jit, static_argnames=("inverse", "radices"))
def _stockham_pow2(x: jax.Array, *, inverse: bool = False,
                   radices: tuple[int, ...] = DEFAULT_RADICES) -> jax.Array:
    """Mixed-radix Stockham FFT along the last axis (power-of-two length)."""
    n = x.shape[-1]
    assert _is_pow2(n), n
    if n == 1:
        return x
    batch = x.shape[:-1]
    y = x.reshape(*batch, 1, n)                     # (..., L=1, M=n)
    l, m = 1, n
    schedule = radix_schedule(n, radices)
    tables = stage_twiddles(n, radices, inverse)
    for r, tw in zip(schedule, tables):
        h = m // r
        dft = dft_matrix(r, inverse)
        parts = [y[..., p * h:(p + 1) * h] for p in range(r)]
        outs = []
        for k in range(r):
            acc = parts[0]                          # dft[0, k] == 1
            for p in range(1, r):
                acc = acc + parts[p] * complex(dft[p, k])
            if k:
                acc = acc * jnp.asarray(tw[k - 1]).astype(x.dtype)
            outs.append(acc)
        # Branch k is the LEAST significant undecided digit -> stack the
        # branches *before* L so the combined index is k * L + l.
        y = jnp.stack(outs, axis=-3).reshape(*batch, r * l, h)
        l, m = r * l, h
    out = y.reshape(*batch, n)
    if inverse:
        out = out / n
    return out


# ---------------------------------------------------------------------------
# R2C / C2R building blocks (shared with repro.fft.plan's routed paths)
# ---------------------------------------------------------------------------

def _pack_real(x: jax.Array) -> jax.Array:
    """(..., N) real -> (..., N/2) complex: z[j] = x[2j] + i*x[2j+1]."""
    n = x.shape[-1]
    v = x.reshape(*x.shape[:-1], n // 2, 2)
    return jax.lax.complex(v[..., 0], v[..., 1])


def _unpack_real(z: jax.Array) -> jax.Array:
    """Inverse of :func:`_pack_real`."""
    m = z.shape[-1]
    return jnp.stack([z.real, z.imag], axis=-1).reshape(*z.shape[:-1], 2 * m)


def _rfft_split(Z: jax.Array, n: int) -> jax.Array:
    """Post-pass of the packed R2C: (..., N/2) -> (..., N/2+1) spectrum."""
    m = n // 2
    Zf = jnp.concatenate([Z, Z[..., :1]], axis=-1)   # wrap Z[m] = Z[0]
    Zr = jnp.conj(Zf[..., ::-1])                     # conj(Z[m-k])
    w = jnp.asarray(rfft_split_twiddles(n)).astype(Z.dtype)
    return 0.5 * (Zf + Zr) - 0.5j * w * (Zf - Zr)


def _irfft_merge(X: jax.Array, n: int) -> jax.Array:
    """Pre-pass of the packed C2R: (..., N/2+1) -> (..., N/2) packed Z."""
    m = n // 2
    Xr = jnp.conj(X[..., ::-1])                      # conj(X[m-k])
    ze = (0.5 * (X + Xr))[..., :m]
    wc = jnp.conj(jnp.asarray(rfft_split_twiddles(n))).astype(X.dtype)
    zo = (0.5 * wc * (X - Xr))[..., :m]
    return ze + 1j * zo


@functools.partial(jax.jit, static_argnames=("radices",))
def _rfft_pow2(x: jax.Array, *,
               radices: tuple[int, ...] = DEFAULT_RADICES) -> jax.Array:
    """R2C FFT along the last axis: (..., N) real -> (..., N/2+1) complex."""
    n = x.shape[-1]
    assert _is_pow2(n) and n >= 2, n
    if not jnp.issubdtype(x.dtype, jnp.floating):
        x = x.astype(jnp.float32)
    z = _pack_real(x)
    return _rfft_split(_stockham_pow2(z, radices=radices), n)


@functools.partial(jax.jit, static_argnames=("radices",))
def _irfft_pow2(X: jax.Array, *,
                radices: tuple[int, ...] = DEFAULT_RADICES) -> jax.Array:
    """C2R inverse: (..., N/2+1) half-spectrum -> (..., N) real (1/N norm)."""
    m = X.shape[-1] - 1
    n = 2 * m
    assert m >= 1 and _is_pow2(n), X.shape
    X = _as_complex(X)
    z = _stockham_pow2(_irfft_merge(X, n), inverse=True, radices=radices)
    return _unpack_real(z)


# ---------------------------------------------------------------------------
# Public pure-JAX reference API
# ---------------------------------------------------------------------------

def _along_axis(fn, x: jax.Array, axis: int) -> jax.Array:
    if axis != -1 and axis != x.ndim - 1:
        return jnp.moveaxis(fn(jnp.moveaxis(x, axis, -1)), -1, axis)
    return fn(x)


def fft(x: jax.Array, axis: int = -1) -> jax.Array:
    """Forward C2C FFT along ``axis``; power-of-two lengths only.

    Non-power-of-two lengths are handled by :mod:`repro.fft.bluestein`
    (wired together in :mod:`repro.fft.plan`).
    """
    return _along_axis(_stockham_pow2, _as_complex(x), axis)


def ifft(x: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse C2C FFT along ``axis`` (normalised by 1/N)."""
    return _along_axis(functools.partial(_stockham_pow2, inverse=True),
                       _as_complex(x), axis)


def rfft(x: jax.Array, axis: int = -1) -> jax.Array:
    """R2C FFT of real input along ``axis``; pow2 lengths, N/2+1 bins out."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    return _along_axis(_rfft_pow2, x, axis)


def irfft(x: jax.Array, axis: int = -1) -> jax.Array:
    """C2R inverse of :func:`rfft` along ``axis`` (1/N normalised)."""
    return _along_axis(_irfft_pow2, _as_complex(x), axis)


def fft_flop_count(n: int, batch: int = 1) -> float:
    """5 N log2 N per transform — the paper's Eq. (5) accounting."""
    return 5.0 * n * math.log2(n) * batch
