"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

Weak-type-correct, shardable, no device allocation — the full configs are
exercised ONLY through these (smoke tests use reduced configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.api import Model, build_model
from repro.models.common import dtype_of


def _batch_spec(mesh, *trailing) -> P:
    names = mesh.axis_names
    b = ("pod", "data") if "pod" in names else ("data",)
    return P(b, *trailing)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fix_sharding(shape: tuple[int, ...], spec: P, mesh) -> P:
    """Make ``spec`` divisibility-correct for ``shape`` on ``mesh``.

    jit in_shardings require every sharded dim to divide exactly.  Where a
    dim does not (e.g. kv_heads=2 over a 16-way model axis, or vocab=50280),
    the offending mesh axes are MOVED to the largest dim that can absorb
    them (appending to that dim's existing axes), else dropped.  For decode
    caches this turns head-sharding into sequence-sharding — split-KV
    decode, where attention partial-sums over the cache shards and GSPMD
    inserts the reduction.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    homeless: list[str] = []
    for i, (dim, axes) in enumerate(zip(shape, entries)):
        if axes is None:
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        keep: list[str] = []
        for a in tup:
            cur = _axis_size(mesh, tuple(keep) + (a,))
            if dim % cur == 0:
                keep.append(a)
            else:
                homeless.append(a)
        entries[i] = tuple(keep) if keep else None
    for a in homeless:
        # place on the largest dim that can absorb this axis
        cands = []
        for i, dim in enumerate(shape):
            cur = entries[i]
            cur_t = () if cur is None else (
                (cur,) if isinstance(cur, str) else tuple(cur))
            if a in cur_t:
                continue
            combined = _axis_size(mesh, cur_t + (a,))
            if dim % combined == 0:
                cands.append((dim // _axis_size(mesh, cur_t), i, cur_t))
        if cands:
            _, i, cur_t = max(cands)
            entries[i] = cur_t + (a,)
        # else: drop (replicate over that axis)
    cleaned = [e if e is None or isinstance(e, str) else
               (e[0] if len(e) == 1 else e) for e in entries]
    while cleaned and cleaned[-1] is None:
        cleaned.pop()
    return P(*cleaned)


def fix_tree(sds_tree, spec_tree, mesh):
    """NamedShardings for a pytree, with divisibility fixes per leaf."""
    return jax.tree.map(
        lambda sds, sp: NamedSharding(mesh, fix_sharding(sds.shape, sp,
                                                         mesh)),
        sds_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> dict:
    """ShapeDtypeStructs + shardings for one (arch x shape x mesh) cell."""
    b, s = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)
    model = build_model(cfg)

    def sds(shp, dtype, spec):
        spec = fix_sharding(shp, spec, mesh)
        return (jax.ShapeDtypeStruct(shp, dtype), NamedSharding(mesh, spec))

    if shape.kind == "train":
        if cfg.input_mode == "embeds":
            inputs = sds((b, s, cfg.d_model), dt, _batch_spec(mesh, None,
                                                              None))
        else:
            inputs = sds((b, s), jnp.int32, _batch_spec(mesh, None))
        labels = sds((b, s), jnp.int32, _batch_spec(mesh, None))
        return {"inputs": inputs, "labels": labels}

    if shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            inputs = sds((b, s, cfg.d_model), dt,
                         _batch_spec(mesh, None, None))
        else:
            inputs = sds((b, s), jnp.int32, _batch_spec(mesh, None))
        return {"inputs": inputs}

    # decode: one new token + full cache of seq_len
    if cfg.input_mode == "embeds":
        token = sds((b, 1, cfg.d_model), dt, _batch_spec(mesh, None, None))
    else:
        token = sds((b, 1), jnp.int32, _batch_spec(mesh, None))
    cache_sds = model.cache_shapes(b, s)
    cache_spec = model.cache_specs()

    def remap(spec: P) -> P:
        """Map 'data' -> ('pod','data') batch group on multi-pod meshes."""
        if "pod" not in mesh.axis_names:
            return spec
        return P(*[("pod", "data") if x == "data" else x for x in spec])

    cache = jax.tree.map(
        lambda sd, sp: (sd, NamedSharding(
            mesh, fix_sharding(sd.shape, remap(sp), mesh))),
        cache_sds, cache_spec,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
    return {"token": token, "cache": cache}
