"""Batched serving driver: prefill + decode loop with DVFS clock plan.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
      --batch 4 --prompt-len 32 --gen 16

Serving is where the paper's result bites hardest: decode steps are
memory-bandwidth bound (KV-cache reads dominate), i.e. exactly the
workload class where 40-60% of the clock can be dropped nearly for free.
``--dvfs-report`` prints the per-phase (prefill vs decode) clock plan —
prefill is compute-bound and stays near boost; decode drops to the knee.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.dvfs import sweep
from repro.core.hardware import TPU_V5E
from repro.core.scheduler import DVFSScheduler, Stage
from repro.core.workloads import roofline_workload
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--dvfs-report", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    total_len = args.prompt_len + args.gen
    if cfg.input_mode == "embeds":
        prompt = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, args.prompt_len,
                                    cfg.d_model), jnp.float32)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)

    logits, cache = prefill(params, prompt)
    # grow caches to the full decode length
    def grow(a):
        if a.ndim >= 3 and a.shape[-3] == args.prompt_len:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, args.gen)
            return jnp.pad(a, pad)
        # transformer kv caches: (..., B, S, KV, hd) with S at -3;
        # mamba conv/state caches have no seq axis -> unchanged
        return a
    def grow_kv(a):
        for ax in range(a.ndim):
            if a.shape[ax] == args.prompt_len:
                pad = [(0, 0)] * a.ndim
                pad[ax] = (0, args.gen)
                return jnp.pad(a, pad)
        return a
    cache = jax.tree.map(grow_kv, cache)

    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    generated = [np.asarray(tok)]
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        generated.append(np.asarray(tok))
    out = np.concatenate(generated, axis=1)
    print(f"[serve] generated {out.shape} tokens; first row: {out[0][:12]}")

    if args.dvfs_report:
        dev = TPU_V5E
        # analytic per-phase profiles (full config accounting)
        full = get_arch(args.arch)
        nbytes = full.param_count() * 2
        prefill_prof = roofline_workload(
            "prefill", dev,
            hlo_flops=2 * full.param_count() * args.batch * args.prompt_len,
            hbm_bytes=nbytes, issue_efficiency=0.8)
        cache_bytes = (full.n_layers * 2 * full.n_kv_heads
                       * full.resolved_head_dim * total_len * args.batch * 2)
        decode_prof = roofline_workload(
            "decode", dev,
            hlo_flops=2 * full.param_count() * args.batch,
            hbm_bytes=nbytes + cache_bytes, issue_efficiency=0.8)
        sched = DVFSScheduler(dev)
        plan = []
        for prof in (prefill_prof, decode_prof):
            res = sweep(prof, dev)
            plan.append(Stage(prof, res.optimal.f))
            print(f"[dvfs] {prof.name}: bound={prof.regime(dev)!r} "
                  f"optimal={res.optimal.f:.0f} MHz, "
                  f"power cut {100*res.power_reduction:.0f}%, "
                  f"slowdown {100*res.slowdown:.1f}%")
        rep = sched.evaluate_pipeline(plan)
        print(f"[dvfs] serve pipeline I_ef={rep.i_ef:.2f} "
              f"(slowdown {100*rep.slowdown:.1f}%)")
    return out


if __name__ == "__main__":
    main()
