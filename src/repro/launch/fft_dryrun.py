import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Dry-run of the paper's OWN workload on the production mesh: the
distributed pencil FFT (batch x 32M-point transforms, n1 sharded over the
model axis) lowered + compiled on 16x16 and 2x16x16, with the same
roofline artifact as the LM cells.

  PYTHONPATH=src python -m repro.launch.fft_dryrun [--multi-pod]
"""
import argparse
import gzip
import json
import math
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.configs.fft_bench import CONFIG
from repro.fft.distributed import pencil_collective_bytes, pencil_fft
from repro.launch.mesh import make_production_mesh

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART))
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    c = CONFIG
    n1, n2, b = c.pencil_n1, c.pencil_n2, c.pencil_batch
    n = n1 * n2

    x = jax.ShapeDtypeStruct((b, n1, n2), jnp.complex64)
    sharding = NamedSharding(
        mesh, P(("pod", "data") if "pod" in mesh.axis_names else "data",
                "model", None))

    fn = jax.jit(
        lambda v: pencil_fft(v, mesh, n1=n1, n2=n2, axis="model"),
        in_shardings=(sharding,), out_shardings=sharding)
    t0 = time.monotonic()
    lowered = fn.lower(x)
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    mem = compiled.memory_analysis()
    chips = mesh.devices.size
    model_flops = 5.0 * n * math.log2(n) * b
    # analytic all_to_all check (model axis = 16 devices regardless of pod)
    coll_pred = pencil_collective_bytes(b, n1, n2, 16) / (chips / 16)

    art = {
        "arch": "fft-pencil", "shape": f"c2c_{n1}x{n2}_b{b}",
        "mesh": "2x16x16" if args.multi_pod else "16x16",
        "chips": int(chips), "kind": "fft",
        "flops_per_device": float(hlo["flops"]),
        "hbm_bytes_per_device": float(hlo["bytes"]),
        "collective_bytes_per_device": float(hlo["collective_bytes"]),
        "collective_breakdown": hlo["collectives"],
        "collective_bytes_analytic": coll_pred,
        "model_flops": model_flops,
        "memory": {"argument_bytes": int(mem.argument_size_in_bytes),
                   "fits_16gb": bool(mem.argument_size_in_bytes < 16e9)},
        "compile_s": round(t_compile, 2),
    }
    tag = f"fft-pencil__{art['shape']}__{art['mesh']}"
    os.makedirs(args.out, exist_ok=True)
    with gzip.open(os.path.join(args.out, tag + ".hlo.txt.gz"), "wt") as f:
        f.write(hlo_text)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(art, f, indent=1)
    print(f"[fft-dryrun] {tag}: coll/dev={art['collective_bytes_per_device']:.3e} "
          f"(analytic {coll_pred:.3e}) args={mem.argument_size_in_bytes/1e9:.2f}GB "
          f"compile={t_compile:.1f}s")


if __name__ == "__main__":
    main()
