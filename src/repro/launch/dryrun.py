import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * memory_analysis (proves the cell fits 16 GB/chip),
  * cost_analysis FLOPs / bytes (per-device, partitioned module),
  * collective bytes parsed from the compiled HLO,
  * MODEL_FLOPS (6*N*D accounting) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # every cell, both meshes
"""
import argparse
import gzip
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import model_flops_for
from repro.configs import ARCHS, get_arch, get_shape, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import fix_tree, input_specs
from repro.models.api import build_model
from repro.obs.log import get_logger

log = get_logger("dryrun")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _state_sds(model):
    """ShapeDtypeStructs of the full TrainState without allocating."""
    from repro.optim.adamw import AdamWState
    from repro.train.step import TrainState
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=jax.tree.map(f32, params),
                       v=jax.tree.map(f32, params)),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _strip_data_axis(spec_tree):
    """TP-only weights: remove the ZeRO/FSDP 'data' axis from param specs.

    §Perf optimisation for serving cells: at decode there is no optimizer
    state to shard and weights are read every step, so FSDP-style weight
    sharding only buys an all-gather per matmul.  Replicating over 'data'
    (keeping TP over 'model') removes that collective for +P*2/16 bytes of
    HBM per device.
    """
    def fix(s):
        parts = []
        for e in s:
            if e == "data":
                parts.append(None)
            elif isinstance(e, tuple):
                t = tuple(a for a in e if a != "data")
                parts.append(t if t else None)
            else:
                parts.append(e)
        return P(*parts)
    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _strip_data_axis_nonmoe(spec_tree):
    """serve_tp_only for MoE giants: expert tables stay 2-D sharded (they
    do not fit replicated over 'data'); everything else goes TP-only."""
    if isinstance(spec_tree, dict):
        return {k: (v if k == "moe" else _strip_data_axis_nonmoe(v))
                for k, v in spec_tree.items()}
    if isinstance(spec_tree, list):
        return [_strip_data_axis_nonmoe(v) for v in spec_tree]
    return _strip_data_axis(spec_tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               opts: tuple[str, ...] = ()):
    from repro.models import common as cm
    cm.PERF_OPTS.clear()
    cm.PERF_OPTS.update(opts)
    cfg = get_arch(arch)
    if "moe_group_128" in opts and cfg.moe is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, group_size=128))
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    specs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        from repro.train.step import make_train_step, train_state_specs
        step_fn = make_train_step(model)
        state_sds = _state_sds(model)
        sspecs = train_state_specs(model)
        if "attn_tp_only" in opts:
            # §Perf: attention weights TP-only (no ZeRO sharding) — trades
            # +attn_params*10/16 bytes of optimizer memory per device for
            # removing the per-layer FSDP weight all-gathers.
            import dataclasses as _dc
            def _fix_tree_part(t):
                if isinstance(t, dict):
                    return {k: (_strip_data_axis(v) if k == "attn"
                                else _fix_tree_part(v))
                            for k, v in t.items()}
                if isinstance(t, list):
                    return [_fix_tree_part(v) for v in t]
                return t
            sspecs = _dc.replace(
                sspecs,
                params=_fix_tree_part(sspecs.params),
                opt=_dc.replace(sspecs.opt,
                                m=_fix_tree_part(sspecs.opt.m),
                                v=_fix_tree_part(sspecs.opt.v)))
        state_sh = fix_tree(state_sds, sspecs, mesh)
        in_sh = (state_sh, specs["inputs"][1], specs["labels"][1])
        args = (state_sds, specs["inputs"][0], specs["labels"][0])
        jitted = jax.jit(step_fn, in_shardings=in_sh,
                         out_shardings=(state_sh, None), donate_argnums=(0,))
    elif shape.kind == "prefill":
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = model.param_specs()
        if "serve_tp_only" in opts:
            pspecs = _strip_data_axis(pspecs)
        params_sh = fix_tree(params_sds, pspecs, mesh)
        cache_sh = _shardings(
            mesh, jax.tree.map(lambda x: x[1].spec if isinstance(x, tuple)
                               else x, model.cache_specs(),
                               is_leaf=lambda x: isinstance(x, P)))
        jitted = jax.jit(model.prefill,
                         in_shardings=(params_sh, specs["inputs"][1]),
                         out_shardings=None)
        args = (params_sds, specs["inputs"][0])
    else:  # decode
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pspecs = model.param_specs()
        if "serve_tp_only" in opts:
            pspecs = _strip_data_axis(pspecs)
        params_sh = fix_tree(params_sds, pspecs, mesh)
        cache_sds = jax.tree.map(lambda t: t[0], specs["cache"],
                                 is_leaf=lambda t: isinstance(t, tuple))
        cache_sh = jax.tree.map(lambda t: t[1], specs["cache"],
                                is_leaf=lambda t: isinstance(t, tuple))
        jitted = jax.jit(model.decode,
                         in_shardings=(params_sh, cache_sh,
                                       specs["token"][1]),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
        args = (params_sds, cache_sds, specs["token"][0])

    from repro.models.common import activation_sharding
    from repro.launch.mesh import batch_axes

    t0 = time.monotonic()
    with activation_sharding(mesh, batch_axes(mesh)):
        lowered = jitted.lower(*args)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax < 0.4.35 returned [dict]; newer versions return the dict directly.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)

    chips = mesh.devices.size
    # Scan-carry residency estimate (the part of TPU temp memory that does
    # not disappear with buffer reuse): per-layer hidden saved for backward,
    # sharded per the SP activation constraint (batch x seq over the mesh).
    if shape.kind == "train":
        shards = chips
        carry_est = (cfg.n_layers * shape.global_batch * shape.seq_len
                     * cfg.d_model * 2) / shards
    else:
        carry_est = 0.0
    args_bytes = int(mem.argument_size_in_bytes)
    out_bytes = int(mem.output_size_in_bytes)
    # train state / decode cache outputs are DONATED (alias their input
    # buffers), so arguments + scan carries bound the persistent footprint.
    fits = (args_bytes + carry_est) * 1.15 < 16e9
    artifact = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips),
        "kind": shape.kind,
        # trip-count-aware HLO analysis (see repro.analysis.hlo): the CPU
        # backend's cost_analysis counts while bodies once, so raw values
        # are recorded separately below.
        "flops_per_device": float(hlo["flops"]),
        "hbm_bytes_per_device": float(hlo["bytes"]),
        "collective_bytes_per_device": float(hlo["collective_bytes"]),
        "collective_breakdown": hlo["collectives"],
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "model_flops": model_flops_for(cfg, shape),
        "memory": {
            "argument_bytes": args_bytes,
            "output_bytes": out_bytes,
            # CPU buffer assignment does not reuse across loop iterations
            # the way the TPU assigner does; recorded for completeness.
            "temp_bytes_cpu_backend": int(mem.temp_size_in_bytes),
            "scan_carry_estimate": int(carry_est),
            "fits_16gb": bool(fits),
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    return artifact, hlo_text


def run_one(arch, shape_name, multi_pod, out_dir, opts=()):
    art, hlo_text = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               opts=tuple(opts))
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if opts:
        art["opts"] = sorted(opts)
        tag += "__" + "+".join(sorted(opts))
    path = os.path.join(out_dir, tag + ".json")
    with gzip.open(os.path.join(out_dir, tag + ".hlo.txt.gz"), "wt") as f:
        f.write(hlo_text)
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    log.info("lowered", tag=tag,
             args_gb=art["memory"]["argument_bytes"] / 1e9,
             fits=art["memory"]["fits_16gb"],
             flops_per_dev=art["flops_per_device"],
             coll_per_dev=art["collective_bytes_per_device"],
             compile_s=art["compile_s"])
    return path


def run_all(out_dir: str, multi_pod_only: bool = False):
    """Loop every cell in a fresh subprocess (isolated device state)."""
    cells = []
    for cfg in ARCHS.values():
        for shp in shapes_for(cfg):
            for mp in ((True,) if multi_pod_only else (False, True)):
                cells.append((cfg.name, shp.name, mp))
    failures = []
    for arch, shp, mp in cells:
        tag = f"{arch}__{shp}__{'2x16x16' if mp else '16x16'}"
        if os.path.exists(os.path.join(out_dir, tag + ".json")):
            log.info("cached-skip", tag=tag)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shp, "--out", out_dir]
        if mp:
            cmd.append("--multi-pod")
        r = subprocess.run(cmd)
        if r.returncode != 0:
            failures.append(tag)
            log.error("cell-failed", tag=tag)
    log.info("done", n_failures=len(failures),
             failures=",".join(failures) or "-")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--opt", action="append", default=[],
                    help="enable a named §Perf optimisation (repeatable)")
    args = ap.parse_args()
    if args.all:
        failures = run_all(args.out)
        sys.exit(1 if failures else 0)
    run_one(args.arch, args.shape, args.multi_pod, args.out, args.opt)


if __name__ == "__main__":
    main()
