"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 (two pods, 512 chips).

    Axes:
      pod    pure data parallelism across pods (gradient all-reduce
             crosses the inter-pod DCN/ICI boundary — the multi-pod
             dry-run proves this lowers)
      data   DP for training / batch sharding for decode; also the
             ZeRO-style second weight-sharding axis
      model  tensor/expert parallelism
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)
