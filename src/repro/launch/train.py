"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \\
      --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config -> model -> sharded train state -> synthetic data
-> fault-tolerant loop (checkpoint/restart) -> DVFS clock plan.

The DVFS integration is the paper's Sec. 5.3 made first-class: after the
step is compiled, its roofline profile decides the energy-optimal TPU
clock; on hardware the runtime would lock/unlock around dispatch (NVML
analogue), here the plan and its predicted savings are reported alongside
training metrics.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core.dvfs import sweep
from repro.core.hardware import TPU_V5E
from repro.core.workloads import roofline_workload
from repro.data.synthetic import SyntheticTokens
from repro.models.api import build_model
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.fault import FaultTolerantDriver
from repro.train.step import (init_train_state, make_train_step,
                              train_state_specs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", default="1x1",
                    help="data x model mesh, e.g. 4x2 (needs devices)")
    ap.add_argument("--dvfs-report", action="store_true",
                    help="print the energy-optimal clock plan for the step")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))

    state = init_train_state(model, jax.random.PRNGKey(0))
    specs = train_state_specs(model)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, shardings)

    step_fn = jax.jit(
        make_train_step(model, microbatches=args.microbatches,
                        peak_lr=args.lr),
        in_shardings=(shardings, NamedSharding(mesh, P("data", None)),
                      NamedSharding(mesh, P("data", None))),
        donate_argnums=(0,),
    )

    ds = SyntheticTokens(cfg.vocab, args.seq, args.batch)

    def data(i):
        b = jnp.asarray(ds.batch(i))
        return b[:, :-1], b[:, 1:]

    driver = FaultTolerantDriver(
        train_step=step_fn, state=state, data_iter_fn=data,
        ckpt=CheckpointManager(args.ckpt_dir), ckpt_every=args.ckpt_every,
    )
    final_state, log, restarts = driver.run(args.steps)
    for mrow in log[:: max(len(log) // 20, 1)]:
        print(f"step {mrow['step']:5d}  loss {float(mrow['loss']):.4f}  "
              f"lr {float(mrow['lr']):.2e}  wall {mrow['wall']*1e3:.1f} ms")
    print(f"[train] done: {args.steps} steps, {restarts} restarts, "
          f"final loss {float(log[-1]['loss']):.4f}")

    if args.dvfs_report:
        # Roofline profile of the compiled step -> energy-optimal clock.
        lowered = step_fn.lower(state, *data(0))
        compiled = lowered.compile()
        from repro.analysis.hlo import analyze_hlo
        h = analyze_hlo(compiled.as_text())
        prof = roofline_workload(
            f"train-{cfg.name}", TPU_V5E, hlo_flops=h["flops"],
            hbm_bytes=h["bytes"], collective_bytes=h["collective_bytes"],
            issue_efficiency=0.8)
        res = sweep(prof, TPU_V5E)
        print(f"[dvfs] bound={prof.regime(TPU_V5E)!r} "
              f"optimal={res.optimal.f:.0f} MHz "
              f"({100*res.optimal.f/TPU_V5E.f_max:.0f}% of boost), "
              f"power cut {100*res.power_reduction:.0f}%, "
              f"slowdown {100*res.slowdown:.1f}%, I_ef {res.i_ef_boost:.2f}")
    return final_state


if __name__ == "__main__":
    main()
