"""Deterministic synthetic data: token streams and pulsar filterbanks.

Token stream — a seeded, stateless batch generator: batch ``i`` is a pure
function of (seed, i), so any host can regenerate any shard — this is
what makes checkpoint restart and elastic re-sharding trivial (no
data-loader state to save) and provides the straggler-mitigation story: a
host that falls behind can be reassigned shards without coordination
(see repro.runtime.fault).  The "text" is a mixture of Zipf-distributed
unigrams and short repeated motifs, enough signal for loss-goes-down
integration tests.

Filterbank — the radio-astronomy front half of the real-time pipeline the
paper's Sec. 5 targets: (nchan, ntime) dynamic spectra whose injected
pulsars arrive with the cold-plasma dispersion delay

    dt(DM, f) = K_DM * DM * (f^-2 - f_ref^-2)     [s, f in MHz]

rounded to integer samples.  Injection uses exactly the rounded delays a
:class:`repro.search.pipeline.DispersionPlan` trial computes, so a
pulsar injected at a trial DM dedisperses back into perfect channel
alignment — the property the recovery tests assert at the sample level.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

#: Cold-plasma dispersion constant, s * MHz^2 * (pc cm^-3)^-1.
K_DM = 4.148808e3


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, index: int, *, host_id: int = 0, n_hosts: int = 1
              ) -> np.ndarray:
        """Host-sharded batch ``index`` -> (global_batch/n_hosts, seq+1)."""
        per_host = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index, host_id]))
        # Zipf unigrams clipped to vocab
        base = rng.zipf(1.3, size=(per_host, self.seq_len + 1))
        toks = np.minimum(base - 1, self.vocab - 1).astype(np.int32)
        # motif: every sequence repeats a short pattern (learnable signal)
        motif_len = 8
        motif = rng.integers(0, self.vocab, size=(per_host, motif_len))
        reps = (self.seq_len + 1 + motif_len - 1) // motif_len
        tiled = np.tile(motif, (1, reps))[:, : self.seq_len + 1]
        mask = rng.random((per_host, self.seq_len + 1)) < 0.5
        return np.where(mask, tiled, toks).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class FilterbankSpec:
    """Geometry of one filterbank block (the telescope side of Sec. 2.3).

    ``nchan`` frequency channels spanning [f_lo, f_hi] MHz (channel 0 is
    the highest frequency — the earliest arrival, so all dispersion
    delays are >= 0), sampled every ``tsamp`` seconds for ``ntime``
    samples.  ``t_acquire = ntime * tsamp`` is the real-time budget one
    block must be processed within (RealTimeBudget.t_acquire).
    """

    nchan: int = 32
    ntime: int = 4096
    f_lo: float = 1300.0       # MHz, bottom of the band
    f_hi: float = 1500.0       # MHz, top of the band (reference: no delay)
    tsamp: float = 64e-6       # s per sample

    def __post_init__(self):
        if self.nchan < 1 or self.ntime < 1:
            raise ValueError(
                f"filterbank needs nchan/ntime >= 1, got "
                f"{self.nchan}/{self.ntime}")
        if not 0 < self.f_lo < self.f_hi:
            raise ValueError(
                f"need 0 < f_lo < f_hi, got [{self.f_lo}, {self.f_hi}] MHz")
        if self.tsamp <= 0:
            raise ValueError(f"tsamp must be > 0, got {self.tsamp}")

    @property
    def freqs_mhz(self) -> np.ndarray:
        """(nchan,) channel centres, descending from f_hi to f_lo."""
        return np.linspace(self.f_hi, self.f_lo, self.nchan)

    @property
    def t_acquire(self) -> float:
        """Seconds of sky one block holds (the real-time envelope)."""
        return self.ntime * self.tsamp

    @property
    def dm_step(self) -> float:
        """DM spacing giving ~1 sample of differential delay across the
        band — the classic 'diagonal DM' trial step."""
        span = self.f_lo ** -2 - self.f_hi ** -2
        return self.tsamp / (K_DM * span)

    def delay_seconds(self, dm: float) -> np.ndarray:
        """(nchan,) dispersion delays relative to the top of the band."""
        return K_DM * dm * (self.freqs_mhz ** -2 - self.f_hi ** -2)

    def delay_samples(self, dm: float) -> np.ndarray:
        """(nchan,) integer-sample delays — the grid both injection and
        the dedispersion kernel shift by (so they cancel exactly)."""
        return np.rint(self.delay_seconds(dm) / self.tsamp).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class InjectedPulsar:
    """Ground truth for one injected accelerated pulsar.

    ``k0`` is the spin-frequency Fourier bin at the start of the block
    and ``z`` the Fourier-domain drift in bins over the block (the FDAS
    template axis); ``dm`` should be a DispersionPlan trial value for
    sample-exact dedispersion.
    """

    dm: float                  # pc cm^-3
    k0: int                    # Fourier bin of the spin frequency
    z: float = 0.0             # drift in bins over the block (acceleration)
    amp: float = 0.05          # per-channel tone amplitude
    phase: float = 0.0         # radians


def synthetic_filterbank(
    spec: FilterbankSpec,
    pulsars: tuple[InjectedPulsar, ...] = (),
    *,
    noise: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    """(nchan, ntime) float32 dynamic spectrum with dispersed test tones.

    Each pulsar is a linear chirp  cos(2*pi*(k0*s + z/2*s^2) + phase)
    with s = (t - delay_c)/ntime per channel — i.e. the *same* waveform
    in every channel, shifted by that channel's rounded integer delay.
    Dedispersing at the pulsar's DM therefore re-aligns all channels
    exactly and the channel sum is coherent (amplitude nchan * amp over
    noise growing as sqrt(nchan)); any other trial leaves residual
    shifts that decohere the sum.  ``noise=0`` gives a clean template
    for kernel parity tests; the default unit noise feeds the recovery
    suite and the false-positive control.
    """
    rng = np.random.default_rng(seed)
    x = (noise * rng.standard_normal((spec.nchan, spec.ntime))
         if noise else np.zeros((spec.nchan, spec.ntime)))
    t = np.arange(spec.ntime)[None, :]
    for p in pulsars:
        delays = spec.delay_samples(p.dm)[:, None]
        s = (t - delays) / spec.ntime
        x += p.amp * np.cos(2 * np.pi * (p.k0 * s + 0.5 * p.z * s * s)
                            + p.phase)
    return x.astype(np.float32)


def synthetic_batches(vocab: int, seq_len: int, global_batch: int,
                      n_steps: int, *, seed: int = 0, host_id: int = 0,
                      n_hosts: int = 1):
    """Generator of (inputs, labels) numpy pairs."""
    ds = SyntheticTokens(vocab, seq_len, global_batch, seed)
    for i in range(n_steps):
        b = ds.batch(i, host_id=host_id, n_hosts=n_hosts)
        yield b[:, :-1], b[:, 1:]
