"""Deterministic synthetic token pipeline.

A seeded, stateless stream: batch ``i`` is a pure function of (seed, i),
so any host can regenerate any shard — this is what makes checkpoint
restart and elastic re-sharding trivial (no data-loader state to save)
and provides the straggler-mitigation story: a host that falls behind can
be reassigned shards without coordination (see repro.runtime.fault).

The "text" is a mixture of Zipf-distributed unigrams and short repeated
motifs, enough signal for loss-goes-down integration tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, index: int, *, host_id: int = 0, n_hosts: int = 1
              ) -> np.ndarray:
        """Host-sharded batch ``index`` -> (global_batch/n_hosts, seq+1)."""
        per_host = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index, host_id]))
        # Zipf unigrams clipped to vocab
        base = rng.zipf(1.3, size=(per_host, self.seq_len + 1))
        toks = np.minimum(base - 1, self.vocab - 1).astype(np.int32)
        # motif: every sequence repeats a short pattern (learnable signal)
        motif_len = 8
        motif = rng.integers(0, self.vocab, size=(per_host, motif_len))
        reps = (self.seq_len + 1 + motif_len - 1) // motif_len
        tiled = np.tile(motif, (1, reps))[:, : self.seq_len + 1]
        mask = rng.random((per_host, self.seq_len + 1)) < 0.5
        return np.where(mask, tiled, toks).astype(np.int32)


def synthetic_batches(vocab: int, seq_len: int, global_batch: int,
                      n_steps: int, *, seed: int = 0, host_id: int = 0,
                      n_hosts: int = 1):
    """Generator of (inputs, labels) numpy pairs."""
    ds = SyntheticTokens(vocab, seq_len, global_batch, seed)
    for i in range(n_steps):
        b = ds.batch(i, host_id=host_id, n_hosts=n_hosts)
        yield b[:, :-1], b[:, 1:]
