from repro.data.synthetic import SyntheticTokens, synthetic_batches

__all__ = ["SyntheticTokens", "synthetic_batches"]
