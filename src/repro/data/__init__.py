from repro.data.arrivals import arrival_times, wave_slices
from repro.data.synthetic import SyntheticTokens, synthetic_batches

__all__ = ["SyntheticTokens", "arrival_times", "synthetic_batches",
           "wave_slices"]
