"""Seeded request arrival-time distributions for serving benchmarks.

Real edge telescopes do not deliver work on a fixed grid: channelised
voltage dumps and candidate follow-ups arrive as a point process.  The
crash-and-recover harness (``benchmarks/run.py recovery``) drives the
service from one of two classic processes, both fully seeded so any two
runs of the same schedule see bit-identical arrival times:

  poisson   exponential inter-arrival gaps — the memoryless baseline
            (counts per drain window are Poisson-distributed, so wave
            sizes genuinely vary).
  gamma     Gamma(k)-distributed gaps at the same mean rate.  ``k < 1``
            is burstier than Poisson (heavy clumps and long silences,
            the shape transient RFI storms have), ``k > 1`` smoother
            (closer to the pipeline's own periodic dump cadence).

Times are *simulated* seconds: they define which requests share a drain
wave (the service drains once per ``period_s`` of arrival time), not
when wall-clock work happens.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

__all__ = ["arrival_times", "wave_slices"]


def arrival_times(n: int, *, seed: int, process: str = "poisson",
                  rate_hz: float = 1000.0,
                  gamma_shape: float = 0.5) -> np.ndarray:
    """``n`` cumulative arrival times [s] of a seeded point process.

    ``rate_hz`` is the mean arrival rate for both processes (the gamma
    scale is ``1 / (gamma_shape * rate_hz)`` so changing the shape
    changes burstiness, never the load).
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate_hz <= 0.0:
        raise ValueError(f"rate_hz must be > 0, got {rate_hz}")
    rng = np.random.default_rng(seed)
    if process == "poisson":
        gaps = rng.exponential(scale=1.0 / rate_hz, size=n)
    elif process == "gamma":
        if gamma_shape <= 0.0:
            raise ValueError(
                f"gamma_shape must be > 0, got {gamma_shape}")
        gaps = rng.gamma(shape=gamma_shape,
                         scale=1.0 / (gamma_shape * rate_hz), size=n)
    else:
        raise ValueError(
            f"unknown arrival process {process!r}; "
            f"have 'poisson' or 'gamma'")
    return np.cumsum(gaps)


def wave_slices(times: np.ndarray,
                period_s: float) -> Iterator[tuple[int, int]]:
    """Split arrival times into drain waves of ``period_s`` simulated
    seconds; yields half-open index ranges ``(start, stop)``.

    Empty periods are skipped (the service has nothing to drain), so
    every yielded wave is non-empty and the ranges tile ``[0, len)``.
    """
    if period_s <= 0.0:
        raise ValueError(f"period_s must be > 0, got {period_s}")
    n = len(times)
    start = 0
    while start < n:
        boundary = (np.floor(times[start] / period_s) + 1.0) * period_s
        stop = int(np.searchsorted(times, boundary, side="left"))
        stop = max(stop, start + 1)         # numerical-edge safety
        yield start, stop
        start = stop
