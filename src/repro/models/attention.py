"""Memory-efficient GQA attention with sliding-window and KV-cache support.

Training/prefill use a flash-style chunked softmax: an online
(max, sum, acc) reduction scanned over KV chunks, so the (S, S) score
matrix never materialises — at 32k prefill the transient is (B, H, S, CHUNK)
instead of (B, H, S, S).  Causal and sliding-window masks are applied per
chunk; fully-masked chunks still lower fine (the dry-run is shape-level).

Decode attends one query position against the cached KV — a pair of
einsums, memory-bound by the cache read, which is exactly the workload
class the paper's DVFS result targets (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_attn(q, k, v, *, q_offset, window: int | None, chunk: int):
    """Online-softmax attention.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D).  Causal w.r.t. absolute
    positions (q position = q_offset + i, k position = j).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    groups = h // kv
    qg = q.reshape(b, sq, kv, groups, d)
    scale = d ** -0.5

    n_chunks = max(sk // chunk, 1)
    csize = sk // n_chunks

    def body(carry, idx):
        acc, m, l = carry
        start = idx * csize
        kc = jax.lax.dynamic_slice_in_dim(k, start, csize, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, csize, axis=1)
        s = jnp.einsum("bqkgd,bjkd->bqkgj", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_offset + jnp.arange(sq)[:, None]
        jpos = start + jnp.arange(csize)[None, :]
        mask = qpos >= jpos                                   # causal
        if window is not None:
            mask &= (qpos - jpos) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgj,bjkd->bqkgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kv, groups, dv), jnp.float32)
    m0 = jnp.full((b, sq, kv, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, groups), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def attention(q, k, v, *, causal_offset: int = 0,
              window: int | None = None, chunk: int = 1024) -> jax.Array:
    """Chunked causal (optionally windowed) GQA attention."""
    sk = k.shape[1]
    chunk = min(chunk, sk)
    # make chunk divide sk (shapes here are powers of two)
    while sk % chunk:
        chunk //= 2
    return _chunk_attn(q, k, v, q_offset=causal_offset, window=window,
                       chunk=max(chunk, 1))


def decode_attention(q, k_cache, v_cache, *, cache_len: int | None = None,
                     window: int | None = None) -> jax.Array:
    """One-token attention against a (B, S_cache, KV, D) cache.

    q: (B, 1, H, D).  ``cache_len`` is the current valid length (static
    here: dry-run decodes against a full cache, the paper's decode_32k /
    long_500k cells).
    """
    b, _, h, d = q.shape
    sk, kv = k_cache.shape[1], k_cache.shape[2]
    groups = h // kv
    qg = q.reshape(b, kv, groups, d)
    s = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache,
                   preferred_element_type=jnp.float32) * d ** -0.5
    valid_len = cache_len if cache_len is not None else sk
    jpos = jnp.arange(sk)
    mask = jpos < valid_len
    if window is not None:
        mask &= jpos >= (valid_len - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
