"""Multi-head latent attention (DeepSeek-V2) — compressed KV cache.

The KV path is low-rank: tokens project down to a ``kv_lora_rank`` latent
``c_kv`` (plus a small decoupled RoPE key shared across heads); per-head
keys/values are up-projections of the latent.  The decode cache stores only
``(c_kv, k_rope)`` — (rank + rope_dim) floats per token instead of
2 * H * head_dim, an ~8x cache compression that pulls the decode cells'
memory term down (visible in the roofline table vs the GQA archs).

Decode uses the **weight-absorption** formulation (the DeepSeek-V2 paper's
own serving optimisation): absorb W_uk into the query and W_uv into the
output so attention runs directly in the rank-512 latent space — the
per-head K/V are never materialised over the 32k cache.

Train/prefill materialise per-head K/V but attend through the chunked
online-softmax kernel (repro.models.attention), so the 32k x 32k score
matrix never exists.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import NEG_INF, attention
from repro.models.common import dense_init, rope


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "w_q": dense_init(ks[0], (d, h * qk), dtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank), dtype),
        "w_krope": dense_init(ks[2], (d, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                           dtype),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "w_o": dense_init(ks[5], (h * m.v_head_dim, d), dtype),
    }


def _project_q(params, x, positions, cfg: ArchConfig):
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = (x @ params["w_q"]).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, positions, cfg: ArchConfig):
    c_kv = x @ params["w_dkv"]                            # (B, S, rank)
    k_rope = rope((x @ params["w_krope"])[:, :, None, :], positions,
                  cfg.rope_theta)                         # (B, S, 1, rope)
    return c_kv, k_rope


def mla_attention(params, x, positions, cfg: ArchConfig,
                  with_cache: bool = False):
    """Full-sequence MLA (train/prefill) via the chunked GQA kernel."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(params, x, positions, cfg)
    c_kv, k_rope = _project_kv_latent(params, x, positions, cfg)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)
    # Fold the decoupled rope key into a single MHA call: concatenate the
    # nope and rope parts (rope key broadcast across heads).
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))],
        axis=-1)
    out = attention(q_full, k_full, v)                    # kv == h heads
    out = out.reshape(b, s, h * m.v_head_dim)
    out = out @ params["w_o"]
    if with_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope}
    return out


def mla_decode(params, x, cache: dict, cfg: ArchConfig):
    """One-token decode in latent space (weight absorption)."""
    m, h = cfg.mla, cfg.n_heads
    b = x.shape[0]
    sk = cache["c_kv"].shape[1]
    positions = jnp.full((b, 1), sk - 1, jnp.int32)
    q_nope, q_rope = _project_q(params, x, positions, cfg)
    c_new, kr_new = _project_kv_latent(params, x, positions, cfg)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new,
                                               sk - 1, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new,
                                                 sk - 1, axis=1)
    # Absorb W_uk: q_lat[b,h,r] = sum_d q_nope[b,h,d] * W_uk[r, h*d]
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bhr", q_nope, w_uk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,bjr->bhj", q_lat.astype(jnp.float32),
                    c_kv.astype(jnp.float32))
         + jnp.einsum("bqhd,bjxd->bhj", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    p = jax.nn.softmax(s, axis=-1)                        # (B, H, Sk)
    out_lat = jnp.einsum("bhj,bjr->bhr", p, c_kv.astype(jnp.float32))
    # Absorb W_uv on the way out.
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return out @ params["w_o"], {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_shape(cfg: ArchConfig, batch: int, seq: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, seq, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, seq, 1, m.qk_rope_head_dim),
                                       dtype),
    }
