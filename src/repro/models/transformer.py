"""Config-driven decoder-only transformer LM.

Covers the dense (qwen2/codeqwen/qwen1.5), sliding-window (gemma3),
audio-token (musicgen), VLM-backbone (pixtral) and MoE (dbrx,
deepseek-v2-lite w/ MLA) assigned architectures from one implementation.

Structure decisions driven by the dry-run (512-device compile on 1 CPU):
  * homogeneous layers are stacked (leading L axis) and scanned with
    ``jax.lax.scan`` + ``jax.checkpoint`` — HLO size stays O(1) in depth;
  * gemma3's 5:1 local:global pattern stacks layers as (groups, 6, ...) and
    scans over groups with the 6-layer pattern unrolled in the body;
  * deepseek's first dense layer is kept outside the MoE scan.

Weights are 2-D sharded (TP feature axis x ZeRO-style data axis) per
``repro.models.common`` — see DESIGN.md §Parallelism mapping.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.attention import attention, decode_attention
from repro.models.common import dense_init, rms_norm, rope
from repro.models.mla import (init_mla, mla_attention, mla_cache_shape,
                              mla_decode)
from repro.models.moe import init_moe, moe_block


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "w_k": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "w_v": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "w_o": dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["b_k"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["b_v"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _init_mlp(key, d: int, ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dtype),
        "w_up": dense_init(ks[1], (d, ff), dtype),
        "w_down": dense_init(ks[2], (ff, d), dtype),
    }


def _init_layer(key, cfg: ArchConfig, dtype, *, moe_layer: bool,
                dense_ff: int | None = None) -> dict:
    ka, kf = jax.random.split(key)
    p: dict = {
        "ln_attn": jnp.zeros((cfg.d_model,), dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
    }
    p["attn"] = (init_mla(ka, cfg, dtype) if cfg.mla is not None
                 else _init_attn(ka, cfg, dtype))
    if moe_layer:
        p["moe"] = init_moe(kf, cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = _init_mlp(kf, cfg.d_model, dense_ff or cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = cm.dtype_of(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params: dict = {
        "embed": dense_init(k_embed, (cfg.vocab, cfg.d_model), dtype,
                            scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab),
                                       dtype)

    n_scan = cfg.n_layers - cfg.n_dense_layers
    keys = jax.random.split(k_layers, n_scan)
    moe_layer = cfg.moe is not None
    stacked = [
        _init_layer(keys[i], cfg, dtype, moe_layer=moe_layer)
        for i in range(n_scan)
    ]
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if cfg.local_per_global:
        group = cfg.local_per_global + 1
        assert n_scan % group == 0, (n_scan, group)
        layers = jax.tree.map(
            lambda x: x.reshape(n_scan // group, group, *x.shape[1:]),
            layers)
    params["layers"] = layers
    if cfg.n_dense_layers:
        kd = jax.random.split(k_layers, cfg.n_dense_layers + 1)[-1]
        params["dense_layers"] = [
            _init_layer(jax.random.fold_in(kd, i), cfg, dtype,
                        moe_layer=False, dense_ff=cfg.dense_d_ff)
            for i in range(cfg.n_dense_layers)
        ]
    return params


def param_specs(cfg: ArchConfig) -> Any:
    """PartitionSpec pytree matching ``init_params`` output."""
    attn_spec = (
        {
            "w_q": cm.spec_in_proj(), "w_dkv": cm.spec_in_proj(),
            "w_krope": P("data", None), "w_uk": P(None, "model"),
            "w_uv": P(None, "model"), "w_o": cm.spec_out_proj(),
        } if cfg.mla is not None else {
            "w_q": cm.spec_in_proj(), "w_k": cm.spec_in_proj(),
            "w_v": cm.spec_in_proj(), "w_o": cm.spec_out_proj(),
            **({"b_q": P("model"), "b_k": P("model"), "b_v": P("model")}
               if cfg.qkv_bias else {}),
        })

    def layer_spec(moe_layer: bool) -> dict:
        p = {"ln_attn": P(), "ln_mlp": P(), "attn": attn_spec}
        if moe_layer:
            moe = {
                "router": P("data", None),
                "w_gate": cm.spec_expert_in(),
                "w_up": cm.spec_expert_in(),
                "w_down": cm.spec_expert_out(),
            }
            if cfg.moe.n_shared:
                moe.update({"shared_gate": cm.spec_in_proj(),
                            "shared_up": cm.spec_in_proj(),
                            "shared_down": cm.spec_out_proj()})
            p["moe"] = moe
        else:
            p["mlp"] = {"w_gate": cm.spec_in_proj(),
                        "w_up": cm.spec_in_proj(),
                        "w_down": cm.spec_out_proj()}
        return p

    n_stack_axes = 2 if cfg.local_per_global else 1
    def stack(spec_tree):
        return jax.tree.map(
            lambda s: P(*([None] * n_stack_axes), *s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    specs: dict = {
        "embed": cm.spec_embed(),
        "final_norm": P(),
        "layers": stack(layer_spec(cfg.moe is not None)),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("data", "model")
    if cfg.n_dense_layers:
        specs["dense_layers"] = [layer_spec(False)
                                 for _ in range(cfg.n_dense_layers)]
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _attn_forward(p, x, positions, cfg: ArchConfig, *, window,
                  with_cache: bool = False):
    if cfg.mla is not None:
        return mla_attention(p, x, positions, cfg, with_cache=with_cache)
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = rope(q.reshape(b, s, cfg.n_heads, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(b, s, cfg.n_kv_heads, hd), positions, cfg.rope_theta)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    out = attention(q, k, v, window=window)
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["w_o"]
    if with_cache:
        return out, {"k": k, "v": v}
    return out


def _layer_forward(p, x, positions, cfg: ArchConfig, *, window,
                   moe_layer: bool, with_cache: bool = False):
    a = _attn_forward(p["attn"], rms_norm(x, p["ln_attn"], cfg.norm_eps),
                      positions, cfg, window=window, with_cache=with_cache)
    kv = None
    if with_cache:
        a, kv = a
    h = x + a
    y = rms_norm(h, p["ln_mlp"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe_block(p["moe"], y, cfg.moe)
    else:
        m = p["mlp"]
        f = (jax.nn.silu(y @ m["w_gate"]) * (y @ m["w_up"])) @ m["w_down"]
        aux = jnp.zeros((), jnp.float32)
    out = cm.constrain_acts(h + f)
    if with_cache:
        return out, aux, kv
    return out, aux


def _backbone(params, x, positions, cfg: ArchConfig):
    """Embedded input -> final hidden states; returns (hidden, aux_loss)."""
    moe_layer = cfg.moe is not None
    aux_total = jnp.zeros((), jnp.float32)

    for p in params.get("dense_layers", []):
        x, _ = _layer_forward(p, x, positions, cfg, window=None,
                              moe_layer=False)

    if cfg.local_per_global:
        group = cfg.local_per_global + 1

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def gbody(carry, gp):
            h, aux = carry
            for i in range(group):
                sub = jax.tree.map(lambda a: a[i], gp)
                win = cfg.sliding_window if i < cfg.local_per_global else None
                h, a = _layer_forward(sub, h, positions, cfg, window=win,
                                      moe_layer=moe_layer)
                aux = aux + a
            return (h, aux), None

        (x, aux_total), _ = jax.lax.scan(gbody, (x, aux_total),
                                         params["layers"])
    else:
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def body(carry, lp):
            h, aux = carry
            h, a = _layer_forward(lp, h, positions, cfg,
                                  window=cfg.sliding_window or None,
                                  moe_layer=moe_layer)
            return (h, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total


def embed_input(params, inp, cfg: ArchConfig):
    if cfg.input_mode == "embeds":
        return inp.astype(cm.dtype_of(cfg))
    return jnp.take(params["embed"], inp, axis=0)


def unembed(params, h, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                      preferred_element_type=jnp.float32)


def forward_hidden(params, inp, cfg: ArchConfig):
    """(B, S) tokens or (B, S, d) embeds -> final hidden states, aux."""
    x = embed_input(params, inp, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return _backbone(params, x, positions, cfg)


def forward(params, inp, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward: (B, S) tokens or (B, S, d) embeds -> logits."""
    h, aux = forward_hidden(params, inp, cfg)
    return unembed(params, h, cfg), aux


def prefill_step(params, inp, cfg: ArchConfig):
    """Forward that also materialises the KV cache (serving prefill)."""
    x = embed_input(params, inp, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    moe_layer = cfg.moe is not None

    dense_caches = []
    for p in params.get("dense_layers", []):
        x, _, kv = _layer_forward(p, x, positions, cfg, window=None,
                                  moe_layer=False, with_cache=True)
        dense_caches.append(kv)

    if cfg.local_per_global:
        group = cfg.local_per_global + 1

        def gbody(h, gp):
            kvs = []
            for i in range(group):
                sub = jax.tree.map(lambda a: a[i], gp)
                win = cfg.sliding_window if i < cfg.local_per_global else None
                h, _, kv = _layer_forward(sub, h, positions, cfg,
                                          window=win, moe_layer=moe_layer,
                                          with_cache=True)
                kvs.append(kv)
            return h, jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)

        x, cache = jax.lax.scan(gbody, x, params["layers"])
    else:
        def body(h, lp):
            h, _, kv = _layer_forward(lp, h, positions, cfg,
                                      window=cfg.sliding_window or None,
                                      moe_layer=moe_layer, with_cache=True)
            return h, kv

        x, cache = jax.lax.scan(body, x, params["layers"])

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, h[:, -1:, :], cfg)
    out = {"layers": cache}
    if cfg.n_dense_layers:
        out["dense_layers"] = dense_caches
    return logits, out


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    """ShapeDtypeStruct pytree of the decode cache (stacked over layers)."""
    dtype = cm.dtype_of(cfg)
    n_scan = cfg.n_layers - cfg.n_dense_layers
    if cfg.mla is not None:
        per = mla_cache_shape(cfg, batch, seq, dtype)
    else:
        hd = cfg.resolved_head_dim
        per = {
            "k": jax.ShapeDtypeStruct((batch, seq, cfg.n_kv_heads, hd),
                                      dtype),
            "v": jax.ShapeDtypeStruct((batch, seq, cfg.n_kv_heads, hd),
                                      dtype),
        }
    def stk(s):
        if cfg.local_per_global:
            group = cfg.local_per_global + 1
            return jax.ShapeDtypeStruct(
                (n_scan // group, group, *s.shape), s.dtype)
        return jax.ShapeDtypeStruct((n_scan, *s.shape), s.dtype)
    out = {"layers": jax.tree.map(stk, per)}
    if cfg.n_dense_layers:
        out["dense_layers"] = [per for _ in range(cfg.n_dense_layers)]
    return out


def cache_specs(cfg: ArchConfig) -> Any:
    """Shard caches over batch (data) and kv-heads (model)."""
    if cfg.mla is not None:
        per = {"c_kv": P("data", None, "model"),
               "k_rope": P("data", None, None, None)}
    else:
        per = {"k": P("data", None, "model", None),
               "v": P("data", None, "model", None)}
    n_axes = 2 if cfg.local_per_global else 1
    stk = jax.tree.map(lambda s: P(*([None] * n_axes), *s), per,
                       is_leaf=lambda x: isinstance(x, P))
    out = {"layers": stk}
    if cfg.n_dense_layers:
        out["dense_layers"] = [per for _ in range(cfg.n_dense_layers)]
    return out


def _attn_decode(p, x, cache, cfg: ArchConfig, *, window):
    """x: (B, 1, d); cache k/v: (B, S, KV, hd). Appends at position S-1."""
    if cfg.mla is not None:
        return mla_decode(p, x, cache, cfg)
    b = x.shape[0]
    sk = cache["k"].shape[1]
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), sk - 1, jnp.int32)
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = rope(q.reshape(b, 1, cfg.n_heads, hd), positions, cfg.rope_theta)
    k = rope(k.reshape(b, 1, cfg.n_kv_heads, hd), positions, cfg.rope_theta)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, sk - 1, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, sk - 1, axis=1)
    out = decode_attention(q, kc, vc, window=window)
    out = out.reshape(b, 1, cfg.n_heads * hd) @ p["w_o"]
    return out, {"k": kc, "v": vc}


def _layer_decode(p, x, cache, cfg: ArchConfig, *, window, moe_layer):
    a, cache = _attn_decode(p["attn"], rms_norm(x, p["ln_attn"],
                                                cfg.norm_eps),
                            cache, cfg, window=window)
    h = x + a
    y = rms_norm(h, p["ln_mlp"], cfg.norm_eps)
    if moe_layer:
        f, _ = moe_block(p["moe"], y, cfg.moe)
    else:
        m = p["mlp"]
        f = (jax.nn.silu(y @ m["w_gate"]) * (y @ m["w_up"])) @ m["w_down"]
    return h + f, cache


def decode_step(params, cache, token, cfg: ArchConfig):
    """One decode step: token (B, 1) (or (B, 1, d) embeds) -> logits, cache."""
    x = embed_input(params, token, cfg)
    moe_layer = cfg.moe is not None

    new_dense = []
    for p, c in zip(params.get("dense_layers", []),
                    cache.get("dense_layers", [])):
        x, c2 = _layer_decode(p, x, c, cfg, window=None, moe_layer=False)
        new_dense.append(c2)

    if cfg.local_per_global:
        group = cfg.local_per_global + 1

        def gbody(h, gp_and_cache):
            gp, gc = gp_and_cache
            new_c = []
            for i in range(group):
                sub = jax.tree.map(lambda a: a[i], gp)
                subc = jax.tree.map(lambda a: a[i], gc)
                win = cfg.sliding_window if i < cfg.local_per_global else None
                h, c2 = _layer_decode(sub, h, subc, cfg, window=win,
                                      moe_layer=moe_layer)
                new_c.append(c2)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_c)
            return h, stacked

        x, new_cache = jax.lax.scan(gbody, x,
                                    (params["layers"], cache["layers"]))
    else:
        def body(h, lp_and_cache):
            lp, lc = lp_and_cache
            h, c2 = _layer_decode(lp, h, lc, cfg,
                                  window=cfg.sliding_window or None,
                                  moe_layer=moe_layer)
            return h, c2

        x, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], cache["layers"]))

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, h, cfg)
    out_cache = {"layers": new_cache}
    if cfg.n_dense_layers:
        out_cache["dense_layers"] = new_dense
    return logits, out_cache
