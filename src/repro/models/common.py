"""Shared model components: norms, RoPE, init, loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(q: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; q: (..., S, H, D), positions: (..., S)."""
    d = q.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    q1, q2 = q[..., :half], q[..., half:]
    out = jnp.concatenate(
        [q1 * cos - q2 * sin, q2 * cos + q1 * sin], axis=-1)
    return out.astype(q.dtype)


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * s).astype(dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (..., V) f32-accumulated."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_cross_entropy(unembed_fn, hidden: jax.Array,
                          labels: jax.Array, *, chunk: int = 512
                          ) -> jax.Array:
    """CE without materialising the full (B, S, V) logits.

    The unembed + softmax runs per sequence-chunk inside a rematerialised
    scan, so the transient is (B, chunk, V) — at gemma3's 262k vocab and
    1M tokens this is the difference between ~4 GB and ~0.5 GB per device
    (see EXPERIMENTS.md §Perf).
    """
    b, s = labels.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    n = s // c
    h_c = hidden.reshape(b, n, c, hidden.shape[-1]).swapaxes(0, 1)
    l_c = labels.reshape(b, n, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, hc_lc):
        hc, lc = hc_lc
        logits = unembed_fn(hc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h_c, l_c))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Sharding vocabulary.  Meshes use axes ("data", "model") and optionally a
# leading "pod" axis that is pure DP (params replicated across pods).
# Large 2-D weights are sharded over BOTH axes (TP on the feature axis,
# FSDP/ZeRO-style on the other) so 100B+ models fit 16 GB chips.
# ---------------------------------------------------------------------------

REPLICATED = P()


def spec_embed() -> P:           # (vocab, d): vocab-TP for the 262k vocabs
    return P("model", "data")


def spec_in_proj() -> P:         # (d, features): features on TP axis
    return P("data", "model")


def spec_out_proj() -> P:        # (features, d)
    return P("model", "data")


def spec_expert_in() -> P:       # (E, d, ff): experts on TP axis (EP)
    return P("model", None, "data")


def spec_expert_out() -> P:      # (E, ff, d)
    return P("model", "data", None)


def spec_vector() -> P:          # per-feature vectors (norm scales, biases)
    return P()


def stack_specs(spec: P) -> P:
    """Prepend the layer-stack axis (unsharded) to a per-layer spec."""
    return P(None, *spec)


# ---------------------------------------------------------------------------
# Activation sharding (Megatron-SP style).  The launcher installs a
# NamedSharding for inter-layer activations: batch over the data axes and
# SEQUENCE over the model axis — the scan carry saved for backward then
# scales down with the whole mesh, and GSPMD inserts the all-gather /
# reduce-scatter pair around each block's matmuls (the SP pattern).
# ---------------------------------------------------------------------------

_ACT_SHARDING = None

# Named beyond-baseline optimisations, toggled by the launcher/dry-run
# (--opt <name>).  Each §Perf iteration is one entry here so A/B lowering
# is a flag flip, not a code fork.
PERF_OPTS: set = set()


class activation_sharding:
    """Context manager: install the inter-layer activation sharding."""

    def __init__(self, mesh, batch_axes=("data",)):
        from jax.sharding import NamedSharding
        self.sharding = NamedSharding(mesh, P(batch_axes, "model", None))

    def __enter__(self):
        global _ACT_SHARDING
        self._old = _ACT_SHARDING
        _ACT_SHARDING = self.sharding
        return self

    def __exit__(self, *exc):
        global _ACT_SHARDING
        _ACT_SHARDING = self._old
        return False


def constrain_acts(h):
    """Apply the installed activation sharding to a (B, S, d) tensor."""
    if _ACT_SHARDING is not None and h.ndim == 3 and h.shape[1] > 1:
        return jax.lax.with_sharding_constraint(h, _ACT_SHARDING)
    return h
