"""Zamba2 — Mamba2 backbone with a SHARED attention block [arXiv:2411.15242].

One transformer block's weights are shared across all its application
sites (every ``shared_attn_every`` SSM layers); each site gets its own
input projection over concat(hidden, original_embedding) — the paper's
parameter-efficient way to give an SSM stack periodic global attention.

Layout: n_layers = head + n_sites * every  (e.g. 38 = 2 + 6*6).  The head
layers run unrolled; then a scan over sites runs (``every`` mamba layers +
the shared attention block).  Each site keeps its own KV cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.attention import attention, decode_attention
from repro.models.common import dense_init, rms_norm, rope
from repro.models.mamba2 import (init_mamba_block, mamba_block,
                                 mamba_block_specs, mamba_cache_shapes,
                                 mamba_cache_specs, mamba_decode)


def _site_layout(cfg: ArchConfig) -> tuple[int, int]:
    every = cfg.shared_attn_every
    n_sites = cfg.n_layers // every
    head = cfg.n_layers - n_sites * every
    return head, n_sites


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = cm.dtype_of(cfg)
    head, n_sites = _site_layout(cfg)
    every = cfg.shared_attn_every
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)

    mb_keys = jax.random.split(ks[0], cfg.n_layers)
    blocks = [init_mamba_block(k, cfg, dtype) for k in mb_keys]
    head_blocks = blocks[:head]
    site_blocks = blocks[head:]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *site_blocks)
    stacked = jax.tree.map(
        lambda x: x.reshape(n_sites, every, *x.shape[1:]), stacked)

    ka = jax.random.split(ks[1], 5)
    shared_attn = {
        "ln": jnp.zeros((2 * d,), dtype),
        "w_q": dense_init(ka[0], (2 * d, cfg.n_heads * hd), dtype),
        "w_k": dense_init(ka[1], (2 * d, cfg.n_kv_heads * hd), dtype),
        "w_v": dense_init(ka[2], (2 * d, cfg.n_kv_heads * hd), dtype),
        "w_o": dense_init(ka[3], (cfg.n_heads * hd, d), dtype),
        "ln_mlp": jnp.zeros((d,), dtype),
        "w_gate": dense_init(ka[4], (d, cfg.d_ff), dtype),
        "w_up": dense_init(ka[4], (d, cfg.d_ff), dtype),
        "w_down": dense_init(ka[4], (cfg.d_ff, d), dtype),
    }
    site_proj = dense_init(ks[2], (n_sites, d, d), dtype, scale=0.02)

    return {
        "embed": dense_init(ks[3], (cfg.vocab, cfg.d_model), dtype,
                            scale=1.0),
        "head_layers": [b for b in head_blocks],
        "site_layers": stacked,
        "shared_attn": shared_attn,
        "site_proj": site_proj,                   # per-site output adapter
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": dense_init(ks[4], (d, cfg.vocab), dtype),
    }


def param_specs(cfg: ArchConfig):
    head, n_sites = _site_layout(cfg)
    block = mamba_block_specs(cfg)
    return {
        "embed": cm.spec_embed(),
        "head_layers": [block for _ in range(head)],
        "site_layers": jax.tree.map(lambda s: P(None, None, *s), block,
                                    is_leaf=lambda x: isinstance(x, P)),
        "shared_attn": {
            "ln": P(), "w_q": cm.spec_in_proj(), "w_k": cm.spec_in_proj(),
            "w_v": cm.spec_in_proj(), "w_o": cm.spec_out_proj(),
            "ln_mlp": P(), "w_gate": cm.spec_in_proj(),
            "w_up": cm.spec_in_proj(), "w_down": cm.spec_out_proj(),
        },
        "site_proj": P(None, "data", "model"),
        "final_norm": P(),
        "lm_head": P("data", "model"),
    }


def _shared_attn_forward(sp, proj, h, emb0, positions, cfg: ArchConfig):
    """Shared block over concat(hidden, original embedding)."""
    b, s, d = h.shape
    hd = cfg.resolved_head_dim
    xin = jnp.concatenate([h, emb0], axis=-1)
    xin = rms_norm(xin, sp["ln"], cfg.norm_eps)
    q = rope((xin @ sp["w_q"]).reshape(b, s, cfg.n_heads, hd), positions,
             cfg.rope_theta)
    k = rope((xin @ sp["w_k"]).reshape(b, s, cfg.n_kv_heads, hd), positions,
             cfg.rope_theta)
    v = (xin @ sp["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)
    a = attention(q, k, v).reshape(b, s, cfg.n_heads * hd)
    h = h + (a @ sp["w_o"]) @ proj
    y = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
    return h + (jax.nn.silu(y @ sp["w_gate"]) * (y @ sp["w_up"])) @ sp["w_down"]


def forward_hidden(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    emb0 = x
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    for blk in params["head_layers"]:
        x = mamba_block(blk, x, cfg)

    every = cfg.shared_attn_every
    sp = params["shared_attn"]

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def site_body(h, site):
        blocks, proj = site
        for i in range(every):
            h = mamba_block(jax.tree.map(lambda a: a[i], blocks), h, cfg)
        h = _shared_attn_forward(sp, proj, h, emb0, positions, cfg)
        return h, None

    x, _ = jax.lax.scan(site_body, x,
                        (params["site_layers"], params["site_proj"]))
    return rms_norm(x, params["final_norm"], cfg.norm_eps), \
        jnp.zeros((), jnp.float32)


def unembed(params, h, cfg: ArchConfig):
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                      preferred_element_type=jnp.float32)


def forward(params, tokens, cfg: ArchConfig):
    h, aux = forward_hidden(params, tokens, cfg)
    return unembed(params, h, cfg), aux


def prefill_step(params, tokens, cfg: ArchConfig):
    """Forward collecting SSM states + per-site KV caches."""
    x = jnp.take(params["embed"], tokens, axis=0)
    emb0 = x
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    every = cfg.shared_attn_every
    sp = params["shared_attn"]
    hd = cfg.resolved_head_dim

    head_caches = []
    for blk in params["head_layers"]:
        x, (conv_tail, state) = mamba_block(blk, x, cfg, return_state=True)
        head_caches.append({"conv": conv_tail, "state": state})

    def site_body(h, site):
        blocks, proj = site
        mcs = []
        for i in range(every):
            h, (ct, st) = mamba_block(jax.tree.map(lambda a: a[i], blocks),
                                      h, cfg, return_state=True)
            mcs.append({"conv": ct, "state": st})
        xin = jnp.concatenate([h, emb0], axis=-1)
        xin = rms_norm(xin, sp["ln"], cfg.norm_eps)
        q = rope((xin @ sp["w_q"]).reshape(b, s, cfg.n_heads, hd),
                 positions, cfg.rope_theta)
        k = rope((xin @ sp["w_k"]).reshape(b, s, cfg.n_kv_heads, hd),
                 positions, cfg.rope_theta)
        v = (xin @ sp["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)
        a = attention(q, k, v).reshape(b, s, cfg.n_heads * hd)
        h = h + (a @ sp["w_o"]) @ proj
        y = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
        h = h + (jax.nn.silu(y @ sp["w_gate"])
                 * (y @ sp["w_up"])) @ sp["w_down"]
        return h, (jax.tree.map(lambda *xs: jnp.stack(xs), *mcs), k, v)

    x, (site_mc, ks_, vs_) = jax.lax.scan(
        site_body, x, (params["site_layers"], params["site_proj"]))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h[:, -1:, :], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"head": head_caches, "sites_mamba": site_mc,
                    "attn_k": ks_, "attn_v": vs_}


def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    head, n_sites = _site_layout(cfg)
    every = cfg.shared_attn_every
    per_mamba = mamba_cache_shapes(cfg, batch)
    hd = cfg.resolved_head_dim
    dtype = cm.dtype_of(cfg)
    kv = jax.ShapeDtypeStruct((n_sites, batch, seq, cfg.n_kv_heads, hd),
                              dtype)
    return {
        "head": [per_mamba for _ in range(head)],
        "sites_mamba": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_sites, every, *s.shape),
                                           s.dtype), per_mamba),
        "attn_k": kv, "attn_v": kv,
    }


def cache_specs(cfg: ArchConfig):
    per = mamba_cache_specs(cfg)
    head, _ = _site_layout(cfg)
    kv_spec = P(None, "data", None, "model", None)
    return {
        "head": [per for _ in range(head)],
        "sites_mamba": jax.tree.map(lambda s: P(None, None, *s), per,
                                    is_leaf=lambda x: isinstance(x, P)),
        "attn_k": kv_spec, "attn_v": kv_spec,
    }


def decode_step(params, cache, token, cfg: ArchConfig):
    x = jnp.take(params["embed"], token, axis=0)
    emb0 = x
    b = x.shape[0]
    sk = cache["attn_k"].shape[2]
    positions = jnp.full((b, 1), sk - 1, jnp.int32)
    every = cfg.shared_attn_every
    sp = params["shared_attn"]
    hd = cfg.resolved_head_dim

    new_head = []
    for blk, c in zip(params["head_layers"], cache["head"]):
        x, c2 = mamba_decode(blk, x, c, cfg)
        new_head.append(c2)

    def site_body(h, site):
        blocks, proj, mcache, kc, vc = site
        new_mc = []
        for i in range(every):
            h, c2 = mamba_decode(jax.tree.map(lambda a: a[i], blocks), h,
                                 jax.tree.map(lambda a: a[i], mcache), cfg)
            new_mc.append(c2)
        xin = jnp.concatenate([h, emb0], axis=-1)
        xin = rms_norm(xin, sp["ln"], cfg.norm_eps)
        q = rope((xin @ sp["w_q"]).reshape(b, 1, cfg.n_heads, hd),
                 positions, cfg.rope_theta)
        k = rope((xin @ sp["w_k"]).reshape(b, 1, cfg.n_kv_heads, hd),
                 positions, cfg.rope_theta)
        v = (xin @ sp["w_v"]).reshape(b, 1, cfg.n_kv_heads, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, sk - 1, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, sk - 1, axis=1)
        a = decode_attention(q, kc, vc).reshape(b, 1, cfg.n_heads * hd)
        h = h + (a @ sp["w_o"]) @ proj
        y = rms_norm(h, sp["ln_mlp"], cfg.norm_eps)
        h = h + (jax.nn.silu(y @ sp["w_gate"])
                 * (y @ sp["w_up"])) @ sp["w_down"]
        stacked_mc = jax.tree.map(lambda *xs: jnp.stack(xs), *new_mc)
        return h, (stacked_mc, kc, vc)

    x, (new_sites, new_k, new_v) = jax.lax.scan(
        site_body, x,
        (params["site_layers"], params["site_proj"],
         cache["sites_mamba"], cache["attn_k"], cache["attn_v"]))

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"head": new_head, "sites_mamba": new_sites,
                    "attn_k": new_k, "attn_v": new_v}
