"""Model zoo: the 10 assigned architectures as config-driven JAX models.

  common       norms, RoPE, initialisation, loss
  attention    chunked flash-style GQA attention (+sliding window, KV cache)
  mla          DeepSeek multi-head latent attention (compressed KV cache)
  moe          GShard-style top-k mixture with expert parallelism
  transformer  config-driven decoder LM (covers 8 of 10 archs)
  mamba2       SSD (state-space duality) backbone
  zamba2       hybrid: Mamba2 backbone + shared attention block
  api          build_model(cfg) -> Model(init, forward, prefill, decode, specs)
"""
from repro.models.api import Model, build_model

__all__ = ["Model", "build_model"]
