"""Mamba2 — SSD (state-space duality) backbone [arXiv:2405.21060].

Chunked SSD forward: the sequence is split into chunks of Q tokens; within
a chunk the output is a masked quadratic form (the "attention-like" dual),
across chunks a linear state recurrence carries (H, P, N) states.  Decode
is a single O(1) state update — why this family runs the long_500k cell.

Shapes: inner = expand * d_model = H * P heads; B/C share one state group
(ngroups = 1, the published 370M config).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models.common import dense_init, rms_norm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    return inner, n_heads, s.head_dim, s.state_dim


def init_mamba_block(key, cfg: ArchConfig, dtype) -> dict:
    inner, h, p_dim, n = _dims(cfg)
    conv_dim = inner + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((cfg.d_model,), dtype),
        "in_proj": dense_init(ks[0],
                              (cfg.d_model, 2 * inner + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm.conv_width, conv_dim), dtype,
                             scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.zeros((inner,), dtype),
        "out_proj": dense_init(ks[2], (inner, cfg.d_model), dtype),
    }


def mamba_block_specs(cfg: ArchConfig) -> dict:
    return {
        "ln": P(), "in_proj": cm.spec_in_proj(), "conv_w": P(None, "model"),
        "conv_b": P("model"), "A_log": P(), "D": P(), "dt_bias": P(),
        "gate_norm": P("model"), "out_proj": cm.spec_out_proj(),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds (width is small & static)."""
    width = w.shape[0]
    out = xbc * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        shifted = shifted[:, :xbc.shape[1], :]
        out = out + shifted * w[width - 1 - i]
    return jax.nn.silu(out + b)


def _segsum(dacum: jax.Array) -> jax.Array:
    """L[l, s] = exp(dacum[l] - dacum[s]) masked to l >= s; (..., Q)."""
    q = dacum.shape[-1]
    diff = dacum[..., :, None] - dacum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def _ssd_chunked(xs, dt, bmat, cmat, a_log, chunk: int):
    """Chunked SSD scan.

    xs: (B, S, H, P)  dt: (B, S, H)  bmat/cmat: (B, S, N)
    Returns y (B, S, H, P) and the final state (B, H, P, N).
    """
    b, s, h, p = xs.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    a = -jnp.exp(a_log)                                   # (H,)
    da = dt * a                                           # (B, S, H)

    xs_c = xs.reshape(b, nc, q, h, p)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    b_c = bmat.reshape(b, nc, q, n)
    c_c = cmat.reshape(b, nc, q, n)

    dacum = jnp.cumsum(da_c, axis=2)                      # (B, C, Q, H)
    xdt = xs_c * dt_c[..., None]                          # (B, C, Q, H, P)

    # ---- intra-chunk (quadratic dual) --------------------------------
    lmat = _segsum(jnp.moveaxis(dacum, -1, -2))           # (B, C, H, Q, Q)
    scores = jnp.einsum("bcln,bcsn->bcls", c_c, b_c,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores, lmat, xdt.astype(jnp.float32))

    # ---- chunk states + inter-chunk recurrence ------------------------
    decay_out = jnp.exp(dacum[:, :, -1:, :] - dacum)      # (B, C, Q, H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", b_c.astype(jnp.float32),
                        decay_out, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(dacum[:, :, -1, :])             # (B, C, H)

    def scan_fn(carry, inp):
        st_c, dec = inp
        new = carry * dec[..., None, None] + st_c
        return new, carry                                 # emit PRE-state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B, C, H, P, N)

    decay_in = jnp.exp(dacum)                             # (B, C, Q, H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       c_c.astype(jnp.float32), prev_states, decay_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(xs.dtype), final


def mamba_block(params, x, cfg: ArchConfig, *, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) [+ (conv_tail, state) when prefilling]."""
    inner, h, p_dim, n = _dims(cfg)
    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    proj = xn @ params["in_proj"]
    z = proj[..., :inner]
    xbc = proj[..., inner:inner + inner + 2 * n]
    dt_raw = proj[..., -h:]
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :inner].reshape(*xbc.shape[:2], h, p_dim)
    bmat = xbc[..., inner:inner + n]
    cmat = xbc[..., inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    y, state = _ssd_chunked(xs, dt, bmat, cmat, params["A_log"],
                            cfg.ssm.chunk)
    y = y + (params["D"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*y.shape[:2], inner)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = cm.constrain_acts(res + y @ params["out_proj"])
    if return_state:
        w = cfg.ssm.conv_width
        pre_conv = proj[..., inner:inner + inner + 2 * n]
        conv_tail = pre_conv[:, -(w - 1):, :]
        return out, (conv_tail, state)
    return out


def mamba_decode(params, x, cache, cfg: ArchConfig):
    """One-token state update.  cache = {"conv": (B, W-1, CD), "state": ...}."""
    inner, h, p_dim, n = _dims(cfg)
    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    proj = xn @ params["in_proj"]                         # (B, 1, ...)
    z = proj[..., :inner]
    xbc_new = proj[..., inner:inner + inner + 2 * n]
    dt_raw = proj[..., -h:]
    # conv over [cached, new]
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, W, CD)
    w = params["conv_w"]
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w)
                      + params["conv_b"])[:, None, :]
    xs = xbc[..., :inner].reshape(-1, 1, h, p_dim)
    bmat = xbc[..., inner:inner + n]
    cmat = xbc[..., inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[:, 0, :] * a)                         # (B, H)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32),
        (xs[:, 0] * dt[:, 0, :, None]).astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), state)
    y = y + params["D"][:, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(-1, 1, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = res + y @ params["out_proj"]
    new_cache = {"conv": window[:, 1:, :], "state": state}
    return out, new_cache


def mamba_cache_shapes(cfg: ArchConfig, batch: int):
    inner, h, p_dim, n = _dims(cfg)
    conv_dim = inner + 2 * n
    w = cfg.ssm.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, w - 1, conv_dim),
                                     cm.dtype_of(cfg)),
        "state": jax.ShapeDtypeStruct((batch, h, p_dim, n), jnp.float32),
    }


def mamba_cache_specs(cfg: ArchConfig):
    return {"conv": P("data", None, "model"),
            "state": P("data", "model", None, None)}


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> dict:
    dtype = cm.dtype_of(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = [init_mamba_block(k, cfg, dtype) for k in keys]
    return {
        "embed": dense_init(k_embed, (cfg.vocab, cfg.d_model), dtype,
                            scale=1.0),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *stacked),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab), dtype),
    }


def param_specs(cfg: ArchConfig):
    block = mamba_block_specs(cfg)
    return {
        "embed": cm.spec_embed(),
        "layers": jax.tree.map(lambda s: P(None, *s), block,
                               is_leaf=lambda x: isinstance(x, P)),
        "final_norm": P(),
        "lm_head": P("data", "model"),
    }


def forward_hidden(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(h, lp):
        return mamba_block(lp, h, cfg), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), \
        jnp.zeros((), jnp.float32)


def unembed(params, h, cfg: ArchConfig):
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                      preferred_element_type=jnp.float32)


def forward(params, tokens, cfg: ArchConfig):
    h, aux = forward_hidden(params, tokens, cfg)
    return unembed(params, h, cfg), aux


def prefill_step(params, tokens, cfg: ArchConfig):
    """Forward that also returns the (conv tail, SSM state) caches."""
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(h, lp):
        h, (conv_tail, state) = mamba_block(lp, h, cfg, return_state=True)
        return h, {"conv": conv_tail, "state": state}

    x, cache = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h[:, -1:, :], params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"layers": cache}


def cache_shapes(cfg: ArchConfig, batch: int, seq: int):
    per = mamba_cache_shapes(cfg, batch)
    return {"layers": jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers, *s.shape), s.dtype),
        per)}


def cache_specs(cfg: ArchConfig):
    per = mamba_cache_specs(cfg)
    return {"layers": jax.tree.map(lambda s: P(None, *s), per,
                                   is_leaf=lambda x: isinstance(x, P))}


def decode_step(params, cache, token, cfg: ArchConfig):
    x = jnp.take(params["embed"], token, axis=0)

    def body(h, lp_lc):
        lp, lc = lp_lc
        h, c2 = mamba_decode(lp, h, lc, cfg)
        return h, c2

    x, new_cache = jax.lax.scan(body, x, (params["layers"],
                                          cache["layers"]))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return logits, {"layers": new_cache}
