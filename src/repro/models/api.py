"""Unified model API: build_model(cfg) -> Model.

All three implementations (transformer / mamba2 / zamba2) expose the same
five functions so the launcher, trainer and dry-run treat every assigned
architecture uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ArchConfig
from repro.models import mamba2, transformer, zamba2


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]              # (rng) -> params
    forward: Callable[..., Any]           # (params, inp) -> (logits, aux)
    prefill: Callable[..., Any]           # (params, inp) -> (logits, cache)
    decode: Callable[..., Any]            # (params, cache, tok) -> (logits, cache)
    forward_hidden: Callable[..., Any]    # (params, inp) -> (hidden, aux)
    unembed: Callable[..., Any]           # (params, hidden) -> logits
    param_specs: Callable[[], Any]
    cache_shapes: Callable[..., Any]      # (batch, seq) -> SDS pytree
    cache_specs: Callable[[], Any]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "hybrid":
        mod = zamba2
    elif cfg.family == "ssm":
        mod = mamba2
    else:
        mod = transformer
    return Model(
        cfg=cfg,
        init=lambda rng: mod.init_params(rng, cfg),
        forward=lambda params, inp: mod.forward(params, inp, cfg),
        prefill=lambda params, inp: mod.prefill_step(params, inp, cfg),
        decode=lambda params, cache, tok: mod.decode_step(params, cache,
                                                          tok, cfg),
        forward_hidden=lambda params, inp: mod.forward_hidden(params, inp,
                                                              cfg),
        unembed=lambda params, h: mod.unembed(params, h, cfg),
        param_specs=lambda: mod.param_specs(cfg),
        cache_shapes=lambda batch, seq: mod.cache_shapes(cfg, batch, seq),
        cache_specs=lambda: mod.cache_specs(cfg),
    )
