"""GShard-style top-k mixture-of-experts with expert parallelism.

Dispatch is capacity-based within token groups: tokens are split into
``n_groups`` groups that route independently, keeping the one-hot dispatch
tensor small (the standard GShard/Switch trick).  Experts are sharded over
the ``model`` mesh axis (expert parallelism): the dispatch einsum induces
the all-to-all that shows up in the roofline's collective term — this is
the collective-bound cell class the DVFS planner flags (EXPERIMENTS.md).

Router: softmax top-k, probabilities renormalised over the selected
experts, with an auxiliary load-balancing loss (Switch-style).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import common as cm
from repro.models.common import dense_init


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    ks = jax.random.split(key, 5)
    e = cfg.n_experts
    ff = cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d_model, ff), dtype),
        "w_up": dense_init(ks[2], (e, d_model, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d_model), dtype),
    }
    if cfg.n_shared:
        p["shared_gate"] = dense_init(ks[4], (d_model, ff * cfg.n_shared),
                                      dtype)
        p["shared_up"] = dense_init(ks[4], (d_model, ff * cfg.n_shared),
                                    dtype)
        p["shared_down"] = dense_init(ks[4], (ff * cfg.n_shared, d_model),
                                      dtype)
    return p


def moe_block(params: dict, x: jax.Array, cfg: MoEConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    gs = min(cfg.group_size, t)
    while t % gs:
        gs //= 2
    g = t // gs
    tg = tokens.reshape(g, gs, d)                         # (G, Tg, d)
    tg_per = gs
    cap = max(int(tg_per * cfg.top_k / cfg.n_experts * cfg.capacity_factor),
              cfg.top_k)

    logits = jnp.einsum("gtd,de->gte", tg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)               # (G, Tg, E)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)          # (G, Tg, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # Switch aux loss: fraction-of-tokens x mean router prob per expert.
    frac = jnp.mean(
        jax.nn.one_hot(topi[..., 0], cfg.n_experts), axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    # Capacity positions: cumulative count of each expert along the group.
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.int32)  # (G,Tg,K,E)
    flatoh = onehot.reshape(g, tg_per * cfg.top_k, cfg.n_experts)
    pos = jnp.cumsum(flatoh, axis=1) - 1                  # position per slot
    pos = pos.reshape(g, tg_per, cfg.top_k, cfg.n_experts)
    slot = jnp.sum(pos * onehot, axis=-1)                 # (G, Tg, K)
    keep = slot < cap
    gate = topv * keep

    # Dispatch tensor (G, Tg, E, C) — the GShard one-hot pair.
    disp = (jax.nn.one_hot(topi, cfg.n_experts, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                             dtype=x.dtype)[..., :cap][..., None, :])
    disp = jnp.sum(disp, axis=2)                          # (G, Tg, E, C)
    expert_in = jnp.einsum("gtec,gtd->egcd", disp, tg)    # (E, G, C, d)

    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in,
                               params["w_gate"])) * jnp.einsum(
        "egcd,edf->egcf", expert_in, params["w_up"])
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"])

    combine = (gate[..., None, None]
               * jax.nn.one_hot(topi, cfg.n_experts, dtype=x.dtype)[..., None]
               * jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                                dtype=x.dtype)[..., :cap][..., None, :])
    combine = jnp.sum(combine, axis=2).astype(x.dtype)    # (G, Tg, E, C)
    out = jnp.einsum("gtec,egcd->gtd", combine, expert_out)

    if "shared_gate" in params:
        sh = jax.nn.silu(tg @ params["shared_gate"]) * (tg @ params["shared_up"])
        out = out + sh @ params["shared_down"]

    out = out.reshape(b, s, d)
    if "moe_seq_combine" in cm.PERF_OPTS:
        # §Perf: force the combine einsum's TP reduction to land directly
        # in the SP (sequence-sharded) layout -> GSPMD emits reduce-scatter
        # instead of all-reduce (1/16th the bytes on a 16-way model axis).
        out = cm.constrain_acts(out)
    return out, aux
