"""AdamW with decoupled weight decay and global-norm clipping.

Implemented directly (no optax dependency) so the optimizer state pytree
can carry the same PartitionSpecs as the parameters (ZeRO-style sharding:
m/v inherit the weight's spec, so optimizer memory scales down with the
mesh exactly like the weights do).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(
    params, grads, state: AdamWState, *,
    lr: float | jax.Array = 3e-4,
    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, clip_norm: float | None = 1.0,
):
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + eps)
        # decoupled decay (skip 1-D params: norms/biases)
        if p.ndim >= 2:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


def optimizer_specs(param_specs) -> AdamWState:
    """PartitionSpecs for the optimizer state (m/v mirror the params)."""
    from jax.sharding import PartitionSpec
    return AdamWState(step=PartitionSpec(), m=param_specs, v=param_specs)
