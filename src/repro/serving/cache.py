"""Plan + frequency-sweep cache: plan and sweep once per shape.

The two expensive per-shape artefacts of the paper's method are

  * the FFT plan (algorithm choice + pass count, repro.fft.plan), and
  * the DVFS frequency sweep over the device clock grid (repro.core.dvfs)
    that yields the minimum-energy operating point (Sec. 4).

Both depend only on (kind, length, precision, device), so the service
computes them once per distinct shape and serves every subsequent request
for that shape from the cache; differing real-time budgets re-select an
operating point from the cached sweep without re-sweeping.

``plan_fn`` / ``sweep_fn`` are injectable so tests can count invocations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core import dvfs
from repro.core.energy import OperatingPoint
from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel
from repro.core.workloads import FFTCase, fft_workload
from repro.fft.plan import FFTPlan, plan_for_length
from repro.serving.request import KIND_FDAS, KIND_PULSAR, ShapeKey


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    plan_builds: int = 0
    sweeps: int = 0
    degraded_builds: int = 0    # sweep-free boost-heuristic entries built

    @property
    def hit_rate(self) -> float:
        """Hits / lookups; 0.0 for an untouched cache (no lookups = no
        hits, the guarded_ratio "fraction of events" convention)."""
        from repro.core.energy import guarded_ratio
        return guarded_ratio(self.hits, self.hits + self.misses,
                             on_zero=0.0)

    def fill_metrics(self, registry) -> None:
        """Publish the cache counters into a repro.obs MetricsRegistry."""
        for field, help in (
                ("hits", "plan+sweep cache hits"),
                ("misses", "plan+sweep cache misses"),
                ("plan_builds", "plans compiled"),
                ("sweeps", "DVFS sweeps run"),
                ("degraded_builds", "sweep-free boost-heuristic builds")):
            registry.gauge(f"repro_cache_{field}", help).set(
                getattr(self, field))
        registry.gauge("repro_cache_hit_rate",
                       "hits / lookups (0 when untouched)").set(
                           self.hit_rate)


@dataclasses.dataclass
class CacheEntry:
    """Everything the executor needs for one shape."""

    key: ShapeKey
    plan: FFTPlan | Any | None  # NDPlan for N-D; DispersionPlan for pulsar
    fn: Callable                # jitted executable for the shape
    profile: WorkloadProfile    # analytic workload model of one full batch
    sweep: dvfs.SweepResult     # full clock-grid sweep for ``profile``
    n_fft_model: int            # transforms the modelled batch contains
    # Pulsar-pipeline entries only: the per-stage DVFS plan (clock +
    # modelled J per stage, scheduler.PipelineReport), the locked clocks
    # and the end-to-end real-time margin at those clocks.
    stages: Any | None = None
    locked: dict | None = None
    realtime_margin: float | None = None

    def point_for(self, time_budget: float | None) -> OperatingPoint:
        """Operating point under a real-time budget — from cached points."""
        return self.sweep.optimal_under_budget(time_budget)

    def per_transform(self, point: OperatingPoint) -> tuple[float, float]:
        """(time_s, energy_j) of ONE transform at ``point``.

        The sweep models a canonical memory-budget-sized batch (Eq. 6);
        both time and energy are linear in the transform count, so actual
        batches scale from the per-transform figures.
        """
        return (point.time / self.n_fft_model,
                point.energy / self.n_fft_model)


class PlanSweepCache:
    """(kind, n, precision, device)-keyed cache of plans + sweeps."""

    def __init__(
        self,
        device: DeviceSpec,
        *,
        batch_bytes: float,
        # Called as plan_fn(n) for c2c keys and plan_fn(n, kind) for real
        # transforms — single-arg injectables only serve c2c traffic.
        plan_fn: Callable[..., FFTPlan] = plan_for_length,
        sweep_fn: Callable[..., dvfs.SweepResult] = dvfs.sweep,
        power_model: PowerModel | None = None,
    ):
        self.device = device
        self.batch_bytes = batch_bytes
        self._plan_fn = plan_fn
        self._sweep_fn = sweep_fn
        self._power_model = power_model or PowerModel(device)
        # Entries are keyed on (shape key, active tuned kernel config):
        # the plan a shape resolves to depends on the tuning context, so
        # a re-tune (or toggling REPRO_FFT_DISABLE_TUNING) can never be
        # served a stale plan built under the previous config.
        self._entries: dict[tuple, CacheEntry] = {}
        # Degraded (boost-heuristic) entries are keyed on the bare shape
        # key: the whole point of the rung is to skip tuning lookups and
        # sweeps, so the tuned config can play no part in the build.
        self._degraded: dict[ShapeKey, CacheEntry] = {}
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def _tuned_config(self, key: ShapeKey):
        """The tuned config this key's plan build will resolve to.

        Every kind keys on the config its build actually consults — the
        context memoises, so repeated entry lookups never re-read the
        tuning cache.  FDAS entries with ``segment=0`` resolve the conv
        key exactly like ``fft.convolve.conv_plan`` will at build time
        (an explicit segment is already part of the ShapeKey); pulsar
        entries key on their inner FFT length's config.
        """
        from repro.tune.context import plan_config
        if key.kind == KIND_FDAS:
            if key.segment:
                return None          # segment pinned in the ShapeKey itself
            from repro.search.templates import TemplateBank
            bank = TemplateBank.linear(
                zmax=max((key.templates - 1) / 2.0, 0.0),
                n_templates=key.templates)
            return plan_config((key.n // 2 + 1, bank.taps, key.templates),
                               "conv")
        if key.kind == KIND_PULSAR:
            # The pipeline's tunable inner passes: the R2C over the
            # dedispersed series (length = the filterbank's time axis)
            # and the overlap-save conv against the acceleration bank.
            # Keying on BOTH means a re-tune of either — or a DM-grid /
            # bank change (already in the ShapeKey) — rebuilds the entry.
            from repro.search.templates import TemplateBank
            ntime = key.shape[-1] if key.shape else key.n
            bank = TemplateBank.linear(
                zmax=max((key.templates - 1) / 2.0, 0.0),
                n_templates=key.templates)
            return (plan_config((ntime,), "r2c"),
                    plan_config((ntime // 2 + 1, bank.taps, key.templates),
                                "conv"))
        return plan_config(key.shape or (key.n,), key.transform)

    def entry(self, key: ShapeKey) -> CacheEntry:
        cache_key = (key, self._tuned_config(key))
        cached = self._entries.get(cache_key)
        if cached is not None:
            self.stats.hits += 1
            return cached
        self.stats.misses += 1
        entry = self._build(key)
        self._entries[cache_key] = entry
        return entry

    def peek(self, key: ShapeKey) -> CacheEntry | None:
        """The cached tuned entry, or None — never builds, never counts.

        The admission controller's deterministic backlog estimates read
        cached sweeps through this without perturbing hit/miss stats.
        """
        return self._entries.get((key, self._tuned_config(key)))

    def degraded_entry(self, key: ShapeKey) -> CacheEntry:
        """The degradation ladder's rung-1 entry for ``key``.

        Built with the *heuristic* plan (tuning context bypassed) and NO
        clock-grid sweep — the one operating point is boost, evaluated
        directly — so it is the cheapest entry the service can stand up
        under pressure or after a tuned plan/sweep build failure.
        """
        cached = self._degraded.get(key)
        if cached is not None:
            return cached
        entry = self._build(key, degraded=True)
        self._degraded[key] = entry
        return entry

    def _boost_only_sweep(self, profile: WorkloadProfile) -> dvfs.SweepResult:
        """A single-point 'sweep': the boost clock, evaluated directly."""
        from repro.core.energy import evaluate
        import numpy as np
        boost = evaluate(profile, self.device, self._power_model,
                         np.array([self.device.f_max]))[0]
        return dvfs.SweepResult(profile=profile, points=[boost],
                                optimal=boost, boost=boost, base=None)

    def _build(self, key: ShapeKey, *, degraded: bool = False) -> CacheEntry:
        extras: dict = {}
        if key.kind == KIND_PULSAR:
            plan, fn, profile, n_fft, extras = self._build_pulsar(
                key, degraded=degraded)
        elif key.kind == KIND_FDAS:
            plan, fn, profile, n_fft = self._build_fdas(key)
        else:
            plan, fn, profile, n_fft = self._build_fft(key,
                                                       degraded=degraded)
        if degraded:
            self.stats.degraded_builds += 1
            sweep = self._boost_only_sweep(profile)
        else:
            self.stats.sweeps += 1
            sweep = self._sweep_fn(profile, self.device, self._power_model)
        return CacheEntry(key=key, plan=plan, fn=fn, profile=profile,
                          sweep=sweep, n_fft_model=n_fft, **extras)

    def _build_fft(self, key: ShapeKey, *, degraded: bool = False):
        self.stats.plan_builds += 1
        if key.shape:
            # N-D shapes are first-class: one plan graph (fused
            # transpose-write passes) + one sweep per distinct shape.
            from repro.fft.plan_nd import plan_nd, plan_nd_with_config
            plan = (plan_nd_with_config(key.shape, key.transform)
                    if degraded else plan_nd(key.shape, key.transform))
        elif degraded:
            # Degraded builds bypass the tuning context: the heuristic
            # plan object, no tuning-cache consults.
            from repro.fft.plan import plan_with_config
            plan = plan_with_config(key.n, key.transform)
        elif key.transform == "c2c":
            # The injectable plan_fn keeps its historical (n) signature
            # for C2C; real transforms pass the kind through
            # plan_for_length-style two-argument callables.
            plan = self._plan_fn(key.n)
        else:
            plan = self._plan_fn(key.n, key.transform)
        fn = jax.jit(plan.fn)
        case = FFTCase(n=0 if key.shape else key.n, precision=key.precision,
                       batch_bytes=self.batch_bytes,
                       transform=key.transform,
                       shape=key.shape or None)
        profile = fft_workload(case, self.device)
        return plan, fn, profile, case.n_fft

    def _build_pulsar(self, key: ShapeKey, *, degraded: bool = False):
        """Pulsar-pipeline entries: the full search graph (dedispersion ->
        FDAS -> harmonic sum -> sift) with a per-stage clock plan.
        Degraded builds replace every per-stage clock sweep with the
        boost point (no grid sweeps anywhere on the build path).

        The entry's canonical geometry comes from the ShapeKey alone —
        a default FilterbankSpec at the key's (nchan, ntime), the
        default DM grid at ``dm_trials``, the linear bank at
        ``templates`` — so identical submissions always share one
        compiled graph and one set of sweeps.  The merged four-stage
        profile feeds the entry-level sweep (single-clock serving);
        ``plan_pulsar_stages`` prices the per-stage locks the receipts
        report.
        """
        from repro.data.synthetic import FilterbankSpec
        from repro.search.pipeline import (DispersionPlan,
                                           plan_pulsar_stages,
                                           pulsar_search, serving_sifted)
        from repro.search.templates import TemplateBank
        self.stats.plan_builds += 1
        if len(key.shape) != 2:
            raise ValueError(
                f"pulsar keys need a (nchan, ntime) shape, got {key.shape}")
        nchan, ntime = key.shape
        spec = FilterbankSpec(nchan=nchan, ntime=ntime)
        dplan = DispersionPlan.from_spec(spec, n_trials=key.dm_trials)
        bank = TemplateBank.linear(
            zmax=max((key.templates - 1) / 2.0, 0.0),
            n_templates=key.templates)
        stage_sweep = (
            (lambda profile, device, power_model=None, **kw:
             self._boost_only_sweep(profile))
            if degraded else self._sweep_fn)
        stage_plan = plan_pulsar_stages(
            spec, dplan, bank, key.n_harmonics, self.device,
            batch_bytes=self.batch_bytes, power_model=self._power_model,
            sweep_fn=stage_sweep)

        def fn(x, _plan=dplan, _bank=bank, _h=key.n_harmonics):
            return serving_sifted(
                pulsar_search(x, _plan, _bank, n_harmonics=_h))

        extras = {"stages": stage_plan.report, "locked": stage_plan.locked,
                  "realtime_margin": stage_plan.realtime_margin}
        return (dplan, fn, stage_plan.total_profile,
                stage_plan.case.n_rows, extras)

    def _build_fdas(self, key: ShapeKey):
        """Acceleration-search entries: one template bank, one overlap-save
        plan and one sweep per (n, segment, templates) key.

        The bank and its cached filter spectra are shared process-wide
        (``repro.search.templates`` / ``repro.fft.convolve`` caches); the
        entry pins the jitted search closure and the merged stage profile
        the sweep prices.
        """
        from repro.core.workloads import ConvCase, fdas_total_profile
        from repro.search.fdas import fdas_search, serving_candidates
        from repro.search.templates import TemplateBank
        self.stats.plan_builds += 1
        n = key.n
        bank = TemplateBank.linear(zmax=max((key.templates - 1) / 2.0, 0.0),
                                   n_templates=key.templates)
        case = ConvCase(n=n // 2 + 1, templates=key.templates,
                        taps=bank.taps, nfft=key.segment,
                        precision=key.precision,
                        batch_bytes=self.batch_bytes)
        profile = fdas_total_profile(case, self.device, series_n=n)
        nfft = key.segment or None

        def fn(x, _bank=bank, _nfft=nfft):
            return serving_candidates(fdas_search(x, _bank, nfft=_nfft))

        # Per-transform receipts divide by the row count the swept profile
        # actually models: ConvCase.n_rows (real half-spectrum rows), NOT
        # the complex-bytes Eq. 6 cap — keeps FDAS receipts consistent
        # with plain r2c ones at the same series length.
        return case.plan, fn, profile, case.n_rows
