"""Energy-aware streaming FFT serving (the paper's method, as a runtime).

  request    FFTRequest / RequestReceipt / ShapeKey
  batcher    Eq. 6 memory-budgeted request coalescing
  cache      plan + DVFS-sweep cache (one sweep per shape, ever)
  dispatch   work-stealing batch placement across devices
  service    FFTService: enqueue -> batch -> plan-cache -> clock-plan ->
             execute -> account (see docs/serving.md)
"""
from repro.serving.batcher import Batch, coalesce
from repro.serving.cache import CacheEntry, CacheStats, PlanSweepCache
from repro.serving.dispatch import Dispatcher
from repro.serving.request import (KIND_FDAS, KIND_FFT, KIND_PULSAR,
                                   FFTRequest, RequestReceipt, ShapeKey)
from repro.serving.service import FFTService, ServiceReport

__all__ = [
    "Batch", "CacheEntry", "CacheStats", "Dispatcher", "FFTRequest",
    "FFTService", "KIND_FDAS", "KIND_FFT", "KIND_PULSAR", "PlanSweepCache",
    "RequestReceipt", "ServiceReport", "ShapeKey", "coalesce",
]
