"""Energy-aware streaming FFT serving (the paper's method, as a runtime).

  request    FFTRequest / RequestReceipt / ShapeKey
  batcher    Eq. 6 memory-budgeted request coalescing
  cache      plan + DVFS-sweep cache (one sweep per shape, ever)
  dispatch   work-stealing batch placement across devices
  slo        per-kind SLO budgets, admission control / load shedding and
             the graceful-degradation ladder (docs/robustness.md)
  service    FFTService: enqueue -> batch -> plan-cache -> clock-plan ->
             execute -> account (see docs/serving.md)
  recovery   crash recovery from the write-ahead request journal:
             snapshot/restore, journal replay, exactly-once receipts
             (see docs/recovery.md)
"""
from repro.serving.batcher import Batch, coalesce
from repro.serving.cache import CacheEntry, CacheStats, PlanSweepCache
from repro.serving.dispatch import Dispatcher
from repro.serving.recovery import (RecoveredRequest, ReplayResult,
                                    ServiceSnapshot, recover_service,
                                    replay_journal)
from repro.serving.request import (KIND_FDAS, KIND_FFT, KIND_PULSAR,
                                   FFTRequest, RequestReceipt, ShapeKey)
from repro.serving.service import FFTService, ServiceReport
from repro.serving.slo import (RUNG_BOOST_HEURISTIC, RUNG_PURE_JAX,
                               RUNG_TUNED_DVFS, SLO, AdmissionController,
                               AdmissionDecision, SLOPolicy,
                               max_rung_for_kind, rung_name)

__all__ = [
    "AdmissionController", "AdmissionDecision", "Batch", "CacheEntry",
    "CacheStats", "Dispatcher", "FFTRequest", "FFTService", "KIND_FDAS",
    "KIND_FFT", "KIND_PULSAR", "PlanSweepCache", "RecoveredRequest",
    "ReplayResult", "RequestReceipt", "RUNG_BOOST_HEURISTIC",
    "RUNG_PURE_JAX", "RUNG_TUNED_DVFS", "SLO", "SLOPolicy",
    "ServiceReport", "ServiceSnapshot", "ShapeKey", "coalesce",
    "max_rung_for_kind", "recover_service", "replay_journal", "rung_name",
]
