"""Request coalescing: group compatible requests into memory-bounded batches.

The paper's Eq. (6), N_FFT = M_GB / (N * B), sizes a batch by how many
length-N transforms fit a memory budget.  The batcher applies exactly that
cap: pending requests are grouped by shape key (same kind, length,
precision — transforms of different lengths cannot share one plan), kept
in FIFO arrival order, and split whenever the accumulated transform count
would exceed the Eq. 6 budget.

A single request larger than the budget is never split (a client's batch
is one array); it becomes an oversized batch of its own, which the
executor shards across devices instead.
"""
from __future__ import annotations

import dataclasses

from repro.core.energy import ffts_per_batch
from repro.serving.request import FFTRequest, ShapeKey


@dataclasses.dataclass
class Batch:
    """One executable unit: same-shape requests fused into a single call."""

    batch_id: int
    key: ShapeKey
    requests: list[FFTRequest]

    @property
    def n_transforms(self) -> int:
        return sum(r.batch for r in self.requests)

    @property
    def bytes(self) -> int:
        """Payload footprint at the batch's executed precision (real for
        pow2 r2c payloads, complex otherwise)."""
        return self.n_transforms * self.key.n * self.key.elem_bytes

    @property
    def latency_budget(self) -> float | None:
        """Strictest (smallest) per-request budget governs the whole batch."""
        budgets = [r.latency_budget for r in self.requests
                   if r.latency_budget is not None]
        return min(budgets) if budgets else None


def coalesce(
    pending: list[FFTRequest],
    *,
    device_name: str,
    batch_bytes: float,
    start_id: int = 0,
) -> list[Batch]:
    """Coalesce ``pending`` (arrival order) into memory-bounded batches."""
    groups: dict[ShapeKey, list[FFTRequest]] = {}
    order: list[ShapeKey] = []
    for req in pending:
        key = req.shape_key(device_name)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(req)

    batches: list[Batch] = []
    next_id = start_id
    for key in order:
        # Eq. 6 cap at the bytes the batch will actually occupy: pow2 r2c
        # payloads execute as real arrays, so twice as many fit.
        cap = ffts_per_batch(batch_bytes, key.n, key.elem_bytes)
        current: list[FFTRequest] = []
        count = 0
        for req in groups[key]:
            if current and count + req.batch > cap:
                batches.append(Batch(next_id, key, current))
                next_id += 1
                current, count = [], 0
            current.append(req)
            count += req.batch
        if current:
            batches.append(Batch(next_id, key, current))
            next_id += 1
    return batches
