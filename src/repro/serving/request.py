"""Request and receipt types for the energy-aware FFT service.

A request is a batch of same-length transforms submitted by one client;
a receipt is everything the paper would report about serving it: which
clock it ran at, its modelled energy (Eqs. 3-4), and its measured queue +
service latency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

from repro.core.workloads import COMPLEX_BYTES, is_pow2

_REQUEST_IDS = itertools.count()

#: Request kinds the service understands.
KIND_FFT = "fft"            # batched 1-D C2C transform (the paper's workload)
KIND_PULSAR = "pulsar"      # end-to-end pulsar search (repro.search.pipeline)
KIND_FDAS = "fdas"          # Fourier-domain acceleration search (repro.search)


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Cache key: one plan + one frequency sweep per distinct value.

    The latency budget is deliberately NOT part of the key — budgets only
    re-select a point from the cached sweep (SweepResult.optimal_under_budget),
    they never require re-planning or re-sweeping.

    ``shape`` makes N-D transforms first-class: () for the 1-D workload,
    the transform-axes lengths (e.g. a 2-D image's (n0, n1)) otherwise —
    each distinct shape compiles one plan graph (repro.fft.plan_nd) and
    one sweep, cached forever.  ``n`` is always the total points per
    transform, so Eq. 6 batch caps work unchanged.
    """

    kind: str
    n: int
    precision: str
    n_harmonics: int = 0            # pulsar requests only; 0 for plain FFTs
    device: str = ""
    transform: str = "c2c"          # "c2c" | "r2c" — distinct plans + sweeps
    shape: tuple[int, ...] = ()     # N-D transform-axes lengths; () for 1-D
    templates: int = 0              # fdas/pulsar: acceleration-bank size
    segment: int = 0                # fdas: overlap-save nfft (0 = auto)
    dm_trials: int = 0              # pulsar: dedispersion DM-grid size

    @property
    def last_axis(self) -> int:
        """The axis length R2C packing applies to (the last transform axis)."""
        return self.shape[-1] if self.shape else self.n

    @property
    def elem_bytes(self) -> int:
        """Per-point device bytes of this shape's payload.

        R2C payloads at pow2 lengths execute as real arrays — half the
        complex footprint, so Eq. 6 fits twice as many per batch.  Non-pow2
        r2c falls back to the full C2C algorithm (repro.fft.plan), so it
        pays complex bytes and must be capped accordingly.  N-D payloads
        pack along the last transform axis.  Pulsar filterbanks are real
        samples regardless of length.  Must stay in lockstep with
        ``core.workloads.FFTCase.elem_bytes`` /
        ``core.workloads.PulsarCase.sample_bytes`` (the cost-model twins).
        """
        full = COMPLEX_BYTES[self.precision]
        if self.kind == KIND_PULSAR:
            return full // 2
        if self.transform == "r2c" and is_pow2(self.last_axis):
            return full // 2
        return full


@dataclasses.dataclass
class FFTRequest:
    """One client submission: ``x`` rows are independent transforms.

    ``ndim`` is the transform rank: 1 (default) serves the paper's 1-D
    workload from (batch, n) / (n,) payloads; 2+ serves N-D transforms
    from (batch, *shape) / (*shape,) payloads through the plan-graph
    engine (one fused pass per pow2 axis).
    """

    x: Any                               # (batch, *shape) or (*shape,) array
    precision: str = "fp32"
    kind: str = KIND_FFT
    latency_budget: float | None = None  # max tolerable slowdown vs boost
    n_harmonics: int = 32                # pulsar kind only
    transform: str = "c2c"               # "c2c" or "r2c" (real payloads)
    ndim: int = 1                        # transform rank (2 for fft2 jobs)
    templates: int = 16                  # fdas/pulsar: bank size
    segment: int = 0                     # fdas kind only: nfft (0 = auto)
    dm_trials: int = 16                  # pulsar kind only: DM-grid size
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))
    t_enqueue: float = 0.0               # stamped by the service
    # Durable identity (repro.runtime.journal): request_id restarts with
    # the process, jseq never does.  None on journal-less services.
    jseq: int | None = None              # journal admit sequence number
    # An opaque, JSON-safe token the *client* can resolve back to the
    # payload (a stream index, an object-store key).  Journaled with the
    # admit record so recovery can re-materialise in-flight payloads.
    payload_ref: Any = None

    def __post_init__(self):
        if self.precision not in COMPLEX_BYTES:
            raise ValueError(
                f"unknown precision {self.precision!r}; "
                f"have {sorted(COMPLEX_BYTES)}")
        if self.kind not in (KIND_FFT, KIND_PULSAR, KIND_FDAS):
            raise ValueError(f"unknown request kind {self.kind!r}")
        if self.kind in (KIND_FDAS, KIND_PULSAR) and self.templates < 1:
            raise ValueError(
                f"{self.kind} requests need templates >= 1, "
                f"got {self.templates}")
        if self.transform not in ("c2c", "r2c"):
            raise ValueError(f"unknown transform {self.transform!r}; "
                             "have ('c2c', 'r2c')")
        if self.kind == KIND_PULSAR:
            # Pulsar payloads are rank-2 filterbanks (nchan, ntime); the
            # transform rank is implied, not caller-chosen.
            if self.dm_trials < 1:
                raise ValueError(
                    f"pulsar requests need dm_trials >= 1, "
                    f"got {self.dm_trials}")
            self.ndim = 2
        if self.ndim < 1:
            raise ValueError(f"transform rank must be >= 1, got {self.ndim}")
        if self.ndim > 1 and self.kind not in (KIND_FFT, KIND_PULSAR):
            raise ValueError("N-D payloads are FFT requests only")
        # Reject malformed payloads at submit time so one bad request can
        # never poison a whole serving cycle.
        ndim = getattr(self.x, "ndim", None)
        if (ndim not in (self.ndim, self.ndim + 1)
                or any(d < 1 for d in self.x.shape)):
            raise ValueError(
                f"rank-{self.ndim} payload must be (batch, *shape) or "
                f"(*shape,) with positive dims; "
                f"got shape {getattr(self.x, 'shape', None)}")

    @property
    def shape(self) -> tuple[int, ...]:
        """Transform-axes lengths (the trailing ``ndim`` payload dims)."""
        return tuple(int(d) for d in self.x.shape[-self.ndim:])

    @property
    def n(self) -> int:
        """Total points per transform (product over the transform axes)."""
        prod = 1
        for d in self.shape:
            prod *= d
        return prod

    @property
    def batch(self) -> int:
        """Number of independent transforms in this request."""
        return (int(self.x.shape[0])
                if self.x.ndim == self.ndim + 1 else 1)

    @property
    def bytes(self) -> int:
        """Device bytes of the request payload at its precision.

        Real (r2c) payloads at pow2 lengths are half the size of complex
        ones — Eq. 6 packs twice as many of them per memory-budgeted
        batch (see :meth:`ShapeKey.elem_bytes` for the non-pow2 caveat).
        """
        return self.batch * self.n * self.shape_key("").elem_bytes

    def shape_key(self, device_name: str) -> ShapeKey:
        """FDAS keys carry (n, segment, templates): distinct banks or
        segment lengths compile distinct plans and sweep separately.
        Pulsar keys carry the full pipeline configuration — filterbank
        shape, DM-grid size, bank size, harmonic count — so any change
        plans, compiles and sweeps its own entry (the inner R2C is
        pinned via ``transform`` for the tuned-config key)."""
        fdas = self.kind == KIND_FDAS
        pulsar = self.kind == KIND_PULSAR
        return ShapeKey(
            kind=self.kind, n=self.n, precision=self.precision,
            n_harmonics=self.n_harmonics if pulsar else 0,
            device=device_name,
            transform="r2c" if pulsar else self.transform,
            shape=self.shape if self.ndim > 1 else (),
            templates=self.templates if (fdas or pulsar) else 0,
            segment=self.segment if fdas else 0,
            dm_trials=self.dm_trials if pulsar else 0)


@dataclasses.dataclass(frozen=True)
class StageReceipt:
    """One pipeline stage's share of a request: the clock the per-stage
    DVFS plan locks it to and its modelled time/energy share."""

    name: str                   # "dedisp" | "fdas" | "harmonic-sum" | "sift"
    clock_mhz: float            # the stage's locked clock
    time_s: float               # modelled stage time of this share
    energy_j: float             # modelled stage energy of this share


@dataclasses.dataclass
class RequestReceipt:
    """Per-request accounting, filled in when the batch executes.

    Every submitted request terminates in exactly one receipt — served at
    some degradation rung (possibly after retries) or shed with an
    explicit reason.  ``rung`` is the graceful-degradation rung the
    request actually executed at (0 = tuned plan + DVFS lock, 1 =
    heuristic plan at boost, 2 = pure-JAX fallback; see
    ``repro.serving.slo``); ``reason`` states why a request was degraded
    or shed (``admission:*`` for load shedding / pressure, ``fault:*``
    for failure-driven outcomes).
    """

    request: FFTRequest
    batch_id: int
    worker: int
    # --- latency (measured wall clock, seconds) --------------------------
    queue_latency: float        # enqueue -> batch execution start
    service_latency: float      # execution start -> results ready
    # --- energy/clock (analytic model, paper Eqs. 3-4 + Sec. 5.3) --------
    clock_mhz: float            # the locked clock the batch ran at
    modelled_time_s: float      # model-predicted execution time of this share
    energy_j: float             # model-predicted energy of this share
    boost_energy_j: float       # same share executed at the boost clock
    # --- telemetry (repro.power), None when the service runs unmetered ---
    measured_energy_j: float | None = None   # watchdog-fresh telemetry share
    result: Any = None          # transform output (None if not retained)
    # --- pulsar-pipeline requests only -----------------------------------
    stages: list[StageReceipt] | None = None   # per-stage clock + J shares
    realtime_margin: float | None = None       # S = t_acquire / t_process
    # --- robustness accounting (repro.serving.slo / runtime.faults) ------
    status: str = "served"      # "served" | "shed"
    rung: int = 0               # degradation rung the batch executed at
    retries: int = 0            # executions lost to faults before success
    reason: str | None = None   # why degraded/shed (None: clean rung-0)
    # --- kernel launch ledger (repro.obs.ledger) --------------------------
    # The launch signature of the compiled executable that served this
    # request's shape: one LaunchRecord per Pallas launch (kernel name,
    # grid, tile, bytes-moved estimate), recorded when the executable
    # first traced.  [] for shed requests and pure-JAX (rung 2) serves.
    launches: list = dataclasses.field(default_factory=list)
    # --- crash consistency (repro.runtime.journal / serving.recovery) -----
    # ``recovered`` marks a receipt replayed from the journal after a
    # process crash (its status/reason/rung are bit-identical to the
    # original; latencies and results are not re-measurable).
    # ``incarnation`` is the journal incarnation that issued it ("" on
    # journal-less services).
    recovered: bool = False
    incarnation: str = ""

    @classmethod
    def make_shed(cls, request: FFTRequest, reason: str,
                  now: float) -> "RequestReceipt":
        """A terminal receipt for a request that was never executed."""
        return cls(request=request, batch_id=-1, worker=-1,
                   queue_latency=max(now - request.t_enqueue, 0.0),
                   service_latency=0.0, clock_mhz=0.0, modelled_time_s=0.0,
                   energy_j=0.0, boost_energy_j=0.0, status="shed",
                   reason=reason)

    @property
    def outcome(self) -> str:
        """"served" | "retried" | "shed" — the chaos-harness taxonomy."""
        if self.status == "shed":
            return "shed"
        return "retried" if self.retries > 0 else "served"

    @property
    def rung_name(self) -> str:
        from repro.serving.slo import rung_name
        return rung_name(self.rung)

    @property
    def latency(self) -> float:
        return self.queue_latency + self.service_latency

    @property
    def joules_per_transform(self) -> float:
        return self.energy_j / max(self.request.batch, 1)

    @property
    def i_ef_boost(self) -> float:
        """Eq. 7 for this request (identical work => energy ratio).

        Shed requests did no work at either clock; by the
        :func:`repro.core.energy.guarded_ratio` convention their
        efficiency increase is 1.0 (nothing ran, nothing got worse).
        """
        from repro.core.energy import guarded_ratio
        return guarded_ratio(self.boost_energy_j, self.energy_j, on_zero=1.0)

    @property
    def energy_error_frac(self) -> float | None:
        """(measured - modelled) / modelled, None without fresh telemetry."""
        from repro.core.energy import guarded_ratio
        if self.measured_energy_j is None:
            return None
        return guarded_ratio(self.measured_energy_j - self.energy_j,
                             self.energy_j, on_zero=0.0)
