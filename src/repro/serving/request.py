"""Request and receipt types for the energy-aware FFT service.

A request is a batch of same-length transforms submitted by one client;
a receipt is everything the paper would report about serving it: which
clock it ran at, its modelled energy (Eqs. 3-4), and its measured queue +
service latency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

from repro.core.workloads import COMPLEX_BYTES

_REQUEST_IDS = itertools.count()

#: Request kinds the service understands.
KIND_FFT = "fft"            # batched 1-D C2C transform (the paper's workload)
KIND_PULSAR = "pulsar"      # full Sec. 5.3 pulsar-search pipeline


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Cache key: one plan + one frequency sweep per distinct value.

    The latency budget is deliberately NOT part of the key — budgets only
    re-select a point from the cached sweep (SweepResult.optimal_under_budget),
    they never require re-planning or re-sweeping.
    """

    kind: str
    n: int
    precision: str
    n_harmonics: int = 0            # pulsar requests only; 0 for plain FFTs
    device: str = ""
    transform: str = "c2c"          # "c2c" | "r2c" — distinct plans + sweeps

    @property
    def elem_bytes(self) -> int:
        """Per-point device bytes of this shape's payload.

        R2C payloads at pow2 lengths execute as real arrays — half the
        complex footprint, so Eq. 6 fits twice as many per batch.  Non-pow2
        r2c falls back to the full C2C algorithm (repro.fft.plan), so it
        pays complex bytes and must be capped accordingly.
        """
        full = COMPLEX_BYTES[self.precision]
        if self.transform == "r2c" and self.n & (self.n - 1) == 0:
            return full // 2
        return full


@dataclasses.dataclass
class FFTRequest:
    """One client submission: ``x`` rows are independent transforms."""

    x: Any                               # (batch, n) or (n,) array-like
    precision: str = "fp32"
    kind: str = KIND_FFT
    latency_budget: float | None = None  # max tolerable slowdown vs boost
    n_harmonics: int = 32                # pulsar kind only
    transform: str = "c2c"               # "c2c" or "r2c" (real payloads)
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))
    t_enqueue: float = 0.0               # stamped by the service

    def __post_init__(self):
        if self.precision not in COMPLEX_BYTES:
            raise ValueError(
                f"unknown precision {self.precision!r}; "
                f"have {sorted(COMPLEX_BYTES)}")
        if self.kind not in (KIND_FFT, KIND_PULSAR):
            raise ValueError(f"unknown request kind {self.kind!r}")
        if self.transform not in ("c2c", "r2c"):
            raise ValueError(f"unknown transform {self.transform!r}; "
                             "have ('c2c', 'r2c')")
        # Reject malformed payloads at submit time so one bad request can
        # never poison a whole serving cycle.
        ndim = getattr(self.x, "ndim", None)
        if ndim not in (1, 2) or self.x.shape[-1] < 1:
            raise ValueError(
                f"payload must be a (batch, n) or (n,) array with n >= 1; "
                f"got shape {getattr(self.x, 'shape', None)}")

    @property
    def n(self) -> int:
        return int(self.x.shape[-1])

    @property
    def batch(self) -> int:
        """Number of independent transforms in this request."""
        return int(self.x.shape[0]) if self.x.ndim == 2 else 1

    @property
    def bytes(self) -> int:
        """Device bytes of the request payload at its precision.

        Real (r2c) payloads at pow2 lengths are half the size of complex
        ones — Eq. 6 packs twice as many of them per memory-budgeted
        batch (see :meth:`ShapeKey.elem_bytes` for the non-pow2 caveat).
        """
        return self.batch * self.n * self.shape_key("").elem_bytes

    def shape_key(self, device_name: str) -> ShapeKey:
        return ShapeKey(
            kind=self.kind, n=self.n, precision=self.precision,
            n_harmonics=self.n_harmonics if self.kind == KIND_PULSAR else 0,
            device=device_name, transform=self.transform)


@dataclasses.dataclass
class RequestReceipt:
    """Per-request accounting, filled in when the batch executes."""

    request: FFTRequest
    batch_id: int
    worker: int
    # --- latency (measured wall clock, seconds) --------------------------
    queue_latency: float        # enqueue -> batch execution start
    service_latency: float      # execution start -> results ready
    # --- energy/clock (analytic model, paper Eqs. 3-4 + Sec. 5.3) --------
    clock_mhz: float            # the locked clock the batch ran at
    modelled_time_s: float      # model-predicted execution time of this share
    energy_j: float             # model-predicted energy of this share
    boost_energy_j: float       # same share executed at the boost clock
    result: Any = None          # transform output (None if not retained)

    @property
    def latency(self) -> float:
        return self.queue_latency + self.service_latency

    @property
    def joules_per_transform(self) -> float:
        return self.energy_j / max(self.request.batch, 1)

    @property
    def i_ef_boost(self) -> float:
        """Eq. 7 for this request (identical work => energy ratio)."""
        return self.boost_energy_j / self.energy_j if self.energy_j else 1.0
