"""SLO budgets, admission control and the graceful-degradation ladder.

The paper's DVFS savings only matter while the pipeline keeps meeting its
real-time deadline (Sec. 2.3: S = t_acquire / t_process >= 1).  Barbosa
et al. (2016) argue SKA-scale power management must be a closed, monitored,
failure-aware control problem — so the serving layer gets an explicit
contract per request kind (:class:`SLO`), an admission controller that
enforces it *before* the p99 budget is blown, and a degradation ladder the
service walks instead of failing:

  rung 0  tuned-dvfs       tuned plan, DVFS-locked at the sweep optimum
  rung 1  boost-heuristic  heuristic plan at the boost clock, sweep skipped
                           (cheapest possible build; the GPU-default cost)
  rung 2  pure-jax         the pure-JAX engine (the path
                           ``REPRO_FFT_DISABLE_PALLAS=1`` forces globally),
                           still at boost — the always-works bottom rung

Admission decisions are **model-predictive and deterministic**: they use
queue depth and the analytic cost model's boost-clock service-time
estimates (from cached sweeps), never wall-clock measurements — so a chaos
run with a fixed fault-plan seed reproduces the exact same admit / degrade
/ shed outcomes.  Every rejected or degraded request still terminates in a
receipt stating why (``RequestReceipt.reason``).
"""
from __future__ import annotations

import dataclasses

from repro.core.hardware import DeviceSpec
from repro.obs.metrics import latency_summary
from repro.serving.request import KIND_FFT, FFTRequest, RequestReceipt

# --------------------------------------------------------------------------
# degradation ladder
# --------------------------------------------------------------------------

RUNG_TUNED_DVFS = 0
RUNG_BOOST_HEURISTIC = 1
RUNG_PURE_JAX = 2

RUNG_NAMES = ("tuned-dvfs", "boost-heuristic", "pure-jax")


def rung_name(rung: int) -> str:
    return RUNG_NAMES[min(max(rung, 0), len(RUNG_NAMES) - 1)]


def max_rung_for_kind(kind: str) -> int:
    """The deepest rung a kind can degrade to.

    Only plain FFT traffic has a pure-JAX twin of its whole executable;
    the science kinds (fdas/pulsar) bottom out at boost-heuristic.
    """
    return RUNG_PURE_JAX if kind == KIND_FFT else RUNG_BOOST_HEURISTIC


# --------------------------------------------------------------------------
# per-kind SLOs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLO:
    """The serving contract for one request kind.

    ``deadline_s`` is the end-to-end (queue + service) deadline the
    admission controller protects using *modelled* backlog time; the
    pressure thresholds are ratios of modelled backlog to that deadline:

      backlog > degrade_at      * deadline  ->  rung 1 (skip sweeps, boost)
      backlog > degrade_hard_at * deadline  ->  rung 2 (pure-JAX)
      backlog > shed_at         * deadline  ->  shed ("admission:deadline")

    ``max_queue_transforms`` is a hard per-kind queue-depth cap (sheds
    with "admission:queue-full").  ``p99_latency_s`` and
    ``max_j_per_transform`` are *reporting* budgets — what
    :meth:`SLOPolicy.evaluate` scores receipts against.  Any None field
    disables that control.
    """

    p99_latency_s: float | None = None
    max_j_per_transform: float | None = None
    max_queue_transforms: int | None = None
    deadline_s: float | None = None
    degrade_at: float = 1.0
    degrade_hard_at: float | None = 2.0
    shed_at: float | None = 4.0


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Per-kind SLOs with a default for kinds not explicitly configured."""

    default: SLO = SLO()
    per_kind: dict = dataclasses.field(default_factory=dict)

    def for_kind(self, kind: str) -> SLO:
        return self.per_kind.get(kind, self.default)

    def evaluate(self, receipts: list[RequestReceipt]) -> dict:
        """Score served receipts against the per-kind reporting budgets.

        Returns ``{kind: {"n", "p99_latency_s", "p99_ok",
        "j_per_transform", "energy_ok", "degraded", "retried"}}`` —
        ``*_ok`` is None when the corresponding budget is unset.
        """
        by_kind: dict[str, list[RequestReceipt]] = {}
        for r in receipts:
            if r.status == "served":
                by_kind.setdefault(r.request.kind, []).append(r)
        out = {}
        for kind, rs in sorted(by_kind.items()):
            slo = self.for_kind(kind)
            p99 = latency_summary(r.latency for r in rs).p99
            transforms = sum(r.request.batch for r in rs)
            jpt = sum(r.energy_j for r in rs) / max(transforms, 1)
            out[kind] = {
                "n": len(rs),
                "p99_latency_s": p99,
                "p99_ok": (None if slo.p99_latency_s is None
                           else p99 <= slo.p99_latency_s),
                "j_per_transform": jpt,
                "energy_ok": (None if slo.max_j_per_transform is None
                              else jpt <= slo.max_j_per_transform),
                "degraded": sum(1 for r in rs if r.rung > 0),
                "retried": sum(1 for r in rs if r.retries > 0),
            }
        return out


# --------------------------------------------------------------------------
# admission control
# --------------------------------------------------------------------------

ADMIT = "admit"
DEGRADE = "degrade"
SHED = "shed"


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    request: FFTRequest
    action: str                  # "admit" | "degrade" | "shed"
    rung: int                    # degradation rung the request executes at
    reason: str | None           # why it was degraded/shed (None for admit)


class AdmissionController:
    """Queue-depth / modelled-deadline admission control (load shedding).

    The controller walks the pending queue in FIFO order, accumulating the
    *modelled* backlog service time at the boost clock (so estimates never
    assume the energy-optimal slowdown is affordable).  Shapes whose sweep
    is already cached use the cached per-transform time; cold shapes fall
    back to a bandwidth-bound estimate (payload bytes x 4 HBM passes) —
    pessimistic, which is the right bias for admission.
    """

    #: HBM passes assumed for a shape with no cached sweep.
    COLD_PASSES = 4.0

    def __init__(self, policy: SLOPolicy, device: DeviceSpec):
        self.policy = policy
        self.device = device
        # Cumulative decision counters (service-lifetime).
        self.admitted = 0
        self.degraded = 0
        self.shed = 0

    def _estimate_s(self, req: FFTRequest, cache) -> float:
        entry = cache.peek(req.shape_key(self.device.name))
        if entry is not None:
            per_t, _ = entry.per_transform(entry.sweep.boost)
            return per_t * req.batch
        return req.bytes * self.COLD_PASSES / self.device.hbm_bandwidth

    def decide(self, pending: list[FFTRequest], cache
               ) -> list[AdmissionDecision]:
        """One decision per pending request, in FIFO order."""
        decisions: list[AdmissionDecision] = []
        backlog_s = 0.0                       # modelled boost-clock backlog
        depth: dict[str, int] = {}            # admitted transforms per kind
        for req in pending:
            slo = self.policy.for_kind(req.kind)
            est = self._estimate_s(req, cache)
            kind_depth = depth.get(req.kind, 0)
            if (slo.max_queue_transforms is not None
                    and kind_depth + req.batch > slo.max_queue_transforms):
                decisions.append(AdmissionDecision(
                    req, SHED, 0, "admission:queue-full"))
                self.shed += 1
                continue
            rung, reason = RUNG_TUNED_DVFS, None
            if slo.deadline_s is not None and slo.deadline_s > 0:
                ratio = (backlog_s + est) / slo.deadline_s
                if slo.shed_at is not None and ratio > slo.shed_at:
                    decisions.append(AdmissionDecision(
                        req, SHED, 0, "admission:deadline"))
                    self.shed += 1
                    continue
                if (slo.degrade_hard_at is not None
                        and ratio > slo.degrade_hard_at):
                    rung = min(RUNG_PURE_JAX, max_rung_for_kind(req.kind))
                    reason = "admission:backlog-hard"
                elif ratio > slo.degrade_at:
                    rung = RUNG_BOOST_HEURISTIC
                    reason = "admission:backlog"
            backlog_s += est
            depth[req.kind] = kind_depth + req.batch
            if rung > RUNG_TUNED_DVFS:
                decisions.append(AdmissionDecision(req, DEGRADE, rung,
                                                   reason))
                self.degraded += 1
            else:
                decisions.append(AdmissionDecision(req, ADMIT, rung, None))
                self.admitted += 1
        return decisions
