"""Batch dispatch across devices via a work-stealing queue.

Batches land on the least-loaded device queue at submit time; during the
drain loop each device pops its own queue FIFO and, when empty, steals
the freshest batch from the longest queue (repro.runtime.workqueue).
This is the paper's "FFTs which fit into GPU memory can be easily
distributed amongst the GPUs" (Sec. 2.3) made operational: batch-parallel
work needs no collectives, only load balance.

The dispatcher is cooperative (round-robin ticks on one host), matching
the repository's deterministic multi-device simulation style; on a real
multi-accelerator host each worker slot maps to one consumer thread.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

from repro.runtime.faults import DrainDeadlineError
from repro.runtime.workqueue import WorkStealingQueue
from repro.serving.batcher import Batch


class Dispatcher:
    """Work-stealing executor over the visible JAX devices."""

    def __init__(self, devices: Sequence[Any] | None = None):
        self.devices = list(devices) if devices is not None else jax.devices()
        self.queue = WorkStealingQueue(len(self.devices))

    @property
    def steals(self) -> int:
        return self.queue.steals

    def submit(self, batch: Batch) -> int:
        """Queue a batch on the least-loaded device; returns the worker."""
        return self.queue.push_least_loaded(batch)

    def clear(self) -> list[Batch]:
        """Remove and return every queued batch (failure recovery)."""
        return self.queue.clear()

    def fill_metrics(self, registry) -> None:
        """Publish dispatch counters into a repro.obs MetricsRegistry."""
        registry.gauge("repro_dispatch_workers",
                       "worker slots (devices)").set(
                           self.queue.n_workers)
        registry.gauge("repro_dispatch_steals",
                       "batches stolen by idle workers").set(self.steals)

    def drain(
        self,
        execute: Callable[[Batch, int, Any], None],
        *,
        timer: Callable[[], float] | None = None,
        deadline_s: float | None = None,
    ) -> int:
        """Run every queued batch; returns the number executed.

        ``execute(batch, worker, device)`` is called once per batch, on the
        worker that actually ran it (owner or thief).  ``execute`` may
        re-queue a batch instead of running it (fault redistribution), so
        with ``deadline_s`` set (seconds on ``timer``'s clock, measured
        from drain start) a wedged worker surfaces a
        :class:`~repro.runtime.faults.DrainDeadlineError` naming the
        stuck batches' shape keys instead of looping forever.
        """
        executed = 0
        t0 = timer() if timer is not None and deadline_s is not None else 0.0
        while self.queue.pending():
            if (deadline_s is not None and timer is not None
                    and timer() - t0 > deadline_s):
                raise DrainDeadlineError(
                    deadline_s, [b.key for b in self.queue.items()])
            for worker in range(self.queue.n_workers):
                batch = self.queue.pop(worker)
                if batch is None:
                    continue
                execute(batch, worker, self.devices[worker])
                executed += 1
        return executed
