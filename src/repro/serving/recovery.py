"""Crash recovery for the serving layer: snapshot, replay, re-enqueue.

``repro.runtime.journal`` gives the service a durable record of every
request's lifecycle (admit -> assign -> served/shed); this module turns
that record back into a *live* service after a process crash:

  snapshot   :class:`ServiceSnapshot` serialises the durable part of a
             running service — plan/sweep cache keys (+ the tuned configs
             they resolved to), circuit-breaker states, telemetry-watchdog
             health, drift-detector EWMAs, metrics counters, and
             optionally per-device power-governor state — into a JSON
             dict the journal persists atomically.
  replay     :func:`replay_journal` folds validated journal records into
             per-request state: which admits exist, which terminated,
             which terminal record came first (duplicates are counted,
             never replayed — the *first* durable terminal record is the
             receipt, full stop).
  recover    :func:`recover_service` (surfaced as
             ``FFTService.recover``) rebuilds a service: restore the
             snapshot, re-warm the plan cache, reconstruct a receipt for
             every already-terminated request (bit-identical
             ``status``/``reason``/``rung``, stamped ``recovered=True``
             with the new incarnation id) and re-enqueue every request
             that was admitted but never receipted.

Exactly-once receipts across any number of crashes follow from two
rules: (1) a request's durable identity is its journal admit seq
(``FFTRequest.jseq``), assigned once, write-ahead, and (2) a terminal
record is only appended *before* the in-memory receipt is stored, so a
request either has its terminal record (replayed, never re-executed) or
does not (re-enqueued, executed, terminated once).  Execution between
those two points is at-least-once — exactly like any WAL database —
but receipts, the client-visible outcome, are exactly-once.

Replayed-receipt accounting (``ReplayResult.availability`` /
``duplicate_rate``) follows the one documented zero-denominator
convention, :func:`repro.core.energy.guarded_ratio`: an empty journal is
availability 1.0 and duplicate rate 0.0, never NaN.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

from repro.core.energy import guarded_ratio
from repro.runtime.journal import (ADMIT, ASSIGN, OPEN, TERMINAL_TYPES,
                                   JournalRecord, RequestJournal)
from repro.serving.request import RequestReceipt, ShapeKey

__all__ = ["ReplayResult", "RecoveredRequest", "ServiceSnapshot",
           "replay_journal", "recover_service"]


# --------------------------------------------------------------------------- #
# journal record payloads (built by FFTService, parsed here)
# --------------------------------------------------------------------------- #

def admit_record(req) -> dict:
    """The JSON-safe admit payload for one request (the durable metadata
    a recovering process needs to rebuild the request, minus the payload
    itself, which ``payload_ref`` points back to)."""
    return {
        "kind": req.kind, "precision": req.precision,
        "transform": req.transform, "ndim": req.ndim,
        "templates": req.templates, "segment": req.segment,
        "dm_trials": req.dm_trials, "n_harmonics": req.n_harmonics,
        "latency_budget": req.latency_budget,
        "batch": req.batch, "shape": list(req.shape),
        "payload_ref": req.payload_ref,
    }


def key_to_dict(key: ShapeKey) -> dict:
    d = dataclasses.asdict(key)
    d["shape"] = list(d["shape"])
    return d


def key_from_dict(d: dict) -> ShapeKey:
    d = dict(d)
    d["shape"] = tuple(d["shape"])
    return ShapeKey(**d)


def terminal_record(receipt: RequestReceipt, key: ShapeKey | None) -> dict:
    """The JSON-safe terminal payload: everything needed to replay the
    receipt bit-identically minus what cannot survive a crash (results,
    wall-clock latencies)."""
    req = receipt.request
    return {
        "rseq": req.jseq,
        "status": receipt.status, "rung": receipt.rung,
        "retries": receipt.retries, "reason": receipt.reason,
        "batch_id": receipt.batch_id, "worker": receipt.worker,
        "clock_mhz": receipt.clock_mhz,
        "modelled_time_s": receipt.modelled_time_s,
        "energy_j": receipt.energy_j,
        "boost_energy_j": receipt.boost_energy_j,
        "measured_energy_j": receipt.measured_energy_j,
        "realtime_margin": receipt.realtime_margin,
        "kind": req.kind, "precision": req.precision,
        "batch": req.batch, "n": req.n, "shape": list(req.shape),
        "payload_ref": req.payload_ref,
        "key": None if key is None else key_to_dict(key),
    }


# --------------------------------------------------------------------------- #
# replay
# --------------------------------------------------------------------------- #

class ReplayResult:
    """Per-request state folded incrementally from journal records.

    Built to stream: feed it records one at a time (it is the natural
    ``record_sink`` for :class:`repro.runtime.journal.RequestJournal`)
    and memory stays bounded no matter how long the history is —

      open_admit_data   admit payloads for requests with NO terminal yet
                        (bounded by in-flight depth, not history);
                        insertion-ordered, so iteration is admit order.
      terminals         the last ``retain`` terminal payloads (FIFO;
                        ``retain=None`` keeps all — small journals and
                        tests — ``retain=0`` keeps counts only, which is
                        what the 10^6-record end-of-run audit uses).
      admitted          every admitted seq (ints only; the dedup ground
                        truth for the exactly-once check).

    Deduplication happens here: only the FIRST terminal record for an
    admit seq counts (``duplicate_terminals`` tallies the rest), so no
    matter how many times a crashing service re-executed a request, its
    replayed receipt is the one the journal durably promised first.
    """

    def __init__(self, *, retain: int | None = None):
        self.retain = retain
        self.open_admit_data: dict[int, dict] = {}
        self.terminals: dict[int, dict] = {}
        self.admitted: set[int] = set()
        self.admits_total = 0
        self.terminals_total = 0
        self.duplicate_terminals = 0    # extra terminal records for a seq
        #                                 (first one wins; rest ignored)
        self.served = 0
        self.fault_shed = 0             # shed with a fault:* reason
        self.next_batch_id = 0          # 1 + highest assigned batch id
        self.incarnations = 0           # OPEN records seen

    def feed(self, rec: JournalRecord) -> None:
        """Fold one validated record."""
        if rec.type == OPEN:
            self.incarnations += 1
        elif rec.type == ADMIT:
            self.admitted.add(rec.seq)
            self.open_admit_data[rec.seq] = rec.data
            self.admits_total += 1
        elif rec.type == ASSIGN:
            bid = rec.data.get("batch_id")
            if isinstance(bid, int):
                self.next_batch_id = max(self.next_batch_id, bid + 1)
        elif rec.type in TERMINAL_TYPES:
            rseq = rec.data.get("rseq")
            if rseq not in self.admitted:
                return                       # terminal for unknown admit
            if rseq not in self.open_admit_data:
                self.duplicate_terminals += 1
                return
            del self.open_admit_data[rseq]
            self.terminals_total += 1
            if rec.data.get("status") == "served":
                self.served += 1
            elif str(rec.data.get("reason") or "").startswith("fault:"):
                self.fault_shed += 1
            if self.retain is None or self.retain > 0:
                self.terminals[rseq] = rec.data
                if self.retain is not None \
                        and len(self.terminals) > self.retain:
                    self.terminals.pop(next(iter(self.terminals)))

    @property
    def open_admits(self) -> list[int]:
        """Admit seqs with no terminal record, in admit order — the
        requests that were in flight when the process died."""
        return list(self.open_admit_data)

    # Replayed-receipt accounting (guarded_ratio conventions: an empty
    # journal made no promises and broke none).

    @property
    def availability(self) -> float:
        """Served / (served + fault-shed) over replayed terminals;
        admission sheds excluded, empty journal => 1.0."""
        return guarded_ratio(self.served, self.served + self.fault_shed,
                             on_zero=1.0)

    @property
    def duplicate_rate(self) -> float:
        """Duplicate terminal records / total terminal records written;
        empty journal => 0.0."""
        total = self.terminals_total + self.duplicate_terminals
        return guarded_ratio(self.duplicate_terminals, total, on_zero=0.0)


def replay_journal(records: Iterable[JournalRecord], *,
                   retain: int | None = None) -> ReplayResult:
    """Fold validated records into per-request lifecycle state.

    Convenience wrapper over :meth:`ReplayResult.feed` for callers that
    already hold the records; streaming callers pass ``ReplayResult.feed``
    as a ``record_sink`` / ``read_journal`` sink instead.
    """
    out = ReplayResult(retain=retain)
    for rec in records:
        out.feed(rec)
    return out


# --------------------------------------------------------------------------- #
# replayed receipts
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class RecoveredRequest:
    """A payload-less stand-in for a request whose receipt is replayed.

    Already-terminated requests do not need their arrays again — only
    the metadata receipts and reports read.  Quacks like
    :class:`repro.serving.request.FFTRequest` where receipts care.
    """

    kind: str
    precision: str
    batch: int
    n: int
    shape: tuple
    jseq: int
    payload_ref: Any = None
    request_id: int = -1
    t_enqueue: float = 0.0


def receipt_from_terminal(term: dict, *, ledger=None,
                          incarnation: str = "") -> RequestReceipt:
    """Rebuild one receipt from its journaled terminal record.

    ``status``/``reason``/``rung``/``retries`` are bit-identical to the
    receipt the previous incarnation issued.  Launch signatures are
    replayed from the process-wide ledger store when the executable's
    shape key was journaled (a warm jit cache records nothing at re-use
    time, so the store is the only source — see repro.obs.ledger).
    """
    req = RecoveredRequest(
        kind=term["kind"], precision=term["precision"],
        batch=term["batch"], n=term["n"], shape=tuple(term["shape"]),
        jseq=term["rseq"], payload_ref=term.get("payload_ref"))
    launches: list = []
    if ledger is not None and term.get("key") is not None \
            and term["status"] == "served":
        launches = ledger.signature(key_from_dict(term["key"]))
    return RequestReceipt(
        request=req,
        batch_id=term["batch_id"], worker=term["worker"],
        queue_latency=0.0, service_latency=0.0,
        clock_mhz=term["clock_mhz"],
        modelled_time_s=term["modelled_time_s"],
        energy_j=term["energy_j"],
        boost_energy_j=term["boost_energy_j"],
        measured_energy_j=term["measured_energy_j"],
        realtime_margin=term["realtime_margin"],
        status=term["status"], rung=term["rung"],
        retries=term["retries"], reason=term["reason"],
        launches=list(launches),
        recovered=True, incarnation=incarnation)


# --------------------------------------------------------------------------- #
# snapshot / restore of durable service state
# --------------------------------------------------------------------------- #

def _breaker_state(br) -> dict:
    return {"state": br.state, "failures": br.failures,
            "opened_at": br.opened_at, "opens": br.opens,
            "probes": br.probes}


def _restore_breaker(br, st: dict) -> None:
    br.state = st["state"]
    br.failures = int(st["failures"])
    br.opened_at = st["opened_at"]
    br.opens = int(st["opens"])
    br.probes = int(st["probes"])


def _watchdog_state(dog) -> dict:
    base = dog.baseline
    return {"health": dog.health, "bad": dog._bad, "good": dog._good,
            "counts": dict(dog.counts),
            "unhealthy_entries": dog.unhealthy_entries,
            "baseline": (None if base is None else
                         {"device_index": base.device_index,
                          "t": base.t, "power_w": base.power_w})}


def _restore_watchdog(dog, st: dict) -> None:
    from repro.power.sampler import PowerReading
    dog.health = st["health"]
    dog._bad = int(st["bad"])
    dog._good = int(st["good"])
    dog.counts.update({k: int(v) for k, v in st["counts"].items()})
    dog.unhealthy_entries = int(st["unhealthy_entries"])
    b = st["baseline"]
    dog.baseline = None if b is None else PowerReading(
        device_index=int(b["device_index"]), t=float(b["t"]),
        power_w=float(b["power_w"]))


def governor_state(gov) -> dict:
    """Serialise one :class:`repro.power.governor.PowerGovernor`."""
    return {"f_mhz": gov.f_mhz, "integral_w": gov.integral_w,
            "mode": gov.mode, "ticks": gov.ticks, "moves": gov.moves,
            "fallback_engagements": gov.fallback_engagements,
            "target_w": gov.target_w}


def restore_governor(gov, st: dict) -> None:
    gov.f_mhz = float(st["f_mhz"])
    gov.integral_w = float(st["integral_w"])
    gov.mode = st["mode"]
    gov.ticks = int(st["ticks"])
    gov.moves = int(st["moves"])
    gov.fallback_engagements = int(st["fallback_engagements"])
    gov.target_w = float(st["target_w"])


def _drift_state(drift) -> dict:
    states = []
    for key, st in drift.states.items():
        kind, shape, clock = key
        states.append({"key": [kind, list(shape), clock],
                       "ewma": st.ewma, "n": st.n,
                       "last_error": st.last_error})
    return {"observations": drift.observations, "states": states}


def _restore_drift(drift, st: dict) -> None:
    from repro.obs.drift import DriftState
    drift.observations = int(st["observations"])
    for item in st["states"]:
        kind, shape, clock = item["key"]
        drift.states[(kind, tuple(shape), clock)] = DriftState(
            ewma=float(item["ewma"]), n=int(item["n"]),
            last_error=float(item["last_error"]))


def _metrics_state(registry) -> dict:
    from repro.obs.metrics import Counter, Gauge, Histogram
    counters, gauges, histograms = {}, {}, {}
    for name, m in registry._metrics.items():
        if isinstance(m, Counter):
            counters[name] = {"value": m.value, "help": m.help}
        elif isinstance(m, Gauge):
            gauges[name] = {"value": m.value, "help": m.help}
        elif isinstance(m, Histogram):
            histograms[name] = {"bounds": list(m.bounds),
                                "counts": list(m.counts), "help": m.help}
    return {"counters": counters, "gauges": gauges,
            "histograms": histograms}


def _restore_metrics(registry, st: dict) -> None:
    for name, c in st["counters"].items():
        registry.counter(name, c["help"]).value = int(c["value"])
    for name, g in st["gauges"].items():
        registry.gauge(name, g["help"]).set(g["value"])
    for name, h in st["histograms"].items():
        hist = registry.histogram(name, h["help"],
                                  buckets=tuple(h["bounds"]))
        hist.counts = [int(c) for c in h["counts"]]


class ServiceSnapshot:
    """Capture/restore the durable state of a running ``FFTService``."""

    @staticmethod
    def capture(service, *, governors: dict | None = None) -> dict:
        """A JSON-safe dict of everything worth surviving a crash.

        ``governors`` optionally maps names to
        :class:`repro.power.governor.PowerGovernor` instances managed
        alongside the service (the service itself does not own one).
        """
        cache_keys = []
        seen = set()
        for (key, _cfg), entry in service.cache._entries.items():
            if key in seen:
                continue
            seen.add(key)
            cache_keys.append({"key": key_to_dict(key),
                               "config": repr(_cfg)})
        stats = service.cache.stats
        return {
            "cache": {
                "keys": cache_keys,
                "stats": {f: getattr(stats, f) for f in
                          ("hits", "misses", "plan_builds", "sweeps",
                           "degraded_builds")},
            },
            "breakers": {str(w): _breaker_state(br)
                         for w, br in sorted(service.breakers.items())},
            "watchdogs": ({} if service.telemetry is None else
                          {str(i): _watchdog_state(dog) for i, dog in
                           sorted(service.telemetry.watchdogs.items())}),
            "drift": _drift_state(service.drift),
            "metrics": _metrics_state(service.metrics),
            "governors": ({} if not governors else
                          {name: governor_state(g)
                           for name, g in sorted(governors.items())}),
            "next_batch_id": service._next_batch_id,
        }

    @staticmethod
    def restore(service, state: dict, *, governors: dict | None = None,
                warm_cache: bool = True) -> None:
        """Apply a captured snapshot onto a freshly built service.

        ``warm_cache=True`` eagerly rebuilds a cache entry for every
        snapshotted shape key — plans and sweeps are deterministic
        functions of (key, tuned config), so the rebuilt entries match
        the crashed incarnation's, and serving resumes warm.
        """
        for item in state["cache"]["keys"]:
            key = key_from_dict(item["key"])
            if warm_cache:
                service.cache.entry(key)
        # Cache stats: the snapshot counters describe the *previous*
        # incarnation's traffic; restoring after the warm rebuild keeps
        # them from double-counting the rebuild's misses.
        for f, v in state["cache"]["stats"].items():
            setattr(service.cache.stats, f, int(v))
        for w, st in state["breakers"].items():
            _restore_breaker(service._breaker(int(w)), st)
        if service.telemetry is not None:
            for i, st in state["watchdogs"].items():
                _restore_watchdog(service.telemetry.watchdog(int(i)), st)
        _restore_drift(service.drift, state["drift"])
        _restore_metrics(service.metrics, state["metrics"])
        if governors:
            for name, gov in governors.items():
                if name in state["governors"]:
                    restore_governor(gov, state["governors"][name])
        service._next_batch_id = max(service._next_batch_id,
                                     int(state["next_batch_id"]))


# --------------------------------------------------------------------------- #
# recover
# --------------------------------------------------------------------------- #

def recover_service(
    journal_dir: str,
    *,
    payload_fn: Callable[[Any, dict], Any] | None = None,
    governors: dict | None = None,
    warm_cache: bool = True,
    journal_kwargs: dict | None = None,
    retain_receipts: int | None = None,
    **service_kwargs,
):
    """Rebuild a live ``FFTService`` from its journal directory.

    1. open the journal (replays + validates what is on disk, mints the
       next incarnation id, continues seq numbering in a new segment);
    2. restore the newest valid snapshot (breakers, watchdog health,
       drift EWMAs, metrics counters, cache keys — re-warmed — and the
       batch-id high-water mark);
    3. replay request lifecycles: every admitted-and-terminated request
       gets its receipt reconstructed bit-identically (status/reason/
       rung), stamped ``recovered=True`` + the new incarnation id, and
       exposed via ``service.recovered_receipts`` /
       ``service.receipt_for_seq``;
    4. re-enqueue every request admitted but never receipted, in admit
       order, resolving payloads through ``payload_fn(payload_ref,
       admit_meta)``.  Without a ``payload_fn`` such requests terminate
       in a ``shed`` receipt (reason ``recovery:payload-unresolvable``)
       — explicitly accounted, never silently dropped.

    ``service_kwargs`` are forwarded to the ``FFTService`` constructor
    (device spec, SLO policy, fault plan, telemetry, ...).

    Replay streams (the journal's ``record_sink`` seam), so recovery
    memory is bounded by in-flight depth plus ``retain_receipts`` — not
    by journal length.  ``retain_receipts`` caps how many already-
    terminated requests get their receipts reconstructed (newest kept,
    mirroring the live service's own receipt-retention policy); it
    defaults to the service's ``max_retained_receipts`` when that is
    passed, else unbounded.  Older terminals stay durable in the journal
    either way — only eager reconstruction is windowed.
    """
    import jax.numpy as jnp

    from repro.serving.request import FFTRequest
    from repro.serving.service import FFTService

    if retain_receipts is None:
        retain_receipts = service_kwargs.get("max_retained_receipts")
    replay = ReplayResult(retain=retain_receipts)
    journal = RequestJournal(journal_dir, record_sink=replay.feed,
                             **(journal_kwargs or {}))
    snap = journal.load_snapshot()

    service = FFTService(journal=journal, **service_kwargs)
    if snap is not None:
        ServiceSnapshot.restore(service, snap["state"],
                                governors=governors, warm_cache=warm_cache)
    service._next_batch_id = max(service._next_batch_id,
                                 replay.next_batch_id)
    service.replay = replay

    # Replayed receipts: bit-identical outcomes for already-terminated
    # work, in journal (terminal-record) order, newest `retain` of them.
    for rseq, term in replay.terminals.items():
        receipt = receipt_from_terminal(term, ledger=service.ledger,
                                        incarnation=journal.incarnation)
        service.recovered_receipts.append(receipt)
        service._remember_seq(rseq, receipt)

    # Re-enqueue in-flight work (admitted, never receipted), admit order.
    now = service._timer()
    for rseq in replay.open_admits:
        meta = replay.open_admit_data[rseq]
        if payload_fn is None:
            n = 1
            for d in meta["shape"]:
                n *= int(d)
            stub = RecoveredRequest(
                kind=meta["kind"], precision=meta["precision"],
                batch=meta["batch"], n=n,
                shape=tuple(meta["shape"]), jseq=rseq,
                payload_ref=meta.get("payload_ref"),
                request_id=-(rseq + 1))      # unique, never collides with
            #                                  live process-local ids
            service._store(RequestReceipt.make_shed(
                stub, "recovery:payload-unresolvable", now))
            continue
        req = FFTRequest(
            x=jnp.asarray(payload_fn(meta.get("payload_ref"), meta)),
            precision=meta["precision"], kind=meta["kind"],
            latency_budget=meta["latency_budget"],
            n_harmonics=meta["n_harmonics"],
            transform=meta["transform"], ndim=meta["ndim"],
            templates=meta["templates"], segment=meta["segment"],
            dm_trials=meta["dm_trials"])
        req.t_enqueue = now
        req.jseq = rseq                      # keep the durable identity
        req.payload_ref = meta.get("payload_ref")
        service._pending.append(req)
    return service
