"""The energy-aware streaming FFT service.

Request lifecycle (docs/serving.md walks through a full example):

  enqueue      submit() stamps arrival time and parks the request
  batch        drain() coalesces pending requests into Eq. 6-sized batches
  plan-cache   each batch's shape hits the plan + sweep cache (one FFT plan
               and one DVFS sweep per distinct shape, ever)
  clock-plan   the batch's operating point is selected from the cached
               sweep under the strictest per-request real-time budget
  execute      the batch runs with the clock locked (ClockController), on
               the device the work-stealing dispatcher assigned — or
               sharded over the whole mesh for oversized batches
  account      every request gets a receipt: queue/service latency
               (measured) and energy at the locked vs boost clock
               (modelled, Eqs. 3-4)

The energy numbers come from the repository's analytic model — the same
model the benchmarks validate against the paper — because this container
has no power sensor; on instrumented hardware the accounting hook is one
power-trace integration (repro.core.energy.energy_from_trace).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import TPU_V5E, DeviceSpec
from repro.core.power_model import PowerModel
from repro.core.scheduler import ClockController
from repro.serving.batcher import Batch, coalesce
from repro.serving.cache import CacheStats, PlanSweepCache
from repro.serving.dispatch import Dispatcher
from repro.serving.request import (KIND_FFT, KIND_PULSAR, FFTRequest,
                                   RequestReceipt, StageReceipt)

_EXEC_DTYPE = {"fp16": jnp.complex64, "fp32": jnp.complex64,
               "fp64": jnp.complex128}
# Real execution dtypes for R2C payloads — stacking them as complex would
# double the device bytes and forfeit the R2C saving the receipts report.
_REAL_EXEC_DTYPE = {"fp16": jnp.float32, "fp32": jnp.float32,
                    "fp64": jnp.float64}


@dataclasses.dataclass(frozen=True)
class ServiceReport:
    """Service-level summary over every receipt issued so far."""

    n_requests: int
    n_transforms: int
    n_batches: int
    wall_s: float                  # wall time spent executing batches
    energy_j: float                # modelled energy at the locked clocks
    boost_energy_j: float          # same work at boost (the GPU default)
    p50_latency_s: float
    p99_latency_s: float
    mean_latency_s: float
    cache: CacheStats
    steals: int
    clock_locks: int

    @property
    def joules_per_transform(self) -> float:
        return self.energy_j / max(self.n_transforms, 1)

    @property
    def i_ef(self) -> float:
        """Service-level Eq. 7 (identical work => energy ratio)."""
        return self.boost_energy_j / self.energy_j if self.energy_j else 1.0

    @property
    def throughput_tps(self) -> float:
        return self.n_transforms / self.wall_s if self.wall_s else 0.0


class FFTService:
    """Asynchronous-style FFT serving with batching, caching and DVFS.

    ``device_spec`` drives the analytic DVFS/energy model (which clock each
    batch locks to, what it costs); execution runs on the host's actual
    JAX devices.  ``mesh`` (optional) shards plain-FFT batches over every
    mesh device via repro.fft.distributed instead of placing them whole.
    ``coalesce_requests=False`` disables batching (every request executes
    alone) — the naive baseline the benchmarks compare against.
    """

    def __init__(
        self,
        device_spec: DeviceSpec = TPU_V5E,
        *,
        batch_bytes: float | None = None,
        time_budget: float | None = 0.10,
        devices: Sequence[Any] | None = None,
        mesh: Any = None,
        coalesce_requests: bool = True,
        bucket_batches: bool = True,
        keep_results: bool = True,
        max_retained_receipts: int | None = None,
        plan_fn=None,
        sweep_fn=None,
        power_model: PowerModel | None = None,
        timer=time.monotonic,
    ):
        self.device_spec = device_spec
        # Default batch budget: an eighth of device memory, capped at the
        # paper's ~2 GB measurement batches (Sec. 4).
        self.batch_bytes = (batch_bytes if batch_bytes is not None
                            else min(2e9, device_spec.memory_bytes / 8))
        self.time_budget = time_budget
        self.mesh = mesh
        self.coalesce_requests = coalesce_requests
        self.bucket_batches = bucket_batches
        self.keep_results = keep_results
        # Receipts (which pin request payloads and, with keep_results,
        # outputs) grow with traffic; long-running servers should bound
        # retention — oldest receipts are evicted past the cap.  report()
        # then summarises the retained window.
        self.max_retained_receipts = max_retained_receipts
        self._timer = timer
        kwargs = {}
        if plan_fn is not None:
            kwargs["plan_fn"] = plan_fn
        if sweep_fn is not None:
            kwargs["sweep_fn"] = sweep_fn
        self.cache = PlanSweepCache(
            device_spec, batch_bytes=self.batch_bytes,
            power_model=power_model, **kwargs)
        self.clock = ClockController(
            device_spec, timer=timer,
            max_events=(None if max_retained_receipts is None
                        else 2 * max_retained_receipts))
        # With a mesh the whole mesh executes each batch, so one worker.
        self.dispatcher = Dispatcher(
            devices=[None] if mesh is not None else devices)
        self._pending: list[FFTRequest] = []
        self._receipts: dict[int, RequestReceipt] = {}
        self._next_batch_id = 0

    # ------------------------------------------------------------------ #
    # enqueue
    # ------------------------------------------------------------------ #

    def submit(
        self,
        x: Any,
        *,
        precision: str = "fp32",
        kind: str = KIND_FFT,
        latency_budget: float | None = None,
        n_harmonics: int = 32,
        transform: str = "c2c",
        ndim: int = 1,
        templates: int = 16,
        segment: int = 0,
        dm_trials: int = 16,
    ) -> FFTRequest:
        """Enqueue one request (a (batch, *shape) or (*shape,) array).

        ``transform="r2c"`` serves real payloads through the R2C plan —
        half the energy per transform at the same length (Eq. 5/6).
        ``ndim=2`` serves 2-D transforms (e.g. imaging grids) through the
        N-D plan graph — one fused kernel pass per pow2 axis — with their
        own first-class plan + sweep cache entries.  ``kind="fdas"`` runs
        the full acceleration search (repro.search) on real time series;
        ``templates`` sizes the bank and ``segment`` pins the
        overlap-save FFT length (0 = cost-model auto-selection), and both
        are part of the plan/sweep cache key.  ``kind="pulsar"`` runs the
        end-to-end pulsar search (repro.search.pipeline) on (nchan,
        ntime) filterbanks — ``dm_trials`` sizes the dedispersion grid,
        ``templates``/``n_harmonics`` the bank and harmonic ladder, and
        all three join the cache key; its receipts carry per-stage DVFS
        shares (clock, modelled J) and the real-time margin.  The
        request's receipt becomes available after the next drain():
        ``service.receipt(request)``.
        """
        req = FFTRequest(x=jnp.asarray(x), precision=precision, kind=kind,
                         latency_budget=latency_budget,
                         n_harmonics=n_harmonics, transform=transform,
                         ndim=ndim, templates=templates, segment=segment,
                         dm_trials=dm_trials)
        req.t_enqueue = self._timer()
        self._pending.append(req)
        return req

    def receipt(self, request: FFTRequest) -> RequestReceipt | None:
        return self._receipts.get(request.request_id)

    @property
    def receipts(self) -> list[RequestReceipt]:
        return [self._receipts[k] for k in sorted(self._receipts)]

    # ------------------------------------------------------------------ #
    # batch -> plan-cache -> clock-plan -> execute -> account
    # ------------------------------------------------------------------ #

    def drain(self) -> list[RequestReceipt]:
        """Serve every pending request; returns their receipts in order.

        If a batch fails mid-cycle, already-served requests keep their
        receipts and every unserved request is re-queued for the next
        drain before the error propagates — one bad batch never drops
        the rest of the wave.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        try:
            if self.coalesce_requests:
                batches = coalesce(pending, device_name=self.device_spec.name,
                                   batch_bytes=self.batch_bytes,
                                   start_id=self._next_batch_id)
            else:
                batches = [
                    Batch(self._next_batch_id + i,
                          r.shape_key(self.device_spec.name), [r])
                    for i, r in enumerate(pending)
                ]
            self._next_batch_id += len(batches)
            for batch in batches:
                self.dispatcher.submit(batch)
            self.dispatcher.drain(self._execute)
        except BaseException:
            self.dispatcher.clear()          # drop stale queued batches
            unserved = [r for r in pending
                        if r.request_id not in self._receipts]
            self._pending = unserved + self._pending
            raise
        return [self._receipts[r.request_id] for r in pending
                if r.request_id in self._receipts]   # cap may have evicted

    def _stack(self, batch: Batch) -> jax.Array:
        if batch.key.shape:
            # N-D payloads: normalise every request to (rows, *shape).
            rows = [r.x.reshape((-1, *batch.key.shape))
                    for r in batch.requests]
        else:
            rows = [jnp.atleast_2d(r.x) for r in batch.requests]
        x = jnp.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]
        if batch.key.kind == KIND_FFT:
            if batch.key.transform == "r2c":
                return x.real.astype(_REAL_EXEC_DTYPE[batch.key.precision])
            return x.astype(_EXEC_DTYPE[batch.key.precision])
        # The pulsar pipeline and the FDAS search consume real time series.
        return x.real.astype(jnp.float32)

    def _effective_budget(self, batch: Batch) -> float | None:
        """Strictest real-time budget across the batch's requests.

        Budget-less requests fall back to the service default, so a loose
        explicit budget on one request can never relax the guarantee owed
        to a coalesced neighbour; None (from a request AND the default)
        means unconstrained.
        """
        budgets = [self.time_budget if r.latency_budget is None
                   else r.latency_budget for r in batch.requests]
        constrained = [b for b in budgets if b is not None]
        return min(constrained) if constrained else None

    def _execute(self, batch: Batch, worker: int, device: Any) -> None:
        entry = self.cache.entry(batch.key)
        point = entry.point_for(self._effective_budget(batch))
        x = self._stack(batch)
        rows = x.shape[0]
        if self.bucket_batches:
            # Shape bucketing: pad the row count to the next power of two so
            # streaming drains reuse a handful of compiled shapes instead of
            # recompiling for every coalesced batch size.
            from repro.fft.distributed import pad_rows
            x = pad_rows(x, 1 << (rows - 1).bit_length())
        t_start = self._timer()
        with self.clock.locked(point.f):
            if (self.mesh is not None and batch.key.kind == KIND_FFT
                    and x.shape[0] > 1):
                from repro.fft.distributed import batch_parallel_fft
                y = batch_parallel_fft(x, self.mesh, fft_fn=entry.plan)
            else:
                if device is not None:
                    x = jax.device_put(x, device)
                y = entry.fn(x)
            y = jax.block_until_ready(y)
        y = y[:rows]
        t_done = self._timer()
        self._account(batch, worker, entry, point, y, t_start, t_done)

    def _account(self, batch, worker, entry, point, y, t_start, t_done):
        per_time, per_energy = entry.per_transform(point)
        _, per_boost = entry.per_transform(entry.sweep.boost)
        offset = 0
        for req in batch.requests:
            rows = req.batch
            result = y[offset:offset + rows] if self.keep_results else None
            offset += rows
            if (self.max_retained_receipts is not None
                    and len(self._receipts) >= self.max_retained_receipts):
                self._receipts.pop(next(iter(self._receipts)))  # oldest
            stages = None
            if entry.stages is not None:
                # Pipeline entries: scale the modelled batch's per-stage
                # plan (clock + J/stage) to this request's row share.
                share = rows / max(entry.n_fft_model, 1)
                stages = [StageReceipt(name=s.name, clock_mhz=s.f,
                                       time_s=s.time * share,
                                       energy_j=s.energy * share)
                          for s in entry.stages.stages]
            self._receipts[req.request_id] = RequestReceipt(
                request=req,
                batch_id=batch.batch_id,
                worker=worker,
                queue_latency=max(t_start - req.t_enqueue, 0.0),
                service_latency=t_done - t_start,
                clock_mhz=point.f,
                modelled_time_s=per_time * rows,
                energy_j=per_energy * rows,
                boost_energy_j=per_boost * rows,
                result=result,
                stages=stages,
                realtime_margin=entry.realtime_margin,
            )

    # ------------------------------------------------------------------ #
    # service-level reporting
    # ------------------------------------------------------------------ #

    def report(self) -> ServiceReport:
        receipts = self.receipts
        lat = np.array([r.latency for r in receipts]) if receipts else np.zeros(1)
        # One wall-time contribution per batch (receipts in a batch share
        # the batch's service latency), over the *retained* window so every
        # report field covers the same receipts when retention is capped.
        batch_wall = {r.batch_id: r.service_latency for r in receipts}
        return ServiceReport(
            n_requests=len(receipts),
            n_transforms=sum(r.request.batch for r in receipts),
            n_batches=len(batch_wall),
            wall_s=sum(batch_wall.values()),
            energy_j=sum(r.energy_j for r in receipts),
            boost_energy_j=sum(r.boost_energy_j for r in receipts),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            mean_latency_s=float(lat.mean()),
            cache=self.cache.stats,
            steals=self.dispatcher.steals,
            clock_locks=self.clock.lock_count,
        )
