"""The energy-aware streaming FFT service.

Request lifecycle (docs/serving.md walks through a full example):

  enqueue      submit() stamps arrival time and parks the request
  batch        drain() coalesces pending requests into Eq. 6-sized batches
  plan-cache   each batch's shape hits the plan + sweep cache (one FFT plan
               and one DVFS sweep per distinct shape, ever)
  clock-plan   the batch's operating point is selected from the cached
               sweep under the strictest per-request real-time budget
  execute      the batch runs with the clock locked (ClockController), on
               the device the work-stealing dispatcher assigned — or
               sharded over the whole mesh for oversized batches
  account      every request gets a receipt: queue/service latency
               (measured) and energy at the locked vs boost clock
               (modelled, Eqs. 3-4)

The energy numbers come from the repository's analytic model — the same
model the benchmarks validate against the paper — because this container
has no power sensor.  An optional ``telemetry`` bundle
(repro.power.FleetTelemetry) adds a *measured* energy estimate next to
the modelled one: each executed batch takes one watchdog-classified
power sample, and receipts carry ``measured_energy_j`` priced at the
measured power when the reading is fresh, at the model otherwise (the
never-freewheel contract applied to accounting).  On instrumented
hardware the same hook wraps NVML via a hardware PowerSampler.

Robustness (repro.serving.slo + repro.runtime.faults): an optional
``slo`` policy turns drain() into admission-controlled serving — every
rejected or pressure-degraded request still terminates in a receipt
stating why.  An optional ``fault_plan`` injects deterministic serving
faults; the service answers with per-device circuit breakers,
jittered-backoff retries, work redistribution through the work-stealing
queue, and the graceful-degradation ladder (tuned-dvfs -> boost-heuristic
-> pure-jax) instead of crashing.  Every receipt records the rung it was
served at.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import guarded_ratio
from repro.core.hardware import TPU_V5E, DeviceSpec
from repro.core.power_model import PowerModel
from repro.core.scheduler import ClockController
from repro.obs.drift import DriftDetector
from repro.obs.ledger import LaunchLedger
from repro.obs.metrics import MetricsRegistry, latency_summary
from repro.runtime import journal as wal
from repro.runtime.faults import (FAIL_CLOCK_LOCK, FAIL_PLAN_BUILD,
                                  KILL_DEVICE, KILL_HOST, STALL_WORKER,
                                  CircuitBreaker, ClockLockError,
                                  DeviceLostError, FaultPlan, HostLostError,
                                  HostTopology, PlanBuildError, RetryPolicy)
from repro.serving.batcher import Batch, coalesce
from repro.serving.cache import CacheStats, PlanSweepCache
from repro.serving.dispatch import Dispatcher
from repro.serving.request import (KIND_FFT, KIND_PULSAR, FFTRequest,
                                   RequestReceipt, StageReceipt)
from repro.serving.slo import (RUNG_BOOST_HEURISTIC, RUNG_PURE_JAX,
                               RUNG_TUNED_DVFS, SHED, SLOPolicy,
                               AdmissionController, max_rung_for_kind)

_EXEC_DTYPE = {"fp16": jnp.complex64, "fp32": jnp.complex64,
               "fp64": jnp.complex128}
# Real execution dtypes for R2C payloads — stacking them as complex would
# double the device bytes and forfeit the R2C saving the receipts report.
_REAL_EXEC_DTYPE = {"fp16": jnp.float32, "fp32": jnp.float32,
                    "fp64": jnp.float64}


@dataclasses.dataclass(frozen=True)
class ServiceReport:
    """Service-level summary over every receipt issued so far."""

    n_requests: int
    n_transforms: int
    n_batches: int
    wall_s: float                  # wall time spent executing batches
    energy_j: float                # modelled energy at the locked clocks
    boost_energy_j: float          # same work at boost (the GPU default)
    p50_latency_s: float
    p99_latency_s: float
    mean_latency_s: float
    cache: CacheStats
    steals: int
    clock_locks: int
    # --- robustness (zero on a fault-free, SLO-less service) --------------
    shed: int = 0                  # terminal shed receipts (all reasons)
    fault_shed: int = 0            # shed with a fault:* reason
    degraded: int = 0              # served at rung > 0
    retried: int = 0               # served after >= 1 lost execution
    redistributions: int = 0       # batches pushed away from a sick worker
    breaker_opens: int = 0         # circuit-breaker quarantines
    slo: dict | None = None        # SLOPolicy.evaluate() scorecard
    # --- power telemetry (repro.power), zero/None when unmetered ----------
    measured_energy_j: float = 0.0  # watchdog-fresh measured J (model-filled
    #                                 for non-fresh samples: never freewheels)
    telemetry: dict | None = None   # FleetTelemetry.summary()
    # --- observability (repro.obs), None when the service runs unmetered --
    drift: dict | None = None       # DriftDetector.summary()

    # Zero-denominator edges below follow the single documented
    # convention of repro.core.energy.guarded_ratio.

    @property
    def availability(self) -> float:
        """Served / (served + fault-shed).  Admission sheds are excluded:
        refusing work the SLO says cannot be served on time is the
        contract working, not the service failing.  An empty report is
        availability 1.0 (no demand, nothing unserved)."""
        return guarded_ratio(self.n_requests,
                             self.n_requests + self.fault_shed, on_zero=1.0)

    @property
    def joules_per_transform(self) -> float:
        return guarded_ratio(self.energy_j, self.n_transforms, on_zero=0.0)

    @property
    def i_ef(self) -> float:
        """Service-level Eq. 7 (identical work => energy ratio)."""
        return guarded_ratio(self.boost_energy_j, self.energy_j, on_zero=1.0)

    @property
    def throughput_tps(self) -> float:
        return guarded_ratio(self.n_transforms, self.wall_s, on_zero=0.0)


class FFTService:
    """Asynchronous-style FFT serving with batching, caching and DVFS.

    ``device_spec`` drives the analytic DVFS/energy model (which clock each
    batch locks to, what it costs); execution runs on the host's actual
    JAX devices.  ``mesh`` (optional) shards plain-FFT batches over every
    mesh device via repro.fft.distributed instead of placing them whole.
    ``coalesce_requests=False`` disables batching (every request executes
    alone) — the naive baseline the benchmarks compare against.
    """

    def __init__(
        self,
        device_spec: DeviceSpec = TPU_V5E,
        *,
        batch_bytes: float | None = None,
        time_budget: float | None = 0.10,
        devices: Sequence[Any] | None = None,
        mesh: Any = None,
        coalesce_requests: bool = True,
        bucket_batches: bool = True,
        keep_results: bool = True,
        max_retained_receipts: int | None = None,
        plan_fn=None,
        sweep_fn=None,
        power_model: PowerModel | None = None,
        timer=time.monotonic,
        slo: SLOPolicy | None = None,
        fault_plan: FaultPlan | None = None,
        retry_policy: RetryPolicy | None = None,
        breaker_threshold: int = 2,
        breaker_cooldown_s: float = 0.05,
        drain_deadline_s: float | None = None,
        sleep_fn: Callable[[float], None] | None = None,
        telemetry=None,
        tracer=None,
        metrics: MetricsRegistry | None = None,
        ledger: LaunchLedger | None = None,
        drift: DriftDetector | None = None,
        journal=None,
        topology: HostTopology | None = None,
    ):
        self.device_spec = device_spec
        # Default batch budget: an eighth of device memory, capped at the
        # paper's ~2 GB measurement batches (Sec. 4).
        self.batch_bytes = (batch_bytes if batch_bytes is not None
                            else min(2e9, device_spec.memory_bytes / 8))
        self.time_budget = time_budget
        self.mesh = mesh
        self.coalesce_requests = coalesce_requests
        self.bucket_batches = bucket_batches
        self.keep_results = keep_results
        # Receipts (which pin request payloads and, with keep_results,
        # outputs) grow with traffic; long-running servers should bound
        # retention — oldest receipts are evicted past the cap.  report()
        # then summarises the retained window.
        self.max_retained_receipts = max_retained_receipts
        self._timer = timer
        kwargs = {}
        if plan_fn is not None:
            kwargs["plan_fn"] = plan_fn
        if sweep_fn is not None:
            kwargs["sweep_fn"] = sweep_fn
        self.cache = PlanSweepCache(
            device_spec, batch_bytes=self.batch_bytes,
            power_model=power_model, **kwargs)
        self.clock = ClockController(
            device_spec, timer=timer,
            max_events=(None if max_retained_receipts is None
                        else 2 * max_retained_receipts))
        # With a mesh the whole mesh executes each batch, so one worker.
        self.dispatcher = Dispatcher(
            devices=[None] if mesh is not None else devices)
        self._pending: list[FFTRequest] = []
        self._receipts: dict[int, RequestReceipt] = {}
        self._next_batch_id = 0
        # --- robustness state ---------------------------------------------
        self.slo = slo
        self.admission = (AdmissionController(slo, device_spec)
                          if slo is not None else None)
        self.faults = fault_plan
        self.retry = retry_policy if retry_policy is not None else RetryPolicy()
        self.drain_deadline_s = drain_deadline_s
        # Backoff sleeps are computed deterministically but not actually
        # slept by default — the cooperative drain loop would only be
        # blocking itself.  Threaded deployments pass time.sleep.
        self._sleep = sleep_fn if sleep_fn is not None else (lambda s: None)
        self._breaker_threshold = breaker_threshold
        self._breaker_cooldown_s = breaker_cooldown_s
        self.breakers: dict[int, CircuitBreaker] = {}
        self._stalled_until: dict[int, float] = {}
        self._attempts: dict[int, int] = {}      # batch_id -> lost executions
        self._forced: dict[int, tuple[int, str]] = {}  # req_id -> rung, why
        self._rung2_fns: dict[Any, Callable] = {}
        self.redistributions = 0
        self.stalls_honoured = 0
        # --- power telemetry (repro.power.FleetTelemetry, optional) -------
        # One watchdog-classified power sample per executed batch; receipts
        # carry measured_energy_j next to the modelled energy_j.  None
        # leaves the service unmetered (receipts report None).
        self.telemetry = telemetry
        # --- observability (repro.obs) ------------------------------------
        # The launch ledger is always on (recording costs one truthiness
        # check per kernel at trace time); tracing is opt-in via tracer=
        # (a repro.obs.Tracer — pass one sharing the service timer for
        # reproducible traces).  The drift detector only accumulates when
        # telemetry hands back watchdog-fresh power samples.
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.ledger = ledger if ledger is not None else LaunchLedger()
        self.drift = drift if drift is not None else DriftDetector()
        # --- crash consistency (repro.runtime.journal, optional) ----------
        # With a journal attached every admit/assign/terminal transition is
        # logged write-ahead; the journal seq of the admit record is the
        # request's durable identity (FFTRequest.jseq).  See
        # repro.serving.recovery for the replay/re-enqueue half.
        self.journal = journal
        # Host fault domains: which workers share a simulated host (a
        # KILL_HOST event takes the whole group down together).  None
        # means every worker is its own host.
        self.topology = topology
        self._by_seq: dict[int, RequestReceipt] = {}
        self.recovered_receipts: list[RequestReceipt] = []
        self.replay = None              # ReplayResult set by recover()
        self.host_kills = 0

    # ------------------------------------------------------------------ #
    # enqueue
    # ------------------------------------------------------------------ #

    def submit(
        self,
        x: Any,
        *,
        precision: str = "fp32",
        kind: str = KIND_FFT,
        latency_budget: float | None = None,
        n_harmonics: int = 32,
        transform: str = "c2c",
        ndim: int = 1,
        templates: int = 16,
        segment: int = 0,
        dm_trials: int = 16,
        payload_ref: Any = None,
    ) -> FFTRequest:
        """Enqueue one request (a (batch, *shape) or (*shape,) array).

        ``transform="r2c"`` serves real payloads through the R2C plan —
        half the energy per transform at the same length (Eq. 5/6).
        ``ndim=2`` serves 2-D transforms (e.g. imaging grids) through the
        N-D plan graph — one fused kernel pass per pow2 axis — with their
        own first-class plan + sweep cache entries.  ``kind="fdas"`` runs
        the full acceleration search (repro.search) on real time series;
        ``templates`` sizes the bank and ``segment`` pins the
        overlap-save FFT length (0 = cost-model auto-selection), and both
        are part of the plan/sweep cache key.  ``kind="pulsar"`` runs the
        end-to-end pulsar search (repro.search.pipeline) on (nchan,
        ntime) filterbanks — ``dm_trials`` sizes the dedispersion grid,
        ``templates``/``n_harmonics`` the bank and harmonic ladder, and
        all three join the cache key; its receipts carry per-stage DVFS
        shares (clock, modelled J) and the real-time margin.  The
        request's receipt becomes available after the next drain():
        ``service.receipt(request)``.
        """
        req = FFTRequest(x=jnp.asarray(x), precision=precision, kind=kind,
                         latency_budget=latency_budget,
                         n_harmonics=n_harmonics, transform=transform,
                         ndim=ndim, templates=templates, segment=segment,
                         dm_trials=dm_trials)
        req.t_enqueue = self._timer()
        req.payload_ref = payload_ref
        if self.journal is not None:
            # Write-ahead: the admit record is durable (and its seq is the
            # request's crash-stable identity) before the service takes
            # the request.  ``payload_ref`` is the caller's token for
            # re-resolving the payload after a crash — arrays themselves
            # are not journaled.
            from repro.serving.recovery import admit_record
            req.jseq = self.journal.append(wal.ADMIT, admit_record(req))
        self._pending.append(req)
        return req

    def receipt(self, request: FFTRequest) -> RequestReceipt | None:
        return self._receipts.get(request.request_id)

    def receipt_for_seq(self, jseq: int) -> RequestReceipt | None:
        """The receipt for a journal admit seq (survives recovery, where
        process-local request ids reset but journal seqs never do)."""
        return self._by_seq.get(jseq)

    def _remember_seq(self, jseq: int | None, receipt: RequestReceipt) -> None:
        if jseq is None:
            return
        cap = self.max_retained_receipts
        if (cap is not None and jseq not in self._by_seq
                and len(self._by_seq) >= cap):
            self._by_seq.pop(next(iter(self._by_seq)))      # oldest
        self._by_seq[jseq] = receipt

    @property
    def receipts(self) -> list[RequestReceipt]:
        return [self._receipts[k] for k in sorted(self._receipts)]

    # ------------------------------------------------------------------ #
    # batch -> plan-cache -> clock-plan -> execute -> account
    # ------------------------------------------------------------------ #

    def drain(self, *, deadline_s: float | None = None
              ) -> list[RequestReceipt]:
        """Serve every pending request; returns their receipts in order.

        With an ``slo`` policy the admission controller runs first: shed
        requests terminate immediately in a ``status="shed"`` receipt
        (with the reason), pressure-degraded ones carry their forced
        rung into execution.  ``deadline_s`` (default: the service's
        ``drain_deadline_s``) bounds the drain loop on the service timer
        so a wedged worker surfaces a DrainDeadlineError naming the
        stuck shapes instead of looping forever.

        If a batch fails mid-cycle, already-served requests keep their
        receipts and every unserved request is re-queued for the next
        drain before the error propagates — one bad batch never drops
        the rest of the wave.
        """
        pending, self._pending = self._pending, []
        if not pending:
            return []
        deadline = (deadline_s if deadline_s is not None
                    else self.drain_deadline_s)
        serve = pending
        if self.admission is not None:
            serve = []
            for d in self.admission.decide(pending, self.cache):
                if d.action == SHED:
                    self._store(RequestReceipt.make_shed(
                        d.request, d.reason, self._timer()))
                else:
                    if d.rung > RUNG_TUNED_DVFS:
                        self._forced[d.request.request_id] = (d.rung, d.reason)
                    serve.append(d.request)
        try:
            if serve:
                if self.coalesce_requests:
                    batches = coalesce(serve,
                                       device_name=self.device_spec.name,
                                       batch_bytes=self.batch_bytes,
                                       start_id=self._next_batch_id)
                else:
                    batches = [
                        Batch(self._next_batch_id + i,
                              r.shape_key(self.device_spec.name), [r])
                        for i, r in enumerate(serve)
                    ]
                self._next_batch_id += len(batches)
                if self.journal is not None:
                    for batch in batches:
                        self.journal.append(wal.ASSIGN, {
                            "batch_id": batch.batch_id,
                            "rseqs": [r.jseq for r in batch.requests]})
                for batch in batches:
                    self.dispatcher.submit(batch)
                self.dispatcher.drain(self._execute, timer=self._timer,
                                      deadline_s=deadline)
        except BaseException:
            self.dispatcher.clear()          # drop stale queued batches
            unserved = [r for r in serve
                        if r.request_id not in self._receipts]
            self._pending = unserved + self._pending
            raise
        finally:
            for r in serve:
                self._forced.pop(r.request_id, None)
        return [self._receipts[r.request_id] for r in pending
                if r.request_id in self._receipts]   # cap may have evicted

    def _stack(self, batch: Batch) -> np.ndarray:
        # Stacking happens on the host: an eager device-side concatenate
        # compiles one executable per distinct operand signature, and a
        # streaming service sees a new per-request row split nearly every
        # batch — host stacking costs one memcpy and compiles nothing.
        # The executable itself still runs on-device (jit converts the
        # host array at its one bucketed input shape).
        if batch.key.shape:
            # N-D payloads: normalise every request to (rows, *shape).
            rows = [np.asarray(r.x).reshape((-1, *batch.key.shape))
                    for r in batch.requests]
        else:
            rows = [np.atleast_2d(np.asarray(r.x)) for r in batch.requests]
        x = np.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]
        if batch.key.kind == KIND_FFT:
            if batch.key.transform == "r2c":
                return x.real.astype(_REAL_EXEC_DTYPE[batch.key.precision])
            return x.astype(_EXEC_DTYPE[batch.key.precision])
        # The pulsar pipeline and the FDAS search consume real time series.
        return x.real.astype(np.float32)

    def _effective_budget(self, batch: Batch) -> float | None:
        """Strictest real-time budget across the batch's requests.

        Budget-less requests fall back to the service default, so a loose
        explicit budget on one request can never relax the guarantee owed
        to a coalesced neighbour; None (from a request AND the default)
        means unconstrained.
        """
        budgets = [self.time_budget if r.latency_budget is None
                   else r.latency_budget for r in batch.requests]
        constrained = [b for b in budgets if b is not None]
        return min(constrained) if constrained else None

    # ------------------------------------------------------------------ #
    # fault handling
    # ------------------------------------------------------------------ #

    def _breaker(self, worker: int) -> CircuitBreaker:
        br = self.breakers.get(worker)
        if br is None:
            br = CircuitBreaker(failure_threshold=self._breaker_threshold,
                                cooldown_s=self._breaker_cooldown_s)
            self.breakers[worker] = br
        return br

    def _peek_blocked(self, worker: int, now: float) -> bool:
        """Is ``worker`` stalled or quarantined?  Pure — no probe consumed."""
        if self._stalled_until.get(worker, 0.0) > now:
            return True
        br = self.breakers.get(worker)
        return br is not None and not br.would_allow(now)

    def _reassign(self, batch: Batch, *, exclude, now: float) -> None:
        """Push ``batch`` back onto the healthiest other worker's queue.

        ``exclude`` is one worker index or an iterable of them (a host
        fault domain).  When the exclusion covers every worker the batch
        still has to land somewhere — it goes back to the excluded set
        and waits out the breaker cooldowns there.
        """
        excluded = ({exclude} if isinstance(exclude, int) else set(exclude))
        others = [w for w in range(self.dispatcher.queue.n_workers)
                  if w not in excluded]
        healthy = [w for w in others if not self._peek_blocked(w, now)]
        self.dispatcher.queue.push_least_loaded(
            batch, allowed=healthy or others or sorted(excluded))
        self.redistributions += 1

    def _batch_rung(self, batch: Batch) -> tuple[int, list[str]]:
        """The admission-forced rung of the batch: the deepest rung forced
        on any member (a coalesced neighbour's pressure degrade applies to
        the whole batch), capped at what the kind supports."""
        rung, reasons = RUNG_TUNED_DVFS, []
        for r in batch.requests:
            forced = self._forced.get(r.request_id)
            if forced is None:
                continue
            rung = max(rung, forced[0])
            if forced[1] not in reasons:
                reasons.append(forced[1])
        return min(rung, max_rung_for_kind(batch.key.kind)), reasons

    def _rung2_fn(self, key) -> Callable:
        """The pure-JAX twin of ``key``'s executable (bottom rung).

        Traced once per key under ``pallas_disabled()`` so the jitted
        function captures the pure-JAX engine permanently — a kernel-level
        miscompile or Pallas-runtime fault can never reach this rung.
        """
        fn = self._rung2_fns.get(key)
        if fn is None:
            from repro.fft.plan import pallas_disabled, plan_with_config
            with pallas_disabled():
                if key.shape:
                    from repro.fft.plan_nd import plan_nd_with_config
                    plan = plan_nd_with_config(key.shape, key.transform)
                else:
                    plan = plan_with_config(key.n, key.transform)
            fn = jax.jit(plan.fn)
            self._rung2_fns[key] = fn
        return fn

    def _span(self, name: str, **attrs):
        """A tracer span when tracing is on, else a free nullcontext."""
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **attrs)

    def _execute(self, batch: Batch, worker: int, device: Any) -> None:
        """Fault-aware execution wrapper around :meth:`_execute_batch`.

        Blocked workers (stalled or breaker-open) hand the batch to a
        healthy peer; an injected stall marks the worker and redistributes;
        a lost device trips the breaker and retries the batch elsewhere
        under the retry policy, shedding with "fault:retries-exhausted"
        receipts only when it is spent.
        """
        now = self._timer()
        if self._stalled_until.get(worker, 0.0) > now:
            self._reassign(batch, exclude=worker, now=now)
            return
        if not self._breaker(worker).allow(now):
            self._reassign(batch, exclude=worker, now=now)
            return
        if self.faults is not None:
            ev = self.faults.take(STALL_WORKER, batch_id=batch.batch_id,
                                  worker=worker)
            if ev is not None:
                self.stalls_honoured += 1
                self._stalled_until[worker] = now + ev.duration
                self._reassign(batch, exclude=worker, now=now)
                return
        try:
            self._execute_batch(batch, worker, device)
        except HostLostError as e:
            # The whole fault domain died: every worker on the host is
            # quarantined at once (breaker tripped straight to open — no
            # point counting failures towards a threshold when the host
            # is demonstrably gone) and its telemetry rings are wiped
            # (the readings lived in that host's memory).  The batch then
            # follows the normal retry/redistribute/shed ladder, with the
            # whole domain excluded.
            now = self._timer()
            self.host_kills += 1
            for w in e.workers:
                self._breaker(w).trip(now)
                if self.telemetry is not None:
                    ring = self.telemetry.rings.get(w)
                    if ring is not None:
                        ring.clear()
            attempts = self._attempts.get(batch.batch_id, 0) + 1
            self._attempts[batch.batch_id] = attempts
            if attempts > self.retry.max_retries:
                self._attempts.pop(batch.batch_id, None)
                for req in batch.requests:
                    self._store(RequestReceipt.make_shed(
                        req, "fault:host-lost", now))
                return
            self._sleep(self.retry.delay(attempts, token=batch.batch_id))
            self._reassign(batch, exclude=e.workers, now=now)
        except DeviceLostError:
            now = self._timer()
            self._breaker(worker).record_failure(now)
            attempts = self._attempts.get(batch.batch_id, 0) + 1
            self._attempts[batch.batch_id] = attempts
            if attempts > self.retry.max_retries:
                self._attempts.pop(batch.batch_id, None)
                for req in batch.requests:
                    self._store(RequestReceipt.make_shed(
                        req, "fault:retries-exhausted", now))
                return
            self._sleep(self.retry.delay(attempts, token=batch.batch_id))
            self._reassign(batch, exclude=worker, now=now)
        else:
            self._breaker(worker).record_success()

    def _execute_batch(self, batch: Batch, worker: int, device: Any) -> None:
        rung, reasons = self._batch_rung(batch)
        if (self.faults is not None
                and self.faults.take(FAIL_PLAN_BUILD, batch_id=batch.batch_id,
                                     worker=worker)):
            rung = max(rung, RUNG_BOOST_HEURISTIC)
            reasons.append("fault:plan-build-failed")
        try:
            entry = (self.cache.entry(batch.key) if rung == RUNG_TUNED_DVFS
                     else self.cache.degraded_entry(batch.key))
        except PlanBuildError:
            # A real tuned-build failure (not just an injected event):
            # walk down the ladder instead of crashing.
            rung = max(rung, RUNG_BOOST_HEURISTIC)
            if "fault:plan-build-failed" not in reasons:
                reasons.append("fault:plan-build-failed")
            entry = self.cache.degraded_entry(batch.key)
        point = (entry.point_for(self._effective_budget(batch))
                 if rung == RUNG_TUNED_DVFS else entry.sweep.boost)
        x = self._stack(batch)
        rows = x.shape[0]
        if self.bucket_batches:
            # Shape bucketing: pad the row count to the next power of two so
            # streaming drains reuse a handful of compiled shapes instead of
            # recompiling for every coalesced batch size.  Padding stays on
            # the host for the same reason stacking does — an eager pad
            # compiles once per *unbucketed* input shape, defeating the
            # bucketing it implements.
            target = 1 << (rows - 1).bit_length()
            if target > rows:
                x = np.concatenate(
                    [x, np.zeros((target - rows, *x.shape[1:]),
                                 dtype=x.dtype)], axis=0)
        # Rung 0 locks at the sweep optimum; degraded rungs still lock, at
        # boost, to pin against governor drift — clock control is
        # independent of which compute path runs, so a lock failure is
        # observable on every rung.
        lock_f = point.f
        if lock_f is not None and self.faults is not None \
                and self.faults.take(FAIL_CLOCK_LOCK, batch_id=batch.batch_id,
                                     worker=worker):
            # The clock lock could not be acquired: run unlocked at the
            # device's boost default.  At rung 0 the tuned plan is kept —
            # only the clock guarantee is lost.
            if rung == RUNG_TUNED_DVFS:
                rung = RUNG_BOOST_HEURISTIC
                point = entry.sweep.boost
            reasons.append("fault:clock-lock-failed")
            lock_f = None
        t_start = self._timer()
        ctx = (self.clock.locked(lock_f) if lock_f is not None
               else contextlib.nullcontext())
        # Span attributes (kind/shape/rung/clock) inherit to child spans;
        # the ledger capture rides the execution so a first-trace records
        # the shape's launch signature (repro.obs.ledger).
        with self._span("batch", batch_id=batch.batch_id, worker=worker,
                        kind=batch.key.kind,
                        shape=batch.key.shape or (batch.key.n,),
                        rung=rung, clock_mhz=point.f):
            with ctx:
                # Injected kills fire mid-batch: after the lock and
                # dispatch decisions, before results exist.  A host kill
                # takes the worker's whole fault domain with it.
                if (self.faults is not None
                        and self.faults.take(KILL_HOST,
                                             batch_id=batch.batch_id,
                                             worker=worker)):
                    topo = self.topology or HostTopology(
                        self.dispatcher.queue.n_workers)
                    host = topo.host_of(worker)
                    raise HostLostError(worker, host,
                                        topo.workers_of(host))
                if (self.faults is not None
                        and self.faults.take(KILL_DEVICE,
                                             batch_id=batch.batch_id,
                                             worker=worker)):
                    raise DeviceLostError(worker)
                with self._span("execute"), \
                        self.ledger.capture(key=batch.key):
                    if (self.mesh is not None
                            and batch.key.kind == KIND_FFT
                            and x.shape[0] > 1 and rung < RUNG_PURE_JAX):
                        from repro.fft.distributed import batch_parallel_fft
                        y = batch_parallel_fft(jnp.asarray(x), self.mesh,
                                               fft_fn=entry.plan)
                    else:
                        if device is not None:
                            x = jax.device_put(x, device)
                        if (rung >= RUNG_PURE_JAX
                                and batch.key.kind == KIND_FFT):
                            from repro.fft.plan import pallas_disabled
                            with pallas_disabled():
                                y = self._rung2_fn(batch.key)(x)
                        else:
                            y = entry.fn(x)
                    y = jax.block_until_ready(y)
        y = y[:rows]
        t_done = self._timer()
        self._account(batch, worker, entry, point, y, t_start, t_done,
                      rung=rung, reason="; ".join(reasons) or None)

    def _store(self, receipt: RequestReceipt, *, key=None) -> None:
        jseq = getattr(receipt.request, "jseq", None)
        if self.journal is not None and jseq is not None:
            # Durability point: the terminal record hits the journal
            # BEFORE the in-memory receipt exists, so a crash can lose an
            # execution (at-least-once) but never a receipt — replay
            # either finds the terminal record (receipt reconstructed
            # bit-identically) or re-enqueues the admit (executed again,
            # receipted once).  ``key`` (the batch's shape key) lets
            # recovery replay the launch signature from the ledger.
            from repro.serving.recovery import terminal_record
            receipt.incarnation = self.journal.incarnation
            rtype = wal.SERVED if receipt.status == "served" else wal.SHED
            self.journal.append(rtype, terminal_record(receipt, key))
            self._remember_seq(jseq, receipt)
        if (self.max_retained_receipts is not None
                and len(self._receipts) >= self.max_retained_receipts):
            self._receipts.pop(next(iter(self._receipts)))  # oldest
        self._receipts[receipt.request.request_id] = receipt
        # Terminal-receipt metrics: counters live beyond receipt retention.
        if receipt.status == "served":
            self.metrics.counter(
                "repro_requests_served_total",
                "requests served (any rung, incl. after retries)").inc()
            self.metrics.histogram(
                "repro_request_latency_seconds",
                "end-to-end (queue + service) request latency").observe(
                    receipt.latency)
            if receipt.rung > RUNG_TUNED_DVFS:
                self.metrics.counter(
                    "repro_requests_degraded_total",
                    "requests served below the tuned-DVFS rung").inc()
        else:
            self.metrics.counter(
                "repro_requests_shed_total",
                "requests terminated without execution").inc()

    def _account(self, batch, worker, entry, point, y, t_start, t_done,
                 rung=RUNG_TUNED_DVFS, reason=None):
        per_time, per_energy = entry.per_transform(point)
        _, per_boost = entry.per_transform(entry.sweep.boost)
        retries = self._attempts.pop(batch.batch_id, 0)
        # One telemetry sample per executed batch, at the clock it locked.
        # Watchdog-fresh readings price the batch at measured power; any
        # other label falls back to the modelled energy — receipts never
        # carry a number derived from telemetry the watchdog distrusts.
        measured_w = None
        if self.telemetry is not None:
            tr = self.telemetry.read(
                worker, t_done, token=batch.batch_id, f_mhz=point.f,
                u_core=entry.profile.core_utilisation(self.device_spec),
                u_mem=entry.profile.mem_utilisation(self.device_spec))
            measured_w = tr.measured_w
        if measured_w is not None:
            # Model-drift loop: one per-transform modelled-vs-measured
            # observation per metered batch, keyed on (kind, shape,
            # clock).  Fresh-only: suspect telemetry never moves EWMAs.
            self.drift.observe(
                (batch.key.kind, batch.key.shape or (batch.key.n,),
                 point.f),
                modelled=per_energy, measured=measured_w * per_time)
        launches = self.ledger.signature(batch.key)
        offset = 0
        for req in batch.requests:
            rows = req.batch
            result = y[offset:offset + rows] if self.keep_results else None
            offset += rows
            stages = None
            if entry.stages is not None:
                # Pipeline entries: scale the modelled batch's per-stage
                # plan (clock + J/stage) to this request's row share.
                share = rows / max(entry.n_fft_model, 1)
                stages = [StageReceipt(name=s.name, clock_mhz=s.f,
                                       time_s=s.time * share,
                                       energy_j=s.energy * share)
                          for s in entry.stages.stages]
            self._store(RequestReceipt(
                request=req,
                batch_id=batch.batch_id,
                worker=worker,
                queue_latency=max(t_start - req.t_enqueue, 0.0),
                service_latency=t_done - t_start,
                clock_mhz=point.f,
                modelled_time_s=per_time * rows,
                energy_j=per_energy * rows,
                boost_energy_j=per_boost * rows,
                measured_energy_j=(
                    None if self.telemetry is None
                    else (measured_w * per_time * rows
                          if measured_w is not None
                          else per_energy * rows)),
                result=result,
                stages=stages,
                realtime_margin=entry.realtime_margin,
                rung=rung,
                retries=retries,
                reason=reason,
                launches=list(launches),
            ), key=batch.key)

    # ------------------------------------------------------------------ #
    # crash consistency
    # ------------------------------------------------------------------ #

    def snapshot(self, *, governors: dict | None = None) -> str:
        """Persist the durable service state to the attached journal.

        Captures the plan/sweep cache keys, breaker and watchdog health,
        drift EWMAs, metrics counters and the batch-id high-water mark
        (plus any caller-managed power ``governors``) as an atomic
        snapshot; recovery replays only the journal records written
        after it.  Returns the snapshot path.
        """
        if self.journal is None:
            raise ValueError("snapshot() requires a journal-attached "
                             "service (pass journal= to the constructor)")
        from repro.serving.recovery import ServiceSnapshot
        return self.journal.write_snapshot(
            ServiceSnapshot.capture(self, governors=governors))

    @classmethod
    def recover(cls, journal_dir: str, **kwargs) -> "FFTService":
        """Rebuild a service from a journal directory after a crash.

        See :func:`repro.serving.recovery.recover_service` — replayed
        receipts land in ``recovered_receipts`` (and
        ``receipt_for_seq``), in-flight admits are re-enqueued via
        ``payload_fn``, and the replay accounting is on ``.replay``.
        """
        from repro.serving.recovery import recover_service
        return recover_service(journal_dir, **kwargs)

    # ------------------------------------------------------------------ #
    # service-level reporting
    # ------------------------------------------------------------------ #

    def report(self) -> ServiceReport:
        receipts = self.receipts
        served = [r for r in receipts if r.status == "served"]
        shed = [r for r in receipts if r.status == "shed"]
        fault_shed = sum(1 for r in shed
                         if (r.reason or "").startswith("fault:"))
        lat = latency_summary(r.latency for r in served)
        # One wall-time contribution per batch (receipts in a batch share
        # the batch's service latency), over the *retained* window so every
        # report field covers the same receipts when retention is capped.
        batch_wall = {r.batch_id: r.service_latency for r in served}
        return ServiceReport(
            n_requests=len(served),
            n_transforms=sum(r.request.batch for r in served),
            n_batches=len(batch_wall),
            wall_s=sum(batch_wall.values()),
            energy_j=sum(r.energy_j for r in served),
            boost_energy_j=sum(r.boost_energy_j for r in served),
            p50_latency_s=lat.p50,
            p99_latency_s=lat.p99,
            mean_latency_s=lat.mean,
            cache=self.cache.stats,
            steals=self.dispatcher.steals,
            clock_locks=self.clock.lock_count,
            shed=len(shed),
            fault_shed=fault_shed,
            degraded=sum(1 for r in served if r.rung > RUNG_TUNED_DVFS),
            retried=sum(1 for r in served if r.retries > 0),
            redistributions=self.redistributions,
            breaker_opens=sum(b.opens for b in self.breakers.values()),
            slo=self.slo.evaluate(receipts) if self.slo is not None else None,
            measured_energy_j=sum(r.measured_energy_j or 0.0 for r in served),
            telemetry=(self.telemetry.summary()
                       if self.telemetry is not None else None),
            drift=(self.drift.summary()
                   if self.drift.observations else None),
        )

    def fill_metrics(self) -> MetricsRegistry:
        """Refresh the registry from the current report and subsystem
        counters; returns the registry (render with ``.render()``).

        Terminal-receipt counters and the latency histogram accrue live
        in :meth:`_store`; everything gauge-like — cache stats, steals,
        breaker opens, telemetry labels, drift EWMAs, histogram-derived
        p50/p99 — is refreshed here in one deterministic pass.
        """
        m = self.metrics
        rep = self.report()
        h = m.histogram("repro_request_latency_seconds",
                        "end-to-end (queue + service) request latency")
        m.gauge("repro_request_latency_p50_seconds",
                "histogram-derived median latency").set(h.quantile(0.50))
        m.gauge("repro_request_latency_p99_seconds",
                "histogram-derived tail latency").set(h.quantile(0.99))
        m.gauge("repro_availability",
                "served / (served + fault-shed)").set(rep.availability)
        m.gauge("repro_energy_joules",
                "modelled energy at the locked clocks").set(rep.energy_j)
        m.gauge("repro_measured_energy_joules",
                "telemetry-priced energy (fresh samples)").set(
                    rep.measured_energy_j)
        m.gauge("repro_i_ef", "service-level Eq. 7 efficiency increase"
                ).set(rep.i_ef)
        m.gauge("repro_clock_locks", "DVFS clock locks taken").set(
            rep.clock_locks)
        m.gauge("repro_breaker_opens", "circuit-breaker quarantines").set(
            rep.breaker_opens)
        m.gauge("repro_redistributions",
                "batches pushed away from sick workers").set(
                    rep.redistributions)
        m.gauge("repro_kernel_launches_recorded",
                "ledger records captured (trace-time)").set(
                    len(self.ledger.records))
        self.cache.stats.fill_metrics(m)
        self.dispatcher.fill_metrics(m)
        if self.telemetry is not None:
            self.telemetry.fill_metrics(m)
        self.drift.fill_metrics(m)
        return m

    def metrics_text(self) -> str:
        """One Prometheus-style exposition of the whole service."""
        return self.fill_metrics().render()
