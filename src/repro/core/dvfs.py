"""Optimal-frequency search — the paper's central procedure.

For each workload (FFT length × precision in the paper; compiled step ×
mesh in the TPU application) sweep the device's allowed core-clock grid,
compute E(f) = P(f)·t(f), and pick the minimum-energy clock (Sec. 4).
Then, across a family of workloads, compute the **mean optimal frequency**
(Sec. 5.2 / Table 3) and quantify how little is lost by using it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.energy import OperatingPoint, efficiency_increase, evaluate
from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Full frequency sweep for one workload plus the paper's summary stats."""

    profile: WorkloadProfile
    points: list[OperatingPoint]          # one per allowed frequency (desc)
    optimal: OperatingPoint               # argmin_f E(f)
    boost: OperatingPoint                 # f = f_max (GPU default behaviour)
    base: OperatingPoint | None           # f = f_base if the device has one

    @property
    def optimal_frequency_frac(self) -> float:
        """Fig. 9: optimal frequency as a fraction of the boost clock."""
        return self.optimal.f / self.boost.f

    @property
    def slowdown(self) -> float:
        """Fig. 11: relative execution-time increase at the optimal clock."""
        return self.optimal.time / self.boost.time - 1.0

    @property
    def power_reduction(self) -> float:
        """Abstract's headline: power cut at the optimal clock vs boost."""
        return 1.0 - self.optimal.power / self.boost.power

    @property
    def i_ef_boost(self) -> float:
        """Fig. 13: efficiency increase vs the boost clock (Eq. 7)."""
        return efficiency_increase(self.optimal, self.boost)

    @property
    def i_ef_base(self) -> float | None:
        """Fig. 14: efficiency increase vs the base clock."""
        if self.base is None:
            return None
        return efficiency_increase(self.optimal, self.base)

    def at(self, f: float) -> OperatingPoint:
        """The sweep point closest to clock ``f`` (grid frequencies only)."""
        return min(self.points, key=lambda p: abs(p.f - f))

    def optimal_under_budget(self, time_budget: float | None) -> OperatingPoint:
        """Constrained optimum re-selected from the cached sweep points.

        The serving layer sweeps each shape once and caches the result;
        requests arriving later with different real-time budgets (Sec. 2.3)
        re-select the minimum-energy feasible point from the cached grid
        instead of re-running the sweep.
        """
        if time_budget is None:
            return self.optimal
        return _constrained_optimal(self.points, self.boost, time_budget)


def _constrained_optimal(
    points: list[OperatingPoint],
    boost: OperatingPoint,
    time_budget: float | None,
) -> OperatingPoint:
    """Minimum-energy point whose slowdown vs boost fits the Sec. 2.3 budget."""
    feasible = [
        p for p in points
        if time_budget is None or p.time / boost.time - 1.0 <= time_budget
    ]
    return min(feasible or [boost], key=lambda p: p.energy)


def sweep(
    profile: WorkloadProfile,
    device: DeviceSpec,
    power_model: PowerModel | None = None,
    *,
    time_budget: float | None = None,
    driver_cap_mhz: float | None = None,
) -> SweepResult:
    """Sweep the allowed clock grid; optionally respect a real-time budget.

    ``time_budget`` is the Sec. 2.3 constraint: the maximum tolerable
    t(f)/t(f_max) - 1 before the pipeline drops below real time (S < 1).
    ``driver_cap_mhz`` models the paper's Titan V observation that the
    driver silently caps compute clocks (requested > cap behaves as cap).
    """
    pm = power_model or PowerModel(device)
    freqs = device.frequencies()
    if driver_cap_mhz is not None:
        freqs = np.minimum(freqs, driver_cap_mhz)
        freqs = np.unique(freqs)[::-1]
    points = evaluate(profile, device, pm, freqs)
    boost = points[0]
    optimal = _constrained_optimal(points, boost, time_budget)
    base = None
    if device.f_base is not None:
        base = evaluate(profile, device, pm, np.array([device.f_base]))[0]
    return SweepResult(profile=profile, points=points, optimal=optimal,
                       boost=boost, base=base)


def energy_per_transform(result: SweepResult, n_transforms: int
                         ) -> dict[str, float]:
    """Per-transform J/time at the optimal and boost clocks (Eqs. 3-6).

    The sweep models a memory-budget-sized batch of ``n_transforms``
    transforms (Eq. 6); energy and time are linear in the count, so
    per-transform figures are exact divisions.  This is the J/transform
    proxy the ``fft`` benchmark target persists — an R2C sweep at the same
    N carries ~2x the transforms per batch at ~the same batch energy,
    which is exactly the paper's Eq. 5/6 argument for real inputs.
    """
    k = max(n_transforms, 1)
    return {
        "optimal_j": result.optimal.energy / k,
        "boost_j": result.boost.energy / k,
        "optimal_s": result.optimal.time / k,
        "boost_s": result.boost.time / k,
        "optimal_mhz": result.optimal.f,
    }


@dataclasses.dataclass(frozen=True)
class MeanOptimal:
    """Table 3 row: one clock for a whole workload family."""

    f_mean: float                         # mean optimal frequency [MHz]
    sweeps: list[SweepResult]
    # Efficiency increase (vs boost) using each workload's own optimum ...
    i_ef_tuned: float
    # ... and using the single shared mean-optimal clock.
    i_ef_mean: float

    @property
    def loss_pp(self) -> float:
        """Percentage points lost by the single shared clock (Sec. 6.2)."""
        return (self.i_ef_tuned - self.i_ef_mean) * 100.0


def mean_optimal(
    sweeps: list[SweepResult],
    device: DeviceSpec,
    *,
    exclude: set[str] = frozenset(),
) -> MeanOptimal:
    """Compute the mean optimal frequency across a family of sweeps.

    ``exclude`` mirrors the paper's treatment of Bluestein lengths on the
    Jetson Nano (excluded from the mean because of measurement error).
    """
    kept = [s for s in sweeps if s.profile.name not in exclude]
    if not kept:
        raise ValueError("no sweeps left after exclusions")
    f_mean_raw = float(np.mean([s.optimal.f for s in kept]))
    # Snap to the device grid.
    grid = device.frequencies()
    f_mean = float(grid[np.argmin(np.abs(grid - f_mean_raw))])
    i_tuned = float(np.mean([s.i_ef_boost for s in kept]))
    i_mean = float(np.mean(
        [efficiency_increase(s.at(f_mean), s.boost) for s in kept]
    ))
    return MeanOptimal(f_mean=f_mean, sweeps=kept,
                       i_ef_tuned=i_tuned, i_ef_mean=i_mean)
