"""Execution-time-vs-frequency model: the paper's three regimes, quantified.

The paper (Fig. 6 and the discussion in Sec. 6) observes three behaviours of
t(f)/t(f_max) as the core clock drops:

  (a) slightly *decreasing* at first  — reduced cache contention,
  (b) flat, then slightly increasing  — memory-bandwidth bound with
      compute/issue headroom,
  (c) increasing with every step      — a core-clocked resource (instruction
      issue or cache bandwidth) is already saturated at f_max.

We model a step/kernel with these latency components, executed with perfect
overlap (the bound is the max — the roofline assumption):

  t_mem           HBM traffic            frequency-INDEPENDENT
  t_coll          interconnect traffic   frequency-INDEPENDENT
  t_issue(f)      instruction issue      ~ 1/f
  t_cache(f)      VMEM/L1/shared traffic ~ 1/f  (cache bw scales with clock)
  t_compute(f)    MXU/FPU flops          ~ 1/f

plus an optional contention term that inflates t_mem at *high* f (regime a).
All component magnitudes are stored in seconds *at f_max*.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hardware import DeviceSpec


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """A kernel/step as seen by the DVFS model (all times at f_max, seconds)."""

    name: str
    t_mem: float = 0.0          # HBM traffic (frequency-independent)
    t_issue: float = 0.0        # instruction-issue bound at f_max
    t_cache: float = 0.0        # VMEM/L1/shared-memory bound at f_max
    t_compute: float = 0.0      # MXU/FPU bound at f_max
    t_coll: float = 0.0         # interconnect (frequency-independent)
    contention: float = 0.0     # regime-(a) strength: relative t_mem
    #                             inflation at f_max, fading to 0 at the
    #                             voltage-floor knee.
    flops: float = 0.0          # useful FLOPs (for GFLOPS & GFLOPS/W)

    @property
    def t_core(self) -> float:
        """Core-clocked bound at f_max."""
        return max(self.t_issue, self.t_cache, self.t_compute)

    @property
    def t_flat(self) -> float:
        """Frequency-independent bound."""
        return max(self.t_mem, self.t_coll)

    def time(self, f: np.ndarray | float, device: DeviceSpec) -> np.ndarray:
        """Execution time [s] at core clock ``f`` MHz."""
        f = np.asarray(f, dtype=np.float64)
        scale = device.f_max / f
        knee = device.f_vfloor_frac
        # Regime (a): cache/HBM contention relief as the core slows down.
        frac = np.clip((f / device.f_max - knee) / (1.0 - knee), 0.0, 1.0)
        t_mem_eff = self.t_mem * (1.0 + self.contention * frac)
        # Issue saturation is superlinear (latency-hiding collapse, Sec. 6);
        # cache and MXU/FPU bounds scale linearly with 1/f.
        t_issue = self.t_issue * scale**device.issue_superlinearity
        t_core = np.maximum(t_issue,
                            max(self.t_cache, self.t_compute) * scale)
        t_flat = np.maximum(t_mem_eff, self.t_coll)
        # Overlap blend: beta=1 -> roofline max (perfect latency hiding),
        # beta=0 -> fully serialised (the Jetson Nano's two SMs cannot hide
        # memory latency, so it pays for every clock step: regime c).
        beta = device.exec_overlap
        return beta * np.maximum(t_flat, t_core) + (1.0 - beta) * (t_flat + t_core)

    def regime_on(self, device: DeviceSpec) -> str:
        """Empirically classify into the paper's (a)/(b)/(c) behaviours:
        evaluate t(f) on the device's actual grid, exactly as Fig. 6 does."""
        freqs = device.frequencies()
        t = self.time(freqs, device)
        if len(t) > 2 and t[2] > t[0] * 1.005:
            return "c"
        if t.min() < t[0] * 0.998:
            return "a"
        return "b"

    @property
    def knee_frac(self) -> float:
        """f/f_max below which a core-clocked resource becomes the bound."""
        if self.t_flat <= 0:
            return 1.0
        return min(self.t_core / self.t_flat, 1.0) if self.t_core > 0 else 0.0

    def regime(self, device: DeviceSpec | None = None) -> str:
        """Classify into the paper's (a)/(b)/(c) behaviours.

        With a device, classify empirically on its clock grid (preferred —
        this is what Fig. 6 plots); without one, use the structural bound.
        """
        if device is not None:
            return self.regime_on(device)
        if self.t_flat <= 0 or self.t_core / self.t_flat >= 0.97:
            return "c"
        if self.contention > 0.005:
            return "a"
        return "b"

    def _t0(self, device: DeviceSpec) -> float:
        """Execution time at f_max."""
        return float(self.time(np.array([device.f_max]), device)[0])

    def core_utilisation(self, device: DeviceSpec) -> float:
        """How busy the core-clocked resources are at f_max (feeds P(f)).

        Two contributions: the issue/cache duty cycle itself, plus a stall
        component — on latency-hiding devices (exec_overlap ~ 1) the warps/
        lanes stay resident and switching even while waiting on memory, so
        a stalled core still burns roughly half its switching power.  On
        serialised devices the core clock-gates during memory phases.
        """
        t0 = self._t0(device)
        if t0 <= 0:
            return 1.0
        duty = self.t_core / t0
        stall = device.stall_power_frac * (1.0 - duty)
        return float(np.clip(duty + stall, 0.05, 1.0))

    def mem_utilisation(self, device: DeviceSpec) -> float:
        t0 = self._t0(device)
        return float(np.clip(self.t_mem / t0, 0.0, 1.0)) if t0 > 0 else 0.0


def absolute_profile(
    name: str,
    *,
    device: DeviceSpec,
    hbm_bytes: float,
    flops: float,
    issue_efficiency: float = 1.0,
    cache_bytes: float = 0.0,
    collective_bytes: float = 0.0,
    contention: float = 0.0,
    mxu_flops: float | None = None,
    stages: float = 0.0,
    stage_bytes: float = 0.0,
    passes: float = 1.0,
    pass_bytes: float = 0.0,
) -> WorkloadProfile:
    """Build a profile from absolute traffic/flop counts.

    ``issue_efficiency`` maps raw FLOPs onto the effective issue-limited
    throughput: achieved_flops = issue_efficiency * peak_flops.  The FFT is
    far from peak FLOPs (it is a shuffle-heavy butterfly), so its effective
    ceiling is issue-limited — the paper's Fig. 20 shows issue-slot
    utilisation is what saturates first.  ``mxu_flops`` (default: ``flops``)
    is what actually occupies the matrix/vector units.

    ``stages``/``stage_bytes`` express staged-kernel cache traffic
    (butterfly stages x working-set bytes exchanged per stage, see
    ``repro.fft.radix.stage_count``): they add ``stages * stage_bytes`` to
    ``cache_bytes`` — how a mixed-radix FFT's reduced stage count feeds
    the t_cache term of the frequency model.

    ``passes``/``pass_bytes`` express multi-pass HBM traffic the same way:
    ``passes * pass_bytes`` adds to ``hbm_bytes``.  This is how the plan
    graph's pass counts (``repro.fft.plan_nd`` — fused N-D and four-step
    plans) reach the t_mem term: a pow2 2-D transform passes 2 where the
    per-axis chain passed 4+, and the profile's memory time shrinks by
    exactly that ratio.
    """
    if mxu_flops is None:
        mxu_flops = flops
    cache_bytes = cache_bytes + stages * stage_bytes
    hbm_bytes = hbm_bytes + passes * pass_bytes
    t_issue = flops / (device.peak_flops * issue_efficiency) if flops else 0.0
    return WorkloadProfile(
        name=name,
        t_mem=hbm_bytes / device.hbm_bandwidth,
        t_issue=t_issue,
        t_cache=cache_bytes / device.cache_bandwidth,
        t_compute=mxu_flops / device.peak_flops,
        t_coll=(collective_bytes / device.link_bandwidth
                if device.link_bandwidth and collective_bytes else 0.0),
        contention=contention,
        flops=flops,
    )
