"""Per-stage DVFS scheduling — the paper's Sec. 5.3 pipeline integration.

The paper locks the GPU clock to the mean optimal frequency *only for the
duration of the cuFFT call* inside a pulsar-search pipeline
(``nvmlDeviceSetGpuLockedClocks`` / ``nvmlDeviceResetGpuLockedClocks``) and
shows the composite energy-efficiency gain equals the FFT's time share times
the FFT's gain (Table 4).

Here the same idea is a first-class scheduler object: a pipeline is a list
of stages, each with a workload profile; the scheduler assigns each stage a
clock (its family's mean-optimal, or boost for stages we leave alone),
produces a **clock plan**, simulates the sampled power trace (the paper's
10 ms nvidia-smi view, Fig. 19), and reports the composite I_ef.

On a real TPU runtime the plan's ``apply``/``reset`` events map onto the
platform power-management API between dispatches of the jitted stage
functions; in this repository the plan drives the analytic model and the
benchmarks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import numpy as np

from repro.core.energy import OperatingPoint, evaluate
from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: a profile plus the clock the scheduler chose."""

    profile: WorkloadProfile
    f_locked: float | None = None      # None = run at boost (default clocks)


@dataclasses.dataclass(frozen=True)
class StageReport:
    name: str
    f: float
    time: float
    power: float
    energy: float


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    stages: list[StageReport]
    total_time: float
    total_energy: float
    # Same pipeline, everything at boost:
    boost_time: float
    boost_energy: float

    @property
    def i_ef(self) -> float:
        """Composite efficiency increase (work is identical, so E_d/E_o)."""
        return self.boost_energy / self.total_energy

    @property
    def slowdown(self) -> float:
        return self.total_time / self.boost_time - 1.0


class DVFSScheduler:
    """Assigns per-stage clocks and evaluates the composite pipeline."""

    def __init__(self, device: DeviceSpec, power_model: PowerModel | None = None):
        self.device = device
        self.power_model = power_model or PowerModel(device)

    def _point(self, profile: WorkloadProfile, f: float) -> OperatingPoint:
        return evaluate(profile, self.device, self.power_model,
                        np.array([f]))[0]

    def plan(
        self,
        profiles: list[WorkloadProfile],
        locked: dict[str, float],
    ) -> list[Stage]:
        """Lock the clock for the named stages; others run at boost."""
        return [Stage(p, locked.get(p.name)) for p in profiles]

    def evaluate_pipeline(self, stages: list[Stage]) -> PipelineReport:
        f_boost = self.device.f_max
        reports, t_tot, e_tot, t_b, e_b = [], 0.0, 0.0, 0.0, 0.0
        for st in stages:
            f = st.f_locked if st.f_locked is not None else f_boost
            pt = self._point(st.profile, f)
            bt = self._point(st.profile, f_boost)
            reports.append(StageReport(st.profile.name, f, pt.time,
                                       pt.power, pt.energy))
            t_tot += pt.time
            e_tot += pt.energy
            t_b += bt.time
            e_b += bt.energy
        return PipelineReport(reports, t_tot, e_tot, t_b, e_b)

    def power_trace(
        self,
        stages: list[Stage],
        dt: float = 0.010,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sampled (t, P, f) trace of one pipeline pass — the paper's Fig. 19.

        ``dt`` mirrors the paper's 10 ms nvidia-smi sampling interval.
        """
        times, powers, freqs = [], [], []
        t0 = 0.0
        f_boost = self.device.f_max
        for st in stages:
            f = st.f_locked if st.f_locked is not None else f_boost
            pt = self._point(st.profile, f)
            n = max(int(np.ceil(pt.time / dt)), 1)
            times.append(t0 + dt * np.arange(n))
            powers.append(np.full(n, pt.power))
            freqs.append(np.full(n, f))
            t0 += pt.time
        return (np.concatenate(times), np.concatenate(powers),
                np.concatenate(freqs))


@dataclasses.dataclass(frozen=True)
class ClockEvent:
    """One clock-management call, timestamped relative to controller start."""

    t: float                 # seconds since the controller was created
    action: str              # "lock" | "reset"
    f: float                 # clock in effect after the call [MHz]


class ClockController:
    """Runtime clock locking around dispatches (paper Sec. 5.3).

    The paper brackets the cuFFT call with
    ``nvmlDeviceSetGpuLockedClocks`` / ``nvmlDeviceResetGpuLockedClocks``.
    This object is the serving-runtime analogue: ``with ctrl.locked(f):``
    records the lock/reset pair (on real hardware the same two hooks call
    into NVML or the platform power API) and keeps an event log from which
    a service-level Fig. 19-style frequency trace can be reconstructed.
    """

    def __init__(self, device: DeviceSpec, timer=time.monotonic,
                 max_events: int | None = None):
        """``max_events`` bounds the event log for long-running services
        (oldest events are dropped); None keeps the full history."""
        import collections
        self.device = device
        self._timer = timer
        self._t0 = timer()
        self._f = device.f_max
        self._lock_count = 0
        self.events: collections.deque[ClockEvent] = collections.deque(
            maxlen=max_events)
        # Sticky first sample: with a bounded log, the deque eventually
        # drops the earliest events and a reconstructed trace would start
        # mid-flight at an arbitrary clock.  The controller's defined
        # initial state (t=0, boost clock) is kept outside the deque so
        # trace() always starts from it.
        self._first = ClockEvent(0.0, "init", self._f)

    @property
    def current_f(self) -> float:
        return self._f

    @property
    def lock_count(self) -> int:
        return self._lock_count

    def _record(self, action: str, f: float) -> None:
        self._f = f
        if action == "lock":
            self._lock_count += 1
        self.events.append(ClockEvent(self._timer() - self._t0, action, f))

    @contextlib.contextmanager
    def locked(self, f: float):
        """Lock the core clock to ``f`` for the duration of the block."""
        prev = self._f
        self._record("lock", f)
        try:
            yield
        finally:
            self._record("reset", prev)

    def trace(self) -> tuple[np.ndarray, np.ndarray]:
        """(t, f) step trace of the clock since controller start.

        Always begins with the sticky first sample (t=0, boost clock) so
        the trace starts from a defined state even after a bounded event
        log (``max_events``) has dropped the oldest transitions.
        """
        events = [self._first, *self.events]
        ts = np.array([e.t for e in events])
        fs = np.array([e.f for e in events])
        return ts, fs


def predicted_pipeline_i_ef(fft_share: float, fft_i_ef: float) -> float:
    """The paper's Sec. 6.2 sanity arithmetic for Table 4.

    With only the FFT stage rescaled, composite energy is
    ``E = E_fft/I + E_rest`` so
    ``I_pipeline = 1 / (share/I_fft + (1-share))``.
    """
    return 1.0 / (fft_share / fft_i_ef + (1.0 - fft_share))
