"""The paper's contribution as a composable library.

Layers:
  hardware     device specs + DVFS frequency/voltage tables (paper Tables 1-2)
  power_model  P(f) = static(V) + dynamic(f, V) + memory
  perf_model   t(f) with the paper's three regimes (Fig. 6)
  energy       Eqs. (3)-(7): energy, GFLOPS/W, I_ef
  workloads    FFT plan model + compiled-step roofline profiles
  dvfs         optimal & mean-optimal frequency search (Table 3)
  scheduler    per-stage clock locking for pipelines (Sec. 5.3, Table 4)
  realtime     real-time speed-up S and hardware sizing (Sec. 2.3)
  calibration  paper-faithful V100/Jetson reproduction
"""
from repro.core.dvfs import MeanOptimal, SweepResult, mean_optimal, sweep
from repro.core.energy import (OperatingPoint, efficiency_increase, evaluate,
                               fft_flops, ffts_per_batch)
from repro.core.hardware import (DEVICES, JETSON_NANO, TESLA_V100, TITAN_V,
                                 TPU_V5E, DeviceSpec, get_device)
from repro.core.perf_model import WorkloadProfile, absolute_profile
from repro.core.power_model import PowerModel
from repro.core.realtime import RealTimeBudget, devices_required, extra_hardware
from repro.core.scheduler import DVFSScheduler, PipelineReport, Stage
from repro.core.workloads import (ConvCase, FFTCase, PulsarCase,
                                  conv_workload, fdas_total_profile,
                                  fdas_workload, fft_workload,
                                  merge_profiles, paper_lengths,
                                  pulsar_search_total_profile,
                                  pulsar_search_workload,
                                  roofline_workload)

__all__ = [k for k in dir() if not k.startswith("_")]
