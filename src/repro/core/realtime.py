"""Real-time processing constraints — the paper's Sec. 2.3 / 6.1.

The real-time speed-up S = t_acquire / t_process decides whether an energy
saving is free (S stays >= 1 after the slowdown) or costs hardware (more
devices to share the load).  The paper uses this to translate Fig. 11's
slowdowns into capital cost: "on average 60% more hardware" for the Jetson
at its optimal clock, "below 5%" (i.e. usually none) for the V100.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class RealTimeBudget:
    """A pipeline's real-time envelope."""

    t_acquire: float          # seconds of data per block (telescope side)
    t_process: float          # seconds to process one block at boost clock

    @property
    def speedup(self) -> float:
        """S = t_a / t_p  (>= 1 means real time)."""
        return self.t_acquire / self.t_process

    @property
    def slowdown_margin(self) -> float:
        """Largest tolerable relative slowdown that keeps S >= 1."""
        return max(self.speedup - 1.0, 0.0)

    def is_realtime(self, slowdown: float = 0.0) -> bool:
        return self.t_process * (1.0 + slowdown) <= self.t_acquire


def extra_hardware(slowdown: float, margin: float = 0.0) -> float:
    """Fractional extra devices needed to absorb ``slowdown`` (Sec. 6.1).

    Work is assumed embarrassingly divisible across devices (the paper's
    stated approximation for batched FFTs): processing rate scales linearly
    with device count, so a slowdown beyond the real-time margin must be
    bought back with extra devices.
    """
    needed = (1.0 + slowdown) / (1.0 + margin)
    return max(needed - 1.0, 0.0)


def devices_required(n_devices: int, slowdown: float, margin: float = 0.0) -> int:
    """Integer device count after applying :func:`extra_hardware`."""
    return math.ceil(n_devices * (1.0 + extra_hardware(slowdown, margin)))


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Operational vs capital cost trade-off (Sec. 6.1, 'language of costs')."""

    device_cost: float              # capital cost per device [currency]
    energy_cost: float = 0.25       # electricity [currency/kWh]
    years: float = 5.0              # amortisation horizon

    def operating_cost(self, avg_power_w: float, n_devices: int) -> float:
        kwh = avg_power_w / 1000.0 * 24 * 365 * self.years * n_devices
        return kwh * self.energy_cost

    def total_cost(self, avg_power_w: float, n_devices: int) -> float:
        return self.device_cost * n_devices + self.operating_cost(
            avg_power_w, n_devices
        )
