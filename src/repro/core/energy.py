"""Energy and efficiency metrics — Eqs. (3)-(7) of the paper.

  E_f   = sum_i P_i * t_i                       (3)  energy of a run
  E_ef  = C_p * t / E_f = C_p / P_avg           (4)  energy efficiency
  C_p   = 5 N log2(N) * N_b * N_FFT / t         (5)  FFT computational perf
  N_FFT = M_GB / (N * B)                        (6)  transforms per batch
  I_ef  = E_ef,o / E_ef,d                       (7)  efficiency increase

Here the model is analytic, so (3) collapses to E(f) = P(f) * t(f); the
sampled form is kept for the simulated power-trace path used by the
pipeline scheduler (mirrors the paper's 10 ms nvidia-smi sampling).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel


def guarded_ratio(num: float, den: float, *, on_zero: float = 1.0) -> float:
    """``num / den`` with ONE documented zero-denominator convention.

    Every ratio metric in this repo (availability, cache hit rate,
    efficiency increase, measured-vs-modelled energy) hits the same edge:
    an empty run divides by zero.  The convention, stated once here
    instead of ad hoc at each call site:

      * ``den == 0`` and ``num == 0``  ->  ``on_zero`` — the ratio of two
        absent quantities is *defined by the metric*: 1.0 for "fraction
        of demand served"-style metrics (no demand = nothing unserved),
        0.0 for "fraction of events that hit"-style metrics (no events =
        no hits), NaN when the caller wants absence to propagate;
      * ``den == 0`` and ``num != 0``  ->  NaN, always — a nonzero
        numerator over a zero denominator is a *contradiction* (work
        accounted against no demand), and silently mapping it to
        ``on_zero`` would hide the accounting bug.
    """
    if den == 0:
        return on_zero if num == 0 else float("nan")
    return num / den


def fft_flops(n: int, n_batches: int = 1, n_fft: int = 1) -> float:
    """Eq. (5) numerator: 5 N log2(N) * N_b * N_FFT."""
    return 5.0 * n * np.log2(n) * n_batches * n_fft


def ffts_per_batch(m_bytes: float, n: int, elem_bytes: int) -> int:
    """Eq. (6): how many length-N transforms fill ``m_bytes`` of memory."""
    return max(int(m_bytes // (n * elem_bytes)), 1)


def energy_from_trace(power_samples: np.ndarray, dt: np.ndarray | float) -> float:
    """Eq. (3) on a sampled power trace (paper: 10 ms nvidia-smi samples)."""
    p = np.asarray(power_samples, dtype=np.float64)
    dt = np.broadcast_to(np.asarray(dt, dtype=np.float64), p.shape)
    return float(np.sum(p * dt))


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """Everything the paper reports about running a workload at a clock f."""

    f: float                 # core clock [MHz]
    time: float              # execution time [s]
    power: float             # average power [W]
    energy: float            # E(f) = P * t [J]
    gflops: float            # C_p / 1e9
    gflops_per_watt: float   # E_ef / 1e9  (Eq. 4 with C_p in FLOPS)


def evaluate(
    profile: WorkloadProfile,
    device: DeviceSpec,
    power_model: PowerModel,
    f: np.ndarray | float,
) -> OperatingPoint | list[OperatingPoint]:
    """Evaluate a workload at one or many core-clock frequencies."""
    f_arr = np.atleast_1d(np.asarray(f, dtype=np.float64))
    t = profile.time(f_arr, device)
    p = power_model.power(
        f_arr,
        u_core=profile.core_utilisation(device),
        u_mem=profile.mem_utilisation(device),
    )
    e = p * t
    c_p = profile.flops / t if profile.flops else np.zeros_like(t)
    pts = [
        OperatingPoint(
            f=float(fi), time=float(ti), power=float(pi), energy=float(ei),
            gflops=float(ci) / 1e9,
            gflops_per_watt=(float(ci) / float(pi)) / 1e9 if pi > 0 else 0.0,
        )
        for fi, ti, pi, ei, ci in zip(f_arr, t, p, e, c_p)
    ]
    return pts[0] if np.isscalar(f) or np.asarray(f).ndim == 0 else pts


def efficiency_increase(opt: OperatingPoint, ref: OperatingPoint) -> float:
    """Eq. (7): I_ef = E_ef(optimal) / E_ef(reference clock)."""
    if ref.gflops_per_watt > 0:
        return opt.gflops_per_watt / ref.gflops_per_watt
    # Workloads without a FLOP count: efficiency ratio reduces to E_d/E_o.
    return ref.energy / opt.energy
