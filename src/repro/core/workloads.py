"""Workload builders for the DVFS model.

Two producers feed :class:`repro.core.perf_model.WorkloadProfile`:

* :func:`fft_workload` — an analytic model of a batched out-of-place 1-D C2C
  FFT in the style the paper measures (cuFFT plans on the GPU devices; our
  Stockham/four-step plans on the TPU).  Traffic and FLOP counts follow
  Sec. 2.1/5 of the paper:  FLOPs = 5 N log2 N per transform, HBM traffic =
  one read + one write of the whole batch per *pass*, where a pass is one
  kernel of the multi-kernel plan.

* :func:`roofline_workload` — built from a *compiled* XLA step: HLO FLOPs
  and HBM bytes from ``compiled.cost_analysis()`` plus collective bytes
  parsed from the HLO (see ``repro.analysis.roofline``).  This is how the
  paper's technique is applied to every assigned architecture cell.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile


# Byte sizes of one complex element per precision (paper: C2C transforms).
COMPLEX_BYTES = {"fp16": 4, "fp32": 8, "fp64": 16}

# Peak-FLOP multiplier per precision relative to the device's FP32 figure
# (V100-style ratios: FP64 = 1/2, FP16 = 2x).
PRECISION_PEAK = {"fp16": 2.0, "fp32": 1.0, "fp64": 0.5}


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def largest_prime_factor(n: int) -> int:
    p, f = n, 2
    largest = 1
    while f * f <= p:
        while p % f == 0:
            largest = max(largest, f)
            p //= f
        f += 1
    return max(largest, p if p > 1 else largest)


def uses_bluestein(n: int) -> bool:
    """cuFFT uses Bluestein when a factor exceeds 127 (Sec. 2.1)."""
    return largest_prime_factor(n) > 127


def plan_passes(n: int, *, max_inplace: int = 2**13) -> int:
    """Number of device-memory passes of the FFT plan.

    A single kernel keeps transforms of length <= ``max_inplace`` resident
    in shared memory/VMEM (one HBM read + one write).  Longer transforms
    use the four-step/multi-kernel decomposition: each extra level adds a
    full read+write pass.  This reproduces the staircase in the paper's
    Fig. 4 (flat regions separated by jumps at kernel switches).
    """
    if n <= max_inplace:
        return 1
    # Each pass can fold max_inplace points; levels = ceil(log(n)/log(max)).
    return max(1, math.ceil(math.log(n) / math.log(max_inplace)))


@dataclasses.dataclass(frozen=True)
class FFTCase:
    """One measured configuration: a length, precision and batch memory."""

    n: int
    precision: str = "fp32"
    batch_bytes: float = 2e9      # paper: ~2 GB of input per batch
    name: str = ""

    def __post_init__(self):
        if not self.name:
            object.__setattr__(
                self, "name", f"fft-n{self.n}-{self.precision}"
            )

    @property
    def elem_bytes(self) -> int:
        return COMPLEX_BYTES[self.precision]

    @property
    def n_fft(self) -> int:
        return max(int(self.batch_bytes // (self.n * self.elem_bytes)), 1)


def fft_workload(
    case: FFTCase,
    device: DeviceSpec,
    *,
    regime_c: bool = False,
) -> WorkloadProfile:
    """Analytic profile of a batched FFT on ``device``.

    ``regime_c`` marks plan/length combinations whose kernel saturates a
    core-clocked cache at f_max (the paper observes this for specific
    lengths, notably N = 8192 on the V100): the cache term is pinned just
    above the memory term so every frequency step costs time.
    """
    n, b = case.n, case.elem_bytes
    n_fft = case.n_fft
    data_bytes = float(n) * b * n_fft

    if uses_bluestein(n):
        # Bluestein: two forward + one inverse FFT of length M ~ 2N (pow2)
        # plus three pointwise passes — roughly 3x the traffic and flops.
        m = 1 << math.ceil(math.log2(2 * n - 1))
        passes = 3 * plan_passes(m) + 1
        flops = 3 * 5.0 * m * math.log2(m) * n_fft + 20.0 * n * n_fft
    else:
        passes = plan_passes(n)
        flops = 5.0 * n * math.log2(n) * n_fft

    hbm_bytes = 2.0 * data_bytes * passes          # read + write per pass
    peak = device.peak_flops * PRECISION_PEAK[case.precision]

    t_mem = hbm_bytes / device.hbm_bandwidth
    t_issue = flops / (peak * device.issue_efficiency)
    # Shared/VMEM traffic: every butterfly stage exchanges the working set.
    stages = max(math.log2(max_pts := min(n, 2**13)), 1.0)
    cache_bytes = 2.0 * data_bytes * stages / 3.0   # radix-8: log8(N) stages
    t_cache = cache_bytes / device.cache_bandwidth
    if regime_c:
        t_cache = max(t_cache, 1.02 * t_mem)
    return WorkloadProfile(
        name=case.name,
        t_mem=t_mem,
        t_issue=t_issue,
        t_cache=t_cache,
        t_compute=flops / peak,
        contention=0.01,            # mild regime-(a) relief, Fig. 6
        flops=flops,
    )


def roofline_workload(
    name: str,
    device: DeviceSpec,
    *,
    hlo_flops: float,
    hbm_bytes: float,
    collective_bytes: float = 0.0,
    useful_flops: float | None = None,
    issue_efficiency: float | None = None,
) -> WorkloadProfile:
    """Profile a compiled XLA step for the DVFS planner.

    ``issue_efficiency`` defaults to the device's calibrated value; XLA
    steps dominated by large matmuls run much closer to peak than a
    butterfly kernel, so callers may pass a higher value (e.g. 0.7-0.9
    for MXU-saturating training steps).
    """
    eff = device.issue_efficiency if issue_efficiency is None else issue_efficiency
    t_coll = (
        collective_bytes / device.link_bandwidth
        if device.link_bandwidth and collective_bytes else 0.0
    )
    return WorkloadProfile(
        name=name,
        t_mem=hbm_bytes / device.hbm_bandwidth,
        t_issue=hlo_flops / (device.peak_flops * eff),
        t_cache=0.0,
        t_compute=hlo_flops / device.peak_flops,
        t_coll=t_coll,
        flops=useful_flops if useful_flops is not None else hlo_flops,
    )


# The FFT-length sweep the paper covers (powers of two 2^5..2^22 plus a few
# radix-7+/Bluestein lengths for completeness).
def paper_lengths() -> list[int]:
    pow2 = [2**k for k in range(5, 23)]
    other = [3**7, 7**4, 139**2]            # mixed radix-3, radix-7, Bluestein
    return pow2 + other


# V100 lengths the paper singles out as regime (c).
V100_REGIME_C_LENGTHS = {8192}
