"""Workload builders for the DVFS model.

Two producers feed :class:`repro.core.perf_model.WorkloadProfile`:

* :func:`fft_workload` — an analytic model of a batched out-of-place 1-D C2C
  FFT in the style the paper measures (cuFFT plans on the GPU devices; our
  Stockham/four-step plans on the TPU).  Traffic and FLOP counts follow
  Sec. 2.1/5 of the paper:  FLOPs = 5 N log2 N per transform, HBM traffic =
  one read + one write of the whole batch per *pass*, where a pass is one
  kernel of the multi-kernel plan.

* :func:`roofline_workload` — built from a *compiled* XLA step: HLO FLOPs
  and HBM bytes from ``compiled.cost_analysis()`` plus collective bytes
  parsed from the HLO (see ``repro.analysis.roofline``).  This is how the
  paper's technique is applied to every assigned architecture cell.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile


# Byte sizes of one complex element per precision (paper: C2C transforms).
COMPLEX_BYTES = {"fp16": 4, "fp32": 8, "fp64": 16}

# Peak-FLOP multiplier per precision relative to the device's FP32 figure
# (V100-style ratios: FP64 = 1/2, FP16 = 2x).
PRECISION_PEAK = {"fp16": 2.0, "fp32": 1.0, "fp64": 0.5}


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def largest_prime_factor(n: int) -> int:
    p, f = n, 2
    largest = 1
    while f * f <= p:
        while p % f == 0:
            largest = max(largest, f)
            p //= f
        f += 1
    return max(largest, p if p > 1 else largest)


def uses_bluestein(n: int) -> bool:
    """cuFFT uses Bluestein when a factor exceeds 127 (Sec. 2.1)."""
    return largest_prime_factor(n) > 127


def _butterfly_flops(n: int, radices: tuple[int, ...] | None) -> float:
    """FLOPs of one length-``n`` transform.

    ``radices=None`` keeps the paper's Eq. 5 reporting convention
    (5 N log2 N); an explicit schedule counts the operations the
    mixed-radix engine actually executes (repro.fft.radix).
    """
    if n <= 1:
        return 0.0
    if radices is None:
        return 5.0 * n * math.log2(n)
    from repro.fft.radix import mixed_radix_flop_count
    return mixed_radix_flop_count(n, radices)


def _r2c_flops(n: int, radices: tuple[int, ...] | None) -> float:
    """FLOPs of one packed length-``n`` R2C/C2R transform (Eq. 5 at N/2).

    With an explicit schedule this is exactly
    :func:`repro.fft.radix.r2c_flop_count` (the engine's executed count);
    ``radices=None`` keeps the paper's reporting convention.
    """
    if radices is not None:
        from repro.fft.radix import r2c_flop_count
        return r2c_flop_count(n, radices)
    m = max(n // 2, 1)
    return _butterfly_flops(m, None) + 10.0 * (m + 1)


def _stage_count(n: int, radices: tuple[int, ...] | None) -> float:
    """Butterfly stages of one fused pass (feeds the t_cache term).

    ``radices=None`` keeps the legacy cuFFT-flavoured radix-8 estimate
    (log2(N)/3) the paper calibration is pinned against.
    """
    if radices is None:
        return max(math.log2(max(n, 2)), 1.0) / 3.0
    from repro.fft.radix import stage_count
    return float(stage_count(n, radices))


def plan_passes(n: int, *, max_inplace: int = 2**13) -> int:
    """Number of device-memory passes of the FFT plan.

    A single kernel keeps transforms of length <= ``max_inplace`` resident
    in shared memory/VMEM (one HBM read + one write).  Longer transforms
    use the four-step/multi-kernel decomposition: each extra level adds a
    full read+write pass.  This reproduces the staircase in the paper's
    Fig. 4 (flat regions separated by jumps at kernel switches).
    """
    if n <= max_inplace:
        return 1
    # Each pass can fold max_inplace points; levels = ceil(log(n)/log(max)).
    return max(1, math.ceil(math.log(n) / math.log(max_inplace)))


#: Transform kinds the analytic model understands.
TRANSFORMS = ("c2c", "r2c", "c2r")


@dataclasses.dataclass(frozen=True)
class FFTCase:
    """One measured configuration: length/shape, precision, transform, batch.

    ``transform``: C2C (the paper's workload) or the real-input R2C / its
    C2R inverse — real transforms pack N points into an N/2 complex FFT,
    so both the per-transform element size (Eq. 6) and the FLOP count
    (Eq. 5) halve.

    ``shape``: transform-axes lengths for N-D cases (Eq. 2); ``n`` is then
    derived as their product and pass counts come from the plan graph
    (``repro.fft.plan_nd``) instead of the 1-D staircase.  Leave ``None``
    (and set ``n``) for the paper's 1-D sweep.

    ``radices``: the kernel's butterfly schedule, feeding radix-aware
    stage/FLOP counts.  ``None`` keeps the legacy cuFFT-convention model
    the paper calibration is pinned against (radix-8-style stage count,
    5 N log2 N FLOPs).
    """

    n: int = 0
    precision: str = "fp32"
    batch_bytes: float = 2e9      # paper: ~2 GB of input per batch
    name: str = ""
    transform: str = "c2c"
    radices: tuple[int, ...] | None = None
    shape: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.shape is not None:
            prod = 1
            for d in self.shape:
                prod *= d
            if self.n not in (0, prod):
                raise ValueError(
                    f"n={self.n} inconsistent with shape={self.shape}")
            object.__setattr__(self, "n", prod)
        if self.n < 1:
            raise ValueError("FFTCase needs n >= 1 (or a shape)")
        if self.transform not in TRANSFORMS:
            raise ValueError(f"unknown transform {self.transform!r}; "
                             f"have {TRANSFORMS}")
        if not self.name:
            suffix = "" if self.transform == "c2c" else f"-{self.transform}"
            dims = ("x".join(str(d) for d in self.shape)
                    if self.shape else str(self.n))
            object.__setattr__(
                self, "name", f"fft-n{dims}-{self.precision}{suffix}"
            )

    @property
    def last_axis(self) -> int:
        """The axis the R2C packing applies to (Eq. 2: the last one)."""
        return self.shape[-1] if self.shape else self.n

    @property
    def elem_bytes(self) -> int:
        """Per-point input bytes: complex for C2C, real (half) for R2C/C2R.

        Non-pow2 real transforms fall back to the full C2C algorithm
        (repro.fft.plan), so they pay — and are modelled at — complex
        bytes.  N-D r2c packs along the last axis only.
        """
        full = COMPLEX_BYTES[self.precision]
        if self.transform in ("r2c", "c2r") and is_pow2(self.last_axis):
            return full // 2
        return full

    @property
    def n_fft(self) -> int:
        """Eq. 6: transforms per batch — R2C fits 2x more per byte."""
        return max(int(self.batch_bytes // (self.n * self.elem_bytes)), 1)


def fft_workload(
    case: FFTCase,
    device: DeviceSpec,
    *,
    regime_c: bool = False,
) -> WorkloadProfile:
    """Analytic profile of a batched FFT on ``device``.

    ``regime_c`` marks plan/length combinations whose kernel saturates a
    core-clocked cache at f_max (the paper observes this for specific
    lengths, notably N = 8192 on the V100): the cache term is pinned just
    above the memory term so every frequency step costs time.
    """
    if case.shape is not None and len(case.shape) > 1:
        return _nd_fft_workload(case, device, regime_c=regime_c)
    n, b = case.n, case.elem_bytes
    n_fft = case.n_fft
    # The packed R2C/C2R path only exists for pow2 lengths; non-pow2 real
    # plans fall back to full C2C (and elem_bytes stays complex above).
    real = case.transform in ("r2c", "c2r") and is_pow2(n)
    # Real transforms run the packed N/2 complex transform; elem_bytes is
    # already halved, so data_bytes (and every traffic term) halves too.
    n_work = max(n // 2, 1) if real else n
    data_bytes = float(n) * b * n_fft

    if uses_bluestein(n):
        # Bluestein: one forward + one inverse FFT of length M ~ 2N (pow2;
        # the filter spectrum is precomputed per length, repro.fft.bluestein)
        # plus pointwise chirp passes.
        m = 1 << math.ceil(math.log2(2 * n - 1))
        passes = 2 * plan_passes(m) + 1
        flops = 2 * _butterfly_flops(m, case.radices) * n_fft \
            + 20.0 * n * n_fft
        stages = _stage_count(min(m, 2**13), case.radices)
    else:
        passes = plan_passes(n_work)
        flops = (_r2c_flops(n, case.radices) if real
                 else _butterfly_flops(n_work, case.radices)) * n_fft
        stages = _stage_count(min(n_work, 2**13), case.radices)

    hbm_bytes = 2.0 * data_bytes * passes          # read + write per pass
    peak = device.peak_flops * PRECISION_PEAK[case.precision]

    t_mem = hbm_bytes / device.hbm_bandwidth
    t_issue = flops / (peak * device.issue_efficiency)
    # Shared/VMEM traffic: every butterfly stage exchanges the working set.
    cache_bytes = 2.0 * data_bytes * stages
    t_cache = cache_bytes / device.cache_bandwidth
    if regime_c:
        t_cache = max(t_cache, 1.02 * t_mem)
    return WorkloadProfile(
        name=case.name,
        t_mem=t_mem,
        t_issue=t_issue,
        t_cache=t_cache,
        t_compute=flops / peak,
        contention=0.01,            # mild regime-(a) relief, Fig. 6
        flops=flops,
    )


def _nd_fft_workload(
    case: FFTCase,
    device: DeviceSpec,
    *,
    regime_c: bool = False,
) -> WorkloadProfile:
    """Analytic profile of a batched N-D FFT (Eq. 2 factored passes).

    Pass counts come from the compiled plan graph
    (:func:`repro.fft.plan_nd.nd_pass_summary`) — pow2 axes fuse their
    hand-off transpose into the FFT write, so a pow2 2-D transform costs
    2 HBM passes where the per-axis ``moveaxis`` chain paid 4+.  FLOPs sum
    the per-axis butterfly counts over the points of the other axes; an
    R2C last axis does half the work and shrinks every later axis's row
    count to (n_last/2 + 1)/n_last.
    """
    from repro.fft.plan_nd import nd_pass_summary

    shape = case.shape
    n, b = case.n, case.elem_bytes
    n_fft = case.n_fft
    transform = case.transform if case.transform != "c2r" else "r2c"
    passes, _chain, stages = nd_pass_summary(shape, transform)

    def axis_flops(na: int) -> float:
        """One length-``na`` 1-D transform, Bluestein-aware (Sec. 2.1)."""
        if not is_pow2(na):
            m = 1 << math.ceil(math.log2(max(2 * na - 1, 2)))
            return 2 * _butterfly_flops(m, case.radices) + 20.0 * na
        return _butterfly_flops(na, case.radices)

    real = transform == "r2c" and is_pow2(shape[-1]) and shape[-1] >= 2
    flops = 0.0
    rows_frac = 1.0
    for axis in reversed(range(len(shape))):
        na = shape[axis]
        batch_pts = n / na                      # transforms of this axis
        if axis == len(shape) - 1 and real:
            flops += batch_pts * _r2c_flops(na, case.radices)
            rows_frac = (na // 2 + 1) / na      # half-spectrum downstream
        else:
            flops += rows_frac * batch_pts * axis_flops(na)
    flops *= n_fft

    data_bytes = float(n) * b * n_fft
    hbm_bytes = 2.0 * data_bytes * passes
    cache_bytes = 2.0 * data_bytes * stages
    peak = device.peak_flops * PRECISION_PEAK[case.precision]
    t_mem = hbm_bytes / device.hbm_bandwidth
    t_cache = cache_bytes / device.cache_bandwidth
    if regime_c:
        t_cache = max(t_cache, 1.02 * t_mem)
    return WorkloadProfile(
        name=case.name,
        t_mem=t_mem,
        t_issue=flops / (peak * device.issue_efficiency),
        t_cache=t_cache,
        t_compute=flops / peak,
        contention=0.01,
        flops=flops,
    )


@dataclasses.dataclass(frozen=True)
class ConvCase:
    """One overlap-save matched-filter configuration (the FDAS workload).

    ``n`` complex points per row are convolved against a bank of
    ``templates`` filters of ``taps`` points each through the segmented
    engine (``repro.fft.convolve``); ``nfft=0`` lets the engine's cost
    model pick the segment length.  ``batch_bytes`` sizes the batch by
    the Eq. 6 memory budget, exactly like :class:`FFTCase`.
    """

    n: int
    templates: int
    taps: int
    nfft: int = 0
    precision: str = "fp32"
    batch_bytes: float = 2e9
    radices: tuple[int, ...] | None = None
    name: str = ""

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"ConvCase needs n >= 1, got {self.n}")
        if self.templates < 1 or self.taps < 1:
            raise ValueError(
                f"ConvCase needs templates/taps >= 1, got "
                f"{self.templates}/{self.taps}")
        if self.precision not in COMPLEX_BYTES:
            raise ValueError(f"unknown precision {self.precision!r}")
        if not self.name:
            object.__setattr__(
                self, "name",
                f"conv-n{self.n}-t{self.templates}x{self.taps}"
                f"-{self.precision}")

    @property
    def plan(self):
        """The memoised overlap-save plan (segmentation + pass counts)."""
        from repro.fft.convolve import conv_plan
        return conv_plan(self.n, self.taps, self.templates, self.nfft)

    @property
    def n_rows(self) -> int:
        """Eq. 6: complex rows per memory-budgeted batch."""
        return max(int(self.batch_bytes
                       // (self.n * COMPLEX_BYTES[self.precision])), 1)


def conv_workload(case: ConvCase, device: DeviceSpec) -> WorkloadProfile:
    """Analytic profile of one batched overlap-save matched-filter plane.

    Pass and traffic counts come straight from the engine's own plan
    (``ConvPlan``: one fused forward pass feeding T filters, T inverse
    passes, zero standalone multiply passes), so the DVFS model and the
    implementation stay consistent the same way ``fft_workload`` and
    ``repro.fft.plan`` do.
    """
    plan = case.plan
    rows = case.n_rows
    t = case.templates
    seg_pts = plan.n_segments * plan.nfft
    scale = COMPLEX_BYTES[case.precision] / 8.0    # plan bytes are complex64
    hbm_bytes = plan.os_bytes * scale * rows
    flops = ((1 + t) * _butterfly_flops(plan.nfft, case.radices)
             * plan.n_segments + 6.0 * t * seg_pts) * rows
    # Every fused pass exchanges its working set once per butterfly stage.
    stages = _stage_count(plan.nfft, case.radices)
    cache_bytes = 2.0 * seg_pts * 8.0 * scale * rows * stages * (1 + t)
    peak = device.peak_flops * PRECISION_PEAK[case.precision]
    return WorkloadProfile(
        name=case.name,
        t_mem=hbm_bytes / device.hbm_bandwidth,
        t_issue=flops / (peak * device.issue_efficiency),
        t_cache=cache_bytes / device.cache_bandwidth,
        t_compute=flops / peak,
        contention=0.01,
        flops=flops,
    )


def fdas_workload(case: ConvCase, device: DeviceSpec, *,
                  series_n: int | None = None) -> list[WorkloadProfile]:
    """Per-stage profiles of the acceleration search (Sec. 5.3 applied to
    the White et al. workload): R2C FFT -> template convolution ->
    power/threshold detection.

    ``case.n`` is the half-spectrum length; ``series_n`` overrides the
    time-series length (default ``2 * (n - 1)``).  The returned stages
    feed ``core.dvfs.sweep`` / ``core.scheduler.DVFSScheduler`` exactly
    like ``fft.pipeline.stage_profiles`` — but here the FFT-class stages
    (R2C + convolution) dominate, so the composite Table-4 saving is far
    closer to the FFT-only figure.
    """
    if series_n is None:
        series_n = 2 * (case.n - 1)
    fft_prof = fft_workload(
        FFTCase(n=series_n, precision=case.precision,
                batch_bytes=case.batch_bytes, transform="r2c",
                radices=case.radices, name="fdas-fft"),
        device,
    )
    conv_prof = dataclasses.replace(conv_workload(case, device),
                                    name="fdas-conv")
    # Detection: read the (T, nbins) plane, write power + the top-k pass.
    rows = case.n_rows
    plane = float(case.templates * case.n * rows)
    det_bytes = plane * (8.0 + 4.0) * (COMPLEX_BYTES[case.precision] / 8.0)
    det_flops = 5.0 * plane
    peak = device.peak_flops * PRECISION_PEAK[case.precision]
    detect = WorkloadProfile(
        name="fdas-detect",
        t_mem=det_bytes / device.hbm_bandwidth,
        t_issue=det_flops / (peak * 0.4),
        t_compute=det_flops / peak,
        flops=det_flops,
    )
    return [fft_prof, conv_prof, detect]


def merge_profiles(name: str,
                   profs: list[WorkloadProfile]) -> WorkloadProfile:
    """Sum stage profiles into one (for service-level single-clock sweeps).

    Times and FLOPs add; contention is t_mem-weighted (the memory-bound
    fraction is what the contention term scales, Fig. 6)."""
    t_mem = sum(p.t_mem for p in profs)
    contention = (sum(p.contention * p.t_mem for p in profs) / t_mem
                  if t_mem > 0 else 0.0)
    return WorkloadProfile(
        name=name,
        t_mem=t_mem,
        t_issue=sum(p.t_issue for p in profs),
        t_cache=sum(p.t_cache for p in profs),
        t_compute=sum(p.t_compute for p in profs),
        t_coll=sum(p.t_coll for p in profs),
        contention=contention,
        flops=sum(p.flops for p in profs),
    )


def fdas_total_profile(case: ConvCase, device: DeviceSpec, *,
                       series_n: int | None = None) -> WorkloadProfile:
    """All FDAS stages merged into one profile (service-level sweeps)."""
    return merge_profiles(f"fdas-n{case.n}-t{case.templates}",
                          fdas_workload(case, device, series_n=series_n))


@dataclasses.dataclass(frozen=True)
class PulsarCase:
    """One end-to-end pulsar-search configuration (repro.search.pipeline).

    A batch holds ``n_rows`` filterbanks of (nchan, ntime) float32
    samples (the Eq. 6 memory budget applied to the pipeline's *input*);
    each expands to ``dm_trials`` dedispersed series, which FDAS turns
    into (dm_trials * templates) power rows of ``nbins`` each for the
    harmonic-sum and sift stages.
    """

    nchan: int
    ntime: int
    dm_trials: int
    templates: int
    taps: int
    n_harmonics: int = 8
    precision: str = "fp32"
    batch_bytes: float = 2e9
    radices: tuple[int, ...] | None = None
    name: str = ""

    def __post_init__(self):
        if min(self.nchan, self.ntime, self.dm_trials, self.templates,
               self.taps) < 1:
            raise ValueError(
                f"PulsarCase needs every dimension >= 1, got nchan="
                f"{self.nchan} ntime={self.ntime} dm_trials="
                f"{self.dm_trials} templates={self.templates} "
                f"taps={self.taps}")
        if self.n_harmonics < 1 or self.n_harmonics & (self.n_harmonics - 1):
            raise ValueError(
                f"n_harmonics must be a power of two, got "
                f"{self.n_harmonics}")
        if self.precision not in COMPLEX_BYTES:
            raise ValueError(f"unknown precision {self.precision!r}")
        if not self.name:
            object.__setattr__(
                self, "name",
                f"pulsar-c{self.nchan}x{self.ntime}-d{self.dm_trials}"
                f"-t{self.templates}-{self.precision}")

    @property
    def sample_bytes(self) -> int:
        """Bytes of one filterbank sample (real, half the complex size)."""
        return COMPLEX_BYTES[self.precision] // 2

    @property
    def n_rows(self) -> int:
        """Eq. 6: filterbanks per memory-budgeted batch."""
        return max(int(self.batch_bytes
                       // (self.nchan * self.ntime * self.sample_bytes)), 1)

    @property
    def nbins(self) -> int:
        return self.ntime // 2 + 1


def pulsar_search_workload(case: PulsarCase,
                           device: DeviceSpec) -> list[WorkloadProfile]:
    """Per-stage profiles of the end-to-end search: dedisp -> fdas ->
    harmonic-sum -> sift.

    Each stage's traffic follows its kernel's actual HBM/VMEM pattern
    (the same discipline as ``fft_workload`` vs ``repro.fft.plan``):
    dedispersion reads the (C, N) block once and writes D series while
    re-reading VMEM D*C times; FDAS is the merged R2C + overlap-save
    model over D series per filterbank; the harmonic-sum plane kernel
    reads the power plane once and writes only (stat, level); sifting
    is one streaming top-k pass.  These four feed ``dvfs.sweep`` +
    ``DVFSScheduler`` for the per-stage clock plan.
    """
    rows = case.n_rows
    sb = float(case.sample_bytes)
    peak = device.peak_flops * PRECISION_PEAK[case.precision]
    c, n, d, t = case.nchan, case.ntime, case.dm_trials, case.templates

    # --- dedispersion: shift-and-sum, memory-bound ----------------------
    dd_hbm = (c + d) * n * sb * rows                 # read block, write D
    dd_flops = float(d) * c * n * rows               # one add per (dm, ch)
    dd_cache = 2.0 * d * c * n * sb * rows           # VMEM re-reads
    dedisp = WorkloadProfile(
        name="dedisp",
        t_mem=dd_hbm / device.hbm_bandwidth,
        t_issue=dd_flops / (peak * 0.4),
        t_cache=dd_cache / device.cache_bandwidth,
        t_compute=dd_flops / peak,
        contention=0.01,
        flops=dd_flops,
    )

    # --- FDAS (R2C + matched filter) over D series per filterbank -------
    conv_case = ConvCase(
        n=case.nbins, templates=t, taps=case.taps,
        precision=case.precision,
        batch_bytes=float(rows * d) * case.nbins
        * COMPLEX_BYTES[case.precision],
        radices=case.radices)
    fdas = dataclasses.replace(
        merge_profiles("fdas", fdas_workload(conv_case, device,
                                             series_n=n)[:2]),
        name="fdas")

    # --- harmonic sum: fused plane kernel (stat + level out only) -------
    plane_rows = float(rows * d) * t
    hs_hbm = plane_rows * case.nbins * (sb + 2 * sb)  # read P, write 2
    hs_levels = max(case.n_harmonics.bit_length(), 1)
    hs_flops = plane_rows * case.nbins * (case.n_harmonics + 3 * hs_levels)
    hs_cache = 2.0 * plane_rows * case.nbins * sb * hs_levels
    hsum = WorkloadProfile(
        name="harmonic-sum",
        t_mem=hs_hbm / device.hbm_bandwidth,
        t_issue=hs_flops / (peak * 0.4),
        t_cache=hs_cache / device.cache_bandwidth,
        t_compute=hs_flops / peak,
        contention=0.01,
        flops=hs_flops,
    )

    # --- sift: one streaming top-k over the statistic volume ------------
    sf_bytes = plane_rows * case.nbins * 2 * sb      # read stat + level
    sf_flops = 5.0 * plane_rows * case.nbins
    sift = WorkloadProfile(
        name="sift",
        t_mem=sf_bytes / device.hbm_bandwidth,
        t_issue=sf_flops / (peak * 0.4),
        t_compute=sf_flops / peak,
        flops=sf_flops,
    )
    return [dedisp, fdas, hsum, sift]


def pulsar_search_total_profile(case: PulsarCase,
                                device: DeviceSpec) -> WorkloadProfile:
    """All four stages merged into one profile (service-level sweeps)."""
    return merge_profiles(case.name, pulsar_search_workload(case, device))


def roofline_workload(
    name: str,
    device: DeviceSpec,
    *,
    hlo_flops: float,
    hbm_bytes: float,
    collective_bytes: float = 0.0,
    useful_flops: float | None = None,
    issue_efficiency: float | None = None,
) -> WorkloadProfile:
    """Profile a compiled XLA step for the DVFS planner.

    ``issue_efficiency`` defaults to the device's calibrated value; XLA
    steps dominated by large matmuls run much closer to peak than a
    butterfly kernel, so callers may pass a higher value (e.g. 0.7-0.9
    for MXU-saturating training steps).
    """
    eff = device.issue_efficiency if issue_efficiency is None else issue_efficiency
    t_coll = (
        collective_bytes / device.link_bandwidth
        if device.link_bandwidth and collective_bytes else 0.0
    )
    return WorkloadProfile(
        name=name,
        t_mem=hbm_bytes / device.hbm_bandwidth,
        t_issue=hlo_flops / (device.peak_flops * eff),
        t_cache=0.0,
        t_compute=hlo_flops / device.peak_flops,
        t_coll=t_coll,
        flops=useful_flops if useful_flops is not None else hlo_flops,
    )


# The FFT-length sweep the paper covers (powers of two 2^5..2^22 plus a few
# radix-7+/Bluestein lengths for completeness).
def paper_lengths() -> list[int]:
    pow2 = [2**k for k in range(5, 23)]
    other = [3**7, 7**4, 139**2]            # mixed radix-3, radix-7, Bluestein
    return pow2 + other


# V100 lengths the paper singles out as regime (c).
V100_REGIME_C_LENGTHS = {8192}
