"""Hardware specifications and DVFS frequency tables.

Paper reference: Table 1 (allowed core clock frequencies) and Table 2 (GPU
card specifications).  We carry the two devices the paper focuses its
discussion on (Tesla V100 and Jetson Nano) for the paper-faithful
calibration, plus the TPU v5e target used by the rest of this framework.

Frequencies are MHz, bandwidths are bytes/s, powers are watts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Static description of one device model for the DVFS model."""

    name: str
    # --- frequency tables (paper Table 1) -------------------------------
    f_max: float                  # maximal / boost core clock [MHz]
    f_base: float | None          # base core clock [MHz] (None: no base clock)
    f_min: float                  # minimal core clock [MHz]
    f_step: float                 # nominal frequency step [MHz]
    # --- compute/memory capability (paper Table 2) ----------------------
    peak_flops: float             # peak FLOP/s at f_max for the modelled dtype
    hbm_bandwidth: float          # device-memory bandwidth [bytes/s]
    cache_bandwidth: float        # shared/L1-class bandwidth at f_max [bytes/s]
    memory_bytes: float           # device memory size [bytes]
    tdp: float                    # thermal design power [W]
    idle_power: float             # static (idle/P-state floor) power [W]
    # --- DVFS voltage model ---------------------------------------------
    v_max: float = 1.0            # relative voltage at f_max
    v_floor: float = 0.60         # voltage floor (no undervolting below this)
    f_vfloor_frac: float = 0.45   # f/f_max below which voltage stays at floor
    # --- scheduler behaviour ---------------------------------------------
    # Exponent p in t_issue(f) = t_issue(f_max) * (f_max/f)^p.  p > 1 models
    # the paper's Sec. 6 observation that once instruction issue saturates,
    # latency hiding collapses and the slowdown is superlinear in 1/f.
    issue_superlinearity: float = 2.0
    # Effective fraction of peak FLOP/s the device can issue for a
    # shuffle-heavy butterfly kernel (calibrated; cuFFT is far from peak).
    issue_efficiency: float = 0.33
    # Fraction of core switching power still burned while stalled on
    # memory (datacenter parts keep warps resident and hot; mobile SoCs
    # clock-gate aggressively).
    stall_power_frac: float = 0.75
    # How well the memory system and the core pipelines overlap (1.0 =
    # perfect latency hiding, the roofline max; 0.0 = fully serialised).
    # Small devices with few SMs cannot hide HBM latency behind compute,
    # which is why the Nano pays for every clock step (paper Fig. 6).
    exec_overlap: float = 1.0
    # Fraction of the dynamic power envelope drawn by the memory system
    # when saturated (HBM2 stacks are power-hungry; LPDDR4 is not).
    mem_power_frac: float = 0.12
    # Whether the device's power sensor covers the memory rail.  nvidia-smi
    # reports whole-board power; the Nano's tegrastats POM_5V_GPU rail
    # covers the GPU core only (DRAM is on a separate rail), which the
    # paper's Sec. 4 measurement setup inherits.
    power_sensor_includes_mem: bool = True
    # --- interconnect (TPU) ----------------------------------------------
    link_bandwidth: float | None = None   # per-link ICI/NVLink [bytes/s]

    def frequencies(self) -> np.ndarray:
        """The discrete allowed core-clock grid, descending from f_max.

        The paper notes the step alternates between two close values
        (e.g. 7/8 MHz on V100); a fixed nominal step is an accurate model.
        """
        n = int(np.floor((self.f_max - self.f_min) / self.f_step)) + 1
        f = self.f_max - self.f_step * np.arange(n)
        return np.clip(f, self.f_min, None)

    def voltage(self, f: np.ndarray | float) -> np.ndarray:
        """Relative supply voltage V(f)/V(f_max), piecewise linear with floor.

        Models the paper's observation that below a certain frequency the
        P-state (and voltage) stops dropping, which is why power flattens
        at the low end of Fig. 8.
        """
        f = np.asarray(f, dtype=np.float64)
        frac = f / self.f_max
        knee = self.f_vfloor_frac
        slope = (self.v_max - self.v_floor) / (1.0 - knee)
        v = self.v_floor + slope * np.clip(frac - knee, 0.0, None)
        return np.clip(v, self.v_floor, self.v_max)


# ---------------------------------------------------------------------------
# Paper devices (Tables 1 & 2).  peak_flops is the FP32 figure.
# idle_power is estimated from the paper's Fig. 8 low-frequency plateau
# (~55 W on the V100, ~1.3 W on the Nano module rail).
# ---------------------------------------------------------------------------

TESLA_V100 = DeviceSpec(
    name="tesla-v100",
    f_max=1530.0, f_base=1200.0, f_min=135.0, f_step=7.5,
    peak_flops=15.7e12,           # FP32 TFLOP/s at boost
    hbm_bandwidth=900e9,
    cache_bandwidth=14550e9,      # shared-memory bandwidth, Table 2
    memory_bytes=16e9,
    tdp=300.0,
    idle_power=40.0,
    v_floor=0.60, f_vfloor_frac=0.45,
    issue_superlinearity=2.0, issue_efficiency=0.42,
    stall_power_frac=0.75, exec_overlap=1.0,
    mem_power_frac=0.30,                     # HBM2 stacks draw ~60-70 W
)

JETSON_NANO = DeviceSpec(
    name="jetson-nano",
    f_max=921.6, f_base=None, f_min=76.8, f_step=76.8,
    peak_flops=472e9,             # FP32 GFLOP/s
    hbm_bandwidth=25.6e9,
    cache_bandwidth=230e9,
    memory_bytes=4e9,
    tdp=10.0,
    idle_power=0.5,                # GPU rail only (tegrastats view)
    # The Nano has little compute margin over its LPDDR4 bandwidth, so the
    # issue term is near-saturated at f_max -> regime (c) dominates (Fig 6)
    # and every frequency step costs execution time.
    v_floor=0.72, f_vfloor_frac=0.50,
    issue_superlinearity=1.0, issue_efficiency=0.16,
    stall_power_frac=0.30, exec_overlap=0.5,
    mem_power_frac=0.10,                     # LPDDR4 is cheap to drive
)

TITAN_V = DeviceSpec(
    name="titan-v",
    f_max=1912.0, f_base=1220.0, f_min=135.0, f_step=7.5,
    peak_flops=14.9e12,
    hbm_bandwidth=652e9,
    cache_bandwidth=14550e9,
    memory_bytes=12e9,
    tdp=250.0,
    idle_power=36.0,
    v_floor=0.60, f_vfloor_frac=0.45,
    issue_superlinearity=2.0, issue_efficiency=0.42,
    stall_power_frac=0.75, exec_overlap=1.0,
    mem_power_frac=0.30,
)

# Driver cap observed by the paper on the Titan V during compute kernels.
TITAN_V_DRIVER_CAP_MHZ = 1335.0

# ---------------------------------------------------------------------------
# TPU v5e — the deployment target of this framework.
#
# The roofline constants are the assignment's: 197 TFLOP/s bf16 per chip,
# 819 GB/s HBM, ~50 GB/s/link ICI.  The DVFS grid mirrors the *shape* of the
# paper's Table 1 (a dense grid from f_max down to a deep floor); absolute
# MHz values follow public v5e clocks (~1.67 GHz sustained).
# ---------------------------------------------------------------------------

TPU_V5E = DeviceSpec(
    name="tpu-v5e",
    f_max=1670.0, f_base=1411.0, f_min=500.0, f_step=65.0,
    peak_flops=197e12,            # bf16
    hbm_bandwidth=819e9,
    cache_bandwidth=20000e9,      # VMEM-class bandwidth at f_max (scales with f)
    memory_bytes=16e9,
    tdp=220.0,                    # per-chip board power envelope
    idle_power=45.0,
    v_floor=0.62, f_vfloor_frac=0.48,
    issue_superlinearity=1.6, issue_efficiency=0.45,
    stall_power_frac=0.70, exec_overlap=0.92,
    mem_power_frac=0.15,
    link_bandwidth=50e9,
)

DEVICES: dict[str, DeviceSpec] = {
    d.name: d for d in (TESLA_V100, JETSON_NANO, TITAN_V, TPU_V5E)
}


def get_device(name: str) -> DeviceSpec:
    try:
        return DEVICES[name]
    except KeyError as e:
        raise KeyError(f"unknown device {name!r}; have {sorted(DEVICES)}") from e
