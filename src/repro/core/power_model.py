"""Analytic DVFS power model.

P(f) = P_static + u_core * P_dyn_max * (f/f_max) * (V(f)/V_max)^2
              + u_mem  * P_mem_max

The dynamic CMOS term ``f * V(f)^2`` is the standard DVFS scaling used by the
DVFS literature the paper builds on (Mittal & Vetter 2014; Mei et al. 2017).
``V(f)`` comes from :class:`repro.core.hardware.DeviceSpec` and carries the
P-state voltage floor that produces the low-frequency power plateau the
paper observes in Fig. 8.

``u_core``/``u_mem`` are workload utilisation factors in [0, 1]: a
memory-bandwidth-bound FFT keeps the memory system saturated (u_mem ~ 1)
while using a modest fraction of the core's switching capacity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hardware import DeviceSpec


@dataclasses.dataclass(frozen=True)
class PowerModel:
    device: DeviceSpec
    # Fraction of the (TDP - idle) dynamic envelope attributable to the
    # memory system when fully utilised.  HBM devices spend a sizeable,
    # frequency-independent share of board power on the memory stacks.
    # ``None`` defers to the device's calibrated value.
    mem_power_frac: float | None = None

    @property
    def _mem_frac(self) -> float:
        if self.mem_power_frac is not None:
            return self.mem_power_frac
        return self.device.mem_power_frac

    @property
    def p_dyn_max(self) -> float:
        return (self.device.tdp - self.device.idle_power) * (1.0 - self._mem_frac)

    @property
    def p_mem_max(self) -> float:
        return (self.device.tdp - self.device.idle_power) * self._mem_frac

    def power(
        self,
        f: np.ndarray | float,
        *,
        u_core: float = 1.0,
        u_mem: float = 1.0,
    ) -> np.ndarray:
        """Board power [W] at core clock ``f`` MHz under the given utilisation."""
        d = self.device
        f = np.asarray(f, dtype=np.float64)
        v_rel = d.voltage(f) / d.v_max
        # Static/leakage power also scales with supply voltage (~V^2), which
        # is why the paper's Fig. 8 keeps falling below the compute knee.
        p_static = d.idle_power * v_rel**2
        p_core = u_core * self.p_dyn_max * (f / d.f_max) * v_rel**2
        p_mem = u_mem * self.p_mem_max
        return p_static + p_core + p_mem
