"""Paper-faithful reproduction: the V100/Jetson DVFS study, from the model.

This module runs the exact experiment grid of the paper (FFT lengths x
precisions x allowed clock grid) through the analytic DVFS model and
summarises it with the paper's own metrics.  ``tests/test_calibration.py``
asserts the summary against the paper's published claims (Abstract, Table 3,
Figs. 9/11/13/15, Sec. 6.2) — this is the reproduction baseline that the
TPU-side application builds on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import workloads
from repro.core.dvfs import MeanOptimal, SweepResult, mean_optimal, sweep
from repro.core.hardware import DeviceSpec, JETSON_NANO, TESLA_V100
from repro.core.power_model import PowerModel
from repro.core.workloads import FFTCase, V100_REGIME_C_LENGTHS, fft_workload


@dataclasses.dataclass(frozen=True)
class CalibrationSummary:
    """The paper's headline numbers for one (device, precision)."""

    device: str
    precision: str
    sweeps: list[SweepResult]
    mean_opt: MeanOptimal

    # Fig. 9 / Table 3
    @property
    def mean_opt_frac(self) -> float:
        return self.mean_opt.f_mean / self.sweeps[0].boost.f

    # Fig. 11 (median over lengths; paper: "below 5-10% with few exceptions")
    @property
    def median_slowdown(self) -> float:
        return float(np.median([s.slowdown for s in self.sweeps]))

    @property
    def max_power_reduction(self) -> float:
        return float(np.max([s.power_reduction for s in self.sweeps]))

    @property
    def mean_power_reduction(self) -> float:
        return float(np.mean([s.power_reduction for s in self.sweeps]))

    # Fig. 13 (mean over lengths)
    @property
    def mean_i_ef_boost(self) -> float:
        return float(np.mean([s.i_ef_boost for s in self.sweeps]))

    # Fig. 14
    @property
    def mean_i_ef_base(self) -> float | None:
        vals = [s.i_ef_base for s in self.sweeps if s.i_ef_base is not None]
        return float(np.mean(vals)) if vals else None

    def row(self) -> dict:
        return {
            "device": self.device,
            "precision": self.precision,
            "mean_opt_mhz": self.mean_opt.f_mean,
            "mean_opt_frac_boost": round(self.mean_opt_frac, 3),
            "median_slowdown_pct": round(100 * self.median_slowdown, 2),
            "max_power_cut_pct": round(100 * self.max_power_reduction, 1),
            "mean_power_cut_pct": round(100 * self.mean_power_reduction, 1),
            "mean_I_ef_boost": round(self.mean_i_ef_boost, 3),
            "mean_I_ef_base": (round(v, 3)
                               if (v := self.mean_i_ef_base) is not None else None),
            "mean_opt_loss_pp": round(self.mean_opt.loss_pp, 2),
        }


def supported_precisions(device: DeviceSpec) -> list[str]:
    # Paper Sec. 5: P4/Titan XP lack FP16; Nano and consumer cards have
    # crippled FP64 (modelled via PRECISION_PEAK anyway); V100 has all.
    if device.name == "jetson-nano":
        return ["fp32", "fp16"]
    return ["fp32", "fp64", "fp16"]


def calibrate(
    device: DeviceSpec,
    precision: str = "fp32",
    lengths: list[int] | None = None,
) -> CalibrationSummary:
    lengths = lengths or workloads.paper_lengths()
    if precision == "fp16":
        # cuFFT restricts FP16 to power-of-two lengths (Sec. 5).
        lengths = [n for n in lengths if workloads.is_pow2(n)]
    pm = PowerModel(device)
    sweeps = []
    batch = 2e9 if device.name != "jetson-nano" else 0.5e9   # Nano: 1/4 data
    for n in lengths:
        case = FFTCase(n=n, precision=precision, batch_bytes=batch)
        profile = fft_workload(
            case, device,
            regime_c=(device.name == "tesla-v100" and n in V100_REGIME_C_LENGTHS),
        )
        sweeps.append(sweep(profile, device, pm))
    # Paper: Bluestein lengths excluded from the Nano's mean (Sec. 4).
    exclude = set()
    if device.name == "jetson-nano":
        exclude = {s.profile.name for s in sweeps
                   if workloads.uses_bluestein(int(s.profile.name.split("-")[1][1:]))}
    mo = mean_optimal(sweeps, device, exclude=exclude)
    return CalibrationSummary(
        device=device.name, precision=precision, sweeps=sweeps, mean_opt=mo
    )


def full_report() -> list[dict]:
    rows = []
    for device in (TESLA_V100, JETSON_NANO):
        for prec in supported_precisions(device):
            rows.append(calibrate(device, prec).row())
    return rows


if __name__ == "__main__":
    from repro.obs.log import get_logger
    _log = get_logger("calibration")
    for r in full_report():
        _log.info("calibration-row", **{str(k): v for k, v in r.items()})
