"""Deterministic tracing: nested spans, flight recorder, exporters.

The :class:`Tracer` mirrors the repository's injectable-clock idiom
(``tune.timing.time_fn``'s ``timer=`` / the tests' FakeTimer): span
timestamps come from whatever monotonic callable the caller provides, so
a trace driven by a fake timer is bit-reproducible — the property the
``obs`` benchmark gates with a blake2b digest over two fresh runs.

Spans nest via a context-manager stack and *inherit* their parent's
attributes (``kind``/``shape``/``rung``/``clock_mhz`` set on a batch
span flow down to its children unless overridden).  Completed spans also
feed a bounded per-device :class:`FlightRecorder` ring; when any
``repro.runtime.faults`` error is raised, every live tracer snapshots
its rings (plus the spans still open at the moment of failure) for
postmortems — the crash-dump analogue of an aircraft flight recorder.

Exporters: :func:`to_chrome_trace` (load the JSON in ``about:tracing``
/ Perfetto), :func:`to_jsonl` (one span per line, canonical key order)
and :func:`digest` (blake2b of the JSONL).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import json
import time
import weakref
from typing import Any

__all__ = ["Span", "FlightSnapshot", "FlightRecorder", "Tracer",
           "notify_fault", "to_chrome_trace", "to_jsonl", "digest"]


@dataclasses.dataclass
class Span:
    """One timed region on the tracer's clock."""

    name: str
    t_start: float
    duration: float = 0.0
    depth: int = 0                      # nesting depth at open time
    parent: str | None = None           # enclosing span's name
    attrs: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "t_start": self.t_start,
                "duration": self.duration, "depth": self.depth,
                "parent": self.parent,
                "attrs": {k: (list(v) if isinstance(v, tuple) else v)
                          for k, v in sorted(self.attrs.items())}}


@dataclasses.dataclass(frozen=True)
class FlightSnapshot:
    """The flight-recorder state frozen at the moment of one fault."""

    error_type: str                     # e.g. "DeviceLostError"
    message: str
    spans: dict                         # device -> last-N completed spans
    open_spans: tuple                   # spans still open when it fired


class FlightRecorder:
    """Bounded per-device ring of the most recent completed spans."""

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._rings: dict[Any, collections.deque] = {}
        self.snapshots: list[FlightSnapshot] = []

    def push(self, span: Span) -> None:
        dev = span.attrs.get("worker", -1)
        ring = self._rings.get(dev)
        if ring is None:
            ring = self._rings[dev] = collections.deque(
                maxlen=self.capacity)
        ring.append(span)

    def ring(self, device: Any = -1) -> list[Span]:
        return list(self._rings.get(device, ()))

    def snapshot(self, error: BaseException,
                 open_spans: tuple = ()) -> FlightSnapshot:
        snap = FlightSnapshot(
            error_type=type(error).__name__, message=str(error),
            spans={dev: list(ring)
                   for dev, ring in sorted(self._rings.items(),
                                           key=lambda kv: str(kv[0]))},
            open_spans=tuple(open_spans))
        self.snapshots.append(snap)
        return snap


#: Live tracers, notified on every runtime.faults error.  A WeakSet so
#: abandoned tracers (and their retained spans) are collectable.
_TRACERS: "weakref.WeakSet[Tracer]" = weakref.WeakSet()


def notify_fault(error: BaseException) -> None:
    """Snapshot every live tracer's flight recorder for ``error``.

    Called (via a lazy import) from ``repro.runtime.faults`` when a fault
    error is constructed; a no-op with no tracers alive.
    """
    for tracer in list(_TRACERS):
        tracer.flight.snapshot(error, open_spans=tuple(tracer._stack))


class Tracer:
    """Nested-span tracer on an injectable monotonic clock."""

    def __init__(self, timer=time.monotonic, *,
                 recorder_capacity: int = 64):
        self.timer = timer
        self.spans: list[Span] = []         # completed, in completion order
        self._stack: list[Span] = []
        self.flight = FlightRecorder(capacity=recorder_capacity)
        _TRACERS.add(self)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Open a span; children inherit attrs (own keys win)."""
        parent = self._stack[-1] if self._stack else None
        merged = dict(parent.attrs) if parent is not None else {}
        merged.update(attrs)
        s = Span(name=name, t_start=self.timer(), depth=len(self._stack),
                 parent=parent.name if parent is not None else None,
                 attrs=merged)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            s.duration = self.timer() - s.t_start
            self.spans.append(s)
            self.flight.push(s)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def to_jsonl(spans: list[Span]) -> str:
    """One canonical JSON object per line (sorted keys, no whitespace)."""
    return "\n".join(json.dumps(s.to_dict(), sort_keys=True,
                                separators=(",", ":")) for s in spans)


def digest(spans: list[Span]) -> str:
    """blake2b over the canonical JSONL — identical spans, identical hex."""
    return hashlib.blake2b(to_jsonl(spans).encode(),
                           digest_size=16).hexdigest()


def to_chrome_trace(spans: list[Span]) -> dict:
    """Chrome trace-event JSON (complete "X" events, microsecond times).

    ``tid`` is the span's worker attribute so each device renders as its
    own track in about:tracing / Perfetto.
    """
    events = []
    for s in spans:
        attrs = s.to_dict()["attrs"]
        events.append({
            "name": s.name, "ph": "X", "pid": 0,
            "tid": int(attrs.get("worker", 0) or 0),
            "ts": s.t_start * 1e6, "dur": s.duration * 1e6,
            "args": attrs,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
