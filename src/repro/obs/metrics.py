"""A deterministic metrics registry: counters, gauges, fixed histograms.

One registry absorbs the ad-hoc statistics previously scattered across
``ServiceReport``, ``PlanSweepCache.stats``, breaker/watchdog counters
and the dispatcher, and renders them once in the Prometheus text format.

Determinism rules (the registry is asserted on in CI benchmarks):

* counters are integers and only ever increment;
* histograms have *fixed* bucket bounds chosen at creation and count
  integer observations per bucket — no wall-clock reads, no float
  accumulation (there is deliberately no ``_sum`` series: summing
  measured floats is the one place Prometheus conventions and
  bit-reproducibility disagree);
* gauges hold the single float they were last set to.

:func:`latency_summary` is the shared guarded-percentile helper the
serving layer and SLO scorer both use (previously two hand-rolled
``np.percentile`` sites).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LatencySummary", "latency_summary",
           "DEFAULT_LATENCY_BUCKETS"]

#: Default latency histogram bounds [s]: sub-ms interpret-mode batches up
#: to multi-second chaos drains.
DEFAULT_LATENCY_BUCKETS = (1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0,
                           5.0, 30.0)


def _fmt(v) -> str:
    """Prometheus sample value: integral floats render as integers."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class Counter:
    """Monotonic integer counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        n = int(n)
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        self.value += n

    def render(self) -> list[str]:
        return [f"{self.name} {self.value}"]


class Gauge:
    """Last-write-wins scalar."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def render(self) -> list[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Histogram:
    """Fixed-bound bucket histogram (cumulative render, no float sum)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        if not buckets or list(buckets) != sorted(set(float(b)
                                                      for b in buckets)):
            raise ValueError(
                f"histogram {name} needs strictly increasing bounds, "
                f"got {buckets!r}")
        self.name, self.help = name, help
        self.bounds = tuple(float(b) for b in buckets)
        # counts[i]: observations in (bounds[i-1], bounds[i]];
        # counts[-1] is the +Inf overflow bucket.
        self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def n(self) -> int:
        return sum(self.counts)

    def quantile(self, q: float) -> float:
        """Histogram-derived quantile: the upper bound of the bucket the
        q-th observation falls in (conservative — never understates).
        Empty histograms and overflow-bucket hits return the top bound.
        """
        total = self.n
        if total == 0:
            return 0.0
        target = max(1, int(np.ceil(q * total)))
        cum = 0
        for i, b in enumerate(self.bounds):
            cum += self.counts[i]
            if cum >= target:
                return b
        return self.bounds[-1]

    def render(self) -> list[str]:
        lines, cum = [], 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(b)}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self.n}')
        lines.append(f"{self.name}_count {self.n}")
        return lines


class MetricsRegistry:
    """Named metrics with get-or-create accessors and one text render."""

    def __init__(self) -> None:
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, "
                f"not a {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def render(self) -> str:
        """The Prometheus text exposition of every registered metric."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# shared guarded percentile summary
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LatencySummary:
    """Percentile summary of a latency sample (seconds)."""

    n: int
    mean: float
    p50: float
    p99: float


def latency_summary(values: Iterable[float], *,
                    on_empty: float = 0.0) -> LatencySummary:
    """Guarded p50/p99/mean over ``values``.

    Empty-input convention (the percentile analogue of
    ``repro.core.energy.guarded_ratio``): with no observations there is
    no latency evidence, so every field is ``on_empty`` (default 0.0 —
    "no latency was incurred") rather than NaN, keeping report
    arithmetic and JSON serialisation safe.
    """
    arr = np.asarray([float(v) for v in values], dtype=float)
    if arr.size == 0:
        return LatencySummary(n=0, mean=on_empty, p50=on_empty,
                              p99=on_empty)
    return LatencySummary(n=int(arr.size), mean=float(arr.mean()),
                          p50=float(np.percentile(arr, 50)),
                          p99=float(np.percentile(arr, 99)))
