"""The kernel launch ledger: first-class accounting of Pallas launches.

Every public kernel wrapper in ``repro.kernels.*.ops`` calls
:func:`record_launch` once per successful ``pallas_call`` — with the
kernel's name, grid, tile and an HBM bytes-moved estimate — replacing
the test-only monkeypatch counters of earlier PRs with accounting the
serving layer and benchmarks can read.

Trace-time semantics: under ``jax.jit`` the wrapper bodies run while the
function is *traced*, not on every execution, so a captured record means
"this compiled executable launches this kernel (once per grid step) each
time it runs".  The records captured while an executable first traces
are therefore its launch **signature**; :meth:`LaunchLedger.capture`
stores the first non-empty capture per key and
:meth:`LaunchLedger.signature` replays it for every later request served
by the same compiled artifact.  Benchmarks that want one record per
*call* simply run the un-jitted function inside a capture.

Recording is a no-op (one truthiness check) when no ledger is actively
capturing, so instrumented kernels cost nothing on the hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
from typing import Any, Iterable

__all__ = ["LaunchRecord", "LaunchLedger", "record_launch",
           "launches_digest"]


def _ints(t) -> tuple[int, ...]:
    if isinstance(t, int):
        return (int(t),)
    return tuple(int(v) for v in t)


@dataclasses.dataclass(frozen=True)
class LaunchRecord:
    """One Pallas kernel launch (as recorded at trace time).

    ``bytes_moved`` is the wrapper's HBM traffic estimate for the launch
    (inputs read + outputs written, padded shapes) — the quantity the
    paper's pass accounting is denominated in.
    """

    kernel: str                     # e.g. "fft-c2c-t"
    grid: tuple[int, ...] = ()      # pallas grid (tiles launched)
    tile: tuple[int, ...] = ()      # block shape per grid step
    bytes_moved: int = 0            # HBM read+write estimate [bytes]
    shape: tuple[int, ...] = ()     # logical (batch, ...) problem shape

    def to_dict(self) -> dict:
        return {"kernel": self.kernel, "grid": list(self.grid),
                "tile": list(self.tile), "bytes_moved": self.bytes_moved,
                "shape": list(self.shape)}


#: Ledgers currently capturing (a stack; normally depth 0 or 1).
_ACTIVE: list["LaunchLedger"] = []

#: Process-wide launch signatures, keyed on capture key.  ``jax.jit``
#: caches compiled executables globally, so a warm executable re-served
#: through a *fresh* ledger records nothing at trace time; its signature
#: is a property of the executable, not of any one ledger, and lives
#: here so :meth:`LaunchLedger.signature` can replay it for every later
#: consumer (first trace in the process wins).
_SIGNATURES: dict[Any, tuple[LaunchRecord, ...]] = {}


def record_launch(kernel: str, *, grid=(), tile=(), bytes_moved: int = 0,
                  shape=()) -> None:
    """Record one kernel launch into every actively-capturing ledger.

    Called by the kernel wrappers after a successful pallas call (so
    exception-driven fallback paths never record phantom launches).
    A no-op when nothing is capturing.
    """
    if not _ACTIVE:
        return
    rec = LaunchRecord(kernel=kernel, grid=_ints(grid), tile=_ints(tile),
                       bytes_moved=int(bytes_moved), shape=_ints(shape))
    # dict.fromkeys: a ledger nested inside its own capture records once.
    for ledger in dict.fromkeys(_ACTIVE):
        ledger._record(rec)


class LaunchLedger:
    """An append-only launch log plus per-key launch signatures."""

    def __init__(self) -> None:
        self.records: list[LaunchRecord] = []

    @contextlib.contextmanager
    def capture(self, key: Any = None):
        """Capture launches recorded in the body; yields this ledger.

        With ``key`` set, the first capture *in the process* that records
        anything becomes the key's launch signature (first-capture-wins:
        under jit only the tracing call records, re-captures of the warm
        executable see nothing, and the jit cache the signature describes
        is itself process-wide).
        """
        mark = len(self.records)
        _ACTIVE.append(self)
        try:
            yield self
        finally:
            _ACTIVE.remove(self)
            if key is not None and len(self.records) > mark:
                _SIGNATURES.setdefault(key, tuple(self.records[mark:]))

    def _record(self, rec: LaunchRecord) -> None:
        self.records.append(rec)

    def signature(self, key: Any) -> list[LaunchRecord]:
        """The launch signature captured for ``key`` ([] if never seen).

        Reads the process-wide store, so an executable traced (and
        recorded) under any earlier ledger keeps its signature when a
        fresh service re-serves it from the warm jit cache.
        """
        return list(_SIGNATURES.get(key, ()))

    def counts(self, records: Iterable[LaunchRecord] | None = None
               ) -> dict[str, int]:
        """Launches per kernel name over ``records`` (default: all)."""
        out: dict[str, int] = {}
        for r in (self.records if records is None else records):
            out[r.kernel] = out.get(r.kernel, 0) + 1
        return dict(sorted(out.items()))

    def total_bytes(self) -> int:
        return sum(r.bytes_moved for r in self.records)

    def to_dicts(self) -> list[dict]:
        return [r.to_dict() for r in self.records]

    def digest(self) -> str:
        """blake2b over the canonical JSON of every record (reproducible
        across runs that record the same launches in the same order)."""
        payload = json.dumps(self.to_dicts(), sort_keys=True,
                             separators=(",", ":")).encode()
        return hashlib.blake2b(payload, digest_size=16).hexdigest()


def launches_digest(launch_lists: Iterable[Iterable[LaunchRecord]]) -> str:
    """blake2b over per-receipt launch signatures, in receipt order.

    The reproducibility gate for *served* launches: two runs whose
    receipts carry the same launch signatures in the same order hash
    identically, whether the records were captured live or replayed from
    the process-wide signature store (a warm jit cache records nothing,
    so :meth:`LaunchLedger.digest` alone cannot compare a cold run to a
    warm one).
    """
    payload = json.dumps(
        [[rec.to_dict() for rec in launches] for launches in launch_lists],
        sort_keys=True, separators=(",", ":")).encode()
    return hashlib.blake2b(payload, digest_size=16).hexdigest()
