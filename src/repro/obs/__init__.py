"""repro.obs — the unified observability plane.

Four cooperating pieces (docs/observability.md walks through the loop):

* :mod:`repro.obs.trace` — deterministic nested-span tracing on an
  injectable clock, with a per-device flight recorder snapshotted on
  every ``runtime.faults`` error, and Chrome-trace / JSONL / blake2b
  exporters.
* :mod:`repro.obs.metrics` — a counters/gauges/fixed-bucket-histogram
  registry with one Prometheus-style text rendering, plus the shared
  guarded percentile helper.
* :mod:`repro.obs.ledger` — the kernel launch ledger: every Pallas
  kernel wrapper records its launches (name, grid, tile, bytes moved);
  serving receipts carry per-shape launch signatures and benchmarks
  audit pass counts from it.
* :mod:`repro.obs.drift` — EWMA model-vs-measured drift detection per
  (kind, shape, clock), fed from watchdog-fresh telemetry.
"""
from repro.obs.drift import DriftDetector, DriftState
from repro.obs.ledger import (LaunchLedger, LaunchRecord, launches_digest,
                              record_launch)
from repro.obs.log import StructuredLogger, get_logger
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, LatencySummary, MetricsRegistry,
                               latency_summary)
from repro.obs.trace import (FlightRecorder, FlightSnapshot, Span, Tracer,
                             digest, notify_fault, to_chrome_trace,
                             to_jsonl)

__all__ = [
    "DriftDetector", "DriftState",
    "LaunchLedger", "LaunchRecord", "launches_digest", "record_launch",
    "StructuredLogger", "get_logger",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LatencySummary", "latency_summary", "DEFAULT_LATENCY_BUCKETS",
    "FlightRecorder", "FlightSnapshot", "Span", "Tracer",
    "digest", "notify_fault", "to_chrome_trace", "to_jsonl",
]
