"""Structured key=value logging for the repository's CLI tools.

A deliberately small logger (no stdlib ``logging`` config surface): one
line per event, ``LEVEL component: event key=value ...``, written to
stderr.  The level threshold is resolved *per call* from the
environment:

* ``REPRO_LOG_LEVEL`` (debug/info/warning/error, or ``off``) wins;
* otherwise, under pytest (``PYTEST_CURRENT_TEST`` set) everything is
  silenced — test output stays clean unless a test opts in;
* otherwise the default is ``info``.

Replaces the bare ``print()`` calls in ``launch.dryrun`` and
``core.calibration`` so their progress chatter is structured, routed to
stderr, and silent inside the test suite.
"""
from __future__ import annotations

import os
import sys
from typing import Any, TextIO

__all__ = ["StructuredLogger", "get_logger", "LEVELS"]

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


def _threshold() -> int | None:
    """The active minimum level, or None when fully silenced."""
    env = os.environ.get("REPRO_LOG_LEVEL", "").strip().lower()
    if env:
        if env in ("off", "none", "silent"):
            return None
        return LEVELS.get(env, LEVELS["info"])
    if "PYTEST_CURRENT_TEST" in os.environ:
        return None
    return LEVELS["info"]


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:g}"
    s = str(v)
    return repr(s) if (" " in s or s == "") else s


class StructuredLogger:
    """level + event + key=value pairs on one stderr line."""

    def __init__(self, component: str, *, stream: TextIO | None = None):
        self.component = component
        self._stream = stream          # None: resolve sys.stderr per call

    def log(self, level: str, event: str, **fields: Any) -> None:
        if level not in LEVELS:
            raise ValueError(f"unknown log level {level!r}; have "
                             f"{sorted(LEVELS)}")
        thr = _threshold()
        if thr is None or LEVELS[level] < thr:
            return
        parts = [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        line = f"{level.upper():<7} {self.component}: {event}"
        if parts:
            line += " " + " ".join(parts)
        print(line, file=self._stream or sys.stderr)

    def debug(self, event: str, **fields: Any) -> None:
        self.log("debug", event, **fields)

    def info(self, event: str, **fields: Any) -> None:
        self.log("info", event, **fields)

    def warning(self, event: str, **fields: Any) -> None:
        self.log("warning", event, **fields)

    def error(self, event: str, **fields: Any) -> None:
        self.log("error", event, **fields)


_LOGGERS: dict[str, StructuredLogger] = {}


def get_logger(component: str) -> StructuredLogger:
    """One cached logger per component name."""
    lg = _LOGGERS.get(component)
    if lg is None:
        lg = _LOGGERS[component] = StructuredLogger(component)
    return lg
