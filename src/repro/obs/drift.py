"""Model-drift detection: modelled vs measured cost, EWMA-tracked.

The analytic cost model (``core.workloads`` + ``core.power_model``)
drives admission control, DVFS sweeps and the power governor; its
numbers are only trustworthy while they track measured reality.  The
:class:`DriftDetector` closes that loop: the serving layer feeds it one
observation per executed batch — the modelled per-transform energy next
to the telemetry-priced one (watchdog-fresh samples only, so suspect
sensors can never *cause* a drift alert) — keyed by
``(kind, shape, clock_mhz)``, and the detector tracks the EWMA of the
relative error per key.  A key alerts when its smoothed error magnitude
exceeds ``threshold`` after at least ``min_samples`` observations: a
persistently miscalibrated model trips it, sensor noise (zero-mean by
construction of the simulated backend) does not.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable

from repro.core.energy import guarded_ratio

__all__ = ["DriftState", "DriftDetector"]


@dataclasses.dataclass
class DriftState:
    """EWMA error state for one (kind, shape, clock) key."""

    ewma: float = 0.0           # smoothed relative error
    n: int = 0                  # observations
    last_error: float = 0.0     # most recent raw relative error


class DriftDetector:
    """Per-key EWMA tracking of (measured - modelled) / modelled."""

    def __init__(self, *, alpha: float = 0.25, threshold: float = 0.2,
                 min_samples: int = 4):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self.states: dict[Hashable, DriftState] = {}
        self.observations = 0

    def observe(self, key: Hashable, *, modelled: float,
                measured: float) -> float:
        """Fold one modelled/measured pair in; returns the key's EWMA.

        The relative error follows the ``guarded_ratio`` convention:
        0/0 -> 0 (nothing modelled, nothing measured: no drift).
        """
        err = guarded_ratio(measured - modelled, modelled, on_zero=0.0)
        st = self.states.get(key)
        if st is None:
            st = self.states[key] = DriftState()
        st.ewma = err if st.n == 0 else (
            (1.0 - self.alpha) * st.ewma + self.alpha * err)
        st.n += 1
        st.last_error = err
        self.observations += 1
        return st.ewma

    def alerting(self, key: Hashable) -> bool:
        st = self.states.get(key)
        return (st is not None and st.n >= self.min_samples
                and abs(st.ewma) > self.threshold)

    @property
    def alerts(self) -> list[Hashable]:
        """Keys currently in alert, in deterministic order."""
        return sorted((k for k in self.states if self.alerting(k)),
                      key=str)

    @property
    def drift_alerts(self) -> int:
        return len(self.alerts)

    def summary(self) -> dict:
        """JSON-safe rollup for ``ServiceReport`` / benchmark artifacts."""
        worst = 0.0
        for st in self.states.values():
            if abs(st.ewma) > abs(worst):
                worst = st.ewma
        return {
            "tracked_keys": len(self.states),
            "observations": self.observations,
            "drift_alerts": self.drift_alerts,
            "alerting": [str(k) for k in self.alerts],
            "worst_ewma_error": worst,
            "threshold": self.threshold,
        }

    def fill_metrics(self, registry: Any) -> None:
        """Publish the rollup into a ``MetricsRegistry``."""
        s = self.summary()
        registry.gauge(
            "repro_drift_alerts",
            "model-vs-measured keys past the EWMA error threshold",
        ).set(s["drift_alerts"])
        registry.gauge(
            "repro_drift_tracked_keys",
            "(kind, shape, clock) keys with drift observations",
        ).set(s["tracked_keys"])
        registry.gauge(
            "repro_drift_worst_ewma_error",
            "largest-magnitude smoothed relative error across keys",
        ).set(s["worst_ewma_error"])
