"""Fourier-Domain Acceleration Search (FDAS) on the FFT substrate.

  templates  acceleration responses + TemplateBank (host-side, cached)
  fdas       matched-filter plane, power, candidate extraction, and the
             end-to-end fdas_search() pipeline

The search workload of White, Adámek & Armour (2022): the FFT-heavy,
DVFS-schedulable stage downstream of the paper's Sec. 5.3 pipeline.
"""
from repro.search.fdas import (Candidates, FDASResult, extract_candidates,
                               fdas_conv_plan, fdas_search,
                               matched_filter_plane, power_plane,
                               serving_candidates)
from repro.search.templates import (TemplateBank, acceleration_response,
                                    matched_filter_taps)

__all__ = [
    "Candidates", "FDASResult", "TemplateBank", "acceleration_response",
    "extract_candidates", "fdas_conv_plan", "fdas_search",
    "matched_filter_plane", "matched_filter_taps", "power_plane",
    "serving_candidates",
]
