"""Pulsar searching on the FFT substrate.

  templates  acceleration responses + TemplateBank (host-side, cached)
  fdas       matched-filter plane, power, candidate extraction, and the
             end-to-end fdas_search() acceleration search
  sift       candidate sifting/clustering (threshold, DM/harmonic
             dedupe, top-k) — the pipeline's last stage
  pipeline   the full real-time search graph: dedispersion -> fdas ->
             harmonic sum -> sift, with per-stage DVFS planning

The search workload of White, Adámek & Armour (2022): the FFT-heavy,
DVFS-schedulable pipeline downstream of the paper's Sec. 5.3 discussion.
"""
from repro.search.fdas import (Candidates, FDASResult, extract_candidates,
                               fdas_conv_plan, fdas_search,
                               matched_filter_plane, power_plane,
                               serving_candidates)
from repro.search.pipeline import (DispersionPlan, PulsarSearchResult,
                                   PulsarStagePlan, plan_pulsar_stages,
                                   pulsar_search, serving_sifted)
from repro.search.sift import SiftedCandidates, sift_candidates
from repro.search.templates import (TemplateBank, acceleration_response,
                                    matched_filter_taps)

__all__ = [
    "Candidates", "DispersionPlan", "FDASResult", "PulsarSearchResult",
    "PulsarStagePlan", "SiftedCandidates", "TemplateBank",
    "acceleration_response", "extract_candidates", "fdas_conv_plan",
    "fdas_search", "matched_filter_plane", "matched_filter_taps",
    "plan_pulsar_stages", "power_plane", "pulsar_search",
    "serving_candidates", "serving_sifted", "sift_candidates",
]
