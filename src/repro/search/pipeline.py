"""End-to-end real-time pulsar-search pipeline with per-stage DVFS.

The full binary-pulsar search of White, Adámek & Armour (2022) — the
workload the paper's Sec. 5 "existing pipelines" discussion targets —
wired as ONE jittable streaming graph over the repository's substrate:

  filterbank (batch, C, N) real
    │  brute-force dedispersion (repro.kernels.dedisp: static
    │  shift-and-sum over the DispersionPlan's integer delay table)
  series (batch, D, N)
    │  mean-subtract -> R2C plan -> acceleration matched filter
    │  (repro.search.fdas: fused forward pass + T inverse passes)
  power plane (batch, D, T, nbins)
    │  fused harmonic sum (repro.kernels.harmonic_sum plane kernel:
    │  ladder + normalise + best-level reduce inside VMEM — the full
    │  ladder never round-trips through HBM)
  statistic volume (batch, D, T, nbins)
    │  sifting (repro.search.sift: threshold, DM-adjacency/harmonic
    │  dedupe, top-k)
  candidates (batch, k)

Every stage registers a ``core.workloads`` model
(:func:`repro.core.workloads.pulsar_search_workload`), so
``dvfs.sweep`` + ``core.scheduler.DVFSScheduler`` pick a clock per
stage (:func:`plan_pulsar_stages`); receipts report modelled J/stage
and the end-to-end real-time margin S = t_acquire / t_process
(Sec. 2.3/6.1).  The serving layer routes ``KIND_PULSAR`` requests
through one :class:`~repro.serving.cache.PlanSweepCache` entry per
(filterbank shape, DM count, bank, harmonics) key.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dvfs
from repro.core.hardware import DeviceSpec
from repro.core.perf_model import WorkloadProfile
from repro.core.power_model import PowerModel
from repro.core.realtime import RealTimeBudget
from repro.core.scheduler import DVFSScheduler, PipelineReport
from repro.core.workloads import (PulsarCase, pulsar_search_total_profile,
                                  pulsar_search_workload)
from repro.data.synthetic import FilterbankSpec
from repro.fft.plan import plan_for_length
from repro.kernels.dedisp.ops import dedisperse_kernel
from repro.kernels.harmonic_sum.ops import harmonic_sum_plane
from repro.search.fdas import matched_filter_plane, power_plane
from repro.search.sift import SiftedCandidates, sift_candidates
from repro.search.templates import TemplateBank

# Module-level kernel hooks, resolved at trace time — tests monkeypatch
# these with counters to prove the jitted graph launches each fused
# kernel exactly once (the test_plan_nd.py routing-counter pattern).
_kernel_dedisp = dedisperse_kernel
_kernel_hsum = harmonic_sum_plane


@dataclasses.dataclass(frozen=True)
class DispersionPlan:
    """A DM trial grid with its static integer-sample delay table.

    Hashable (tuples only), so it is a static jit argument exactly like
    :class:`~repro.search.templates.TemplateBank` — the kernel unrolls
    the table at trace time.  Build with :meth:`from_spec` so injection
    (``data.synthetic``) and dedispersion round the SAME delays.
    """

    dms: tuple[float, ...]                    # trial DMs, pc cm^-3
    delays: tuple[tuple[int, ...], ...]       # (D, C) integer samples
    tsamp: float                              # s (for real-time maths)

    def __post_init__(self):
        if not self.dms or not self.delays:
            raise ValueError("DispersionPlan needs >= 1 DM trial")
        if len(self.dms) != len(self.delays):
            raise ValueError(
                f"{len(self.dms)} DMs vs {len(self.delays)} delay rows")

    @classmethod
    def from_spec(cls, spec: FilterbankSpec, *, n_trials: int = 16,
                  dm_step_factor: float = 4.0,
                  dms: tuple[float, ...] | None = None) -> "DispersionPlan":
        """Trial grid ``i * dm_step_factor * spec.dm_step``.

        The default factor of 4 spaces adjacent trials ~4 samples of
        differential delay apart, so a pulsar injected at one trial
        decoheres visibly at its neighbours (clean argmax) while the
        sift stage absorbs whatever leaks into them.
        """
        if dms is None:
            if n_trials < 1:
                raise ValueError(f"need n_trials >= 1, got {n_trials}")
            step = dm_step_factor * spec.dm_step
            dms = tuple(i * step for i in range(n_trials))
        table = []
        for dm in dms:
            row = spec.delay_samples(dm)
            if row.max(initial=0) >= spec.ntime:
                raise ValueError(
                    f"DM {dm} delays up to {int(row.max())} samples exceed "
                    f"the block length ({spec.ntime}); shrink the grid or "
                    f"lengthen the block")
            table.append(tuple(int(d) for d in row))
        return cls(dms=tuple(float(d) for d in dms),
                   delays=tuple(table), tsamp=spec.tsamp)

    @property
    def n_trials(self) -> int:
        return len(self.dms)

    @property
    def nchan(self) -> int:
        return len(self.delays[0])

    @property
    def max_delay(self) -> int:
        return max(max(row) for row in self.delays)

    def delay_array(self) -> np.ndarray:
        return np.asarray(self.delays, dtype=np.int64)


class PulsarSearchResult(NamedTuple):
    """Everything one search produced (a pytree; safe through jit)."""

    power: jax.Array           # (batch, D, T, nbins) normalised power
    stat: jax.Array            # (batch, D, T, nbins) detection statistic
    level: jax.Array           # (batch, D, T, nbins) int32 harmonic level
    candidates: SiftedCandidates
    sigma2: jax.Array          # (batch, D, 1, 1) per-series noise power


@functools.partial(jax.jit, static_argnames=(
    "plan", "bank", "n_harmonics", "max_candidates", "nfft", "pool"))
def pulsar_search(
    fb: jax.Array,
    plan: DispersionPlan,
    bank: TemplateBank,
    *,
    n_harmonics: int = 8,
    threshold: float = 25.0,
    max_candidates: int = 16,
    nfft: int | None = None,
    pool: int = 64,
) -> PulsarSearchResult:
    """Search filterbanks (batch, C, N) or (C, N) end to end.

    ``plan`` and ``bank`` are static (hashable) so the dedispersion
    delay table and the template bank unroll at trace time; the whole
    graph — dedispersion, R2C, matched filtering, harmonic summing,
    sifting — is one XLA computation.
    """
    fb = jnp.asarray(fb)
    if fb.ndim == 2:
        fb = fb[None]
    if fb.ndim != 3:
        raise ValueError(
            f"pulsar_search needs (batch, nchan, ntime) or (nchan, ntime) "
            f"filterbanks, got shape {fb.shape}")
    if jnp.issubdtype(fb.dtype, jnp.complexfloating):
        fb = fb.real
    fb = fb.astype(jnp.float32)

    series = _kernel_dedisp(fb, plan.delays)             # (b, D, N)
    n = series.shape[-1]
    x = series - jnp.mean(series, axis=-1, keepdims=True)
    spectrum = plan_for_length(n, "r2c")(x)              # (b, D, nbins)
    sigma2 = jnp.mean(spectrum.real ** 2 + spectrum.imag ** 2,
                      axis=-1, keepdims=True)[..., None]
    mf = matched_filter_plane(spectrum, bank, nfft=nfft)  # (b, D, T, nbins)
    power = power_plane(mf, sigma2)
    stat, level = _kernel_hsum(power, n_harmonics)
    cands = sift_candidates(stat, level, threshold=threshold,
                            max_candidates=max_candidates, pool=pool,
                            max_harmonic=n_harmonics)
    return PulsarSearchResult(power=power, stat=stat, level=level,
                              candidates=cands, sigma2=sigma2)


def serving_sifted(result: PulsarSearchResult) -> jax.Array:
    """Candidates packed as one (batch, k, 5) f32 array for receipts.

    Columns: DM trial, template, bin, harmonic level, statistic
    (-1/-1/-1/-1/0 padding) — a plain array so the serving layer's
    per-request result slicing works unchanged.
    """
    c = result.candidates
    return jnp.stack([c.dm.astype(jnp.float32),
                      c.template.astype(jnp.float32),
                      c.bin.astype(jnp.float32),
                      c.level.astype(jnp.float32), c.snr], axis=-1)


@dataclasses.dataclass(frozen=True)
class PulsarStagePlan:
    """The DVFS story of one pipeline configuration.

    ``report`` prices one memory-budgeted batch (``case.n_rows``
    filterbanks) with every stage locked to its own sweep-optimal
    clock; ``realtime_margin`` is S = t_acquire / t_process per
    filterbank at those clocks (>= 1 keeps the pipeline real time,
    Sec. 2.3/6.1).
    """

    case: PulsarCase
    profiles: tuple[WorkloadProfile, ...]     # the four stage models
    locked: dict                              # stage name -> clock [MHz]
    report: PipelineReport                    # per-stage J at the locks
    total_profile: WorkloadProfile            # merged (service sweeps)
    t_acquire: float                          # s of sky per filterbank

    @property
    def realtime(self) -> RealTimeBudget:
        return RealTimeBudget(
            t_acquire=self.t_acquire,
            t_process=self.report.total_time / self.case.n_rows)

    @property
    def realtime_margin(self) -> float:
        return self.realtime.speedup


def plan_pulsar_stages(
    spec: FilterbankSpec,
    plan: DispersionPlan,
    bank: TemplateBank,
    n_harmonics: int,
    device: DeviceSpec,
    *,
    batch_bytes: float = 2e9,
    power_model: PowerModel | None = None,
    sweep_fn=dvfs.sweep,
) -> PulsarStagePlan:
    """Sweep each stage's clock grid and lock it at its energy optimum.

    The serving cache and the ``pipeline`` benchmark both build their
    per-stage receipts from this one function, so the receipts schema
    (docs/pipeline.md) has a single source of truth.  ``sweep_fn`` is
    injectable for the same reason ``PlanSweepCache``'s is.
    """
    power_model = power_model or PowerModel(device)
    case = PulsarCase(
        nchan=spec.nchan, ntime=spec.ntime, dm_trials=plan.n_trials,
        templates=bank.n_templates, taps=bank.taps,
        n_harmonics=n_harmonics, batch_bytes=batch_bytes)
    profiles = tuple(pulsar_search_workload(case, device))
    locked = {p.name: sweep_fn(p, device, power_model).optimal.f
              for p in profiles}
    sched = DVFSScheduler(device, power_model)
    report = sched.evaluate_pipeline(sched.plan(list(profiles), locked))
    return PulsarStagePlan(
        case=case, profiles=profiles, locked=locked, report=report,
        total_profile=pulsar_search_total_profile(case, device),
        t_acquire=spec.t_acquire)
