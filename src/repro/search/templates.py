"""Fourier-domain acceleration response templates (FDAS).

A binary pulsar's orbital acceleration makes its spin frequency drift
during an observation; in the Fourier domain the power that a plain FFT
would concentrate in one bin smears across ``z`` neighbouring bins, where
``z`` is the number of bins drifted over the observation.  The
correlation technique (Ransom, Eigenbrode & Middleditch 2002; the GPU
formulation is White, Adámek & Armour 2022) recovers it by
matched-filtering the complex spectrum with the known response of an
accelerated tone — one short filter per trial acceleration.

The response for drift ``z`` at bin offset ``u`` is the DFT of a
unit-amplitude linear chirp,

    c(τ) = exp(iπ z τ²),   τ ∈ [0, 1)
    t_z[u] = ∫ c(τ) · exp(-2πi u τ) dτ ,

evaluated here as an ``oversample``-point Riemann sum via one numpy FFT
(the classical Fresnel-integral closed form, without scipy).  Everything
is host-side numpy, memoised per (z, taps, oversample), and embedded as
constants at trace time — the same discipline as the twiddle and
Bluestein caches.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

#: Default sample count for the chirp DFT; the Riemann-sum error of the
#: response is O(z²/oversample), negligible for |z| << oversample.
DEFAULT_OVERSAMPLE = 4096


@functools.lru_cache(maxsize=None)
def acceleration_response(z: float, taps: int,
                          oversample: int = DEFAULT_OVERSAMPLE) -> np.ndarray:
    """Complex response t_z[u] on the centred window u ∈ [-taps//2, ...).

    A length-n time series whose tone starts at bin k0 and drifts z bins
    has spectrum X[k] ≈ A · t_z[k - k0] (A the tone amplitude times n),
    so correlating X against t_z concentrates the smeared power back into
    one bin.  The window must cover the drift: taps ≥ |z| plus sidelobe
    margin (see :meth:`TemplateBank.linear`).
    """
    if taps < 1:
        raise ValueError(f"template needs >= 1 taps, got {taps}")
    if taps > oversample:
        raise ValueError(
            f"taps={taps} exceeds the chirp resolution ({oversample})")
    tau = np.arange(oversample) / oversample
    chirp = np.exp(1j * np.pi * z * tau * tau)
    spectrum = np.fft.fft(chirp) / oversample
    u = np.arange(taps) - taps // 2                  # centred window
    return spectrum[u % oversample]


def matched_filter_taps(z: float, taps: int,
                        oversample: int = DEFAULT_OVERSAMPLE) -> np.ndarray:
    """Unit-energy convolution taps correlating a spectrum with t_z.

    The conjugate-reversed response window: with the FULL convolution
    ``conv`` of :func:`repro.fft.convolve.overlap_save_conv`,

        conv[b + taps - 1 - taps//2] = Σ_u X[b + u] · conj(t_z[u]) / ||t_z||

    over the whole centred window — consumers trim
    ``taps - 1 - taps//2`` leading points (``TemplateBank.offset``).
    """
    t = acceleration_response(z, taps, oversample)
    h = np.conj(t)[::-1]
    norm = np.sqrt(np.sum(np.abs(h) ** 2))
    return h / max(norm, 1e-30)


@dataclasses.dataclass(frozen=True)
class TemplateBank:
    """A bank of acceleration-trial matched filters.

    Hashable and frozen, so it can be a static jit argument; the heavy
    artefacts (time-domain taps, per-segment-length spectra) live in the
    module-level caches keyed on the bank's defining parameters, never on
    array contents.
    """

    drifts: tuple[float, ...]          # trial drifts z, in Fourier bins
    taps: int                          # filter length, bins
    oversample: int = DEFAULT_OVERSAMPLE

    @classmethod
    def linear(cls, zmax: float, n_templates: int | None = None,
               taps: int | None = None) -> "TemplateBank":
        """Evenly spaced trials over z ∈ [-zmax, zmax].

        Defaults follow the standard search grid: one template per bin of
        drift (2·zmax + 1 trials) and a window wide enough for the
        largest drift plus sidelobes.
        """
        if zmax < 0:
            raise ValueError(f"zmax must be >= 0, got {zmax}")
        if n_templates is None:
            n_templates = 2 * int(round(zmax)) + 1
        if n_templates < 1:
            raise ValueError(f"bank needs >= 1 templates, got {n_templates}")
        if n_templates == 1:
            drifts: tuple[float, ...] = (0.0,)
        else:
            drifts = tuple(float(z) for z in
                           np.linspace(-zmax, zmax, n_templates))
        if taps is None:
            taps = max(32, 2 * int(np.ceil(zmax)) + 16)
        return cls(drifts=drifts, taps=taps)

    @property
    def n_templates(self) -> int:
        return len(self.drifts)

    @property
    def offset(self) -> int:
        """Leading convolution points to trim (the centred-window shift)."""
        return self.taps - 1 - self.taps // 2

    @property
    def key(self) -> tuple:
        """Cache key identifying this bank's tap values."""
        return ("fdas-bank", self.drifts, self.taps, self.oversample)

    def time_domain(self) -> np.ndarray:
        """(T, taps) unit-energy matched-filter taps (host-side numpy)."""
        return np.stack([matched_filter_taps(z, self.taps, self.oversample)
                         for z in self.drifts])
