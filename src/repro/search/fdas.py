"""Fourier-Domain Acceleration Search on the overlap-save engine.

The binary-pulsar search workload of White, Adámek & Armour ("Cutting the
cost of pulsar astronomy", 2022), downstream of the paper's Sec. 5.3
pipeline: a dedispersed time series is FFT'd once (R2C), its complex
half-spectrum is matched-filtered by a bank of acceleration templates
(:mod:`repro.search.templates`), and candidates are read off the
resulting (template, bin) power plane.

Execution path — every heavy pass routes through the FFT substrate:

  series (batch, n) real
    │  R2C plan (fused Pallas kernel, half the C2C work)
  spectrum (batch, n/2+1) complex
    │  overlap-save segments; forward FFT carries the whole bank
    │  multiply as a fused kernel epilogue (fft_kernel_c2c_mul);
    │  one batched inverse pass over the T product planes
  matched-filter plane (batch, T, n/2+1) complex
    │  |·|² / σ² normalisation
  power plane  ──  threshold + top-k  ──>  candidates

``fdas_search`` is jittable end to end (the bank is a static argument);
the serving layer wraps it per (n, segment, templates) cache entry, and
``core.workloads.fdas_workload`` models its stages for the DVFS
scheduler — the FFT share of this pipeline is far higher than the
harmonic-sum demo's, which widens the paper's Table-4 composite saving.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.fft.convolve import conv_plan, overlap_save_conv
from repro.fft.plan import plan_for_length
from repro.search.templates import TemplateBank


class Candidates(NamedTuple):
    """Top candidates per series, threshold applied.

    ``template``/``bin`` are -1 (and power 0) past the last candidate
    exceeding the threshold, so the arrays are fixed-shape and jittable.
    """

    template: jax.Array        # (batch, k) int32 — index into bank.drifts
    bin: jax.Array             # (batch, k) int32 — Fourier bin
    power: jax.Array           # (batch, k) f32 — normalised matched power


class FDASResult(NamedTuple):
    """Everything one search produced (a pytree; safe through jit)."""

    power: jax.Array           # (batch, T, nbins) normalised power plane
    candidates: Candidates
    sigma2: jax.Array          # (batch, 1, 1) spectrum noise power


def matched_filter_plane(spectrum: jax.Array, bank: TemplateBank,
                         *, nfft: int | None = None) -> jax.Array:
    """Correlate complex spectra (..., nbins) with every bank template.

    Returns (..., T, nbins): element [t, b] is the spectrum correlated
    against the drift-``bank.drifts[t]`` response centred on bin ``b``.
    The full-convolution offset of the matched taps is trimmed here, so
    bin indices line up with the input spectrum's.
    """
    nbins = spectrum.shape[-1]
    conv = overlap_save_conv(spectrum, bank.time_domain(), nfft=nfft,
                             cache_key=bank.key)
    return conv[..., bank.offset:bank.offset + nbins]


def power_plane(mf: jax.Array, sigma2: jax.Array) -> jax.Array:
    """Normalised matched-filter power: |y|² over the noise power.

    With unit-energy templates and a white spectrum of per-bin power
    ``sigma2``, the plane is ~chi²(2)/2 distributed under the null, so a
    threshold of ~6-8 is a few-sigma cut.
    """
    p = mf.real ** 2 + mf.imag ** 2
    return p / jnp.maximum(sigma2, 1e-30)


def extract_candidates(power: jax.Array, *, threshold: float = 8.0,
                       max_candidates: int = 16) -> Candidates:
    """Threshold + top-k over the (..., T, nbins) plane.

    One pass of segment maxima feeding a single top-k — the reduction
    shape a Pallas epilogue could adopt wholesale; entries below the
    threshold are masked to (-1, -1, 0).
    """
    t, nbins = power.shape[-2:]
    flat = power.reshape(*power.shape[:-2], t * nbins)
    k = min(max_candidates, t * nbins)
    vals, idx = jax.lax.top_k(flat, k)
    keep = vals >= threshold
    return Candidates(
        template=jnp.where(keep, (idx // nbins).astype(jnp.int32), -1),
        bin=jnp.where(keep, (idx % nbins).astype(jnp.int32), -1),
        power=jnp.where(keep, vals, 0.0),
    )


@functools.partial(jax.jit, static_argnames=("bank", "nfft",
                                             "max_candidates"))
def fdas_search(x: jax.Array, bank: TemplateBank, *,
                threshold: float = 8.0, max_candidates: int = 16,
                nfft: int | None = None) -> FDASResult:
    """End-to-end acceleration search on dedispersed series (batch, n).

    Chains R2C plan -> template convolution (fused multiply epilogues)
    -> normalised power -> candidate extraction.  ``bank`` is static
    (hashable); ``nfft`` pins the overlap-save segment length (None =
    cost-model auto-selection), and both are part of the serving layer's
    cache key.
    """
    x = jnp.atleast_2d(jnp.asarray(x))
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    x = x.astype(jnp.float32)
    n = x.shape[-1]
    # Mean-subtract so the DC bin carries no baseline power.
    x = x - jnp.mean(x, axis=-1, keepdims=True)
    spectrum = plan_for_length(n, "r2c")(x)
    # Noise power per bin (the DC bin is zero after mean subtraction).
    sigma2 = jnp.mean(spectrum.real ** 2 + spectrum.imag ** 2,
                      axis=-1, keepdims=True)[..., None]
    mf = matched_filter_plane(spectrum, bank, nfft=nfft)
    power = power_plane(mf, sigma2)
    cands = extract_candidates(power, threshold=threshold,
                               max_candidates=max_candidates)
    return FDASResult(power=power, candidates=cands, sigma2=sigma2)


def fdas_conv_plan(n: int, bank: TemplateBank, nfft: int = 0):
    """The overlap-save plan a search over length-``n`` series executes.

    ``n`` is the time-series length; the convolution runs over the
    n//2+1-bin half-spectrum.  Exposed for the cost model, benchmarks and
    routing tests.
    """
    return conv_plan(n // 2 + 1, bank.taps, bank.n_templates, nfft)


def serving_candidates(result: FDASResult) -> jax.Array:
    """Candidates packed as one (batch, k, 3) f32 array for receipts.

    Columns: template index, bin, normalised power (-1/-1/0 padding) —
    a plain array so the serving layer's per-request result slicing
    works unchanged.
    """
    c = result.candidates
    return jnp.stack([c.template.astype(jnp.float32),
                      c.bin.astype(jnp.float32), c.power], axis=-1)
