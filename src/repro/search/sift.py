"""Candidate sifting/clustering — the pipeline's last stage.

The raw detection-statistic volume (dm, template, bin) fires a cloud of
cells around every real pulsar: neighbouring DM trials share most of the
signal, neighbouring bins catch spectral leakage, and the harmonic
ladder lights multiples of the spin frequency.  Sifting collapses each
cloud to its strongest cell:

  1. pool the top-``pool`` cells of the volume (one ``lax.top_k``),
  2. suppress any pooled cell that a *stronger* cell within ``dm_tol``
     DM trials dominates — either bin-adjacent (|Δbin| <= bin_tol) or
     harmonically related (bin_j ~ m * bin_i up to ``max_harmonic``),
  3. keep the top-``max_candidates`` survivors above ``threshold``.

Everything is fixed-shape (pool is static), so the whole stage jits and
fuses into the search graph; padding entries are (-1, -1, -1, -1, 0)
like :class:`repro.search.fdas.Candidates`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SiftedCandidates(NamedTuple):
    """Top candidates per filterbank, deduped; -1/0 past the last one."""

    dm: jax.Array              # (..., k) int32 — DM trial index
    template: jax.Array        # (..., k) int32 — index into bank.drifts
    bin: jax.Array             # (..., k) int32 — Fourier bin
    level: jax.Array           # (..., k) int32 — winning harmonic level
    snr: jax.Array             # (..., k) f32 — detection statistic


def sift_candidates(
    stat: jax.Array,
    level: jax.Array,
    *,
    threshold: float = 25.0,
    max_candidates: int = 16,
    pool: int = 64,
    dm_tol: int = 1,
    bin_tol: int = 1,
    max_harmonic: int = 8,
) -> SiftedCandidates:
    """Threshold + cluster + top-k over a (..., D, T, N) statistic volume.

    ``level`` is the matching (..., D, T, N) harmonic-level plane from
    :func:`repro.kernels.harmonic_sum.harmonic_sum_plane`.  The default
    ``threshold`` is sized for ~10^6-cell volumes: the per-cell null is
    ~N(0,1)-ish sub-exponential, so the expected null maximum sits near
    ln(cells) ~ 14 and 25 leaves a wide false-positive margin.
    """
    if stat.ndim < 3:
        raise ValueError(
            f"sift needs a (..., dm, template, bin) volume, got shape "
            f"{stat.shape}")
    if stat.shape != level.shape:
        raise ValueError(
            f"stat/level shapes differ: {stat.shape} vs {level.shape}")
    d, t, nb = stat.shape[-3:]
    lead = stat.shape[:-3]
    m = d * t * nb
    batch = 1
    for dim in lead:
        batch *= dim
    s = stat.reshape(batch, m)
    lv = level.reshape(batch, m)

    p = min(pool, m)
    vals, idx = jax.lax.top_k(s, p)                      # (batch, p)
    dmi = (idx // (t * nb)).astype(jnp.int32)
    ti = ((idx // nb) % t).astype(jnp.int32)
    bi = (idx % nb).astype(jnp.int32)
    lev = jnp.take_along_axis(lv, idx, axis=-1).astype(jnp.int32)
    above = vals >= threshold

    # Pairwise (batch, i, j): does pooled cell i dominate and absorb j?
    vi, vj = vals[:, :, None], vals[:, None, :]
    stronger = (vi > vj) | ((vi == vj) & (idx[:, :, None] < idx[:, None, :]))
    close_dm = jnp.abs(dmi[:, :, None] - dmi[:, None, :]) <= dm_tol
    ms = jnp.arange(1, max_harmonic + 1)                 # m=1 is adjacency
    bi_i = bi[:, :, None, None]
    bi_j = bi[:, None, :, None]
    related = ((jnp.abs(bi_j - ms * bi_i) <= ms * bin_tol)
               | (jnp.abs(bi_i - ms * bi_j) <= ms * bin_tol)).any(axis=-1)
    absorbed = (stronger & close_dm & related
                & above[:, :, None]).any(axis=-2)        # any i absorbs j
    keep = above & ~absorbed

    k = min(max_candidates, p)
    score = jnp.where(keep, vals, -jnp.inf)
    top, sel = jax.lax.top_k(score, k)                   # (batch, k)
    kept = top > -jnp.inf

    def _take(a, fill):
        return jnp.where(kept, jnp.take_along_axis(a, sel, axis=-1), fill)

    return SiftedCandidates(
        dm=_take(dmi, -1).reshape(*lead, k),
        template=_take(ti, -1).reshape(*lead, k),
        bin=_take(bi, -1).reshape(*lead, k),
        level=_take(lev, -1).reshape(*lead, k),
        snr=_take(vals, 0.0).reshape(*lead, k),
    )
