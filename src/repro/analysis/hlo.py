"""Mini HLO cost model with while-loop trip-count awareness.

``compiled.cost_analysis()`` on the CPU backend counts a ``while`` body
ONCE, so a 40-layer ``lax.scan`` under-reports FLOPs/bytes/collectives by
40x.  This module re-derives the three roofline inputs directly from the
compiled (SPMD-partitioned, per-device) HLO text:

  * FLOPs        — from ``dot`` ops: 2 * prod(output) * prod(contracted)
  * HBM bytes    — per-op traffic (operands + outputs) of fusions, dots,
                   copies, slices, reduces and collectives; tuple plumbing
                   (bitcast/get-tuple-element/tuple) is free, matching TPU
                   semantics where only fusion boundaries touch HBM
  * collectives  — output bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute

Each total is accumulated per computation; ``while`` call sites multiply
the body's totals by ``backend_config.known_trip_count`` (1 if unknown).
Fusion-called computations are NOT recursed (a fusion is one kernel).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# Ops that MATERIALISE on TPU (fusion boundaries): these are where HBM
# traffic actually happens.  Elementwise/reduce/broadcast/slice chains fuse
# into their neighbouring dots on TPU, so counting them (as the raw CPU
# HLO would suggest) overstates traffic ~50x; their tensors are already
# accounted as the producing/consuming dot's output/operand.
_TRAFFIC_OPS = {
    "dot", "convolution", "copy", "dynamic-update-slice", "gather",
    "scatter", "sort", "rng-bit-generator", "fusion",
} | set(COLLECTIVE_KINDS)

_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "partition-id", "replica-id"}


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",") if d] if dim_str else []


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape(type_str: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return m.group(1), _dims(m.group(2))


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = defaultdict(float)
        # (callee, multiplier) pairs from while ops
        self.calls: list[tuple[str, float]] = []


def analyze_hlo(hlo_text: str) -> dict:
    comps: dict[str, _Computation] = {}
    entry: str | None = None
    cur: _Computation | None = None
    symbols: dict[str, str] = {}

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        mstart = _COMP_START_RE.match(line)
        if mstart and line.endswith("{"):
            name = mstart.group(2)
            cur = _Computation(name)
            comps[name] = cur
            symbols = {}
            if mstart.group(1):
                entry = name
            continue
        if cur is None:
            continue
        if line.strip() == "}" or line.strip().startswith("} "):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        symbols[name] = type_str

        base_op = re.sub(r"-(start|done)$", "", op)
        if op.endswith("-done"):
            continue                      # counted at -start

        # operand names: within the first top-level paren group
        paren = line[line.index(op + "(") + len(op) + 1:]
        depth = 1
        arglist = []
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            arglist.append(ch)
        argstr = "".join(arglist)
        operands = re.findall(r"%([\w\.\-]+)", argstr)

        if base_op == "while":
            trip = 1.0
            mt = _TRIP_RE.search(line)
            if mt:
                trip = float(mt.group(1))
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            if mb:
                cur.calls.append((mb.group(1), trip))
            continue
        if base_op == "conditional":
            for mc in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w\.\-]+))",
                                 line):
                for grp in mc:
                    for nm in re.findall(r"%?([\w\.\-]+)", grp or ""):
                        if nm in ("",):
                            continue
                        cur.calls.append((nm, 1.0))
            continue
        if base_op == "call":
            mc = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if mc:
                cur.calls.append((mc.group(1), 1.0))
            continue

        if base_op in _FREE_OPS:
            continue

        out_bytes = _shape_bytes(type_str)
        opnd_bytes = sum(_shape_bytes(symbols.get(o, "")) for o in operands)

        if base_op == "dot":
            fs = _first_shape(type_str)
            mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if fs and mcd and operands:
                lhs_type = symbols.get(operands[0], "")
                lhs = _first_shape(lhs_type)
                if lhs:
                    contracted = 1
                    for d in _dims(mcd.group(1)):
                        if d < len(lhs[1]):
                            contracted *= lhs[1][d]
                    out_elems = 1
                    for d in fs[1]:
                        out_elems *= d
                    cur.flops += 2.0 * out_elems * contracted
            cur.bytes += out_bytes + opnd_bytes
            continue

        if base_op in COLLECTIVE_KINDS:
            cur.coll[base_op] += out_bytes
            cur.bytes += out_bytes + opnd_bytes
            continue

        if base_op in _TRAFFIC_OPS:
            cur.bytes += out_bytes + opnd_bytes

    # resolve call graph (memoised)
    memo: dict[str, tuple[float, float, dict]] = {}

    def total(name: str, seen=()) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        if name not in comps or name in seen:
            return 0.0, 0.0, {}
        c = comps[name]
        fl, by = c.flops, c.bytes
        co = dict(c.coll)
        for callee, mult in c.calls:
            cf, cb, cc = total(callee, seen + (name,))
            fl += mult * cf
            by += mult * cb
            for k, v in cc.items():
                co[k] = co.get(k, 0.0) + mult * v
        memo[name] = (fl, by, co)
        return memo[name]

    if entry is None:
        entry = next(iter(comps), None)
    fl, by, co = total(entry) if entry else (0.0, 0.0, {})
    co_total = float(sum(co.values()))
    return {
        "flops": fl,
        "bytes": by,
        "collectives": co,
        "collective_bytes": co_total,
    }


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Back-compat wrapper: {kind: bytes, 'total': bytes} with trip counts."""
    a = analyze_hlo(hlo_text)
    out = dict(a["collectives"])
    out["total"] = a["collective_bytes"]
    return out
